"""Baseline vs optimized sweep comparison: per-(arch × shape) modeled step
time (max of the three roofline terms) and the delta.

``--bench-regress`` switches to trajectory gating instead: the newest
record in each ``BENCH_*.json`` is compared row-by-row against the median
of the prior CLEAN (non-dirty) records' ``tok/s=`` figures, and the
process exits 1 if any row regressed by more than ``--threshold``
(default 10%).  Dirty records — appended from an uncommitted working
tree, flagged by ``benchmarks/run.py`` — never enter the baseline: their
git rev does not identify the code that produced the number.  The median
(not the best) of the clean history is the baseline so one lucky fast
run cannot ratchet the gate above what a loaded CI box can reach.

    python benchmarks/compare.py --bench-regress [BENCH_serving.json ...]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import re
import statistics
import sys
from typing import Dict, Tuple


def _load(run_dir: str, tag: str) -> Dict[Tuple[str, str, str], dict]:
    out = {}
    for p in glob.glob(os.path.join(run_dir, "*.json")):
        with open(p) as f:
            rec = json.load(f)
        if rec.get("tag", "") != tag or rec.get("status") != "ok":
            continue
        out[(rec["arch"], rec["shape"], rec["mesh"])] = rec
    return out


def max_term(rec) -> float:
    r = rec["roofline"]
    return max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"])


_TOKS_RE = re.compile(r"tok/s=([0-9][0-9.]*)")


def _row_toks(row) -> float | None:
    """Extract the throughput figure from a trajectory row's derived
    string, e.g. ``"tok/s=1183.2 ttft_ms=69.7"`` -> 1183.2."""
    m = _TOKS_RE.search(row.get("derived", ""))
    return float(m.group(1)) if m else None


def bench_regress(paths, threshold: float = 0.10) -> int:
    """Gate the newest trajectory record against the clean history.

    Returns the number of regressed rows (0 = pass).  Files with no
    usable baseline (missing, malformed, fewer than one prior clean
    record, or a dirty candidate in CI) are reported and skipped rather
    than failed: the gate protects committed history, it does not require
    one to exist yet."""
    failures = 0
    for path in paths:
        name = os.path.basename(path)
        try:
            with open(path) as f:
                runs = json.load(f)["runs"]
        except (OSError, ValueError, KeyError):
            print(f"{name}: no trajectory (skipped)")
            continue
        if len(runs) < 2:
            print(f"{name}: {len(runs)} record(s) — no baseline yet (skipped)")
            continue
        cand = runs[-1]
        # pre-dirty-flag records carry no key; they were appended by
        # benchmarks/run.py from clean CI checkouts, so absent == clean
        clean = [r for r in runs[:-1] if not r.get("dirty", False)]
        if not clean:
            print(f"{name}: no clean prior records (skipped)")
            continue
        base: Dict[str, list] = {}
        for rec in clean:
            for row in rec["rows"]:
                v = _row_toks(row)
                if v is not None:
                    base.setdefault(row["name"], []).append(v)
        checked = 0
        for row in cand["rows"]:
            v = _row_toks(row)
            if v is None or row["name"] not in base:
                continue
            checked += 1
            med = statistics.median(base[row["name"]])
            ratio = v / med if med > 0 else 1.0
            verdict = "REGRESSED" if ratio < 1 - threshold else "ok"
            print(
                f"{name}: {row['name']}: {v:.1f} tok/s vs median "
                f"{med:.1f} over {len(base[row['name']])} clean run(s) "
                f"({(ratio - 1) * 100:+.1f}%) {verdict}"
            )
            if verdict == "REGRESSED":
                failures += 1
        if not checked:
            print(f"{name}: no tok/s rows shared with the baseline (skipped)")
    return failures


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--bench-regress", action="store_true",
                    help="gate newest BENCH_*.json record vs clean history")
    ap.add_argument("--threshold", type=float, default=0.10,
                    help="max tolerated tok/s drop (fraction, default 0.10)")
    ap.add_argument("paths", nargs="*",
                    help="trajectory files (default: repo-root BENCH_*.json)")
    args = ap.parse_args()

    if args.bench_regress:
        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        paths = args.paths or [
            os.path.join(root, "BENCH_serving.json"),
            os.path.join(root, "BENCH_train.json"),
        ]
        failed = bench_regress(paths, args.threshold)
        if failed:
            print(f"bench-regress: {failed} row(s) regressed "
                  f">{args.threshold * 100:.0f}%")
            sys.exit(1)
        print("bench-regress: ok")
        return

    base = _load("experiments/dryrun", "")
    opt = _load("experiments/dryrun_opt", "opt")
    print("| arch | shape | mesh | baseline max-term (s) | optimized (s) | Δ |")
    print("|---|---|---|---|---|---|")
    total_b = total_o = 0.0
    for key in sorted(base):
        if key not in opt:
            continue
        b, o = max_term(base[key]), max_term(opt[key])
        total_b += b
        total_o += o
        print(
            f"| {key[0]} | {key[1]} | {key[2]} | {b:.3g} | {o:.3g} "
            f"| {'−' if o <= b else '+'}{abs(1 - o / b) * 100:.0f}% |"
        )
    print(
        f"| **sum** | | | **{total_b:.1f}** | **{total_o:.1f}** "
        f"| **−{(1 - total_o / total_b) * 100:.0f}%** |"
    )


if __name__ == "__main__":
    main()
