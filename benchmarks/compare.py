"""Baseline vs optimized sweep comparison: per-(arch × shape) modeled step
time (max of the three roofline terms) and the delta."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, Tuple


def _load(run_dir: str, tag: str) -> Dict[Tuple[str, str, str], dict]:
    out = {}
    for p in glob.glob(os.path.join(run_dir, "*.json")):
        with open(p) as f:
            rec = json.load(f)
        if rec.get("tag", "") != tag or rec.get("status") != "ok":
            continue
        out[(rec["arch"], rec["shape"], rec["mesh"])] = rec
    return out


def max_term(rec) -> float:
    r = rec["roofline"]
    return max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"])


def main() -> None:
    base = _load("experiments/dryrun", "")
    opt = _load("experiments/dryrun_opt", "opt")
    print("| arch | shape | mesh | baseline max-term (s) | optimized (s) | Δ |")
    print("|---|---|---|---|---|---|")
    total_b = total_o = 0.0
    for key in sorted(base):
        if key not in opt:
            continue
        b, o = max_term(base[key]), max_term(opt[key])
        total_b += b
        total_o += o
        print(
            f"| {key[0]} | {key[1]} | {key[2]} | {b:.3g} | {o:.3g} "
            f"| {'−' if o <= b else '+'}{abs(1 - o / b) * 100:.0f}% |"
        )
    print(
        f"| **sum** | | | **{total_b:.1f}** | **{total_o:.1f}** "
        f"| **−{(1 - total_o / total_b) * 100:.0f}%** |"
    )


if __name__ == "__main__":
    main()
