"""Paper table 3 analogue: bio data-pipeline throughput (BioNeMo reports
dataloader scaling as part of the training path)."""
from __future__ import annotations

import tempfile
import time


def run(report):
    from repro.data.dataset import build_synthetic_protein_memmap
    from repro.data.pipeline import CLMBatches, MLMBatches
    from repro.data.sampler import ClusterSampler, greedy_length_clusters

    with tempfile.TemporaryDirectory() as d:
        ds, tok = build_synthetic_protein_memmap(f"{d}/p", n=2000)
        lengths = [len(ds[i]) for i in range(len(ds))]
        sampler = ClusterSampler(greedy_length_clusters(lengths, 64))

        it = iter(MLMBatches(ds, tok, sampler, batch=32, seq_len=256))
        next(it)
        t0 = time.perf_counter()
        n = 20
        for _ in range(n):
            next(it)
        us = (time.perf_counter() - t0) / n * 1e6
        report("data/mlm_cluster_sampled_batch32x256", us,
               f"seqs_per_s={32 / (us / 1e6):.0f}")

        it = iter(CLMBatches(ds, batch=32, seq_len=256))
        next(it)
        t0 = time.perf_counter()
        for _ in range(n):
            next(it)
        us = (time.perf_counter() - t0) / n * 1e6
        report("data/clm_packed_batch32x256", us,
               f"tokens_per_s={32 * 256 / (us / 1e6):.0f}")

        # random access latency into the memmap store
        t0 = time.perf_counter()
        for i in range(0, 2000, 7):
            _ = ds[i]
        us = (time.perf_counter() - t0) / (2000 // 7) * 1e6
        report("data/memmap_random_access", us, "per-sequence")
