"""Paper table 3 analogue: bio data-plane throughput (BioNeMo reports
dataloader scaling and size-aware batching as part of the training path).

Rows (all appended to ``BENCH_train.json`` under the ``data/`` prefix):

  * host-pipeline throughput (cluster-sampled MLM, packed CLM) and
    memmap random-access latency — the original PR-0 rows
  * sharded-store random access — the multi-shard store must stay within
    the same order as the single-file memmap
  * ``padding_waste`` fixed-batch vs size-aware on the length-skewed
    synthetic protein corpus; the >=30% relative reduction is ASSERTED,
    not just reported (the whole point of token-budget batching)
  * sustained trainer tokens/s with the full data plane enabled
    (sharded store -> size-aware sampler -> background producer ->
    Trainer per-shape compile cache)
  * embedding throughput through the serving engine's ``LLM.embed``
    batched path

Derived strings use ``tokens_per_s=`` / ``seqs_per_s=`` — deliberately
NOT the ``tok/s=`` literal ``compare.py --bench-regress`` gates on: these
are data-plane rows on a noisy CPU container, not the guarded train-step
throughput trajectory.
"""
from __future__ import annotations

import tempfile
import time

import numpy as np


def _host_pipeline_rows(report, d: str) -> None:
    from repro.data.dataset import build_synthetic_protein_memmap
    from repro.data.pipeline import CLMBatches, MLMBatches
    from repro.data.sampler import ClusterSampler, greedy_length_clusters

    ds, tok = build_synthetic_protein_memmap(f"{d}/p", n=2000)
    lengths = ds.lengths()
    sampler = ClusterSampler(greedy_length_clusters(lengths, 64))

    it = iter(MLMBatches(ds, tok, sampler, batch=32, seq_len=256))
    next(it)
    t0 = time.perf_counter()
    n = 20
    for _ in range(n):
        next(it)
    us = (time.perf_counter() - t0) / n * 1e6
    report("data/mlm_cluster_sampled_batch32x256", us,
           f"seqs_per_s={32 / (us / 1e6):.0f}")

    it = iter(CLMBatches(ds, batch=32, seq_len=256, eos_id=tok.eos_id))
    next(it)
    t0 = time.perf_counter()
    for _ in range(n):
        next(it)
    us = (time.perf_counter() - t0) / n * 1e6
    report("data/clm_packed_batch32x256", us,
           f"tokens_per_s={32 * 256 / (us / 1e6):.0f}")

    # random access latency into the memmap store
    t0 = time.perf_counter()
    for i in range(0, 2000, 7):
        _ = ds[i]
    us = (time.perf_counter() - t0) / (2000 // 7) * 1e6
    report("data/memmap_random_access", us, "per-sequence")


def _sharded_store_row(report, d: str) -> None:
    from repro.data.dataset import build_synthetic_protein_store

    store, _ = build_synthetic_protein_store(
        f"{d}/store", n=2000, shard_tokens=1 << 15
    )
    t0 = time.perf_counter()
    for i in range(0, 2000, 7):
        _ = store[i]
    us = (time.perf_counter() - t0) / (2000 // 7) * 1e6
    report("data/sharded_store_random_access", us,
           f"per-sequence shards={store.num_shards}")


def _padding_waste_rows(report, d: str) -> None:
    """Padded-vs-real token waste, fixed batches vs size-aware batching
    over the SAME draw stream; asserts the >=30% relative reduction the
    acceptance criteria demand."""
    from repro.data.dataset import build_synthetic_protein_memmap
    from repro.data.sampler import ClusterSampler, greedy_length_clusters
    from repro.data.size_aware import SizeAwareSampler

    ds, _ = build_synthetic_protein_memmap(f"{d}/pw", n=2000)
    seq_len, batch = 256, 32
    budget = batch * seq_len
    lengths = np.minimum(ds.lengths(), seq_len)
    n_batches = 50

    def waste(sampled):  # [(lens_in_batch, padded_len)] -> waste fraction
        padded = sum(len(ls) * L for ls, L in sampled)
        real = sum(int(ls.sum()) for ls, _ in sampled)
        return (padded - real) / padded

    base = ClusterSampler(greedy_length_clusters(lengths, 64), seed=0)
    fixed = waste(
        [(lengths[base.sample(batch)], seq_len) for _ in range(n_batches)]
    )

    base = ClusterSampler(greedy_length_clusters(lengths, 64), seed=0)
    sas = SizeAwareSampler(lengths, budget, base=base)
    sized = []
    for _ in range(n_batches):
        idx, L = sas.sample_batch()
        sized.append((lengths[idx], L))
    sa = waste(sized)

    reduction = (fixed - sa) / fixed
    report("data/padding_waste_fixed_batch32x256", fixed * 1e6,
           f"waste_frac={fixed:.3f}")
    report("data/padding_waste_size_aware_8192tok", sa * 1e6,
           f"waste_frac={sa:.3f} reduction={reduction:.1%}")
    assert reduction >= 0.30, (
        f"size-aware batching reduced padding waste only {reduction:.1%} "
        f"(fixed {fixed:.3f} -> size-aware {sa:.3f}); >=30% required"
    )


def _trainer_row(report, d: str) -> None:
    """Sustained tokens/s with the full data plane enabled: sharded store
    -> size-aware sampler -> background producer -> Trainer."""
    from repro.core.config import ModelConfig, TrainConfig
    from repro.models.model import build_model
    from repro.launch.train import make_batches
    from repro.training.loop import Trainer

    cfg = ModelConfig(
        name="data-bench", family="dense", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, d_ff=256, vocab_size=64,
        dtype="float32", objective="mlm",
    )
    tc = TrainConfig(
        global_batch=8, seq_len=64, total_steps=12, log_every=4,
        warmup_steps=2, decay_steps=2, learning_rate=1e-3,
    )
    batches = make_batches(cfg, tc, f"{d}/tr", sharded=True,
                           max_tokens=512, producer_depth=2)
    try:
        tr = Trainer(build_model(cfg), tc, verbose=False)
        tr.run(batches)
    finally:
        batches.close()
    last = tr.history[-1]
    report("data/producer_sharded_train_step", last["step_time"] * 1e6,
           f"tokens_per_s={last['tokens_per_sec']:.0f} "
           f"shapes={len(tr._compiled)}")


def _embed_row(report) -> None:
    import jax

    from repro.core.config import ModelConfig
    from repro.models.model import build_model
    from repro.serving.api import LLM

    cfg = ModelConfig(
        name="embed-bench", family="dense", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, d_ff=256, vocab_size=64,
        dtype="float32",
    )
    model = build_model(cfg)
    llm = LLM(model, model.init(jax.random.PRNGKey(0)), slots=8,
              max_len=128)
    rng = np.random.default_rng(0)
    prompts = [
        rng.integers(5, 64, size=int(L)).tolist()
        for L in rng.integers(16, 120, size=64)
    ]
    llm.embed(prompts[:8])  # compile the buckets outside the timing
    t0 = time.perf_counter()
    out = llm.embed(prompts)
    dt = time.perf_counter() - t0
    toks = sum(len(p) for p in prompts)
    assert out.shape == (len(prompts), cfg.d_model)
    report("data/embed_llm_batched_64prompts", dt / len(prompts) * 1e6,
           f"seqs_per_s={len(prompts) / dt:.0f} "
           f"tokens_per_s={toks / dt:.0f}")


def run(report):
    with tempfile.TemporaryDirectory() as d:
        _host_pipeline_rows(report, d)
        _sharded_store_row(report, d)
        _padding_waste_rows(report, d)
        _trainer_row(report, d)
    _embed_row(report)


if __name__ == "__main__":
    rows = []
    print("name,us_per_call,derived")
    run(lambda n, us, d="": (rows.append(n), print(f"{n},{us:.1f},{d}")))
    assert rows
