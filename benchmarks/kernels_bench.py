"""Paper table 2 analogue: fused-kernel-semantics paths vs naive oracles
(per-op microbenchmarks, CPU).  The xla blockwise implementations carry the
kernels' O(block) memory behavior; interpret-mode Pallas timings are
included once for reference (they execute the kernel body in Python)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp


def _bench(fn, *args, iters=10, warmup=2) -> float:
    jfn = jax.jit(fn)
    for _ in range(warmup):
        jax.block_until_ready(jfn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(jfn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def run(report):
    from repro.kernels import ops, ref

    key = jax.random.PRNGKey(0)
    B, S, H, Hkv, D = 1, 1024, 8, 2, 64
    q = jax.random.normal(key, (B, S, H, D), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, Hkv, D), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, Hkv, D), jnp.float32)

    us = _bench(lambda q: ops.attention(q, k, v, impl="xla"), q)
    report("kernels/attention_blockwise_1k", us, "flash-semantics jnp path")
    us_n = _bench(lambda q: ops.attention(q, k, v, impl="naive"), q)
    report("kernels/attention_naive_1k", us_n, f"materializes SxS; ratio={us_n/us:.2f}")

    # fwd+bwd (the training step shape): grad wrt q, k, v
    def attn_grad(impl):
        return jax.grad(
            lambda q, k, v: ops.attention(q, k, v, impl=impl)
            .astype(jnp.float32).sum(),
            argnums=(0, 1, 2),
        )

    us_g = _bench(attn_grad("xla"), q, k, v)
    report("kernels/attention_fwd_bwd_blockwise_1k", us_g,
           f"train path; bwd/fwd={us_g/us:.2f}")
    us_gn = _bench(attn_grad("naive"), q, k, v)
    report("kernels/attention_fwd_bwd_naive_1k", us_gn,
           f"materializes SxS twice; ratio={us_gn/us_g:.2f}")

    T, Dh, Vp = 2048, 512, 32768
    h = jax.random.normal(key, (T, Dh), jnp.float32)
    W = jax.random.normal(jax.random.fold_in(key, 3), (Dh, Vp), jnp.float32) * 0.02
    tgt = jax.random.randint(jax.random.fold_in(key, 4), (T,), 0, Vp)
    us = _bench(lambda h: ops.cross_entropy(h, W, tgt, impl="xla")[0], h)
    report("kernels/cross_entropy_blockwise_32k_vocab", us, "logits never materialize")
    us_n = _bench(lambda h: ops.cross_entropy(h, W, tgt, impl="naive")[0], h)
    report("kernels/cross_entropy_naive_32k_vocab", us_n, f"ratio={us_n/us:.2f}")

    # fwd+bwd: grad wrt hidden AND the (D, V) projection — the train path
    def ce_grad(impl):
        return jax.grad(
            lambda h, W: ops.cross_entropy(h, W, tgt, impl=impl)[0].sum(),
            argnums=(0, 1),
        )

    us_g = _bench(ce_grad("xla"), h, W)
    report("kernels/cross_entropy_fwd_bwd_blockwise_32k_vocab", us_g,
           f"TxV grad never materializes; bwd/fwd={us_g/us:.2f}")
    us_gn = _bench(ce_grad("naive"), h, W)
    report("kernels/cross_entropy_fwd_bwd_naive_32k_vocab", us_gn,
           f"ratio={us_gn/us_g:.2f}")

    Bs, Ss, Hs, P, G, N = 1, 512, 8, 64, 1, 64
    x = jax.random.normal(key, (Bs, Ss, Hs, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 5), (Bs, Ss, Hs)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 6), (Hs,)))
    Bm = jax.random.normal(jax.random.fold_in(key, 7), (Bs, Ss, G, N))
    Cm = jax.random.normal(jax.random.fold_in(key, 8), (Bs, Ss, G, N))
    Dv = jax.random.normal(jax.random.fold_in(key, 9), (Hs,))
    us = _bench(lambda x: ops.ssd(x, dt, A, Bm, Cm, Dv, chunk=64, impl="xla")[0], x)
    report("kernels/ssd_chunked_512", us, "SSD dual form")
    us_n = _bench(lambda x: ops.ssd(x, dt, A, Bm, Cm, Dv, impl="naive")[0], x)
    report("kernels/ssd_sequential_512", us_n, f"ratio={us_n/us:.2f}")

    rows, d = 4096, 1024
    xr = jax.random.normal(key, (rows, d), jnp.float32)
    w = jnp.ones((d,))
    us = _bench(lambda x: ops.rmsnorm(x, w), xr)
    report("kernels/rmsnorm_4096x1024", us, "")

    # ragged grouped matmul (MoE expert FFN dispatch) vs the dense one-hot
    # formulation it replaces: dense computes every token against every
    # expert through a (T, E) mask einsum — O(T*E*K*N) FLOPs vs the
    # ragged path's O(T*K*N).  The gap must widen with E; we assert the
    # ragged path wins outright at E >= 8.
    def dense_one_hot(x, w_e, group_sizes):
        # x is sorted by expert; rebuild per-row expert ids and one-hot mix
        ends = jnp.cumsum(group_sizes)
        gid = jnp.searchsorted(ends, jnp.arange(x.shape[0]), side="right")
        one_hot = jax.nn.one_hot(gid, w_e.shape[0], dtype=x.dtype)  # (T, E)
        h = jnp.einsum("te,tk,ekn->tn", one_hot, x, w_e)
        return jnp.where((jnp.arange(x.shape[0]) < ends[-1])[:, None], h, 0)

    Tm, Km, Nm = 2048, 256, 512
    for E in (8, 16):
        xg = jax.random.normal(key, (Tm, Km), jnp.float32)
        we = jax.random.normal(jax.random.fold_in(key, E), (E, Km, Nm),
                               jnp.float32) * 0.02
        # uneven group sizes incl. an empty expert — the ragged win case.
        # max_group_size (the MoE capacity) enables the capacity-batched
        # xla fallback; the TPU pallas kernel needs no bound at all.
        sizes = jnp.full((E,), Tm // E, jnp.int32)
        sizes = sizes.at[0].add(sizes[1]).at[1].set(0)
        cap = 2 * Tm // E
        us_r = _bench(
            lambda x, w: ops.grouped_matmul(
                x, w, sizes, impl="xla", max_group_size=cap
            ),
            xg, we,
        )
        us_d = _bench(dense_one_hot, xg, we, sizes)
        report(f"kernels/moe/gmm_ragged_E{E}", us_r,
               f"megablocks-style; dense/ragged={us_d/us_r:.2f}")
        report(f"kernels/moe/gmm_dense_one_hot_E{E}", us_d,
               "O(T*E) mask einsum")
        assert us_r < us_d, (
            f"ragged grouped matmul slower than dense one-hot at E={E}: "
            f"{us_r:.1f}us vs {us_d:.1f}us"
        )
