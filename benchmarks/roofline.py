"""§Roofline report builder: reads experiments/dryrun/*.json and renders the
per-(arch × shape) table (single-pod mesh) with the three terms, dominant
bottleneck, MODEL_FLOPS/HLO_FLOPs ratio, and a what-would-move-it note.

Also derives the kernel-adjusted memory term: the dry-run lowers the
*XLA-fallback* attention (blockwise scan — score blocks round-trip HBM);
on TPU the Pallas flash kernel keeps them in VMEM, so we additionally
report t_memory with attention-score traffic replaced by ideal Q/K/V/O
traffic (the kernel's HBM footprint)."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

from repro.configs import get_config
from repro.launch.dryrun import HBM_BW, ICI_BW, PEAK_FLOPS
from repro.launch.shapes import SHAPES

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def attention_score_traffic(cfg, shape) -> float:
    """Per-device HBM bytes the XLA blockwise-attention path spends on
    (block_q × block_k) score intermediates, estimated as ~6 fp32
    round-trips of the full (S × S_window) score surface, fwd+bwd(2x),
    across layers; the Pallas kernel reduces this to ~0."""
    if cfg.family == "ssm":
        return 0.0
    S, B = shape.seq_len, shape.global_batch
    if shape.kind == "decode":
        return 0.0
    window = min(cfg.sliding_window or S, S)
    n_attn = sum(cfg.is_attn_layer(i) for i in range(cfg.num_layers))
    passes = 3.0 if shape.kind == "train" else 1.0  # fwd + ~2x recompute/bwd
    rounds = 6.0
    return B * S * window * cfg.num_heads * 4.0 * n_attn * passes * rounds / 256.0


def what_moves_it(rec: Dict) -> str:
    dom = rec["roofline"]["dominant"]
    shape = rec["shape"]
    if dom == "memory" and shape.endswith(("4k", "32k")):
        return "Pallas flash attention (keep score blocks in VMEM) + bf16 intermediates"
    if dom == "memory":
        return "KV-cache dtype (bf16→f8), larger per-chip batch to amortize weight reads"
    if dom == "collective":
        return "overlap collectives w/ compute; decode: batch growth amortizes all-gathers"
    return "MXU utilization: larger tiles / fewer recompute passes (remat policy)"


def load(run_dir: str, mesh: str = "single") -> List[Dict]:
    recs = []
    for p in sorted(glob.glob(os.path.join(run_dir, f"*_{mesh}*.json"))):
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def render_table(
    run_dir: str = "experiments/dryrun", mesh: str = "single", tag: str = ""
) -> str:
    rows = []
    hdr = (
        "| arch | shape | compute (s) | memory (s) | memory-kernel-adj (s) | "
        "collective (s) | dominant | useful/HLO flops | next lever |"
    )
    rows.append(hdr)
    rows.append("|" + "---|" * 9)
    for rec in load(run_dir, mesh):
        if rec.get("tag", "") != tag:
            continue
        if rec["status"] == "skipped":
            rows.append(
                f"| {rec['arch']} | {rec['shape']} | — | — | — | — | SKIP | — | "
                f"{rec['reason'][:60]}… |"
            )
            continue
        if rec["status"] != "ok":
            rows.append(f"| {rec['arch']} | {rec['shape']} | ERROR {rec.get('error','')[:40]} |")
            continue
        r = rec["roofline"]
        cfg = get_config(rec["arch"])
        shape = SHAPES[rec["shape"]]
        hbm = rec["cost"]["hbm_bytes_per_device"]
        f32_large = rec["cost"].get("hbm_bytes_f32_large")
        if f32_large is not None:
            # XLA-CPU computes bf16 dots/fusions in fp32; those buffers are
            # bf16 on the MXU -> halve their traffic for the TPU estimate.
            adj_bytes = hbm - 0.5 * f32_large
        else:  # older records: analytic attention-score estimate
            adj_bytes = max(
                hbm - attention_score_traffic(cfg, shape), hbm * 0.05
            )
        t_adj = adj_bytes / HBM_BW
        variant = f" ({rec['variant']})" if rec.get("variant") else ""
        rows.append(
            f"| {rec['arch']}{variant} | {rec['shape']} "
            f"| {r['t_compute_s']:.3g} | {r['t_memory_s']:.3g} | {t_adj:.3g} "
            f"| {r['t_collective_s']:.3g} | **{r['dominant']}** "
            f"| {min(r['useful_flop_ratio'], 99):.2f} | {what_moves_it(rec)} |"
        )
    return "\n".join(rows)


def main() -> None:
    import argparse

    p = argparse.ArgumentParser()
    p.add_argument("--dir", default="experiments/dryrun")
    p.add_argument("--mesh", default="single")
    p.add_argument("--tag", default="")
    a = p.parse_args()
    print(render_table(a.dir, a.mesh, a.tag))


if __name__ == "__main__":
    main()
