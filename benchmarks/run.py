"""Benchmark harness — one module per paper table/figure.

  throughput     — optimized framework path vs vanilla baseline (paper's
                   headline comparison)
  kernels_bench  — fused-kernel-semantics ops vs naive oracles
  data_bench     — bio data-pipeline throughput (cluster sampling, packing)
  serving_bench  — continuous-batching engine dense vs paged KV cache
                   (tokens/s, TTFT, ITL; asserts layout output parity and
                   the O(page) decode-write advantage)
  train_bench    — distributed-Trainer smoke (tokens/s, step time, accum
                   on/off; asserts one bulk host transfer per log interval
                   under jax.transfer_guard)
  scaling        — projected v5e throughput per arch from the dry-run
                   roofline (requires experiments/dryrun; skipped if absent)

Prints ``name,us_per_call,derived`` CSV.
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    rows = []

    def report(name: str, us: float, derived: str = "") -> None:
        rows.append((name, us, derived))
        print(f"{name},{us:.1f},{derived}", flush=True)

    from benchmarks import (
        data_bench, kernels_bench, scaling, serving_bench, throughput,
        train_bench,
    )

    print("name,us_per_call,derived")
    failures = 0
    for mod in (throughput, kernels_bench, data_bench, serving_bench,
                train_bench, scaling):
        try:
            mod.run(report)
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"# {mod.__name__} FAILED", file=sys.stderr)
            traceback.print_exc()
    if not rows or failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
