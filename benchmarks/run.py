"""Benchmark harness — one module per paper table/figure.

  throughput     — optimized framework path vs vanilla baseline (paper's
                   headline comparison)
  kernels_bench  — fused-kernel-semantics ops vs naive oracles
  data_bench     — bio data-pipeline throughput (cluster sampling, packing)
  serving_bench  — continuous-batching engine dense vs paged KV cache
                   (tokens/s, TTFT, ITL; asserts layout output parity, the
                   O(page) decode-write advantage, and the degraded-mode
                   overload/chaos contract)
  train_bench    — distributed-Trainer smoke (tokens/s, step time, accum
                   on/off; asserts one bulk host transfer per log interval
                   under jax.transfer_guard)
  scaling        — projected v5e throughput per arch from the dry-run
                   roofline (requires experiments/dryrun; skipped if absent)

Prints ``name,us_per_call,derived`` CSV.

Trajectory files: after a clean run, the serving rows (``serving/...``)
and train rows (``train_step...``) are APPENDED as one timestamped record
each to ``BENCH_serving.json`` / ``BENCH_train.json`` at the repo root, so
perf over time survives re-anchors and is diffable in review.  Records
carry the short git rev; the write is tmp-file + ``os.replace`` atomic
(same discipline as ``checkpoint/ckpt.py``).  ``--modules`` runs a subset
(e.g. ``--modules serving_bench,train_bench`` refreshes both trajectories
without the full suite); ``--no-json`` skips the append for scratch runs.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time
import traceback

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# trajectory file -> predicate over row names.  train_bench rows are named
# ``train_step_accum{N}`` (no prefix); everything serving-side is
# ``serving/...``.
_TRAJECTORIES = {
    "BENCH_serving.json": lambda name: name.startswith("serving/"),
    "BENCH_train.json": lambda name: (
        name.startswith("train_step") or name.startswith("data/")
    ),
    # kernel microbenchmarks that gate a perf claim (ragged MoE dispatch
    # vs dense one-hot) — tracked so the ratio is diffable over time
    "BENCH_kernels.json": lambda name: name.startswith("kernels/moe/"),
}


def _git_rev() -> str:
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            cwd=_REPO_ROOT, capture_output=True, text=True, timeout=10,
        )
        return out.stdout.strip() or "unknown"
    except Exception:  # noqa: BLE001 — bench must not die on a bare checkout
        return "unknown"


def _git_dirty() -> bool:
    """True when the working tree differs from HEAD.  Recorded per
    trajectory record so regression gating can skip numbers measured on
    uncommitted code (a dirty row's rev does not identify what ran)."""
    try:
        out = subprocess.run(
            ["git", "status", "--porcelain"],
            cwd=_REPO_ROOT, capture_output=True, text=True, timeout=10,
        )
        return out.returncode == 0 and bool(out.stdout.strip())
    except Exception:  # noqa: BLE001
        return False


def append_trajectories(rows, out_dir: str = _REPO_ROOT) -> None:
    """Append one record per trajectory file for this run's rows."""
    stamp = time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime())
    rev = _git_rev()
    dirty = _git_dirty()
    for fname, match in _TRAJECTORIES.items():
        sel = [
            {"name": n, "us": round(us, 1), "derived": d}
            for n, us, d in rows if match(n)
        ]
        if not sel:
            continue  # subset run: don't append empty records
        path = os.path.join(out_dir, fname)
        try:
            with open(path) as f:
                runs = json.load(f)["runs"]
        except (OSError, ValueError, KeyError):
            runs = []
        runs.append(
            {"timestamp": stamp, "git": rev, "dirty": dirty, "rows": sel}
        )
        tmp = f"{path}.tmp.{os.getpid()}"
        with open(tmp, "w") as f:
            json.dump({"runs": runs}, f, indent=1)
            f.write("\n")
        os.replace(tmp, path)
        print(f"# appended {len(sel)} rows to {fname} ({len(runs)} runs)",
              file=sys.stderr)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--modules", default="",
        help="comma-separated subset of bench modules to run "
             "(default: all)",
    )
    ap.add_argument(
        "--no-json", action="store_true",
        help="skip the BENCH_*.json trajectory append",
    )
    args = ap.parse_args()

    rows = []

    def report(name: str, us: float, derived: str = "") -> None:
        rows.append((name, us, derived))
        print(f"{name},{us:.1f},{derived}", flush=True)

    from benchmarks import (
        data_bench, kernels_bench, scaling, serving_bench, throughput,
        train_bench,
    )

    mods = [throughput, kernels_bench, data_bench, serving_bench,
            train_bench, scaling]
    if args.modules:
        want = {m.strip() for m in args.modules.split(",") if m.strip()}
        known = {m.__name__.rsplit(".", 1)[-1] for m in mods}
        unknown = want - known
        if unknown:
            ap.error(f"unknown modules: {sorted(unknown)} "
                     f"(choose from {sorted(known)})")
        mods = [m for m in mods if m.__name__.rsplit(".", 1)[-1] in want]

    print("name,us_per_call,derived")
    failures = 0
    for mod in mods:
        try:
            mod.run(report)
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"# {mod.__name__} FAILED", file=sys.stderr)
            traceback.print_exc()
    if not rows or failures:
        sys.exit(1)
    # only clean runs enter the trajectory — a failed module would record
    # a partial row set that reads as a perf cliff
    if not args.no_json:
        append_trajectories(rows)


if __name__ == "__main__":
    main()
