"""Paper figure analogue: projected training throughput scaling (tokens/s
per chip and aggregate) for the assigned archs, derived from the dry-run
roofline terms (max of the three terms = modeled step time on v5e)."""
from __future__ import annotations

import glob
import json
import os


def run(report):
    from repro.configs import get_config
    from repro.launch.shapes import SHAPES

    for path in sorted(glob.glob("experiments/dryrun/*_train_4k_*.json")):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("status") != "ok" or rec.get("tag"):
            continue
        r = rec["roofline"]
        step_s = max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"])
        shape = SHAPES["train_4k"]
        chips = rec["n_chips"]
        toks = shape.seq_len * shape.global_batch
        report(
            f"scaling/{rec['arch']}_{rec['mesh']}",
            step_s * 1e6,
            f"modeled_tokens_per_s={toks / step_s:.0f} chips={chips} "
            f"dom={r['dominant']}",
        )
