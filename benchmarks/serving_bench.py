"""Serving-path benchmark: dense-slot vs paged KV-cache engine, prefix
caching + chunked prefill vs the cold paged baseline, and sampled decode
(Generation API v2 fused on-device sampler) vs greedy.

Four measurements:

  * engine comparison — the continuous-batching engine end-to-end on a
    smoke model under both cache layouts, reporting tokens/s,
    time-to-first-token and inter-token latency.  Token-for-token output
    parity between the layouts is ASSERTED (the subsystem's acceptance
    criterion), not just reported.  Every engine runs the workload once
    as a WARMUP before the measured pass, so TTFT no longer includes the
    first-call jit compile; compile time is reported separately
    (``*_compile`` rows = first pass minus steady-state wall).
  * shared-prefix workload — requests carrying a long common task
    preamble (the protein/chemistry serving pattern), served by the
    paged baseline vs the prefix-cached + chunked-prefill engine.
    Token parity is asserted, and the prefix-cached TTFT must be at
    least 2x better: hash-hit blocks skip prefill entirely, so only the
    unique tail is computed.
  * sampled-decode workload — the same engine/prompts with per-request
    SamplingParams (temperature/top-k/top-p, fixed seeds).  Token
    selection runs fused inside the jitted decode step, so sampled
    throughput is ASSERTED within 10% of greedy; the identical-pass
    output check doubles as a sampled-determinism assertion.
  * decode cache-write microbenchmark at a long-cache config — the dense
    layout's O(B·T) one-hot masked select vs the paged O(B·page)
    scatter (``ops.paged_kv_update``).  The paged write must win; this
    asserts the per-token write really is page-local, independent of the
    cache length.
  * degraded-mode workload — a 3x-oversubscribed arrival pattern served
    by an UNBOUNDED queue vs a bounded one (``max_queue``): the bounded
    engine must reject some arrivals AND cut the p99 TTFT of the
    accepted ones (rejections instead of unbounded queueing — the
    fault-tolerance contract), with token parity on every accepted
    request asserted against the unbounded run.  A seeded ``FaultPlan``
    chaos pass (NaN injection + allocator outage) then must drain with
    survivors token-identical to the fault-free engine.

  * sharded-serving scaling workload — the SAME paged workload served
    tensor-parallel on (1, N) meshes for N in 1/4/8 virtual CPU devices
    (``xla_force_host_platform_device_count``, one subprocess per N —
    the device-count flag must be set before jax initializes, mirroring
    the PR 5 ``train-distributed`` harness).  Per-token output parity of
    every mesh run against the single-device run is ASSERTED — the
    tentpole guarantee that sharding the K/V storage changes where bytes
    live, never what tokens come out.  tok/s per mesh size is reported;
    on virtual devices all shards share the same cores, so the numbers
    prove the mechanism (the sharded engine pays no per-step reshard or
    extra host sync), not a speedup — on real accelerators the model
    axis is what fits 35B+ configs at all.

CPU numbers prove the mechanism (data volume per token write, prompt
rows not recomputed); on TPU the same ratios show up as HBM traffic per
decode step and MXU time per admitted prompt.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap
import time

import jax
import jax.numpy as jnp
import numpy as np

_SCALING_CODE = textwrap.dedent("""
    import json, time
    import jax, numpy as np
    from repro.configs import get_smoke_config
    from repro.core.config import ParallelConfig
    from repro.models.model import build_model
    from repro.serving.engine import Engine, Request

    mesh_shape = __MESH_SHAPE__
    mesh = (jax.make_mesh(mesh_shape, ("data", "model"))
            if mesh_shape is not None else None)
    cfg = get_smoke_config("qwen2-7b")
    model = build_model(cfg, ParallelConfig(), mesh)
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [
        rng.integers(5, cfg.vocab_size, size=int(rng.integers(4, 32)))
        .astype(np.int32)
        for _ in range(8)
    ]

    def serve_pass():
        eng = Engine(model, params, slots=4, max_len=64,
                     cache_layout="paged", page_size=16)
        for i, p in enumerate(prompts):
            eng.submit(Request(uid=i, prompt=p, max_new=16))
        t0 = time.time()
        eng.run()
        wall = time.time() - t0
        outs = {r.uid: list(r.output) for r in eng.done}
        return outs, sum(len(o) for o in outs.values()) / wall

    serve_pass()                      # warm the jit caches
    best = 0.0
    for _ in range(3):
        outs, tps = serve_pass()
        best = max(best, tps)
    print("RESULT " + json.dumps({"outs": outs, "tok_s": best}))
""")


def _scaling_run(n_dev: int, mesh_shape=None):
    """Serve the scaling workload on `n_dev` virtual devices (subprocess:
    the XLA device-count flag must be set before jax initializes).

    ``mesh_shape`` is the (data, model) mesh; the model axis must divide
    the smoke config's 4 attention heads, so 8 devices run as (2, 4)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run(
        [sys.executable, "-c",
         _SCALING_CODE.replace("__MESH_SHAPE__", repr(mesh_shape))],
        capture_output=True, text=True, env=env, timeout=900,
    )
    assert out.returncode == 0, (
        f"scaling run on {n_dev} devices failed:\n{out.stderr[-4000:]}"
    )
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


def _run_pass(eng, prompts, max_new, make_params=None):
    """Submit `prompts` to `eng` and run this batch to completion.

    ``make_params(i)`` supplies a per-request ``SamplingParams`` (the
    sampled-decode workload); ``None`` keeps legacy greedy requests."""
    from repro.serving.engine import Request

    n_before = len(eng.done)
    t0 = time.time()
    for i, p in enumerate(prompts):
        sp = make_params(i) if make_params is not None else None
        eng.submit(Request(uid=i, prompt=p, max_new=max_new, params=sp))
    eng.run()
    wall = time.time() - t0
    done = eng.done[n_before:]
    toks = sum(len(r.output) for r in done)
    # median, not mean: a single OS-noise hiccup on a CI box shouldn't
    # dominate an 8-request latency figure
    ttft = float(np.median([r.t_first - r.t_submit for r in done])) * 1e3
    itl = float(np.mean([
        (r.t_done - r.t_first) / max(len(r.output) - 1, 1) for r in done
    ])) * 1e3
    outs = {r.uid: r.output for r in done}
    return outs, toks / wall, ttft, itl, wall


def _serve(model, params, prompts, layout, max_new, slots=4, max_len=128,
           **kw):
    """Warmup pass + measured pass on ONE engine.

    The warmup runs the identical workload first, so the measured TTFT
    excludes the first-call jit compile (and, for the prefix-cached
    engine, reflects a warm hash index — the steady-serving state).  A
    single-request primer pass precedes the warmup batch: it seeds the
    hash index, so the warmup batch itself takes the hash-hit admission
    path and compiles the short-suffix chunk shapes the measured pass
    will use.  Returns measured stats plus the warmup overhead
    (warmup wall minus steady wall, dominated by jit compile)."""
    from repro.serving.engine import Engine

    eng = Engine(
        model, params, slots=slots, max_len=max_len, cache_layout=layout,
        page_size=16, **kw,
    )
    # primer: seeds the hash index so the warmup batch already takes the
    # hash-hit admission path
    *_, primer_wall = _run_pass(eng, prompts[:1], max_new)
    *_, warm_wall = _run_pass(eng, prompts, max_new)
    # best-of-2 measured passes: steady-state latency, not OS jitter
    outs, tps, ttft, itl, wall = _run_pass(eng, prompts, max_new)
    outs2, tps2, ttft2, itl2, wall2 = _run_pass(eng, prompts, max_new)
    assert outs2 == outs, "engine output changed between identical passes"
    if ttft2 < ttft:
        tps, ttft, itl, wall = tps2, ttft2, itl2, wall2
    # compile overhead = cold passes minus their steady-state equivalents
    # (the primer serves 1 of len(prompts) requests)
    steady_cold = wall * (1 + 1 / max(len(prompts), 1))
    compile_s = max(primer_wall + warm_wall - steady_cold, 0.0)
    return outs, tps, ttft, itl, wall, compile_s


def run(report):
    from repro.configs import get_smoke_config
    from repro.kernels import ops
    from repro.models.model import build_model

    # ---------------------------------------------------- engine A/B
    cfg = get_smoke_config("qwen2-7b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [
        rng.integers(5, cfg.vocab_size, size=int(rng.integers(4, 48)))
        .astype(np.int32)
        for _ in range(12)
    ]
    stats = {}
    for layout in ("dense", "paged"):
        outs, tps, ttft, itl, wall, compile_s = _serve(
            model, params, prompts, layout, 16
        )
        stats[layout] = outs
        report(
            f"serving/engine_{layout}", wall * 1e6,
            f"tok/s={tps:.1f} ttft_ms={ttft:.1f} itl_ms={itl:.2f}",
        )
        report(
            f"serving/engine_{layout}_compile", compile_s * 1e6,
            "first-pass jit compile overhead (excluded from ttft)",
        )
    assert stats["paged"] == stats["dense"], \
        "paged engine diverged from dense-slot engine (greedy parity)"

    # ------------------------------------- sampled-decode workload
    # Generation API v2: per-request temperature/top-k/top-p through the
    # fused on-device sampler.  Selection runs inside the same jitted
    # decode step as greedy (the filter is a few VMEM sweeps over the
    # (B, V) logit panel vs the model's matmuls), so sampled throughput
    # must stay within 10% of greedy on the identical workload.  Greedy
    # and sampled passes run INTERLEAVED on one engine (same compiled
    # step, best-of-3 each) so machine drift between phases cannot fake
    # a regression; fixed per-request seeds make the sampled passes
    # deterministic, asserted across repeats.
    from repro.serving.engine import Engine
    from repro.serving.sampling import SamplingParams

    def mk(i):
        return SamplingParams(temperature=0.8, top_k=40, top_p=0.9,
                              seed=1000 + i, max_new=16)

    eng = Engine(model, params, slots=4, max_len=128, cache_layout="paged",
                 page_size=16)
    _run_pass(eng, prompts, 16)             # warm greedy shapes
    _run_pass(eng, prompts, 16, mk)         # warm sampled shapes
    # best-of-3 per variant, interleaved: a single noisy pass on a loaded
    # CI box must not be able to fake a >10% regression
    gs, ss = [], []
    for _ in range(3):
        gs.append(_run_pass(eng, prompts, 16))
        ss.append(_run_pass(eng, prompts, 16, mk))
    assert all(s[0] == ss[0][0] for s in ss), \
        "fixed-seed sampled pass not deterministic"
    assert gs[0][0] == stats["paged"], "greedy output drifted between engines"
    tps_g = max(g[1] for g in gs)
    tps_s = max(s[1] for s in ss)
    ratio = tps_s / max(tps_g, 1e-9)
    report(
        "serving/engine_paged_sampled", min(s[4] for s in ss) * 1e6,
        f"tok/s={tps_s:.1f} itl_ms={min(s[3] for s in ss):.2f} "
        f"vs_greedy={ratio:.2f}x (interleaved best-of-3)",
    )
    assert tps_s >= 0.9 * tps_g, (
        f"sampled decode must stay within 10% of greedy tok/s "
        f"(greedy {tps_g:.1f}, sampled {tps_s:.1f})"
    )

    # ------------------------------------- telemetry overhead A/B
    # Unified telemetry (repro.obs) is host-side appends on paths the
    # engine already walks, so turning the registry + lifecycle tracer ON
    # must cost nothing the clock can see: interleaved best-of-5 greedy
    # passes on two warmed engines (pass-to-pass OS noise on a CI box is
    # ~8%, so the best-of envelope needs more samples than the 10%-band
    # sampled assertion above), token parity asserted, ON tok/s within 2%
    # of OFF.  The instrumented engine's histograms then supply
    # the TTFT/ITL latency distribution rows (p50/p95/p99) — quantiles a
    # single pass's median/mean summary cannot express.
    from repro.obs import MetricsRegistry, TraceRecorder

    reg = MetricsRegistry()
    tracer = TraceRecorder(capacity=16384)
    eng_off = Engine(model, params, slots=4, max_len=128,
                     cache_layout="paged", page_size=16)
    eng_on = Engine(model, params, slots=4, max_len=128,
                    cache_layout="paged", page_size=16,
                    metrics=reg, trace=tracer)
    _run_pass(eng_off, prompts, 16)         # warm (jit caches are shared,
    _run_pass(eng_on, prompts, 16)          # but warm both for symmetry)
    offs, ons = [], []
    for _ in range(5):
        offs.append(_run_pass(eng_off, prompts, 16))
        ons.append(_run_pass(eng_on, prompts, 16))
    assert ons[0][0] == offs[0][0] == stats["paged"], \
        "telemetry changed generated tokens"
    tps_off = max(o[1] for o in offs)
    tps_on = max(o[1] for o in ons)
    report(
        "serving/telemetry_off", min(o[4] for o in offs) * 1e6,
        f"tok/s={tps_off:.1f} (registry+tracer disabled, best-of-5)",
    )
    report(
        "serving/telemetry_on", min(o[4] for o in ons) * 1e6,
        f"tok/s={tps_on:.1f} overhead={(tps_off / max(tps_on, 1e-9) - 1) * 100:+.1f}% "
        f"trace_events={tracer.emitted}",
    )
    assert tps_on >= 0.98 * tps_off, (
        f"instrumentation must cost <2% tok/s "
        f"(off {tps_off:.1f}, on {tps_on:.1f})"
    )
    # registry counters must agree with the engine's own health view
    h = eng_on.health()
    fam = reg.get("engine_requests_total")
    for k, v in h.counters.items():
        assert fam.labels(k).value == v, f"registry/health drift on {k!r}"
    # latency distribution rows from the instrumented engine's histograms
    # (warmup + 5 measured passes x 12 requests): these land in
    # BENCH_serving.json, so TTFT/ITL tail regressions become visible in
    # the trajectory, not just the medians
    h_ttft = reg.get("engine_ttft_seconds")
    h_itl = reg.get("engine_itl_seconds")
    report(
        "serving/ttft_quantiles", h_ttft.quantile(0.5) * 1e6,
        f"p50={h_ttft.quantile(0.5) * 1e3:.1f}ms "
        f"p95={h_ttft.quantile(0.95) * 1e3:.1f}ms "
        f"p99={h_ttft.quantile(0.99) * 1e3:.1f}ms n={h_ttft.count}",
    )
    report(
        "serving/itl_quantiles", h_itl.quantile(0.5) * 1e6,
        f"p50={h_itl.quantile(0.5) * 1e3:.2f}ms "
        f"p95={h_itl.quantile(0.95) * 1e3:.2f}ms "
        f"p99={h_itl.quantile(0.99) * 1e3:.2f}ms n={h_itl.count}",
    )

    # ------------------------------------- shared-prefix workload
    # every request carries the same 480-token task preamble + a unique
    # short tail (the fixed-scaffold protein/chemistry pattern): the
    # prefix cache prefills the preamble once and shares its pages; the
    # baseline recomputes all 488 rows for every request.
    preamble = rng.integers(5, cfg.vocab_size, size=480).astype(np.int32)
    shared_prompts = [
        np.concatenate(
            [preamble, rng.integers(5, cfg.vocab_size, size=8).astype(np.int32)]
        )
        for _ in range(8)
    ]
    # enough slots to admit the whole batch at once: TTFT is then purely
    # prefill-side (admission order), not shared decode-completion waits
    base_out, _, ttft_base, _, _, _ = _serve(
        model, params, shared_prompts, "paged", 8, slots=8, max_len=512
    )
    pfx_out, _, ttft_pfx, _, _, _ = _serve(
        model, params, shared_prompts, "paged", 8, slots=8, max_len=512,
        prefix_cache=True, prefill_chunk=32,
    )
    assert pfx_out == base_out, \
        "prefix caching changed tokens on the shared-prefix workload"
    speedup = ttft_base / max(ttft_pfx, 1e-9)
    report("serving/shared_prefix_ttft_base", ttft_base * 1e3,
           "paged baseline: full 488-token prefill per request")
    report("serving/shared_prefix_ttft_cached", ttft_pfx * 1e3,
           f"prefix cache + chunked prefill; ttft_speedup={speedup:.1f}x")
    assert speedup >= 2.0, (
        f"prefix caching must cut shared-prefix TTFT >=2x "
        f"(got {speedup:.2f}x: {ttft_base:.1f}ms -> {ttft_pfx:.1f}ms)"
    )

    # ------------------------------------- long-cache decode write A/B
    B, T, Hkv, D, page = 8, 4096, 4, 64, 16
    key = jax.random.PRNGKey(1)
    k_cache = jax.random.normal(key, (B, T, Hkv, D), jnp.float32)
    v_cache = jax.random.normal(jax.random.fold_in(key, 1), k_cache.shape,
                                jnp.float32)
    k_new = jax.random.normal(jax.random.fold_in(key, 2), (B, 1, Hkv, D),
                              jnp.float32)
    v_new = jax.random.normal(jax.random.fold_in(key, 3), k_new.shape,
                              jnp.float32)
    widx = jnp.asarray(rng.integers(0, T, size=B), jnp.int32)

    def dense_write(kc, vc, kn, vn, w):
        # the O(B·T) masked select models/attention.py uses per decode
        # token in the dense per-slot layout
        onehot = (jnp.arange(T)[None, :] == w[:, None])[..., None, None]
        return jnp.where(onehot, kn, kc), jnp.where(onehot, vn, vc)

    num_pages = 1 + B * (T // page)
    k_pool = jax.random.normal(key, (num_pages, page, Hkv, D), jnp.float32)
    v_pool = jax.random.normal(jax.random.fold_in(key, 4), k_pool.shape,
                               jnp.float32)
    page_idx = jnp.asarray(1 + rng.integers(0, num_pages - 1, size=B),
                           jnp.int32)
    row = jnp.asarray(rng.integers(0, page, size=B), jnp.int32)

    def _bench_state(fn, state, *args, iters=10, warmup=2) -> float:
        # donate the cache buffers (the serving decode loop's steady state)
        # so XLA may update in place — without donation both layouts pay a
        # full-pool copy that hides the write cost difference
        jfn = jax.jit(fn, donate_argnums=(0, 1))
        for _ in range(warmup):
            state = jfn(*state, *args)
            jax.block_until_ready(state)
        t0 = time.perf_counter()
        for _ in range(iters):
            state = jfn(*state, *args)
            jax.block_until_ready(state)
        return (time.perf_counter() - t0) / iters * 1e6

    us_dense = _bench_state(
        dense_write, (k_cache, v_cache), k_new, v_new, widx
    )
    us_paged = _bench_state(
        lambda kp, vp, kn, vn, pi, r: ops.paged_kv_update(
            kp, vp, kn, vn, pi, r, impl="xla"
        ),
        (k_pool, v_pool), k_new, v_new, page_idx, row,
    )
    report("serving/kv_write_dense_T4096", us_dense,
           f"O(B*T) masked select, {B * T * Hkv * D * 4 * 2 / 1e6:.0f}MB touched")
    report("serving/kv_write_paged_T4096", us_paged,
           f"O(B*page) scatter; speedup={us_dense / us_paged:.1f}x")
    assert us_paged < us_dense, (
        f"paged decode write ({us_paged:.0f}us) should beat the O(B*T) "
        f"masked select ({us_dense:.0f}us) at T={T}"
    )

    # ------------------------------------- degraded-mode workload
    # The fault-tolerance contract under overload: 4 new requests arrive
    # per engine step against 4 slots completing ~0.5 req/step (8x
    # oversubscribed).  The unbounded engine queues every arrival, so the
    # p99 TTFT of ACCEPTED requests grows with the backlog; the bounded
    # engine (max_queue=6) converts the backlog into typed
    # EngineOverloaded rejections the client can retry, keeping accepted
    # p99 TTFT low.  Rejections instead of unbounded queueing — asserted,
    # plus greedy token parity per accepted uid against the unbounded run
    # (backpressure must not change what survivors generate).
    from repro.serving.engine import EngineOverloaded, Request

    over_prompts = [
        rng.integers(5, cfg.vocab_size, size=int(rng.integers(6, 24)))
        .astype(np.int32)
        for _ in range(32)
    ]

    def _overload(max_queue):
        eng = Engine(model, params, slots=4, max_len=64,
                     cache_layout="paged", page_size=16,
                     max_queue=max_queue)
        _run_pass(eng, over_prompts[:4], 8)  # warm the jit caches
        n_before = len(eng.done)
        accepted, rejected = [], 0
        pending = list(enumerate(over_prompts))
        t0 = time.time()
        while pending:
            for _ in range(4):  # 4 arrivals per engine step
                if not pending:
                    break
                i, p = pending.pop(0)
                try:
                    eng.submit(Request(uid=i, prompt=p, max_new=8))
                    accepted.append(i)
                except EngineOverloaded:
                    rejected += 1
            eng.step()
        eng.run()
        wall = time.time() - t0
        done = {r.uid: r for r in eng.done[n_before:]}
        assert sorted(done) == sorted(accepted), \
            "overload pass lost accepted requests"
        ttft_ms = np.asarray(
            [done[u].t_first - done[u].t_submit for u in accepted]
        ) * 1e3
        p99 = float(np.percentile(ttft_ms, 99))
        return {u: done[u].output for u in accepted}, p99, rejected, wall

    outs_unb, p99_unb, rej_unb, _ = _overload(0)
    outs_bnd, p99_bnd, rej_bnd, _ = _overload(6)
    report("serving/overload_unbounded_p99ttft", p99_unb * 1e3,
           f"accepted={len(outs_unb)}/32 rejected={rej_unb} "
           "(every arrival queued)")
    report("serving/overload_bounded_p99ttft", p99_bnd * 1e3,
           f"accepted={len(outs_bnd)}/32 rejected={rej_bnd} max_queue=6 "
           f"p99_cut={p99_unb / max(p99_bnd, 1e-9):.1f}x")
    assert rej_unb == 0, "unbounded engine must not reject"
    assert rej_bnd > 0, "bounded engine must shed load under 8x overload"
    assert p99_bnd < p99_unb, (
        f"bounded queue must cut accepted p99 TTFT under overload "
        f"(unbounded {p99_unb:.1f}ms, bounded {p99_bnd:.1f}ms)"
    )
    for u, out in outs_bnd.items():
        assert out == outs_unb[u], \
            f"backpressure changed tokens for accepted request {u}"

    # seeded chaos pass: NaN injection + an allocator outage from
    # serving/faults.FaultPlan.  The engine must drain every request, and
    # the non-quarantined survivors must be token-identical to a
    # fault-free engine on the same workload (fault isolation: a poisoned
    # slot never contaminates its batch neighbours).
    from repro.serving.faults import FaultPlan

    def _chaos(plan):
        eng = Engine(model, params, slots=4, max_len=64,
                     cache_layout="paged", page_size=16, faults=plan)
        for i, p in enumerate(over_prompts[:8]):
            eng.submit(Request(uid=i, prompt=p, max_new=8))
        t0 = time.time()
        eng.run()
        return ({r.uid: r for r in eng.done}, dict(eng.counters),
                time.time() - t0)

    ref, _, _ = _chaos(None)
    # seed 2 schedules a NaN at step 4 (all slots still active) plus a
    # 4-step allocator outage, so the quarantine path provably fires
    plan = FaultPlan.seeded(2, horizon=24, slots=4, nan_events=2, outages=1)
    fau, counters, chaos_wall = _chaos(plan)
    assert len(fau) == 8, "chaos engine failed to drain all requests"
    assert counters["errors"] >= 1, \
        "seeded plan must quarantine at least one slot"
    survivors = [u for u, r in fau.items()
                 if r.finish_reason in ("stop", "length")]
    for u in survivors:
        assert fau[u].output == ref[u].output, \
            f"chaos survivor {u} diverged from fault-free run"
    report("serving/chaos_seeded_drain", chaos_wall * 1e6,
           f"errors={counters['errors']} survivors={len(survivors)}/8 "
           "token-parity ok")

    # ------------------------------------- sharded-serving scaling
    # one subprocess per device count (the XLA virtual-device flag must
    # be set before jax initializes); per-token parity of every mesh run
    # against the 1-device run is the acceptance assertion — tok/s across
    # 1 -> 8 virtual devices is reported for the trajectory.
    base = _scaling_run(1)
    for n_dev, mesh_shape in ((4, (1, 4)), (8, (2, 4))):
        res = _scaling_run(n_dev, mesh_shape)
        assert res["outs"] == base["outs"], (
            f"{mesh_shape} mesh diverged from single-device output"
        )
        report(
            f"serving/scaling_{n_dev}dev",
            1e6 / max(res["tok_s"], 1e-9),
            f"tok/s={res['tok_s']:.1f} vs 1dev={base['tok_s']:.1f} "
            f"{mesh_shape} mesh, per-token parity asserted; virtual "
            "devices share cores — mechanism proof, not speedup",
        )
    report(
        "serving/scaling_1dev", 1e6 / max(base["tok_s"], 1e-9),
        f"tok/s={base['tok_s']:.1f} single-device reference",
    )


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run(lambda name, us, derived="": print(f"{name},{us:.1f},{derived}",
                                           flush=True))
