"""Serving-path benchmark: dense-slot vs paged KV-cache engine.

Two measurements:

  * engine comparison — the continuous-batching engine end-to-end on a
    smoke model under both cache layouts, reporting tokens/s,
    time-to-first-token and inter-token latency.  Token-for-token output
    parity between the layouts is ASSERTED (the subsystem's acceptance
    criterion), not just reported.
  * decode cache-write microbenchmark at a long-cache config — the dense
    layout's O(B·T) one-hot masked select vs the paged O(B·page)
    scatter (``ops.paged_kv_update``).  The paged write must win; this
    asserts the per-token write really is page-local, independent of the
    cache length.

CPU numbers prove the mechanism (data volume per token write); on TPU the
same ratio shows up as HBM traffic per decode step.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def _serve(model, params, prompts, layout, max_new):
    from repro.serving.engine import Engine, Request

    eng = Engine(
        model, params, slots=4, max_len=128, cache_layout=layout, page_size=16
    )
    t0 = time.time()
    for i, p in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=p, max_new=max_new))
    done = eng.run()
    wall = time.time() - t0
    toks = sum(len(r.output) for r in done)
    ttft = float(np.mean([r.t_first - r.t_submit for r in done])) * 1e3
    itl = float(np.mean([
        (r.t_done - r.t_first) / max(len(r.output) - 1, 1) for r in done
    ])) * 1e3
    outs = {r.uid: r.output for r in done}
    return outs, toks / wall, ttft, itl, wall


def run(report):
    from repro.configs import get_smoke_config
    from repro.kernels import ops
    from repro.models.model import build_model

    # ---------------------------------------------------- engine A/B
    cfg = get_smoke_config("qwen2-7b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [
        rng.integers(5, cfg.vocab_size, size=int(rng.integers(4, 48)))
        .astype(np.int32)
        for _ in range(12)
    ]
    stats = {}
    for layout in ("dense", "paged"):
        outs, tps, ttft, itl, wall = _serve(model, params, prompts, layout, 16)
        stats[layout] = outs
        report(
            f"serving/engine_{layout}", wall * 1e6,
            f"tok/s={tps:.1f} ttft_ms={ttft:.1f} itl_ms={itl:.2f}",
        )
    assert stats["paged"] == stats["dense"], \
        "paged engine diverged from dense-slot engine (greedy parity)"

    # ------------------------------------- long-cache decode write A/B
    B, T, Hkv, D, page = 8, 4096, 4, 64, 16
    key = jax.random.PRNGKey(1)
    k_cache = jax.random.normal(key, (B, T, Hkv, D), jnp.float32)
    v_cache = jax.random.normal(jax.random.fold_in(key, 1), k_cache.shape,
                                jnp.float32)
    k_new = jax.random.normal(jax.random.fold_in(key, 2), (B, 1, Hkv, D),
                              jnp.float32)
    v_new = jax.random.normal(jax.random.fold_in(key, 3), k_new.shape,
                              jnp.float32)
    widx = jnp.asarray(rng.integers(0, T, size=B), jnp.int32)

    def dense_write(kc, vc, kn, vn, w):
        # the O(B·T) masked select models/attention.py uses per decode
        # token in the dense per-slot layout
        onehot = (jnp.arange(T)[None, :] == w[:, None])[..., None, None]
        return jnp.where(onehot, kn, kc), jnp.where(onehot, vn, vc)

    num_pages = 1 + B * (T // page)
    k_pool = jax.random.normal(key, (num_pages, page, Hkv, D), jnp.float32)
    v_pool = jax.random.normal(jax.random.fold_in(key, 4), k_pool.shape,
                               jnp.float32)
    page_idx = jnp.asarray(1 + rng.integers(0, num_pages - 1, size=B),
                           jnp.int32)
    row = jnp.asarray(rng.integers(0, page, size=B), jnp.int32)

    def _bench_state(fn, state, *args, iters=10, warmup=2) -> float:
        # donate the cache buffers (the serving decode loop's steady state)
        # so XLA may update in place — without donation both layouts pay a
        # full-pool copy that hides the write cost difference
        jfn = jax.jit(fn, donate_argnums=(0, 1))
        for _ in range(warmup):
            state = jfn(*state, *args)
            jax.block_until_ready(state)
        t0 = time.perf_counter()
        for _ in range(iters):
            state = jfn(*state, *args)
            jax.block_until_ready(state)
        return (time.perf_counter() - t0) / iters * 1e6

    us_dense = _bench_state(
        dense_write, (k_cache, v_cache), k_new, v_new, widx
    )
    us_paged = _bench_state(
        lambda kp, vp, kn, vn, pi, r: ops.paged_kv_update(
            kp, vp, kn, vn, pi, r, impl="xla"
        ),
        (k_pool, v_pool), k_new, v_new, page_idx, row,
    )
    report("serving/kv_write_dense_T4096", us_dense,
           f"O(B*T) masked select, {B * T * Hkv * D * 4 * 2 / 1e6:.0f}MB touched")
    report("serving/kv_write_paged_T4096", us_paged,
           f"O(B*page) scatter; speedup={us_dense / us_paged:.1f}x")
    assert us_paged < us_dense, (
        f"paged decode write ({us_paged:.0f}us) should beat the O(B*T) "
        f"masked select ({us_dense:.0f}us) at T={T}"
    )


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run(lambda name, us, derived="": print(f"{name},{us:.1f},{derived}",
                                           flush=True))
