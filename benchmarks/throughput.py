"""Paper table 1 analogue: optimized framework path vs vanilla baseline.

BioNeMo's headline claim is a large training-throughput advantage over
"vanilla" (HF-style) implementations.  We reproduce the comparison shape-
faithfully on CPU with a small ESM-2-family model:

  * optimized — the framework path: blockwise (flash-semantics)
    attention + blockwise cross-entropy + donated buffers.
  * vanilla   — naive attention (materializes (S,S) scores) + full
    logits cross-entropy.

Both run fp32 on this CPU (bf16 is *emulated* on CPU — including it would
measure the emulation, not the algorithm; on TPU bf16 doubles MXU
throughput and is part of the optimized path's roofline advantage).
Sequence length is chosen so the quadratic buffers exceed cache.  CPU
numbers prove the mechanism; the TPU projection comes from the roofline
table."""
from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np


def _bench(fn, args, iters=8, warmup=2) -> float:
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def run(report):
    from repro.core.config import ModelConfig, TrainConfig
    from repro.models.model import build_model
    from repro.training.train_step import init_train_state, make_train_step

    B, S = 2, 2048
    os.environ["REPRO_ATTN_BLOCK_K"] = "256"  # real blocking at this scale
    tc = TrainConfig(total_steps=1)
    batch = {
        "tokens": jnp.asarray(
            np.random.default_rng(0).integers(5, 33, size=(B, S)), jnp.int32
        ),
        "targets": jnp.asarray(
            np.random.default_rng(1).integers(5, 33, size=(B, S)), jnp.int32
        ),
        "loss_mask": jnp.ones((B, S), jnp.float32),
    }

    cfg = ModelConfig(
        name="esm2-bench", family="bio_bert", num_layers=4, d_model=256,
        num_heads=8, num_kv_heads=8, d_ff=1024, vocab_size=33,
        causal=False, objective="mlm", act="gelu", norm_type="layernorm",
        qkv_bias=True, mlp_bias=True, tie_embeddings=True, dtype="float32",
    )

    def bench_step(step_fn, state, iters=6, warmup=2):
        for _ in range(warmup):
            state, m = step_fn(state, batch)
            jax.block_until_ready(m["loss"])
        t0 = time.perf_counter()
        for _ in range(iters):
            state, m = step_fn(state, batch)
            jax.block_until_ready(m["loss"])
        return (time.perf_counter() - t0) / iters * 1e6

    # optimized path (blockwise attention + blockwise CE + donation)
    model = build_model(cfg)
    state = init_train_state(model, jax.random.PRNGKey(0), tc)
    step = jax.jit(make_train_step(model, tc), donate_argnums=(0,))
    us_opt = bench_step(step, state)
    report("throughput/esm2ish_optimized_train_step", us_opt,
           f"tokens_per_s={B * S / (us_opt / 1e6):.0f}")

    # vanilla baseline (naive attention + full-logits CE, no donation)
    os.environ["REPRO_FORCE_IMPL"] = "naive"
    try:
        model_v = build_model(cfg)
        state_v = init_train_state(model_v, jax.random.PRNGKey(0), tc)
        step_v = jax.jit(make_train_step(model_v, tc))
        us_van = bench_step(step_v, state_v)
    finally:
        del os.environ["REPRO_FORCE_IMPL"]
    report("throughput/esm2ish_vanilla_train_step", us_van,
           f"tokens_per_s={B * S / (us_van / 1e6):.0f}")
    report("throughput/optimized_vs_vanilla_wallclock", us_van / us_opt,
           "CPU is compute-bound: flash-style recompute costs ~1.7x flops "
           "here and wins only on memory-bound HBM parts (see roofline)")

    # the mechanism the optimized path buys: peak activation memory.
    def temp_bytes(step_fn, state):
        lowered = jax.jit(step_fn).lower(state, batch)
        return lowered.compile().memory_analysis().temp_size_in_bytes

    mem_opt = temp_bytes(make_train_step(model, tc), state)
    os.environ["REPRO_FORCE_IMPL"] = "naive"
    try:
        mem_van = temp_bytes(make_train_step(model_v, tc), state_v)
    finally:
        del os.environ["REPRO_FORCE_IMPL"]
    report("throughput/optimized_temp_bytes", mem_opt, "activation memory")
    report("throughput/vanilla_temp_bytes", mem_van, "materializes (S,S) + logits")
    report("throughput/vanilla_over_optimized_memory", mem_van / max(mem_opt, 1),
           "x less activation memory -> longer seq / bigger per-chip batch")
    del os.environ["REPRO_ATTN_BLOCK_K"]
