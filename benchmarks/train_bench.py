"""Training-engine smoke bench: tokens/s, step time, accumulation on/off.

Runs the distributed Trainer (single device on this CPU container; the
same code path drives the mesh) over a tiny CLM model and reports:

  * ``train_tps_accum1`` / ``train_tps_accum4`` — tokens/s and mean step
    time with gradient accumulation off/on (accum=4 microbatches)

The steady-state host-transfer contract is ASSERTED, not just reported:
the guarded portion of each run must perform exactly one bulk
``jax.device_get`` per log interval and no implicit transfers
(``jax.transfer_guard("disallow")``), mirroring the serving bench's
single-transfer regression.
"""
from __future__ import annotations

import tempfile

import jax


def _run_one(report, accum: int) -> None:
    from repro.core.config import ModelConfig, TrainConfig
    from repro.data.dataset import build_synthetic_protein_memmap
    from repro.data.pipeline import CLMBatches
    from repro.models.model import build_model
    from repro.training.loop import Trainer

    cfg = ModelConfig(
        name="train-bench", family="dense", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, d_ff=256, vocab_size=64,
        dtype="float32",
    )
    tmp = tempfile.mkdtemp(prefix="repro_train_bench_")
    ds, _ = build_synthetic_protein_memmap(tmp + "/prot", n=400, seed=0)
    tc = TrainConfig(
        global_batch=8, seq_len=64, total_steps=10, log_every=4,
        warmup_steps=2, decay_steps=2, learning_rate=1e-3,
        accum_steps=accum,
    )
    tr = Trainer(build_model(cfg), tc, verbose=False)
    tr.prepare(CLMBatches(ds, tc.global_batch, tc.seq_len, seed=0))
    tr.step()  # s=0: compile + first log flush, outside the guard

    calls = []
    real_get = jax.device_get
    jax.device_get = lambda x: calls.append(1) or real_get(x)
    try:
        with jax.transfer_guard("disallow"):
            while tr.step_idx < tc.total_steps:
                tr.step()
    finally:
        jax.device_get = real_get
    # steps 1..9 under the guard flush at s=4, s=8, s=9
    assert len(calls) == 3, f"expected 3 bulk transfers, saw {len(calls)}"

    last = tr.history[-1]
    report(
        f"train_step_accum{accum}",
        last["step_time"] * 1e6,
        f"tok/s={last['tokens_per_sec']:.0f}"
        + (
            f" flop_ratio={last['useful_flop_ratio']:.2f}"
            if "useful_flop_ratio" in last
            else ""
        ),
    )


def run(report) -> None:
    for accum in (1, 4):
        _run_one(report, accum)


if __name__ == "__main__":
    rows = []
    print("name,us_per_call,derived")
    run(lambda n, us, d="": (rows.append(n), print(f"{n},{us:.1f},{d}")))
    assert rows
