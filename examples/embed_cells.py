"""Geneformer-style single-cell embedding example: rank-value encode
synthetic expression profiles, train the reduced Geneformer recipe briefly,
extract cell embeddings THROUGH THE SERVING ENGINE (``LLM.embed`` — the
same batched, length-bucketed, telemetry-instrumented path production
inference uses), and check that they cluster by cell "type".

    PYTHONPATH=src python examples/embed_cells.py
"""
import numpy as np

from repro.configs import get_smoke_config
from repro.core.config import TrainConfig
from repro.models.model import build_model
from repro.obs.metrics import MetricsRegistry
from repro.serving.api import LLM
from repro.training.loop import run_training


def rank_value_encode(expr: np.ndarray, top_k: int) -> np.ndarray:
    """Geneformer input encoding: genes sorted by expression, ids are gene
    indices (offset past special tokens)."""
    order = np.argsort(-expr, axis=1)[:, :top_k]
    return (order + 5).astype(np.int32)


def synthetic_cells(n: int, n_genes: int, n_types: int = 3, seed: int = 0):
    rng = np.random.default_rng(seed)
    centers = rng.gamma(2.0, 1.0, size=(n_types, n_genes))
    types = rng.integers(0, n_types, size=n)
    expr = rng.poisson(centers[types] * 5).astype(np.float32)
    return expr, types


def main() -> None:
    cfg = get_smoke_config("geneformer-106m")
    model = build_model(cfg)
    n_genes = cfg.vocab_size - 5
    S = 64
    print(f"arch={cfg.name} genes={n_genes} seq={S}")

    expr, types = synthetic_cells(512, n_genes)
    tokens = rank_value_encode(expr, S)

    rng = np.random.default_rng(0)

    def batches():
        while True:
            idx = rng.integers(0, len(tokens), size=16)
            t = tokens[idx]
            pick = rng.random(t.shape) < 0.15
            corrupted = t.copy()
            corrupted[pick] = 4  # <mask>
            yield {"tokens": corrupted, "targets": t,
                   "loss_mask": pick.astype(np.float32)}

    tc = TrainConfig(global_batch=16, seq_len=S, total_steps=60,
                     learning_rate=3e-3, warmup_steps=5, decay_steps=5,
                     log_every=20)
    state, hist = run_training(model, tc, batches())

    # embed all cells through the serving engine: batched dispatch,
    # masked mean-pooling on device, one bulk transfer of (n, d) vectors
    reg = MetricsRegistry()
    llm = LLM(model, state.params, slots=32, max_len=S, metrics=reg)
    embs = llm.embed([t.tolist() for t in tokens])
    c = llm.engine.counters
    print(f"embedded {embs.shape[0]} cells -> d={embs.shape[1]} "
          f"(engine: {c['submitted']} submitted, {c['completed']} completed)")

    # silhouette-ish check: same-type distance < cross-type distance
    same, cross = [], []
    for t in range(3):
        e = embs[types == t]
        o = embs[types != t]
        c = e.mean(0)
        same.append(np.linalg.norm(e - c, axis=1).mean())
        cross.append(np.linalg.norm(o - c, axis=1).mean())
    print(f"mean same-type dist {np.mean(same):.3f} vs cross-type {np.mean(cross):.3f}")
    print("cell types separate:", bool(np.mean(cross) > np.mean(same)))


if __name__ == "__main__":
    main()
