"""LoRA fine-tuning example: pretrain a small protein LM briefly, freeze
it, then LoRA-adapt it to a shifted distribution (different motif library)
— the BioNeMo downstream-adaptation recipe shape.

    PYTHONPATH=src python examples/finetune_lora.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core.config import ModelConfig, TrainConfig
from repro.data.dataset import MemmapTokenDataset, synthetic_protein_sequences
from repro.data.tokenizer import ProteinTokenizer
from repro.models.model import build_model
from repro.optim import adamw
from repro.training import lora
from repro.training.loop import run_training


def stream(ds, batch, seq, seed=0):
    rng = np.random.default_rng(seed)
    while True:
        idx = rng.integers(0, len(ds), size=batch)
        toks = np.zeros((batch, seq), np.int32)
        for r, i in enumerate(idx):
            s = ds[int(i)][:seq]
            toks[r, : len(s)] = s
        yield {"tokens": toks}


def main() -> None:
    tok = ProteinTokenizer()
    cfg = ModelConfig(
        name="protein-lm", family="dense", num_layers=4, d_model=128,
        num_heads=8, num_kv_heads=4, d_ff=512, vocab_size=tok.vocab_size,
        dtype="float32",
    )
    model = build_model(cfg)

    # --- pretrain on motif library A ---
    seqs_a = synthetic_protein_sequences(800, seed=0)
    ds_a = MemmapTokenDataset.write(
        "/tmp/lora/a", [np.asarray(tok.encode(s), np.int32) for s in seqs_a]
    )
    tc = TrainConfig(global_batch=8, seq_len=64, total_steps=80,
                     learning_rate=3e-3, warmup_steps=8, decay_steps=8,
                     log_every=20)
    state, hist = run_training(model, tc, stream(ds_a, 8, 64))
    base = state.params

    # --- domain shift: motif library B ---
    seqs_b = synthetic_protein_sequences(800, seed=123)
    ds_b = MemmapTokenDataset.write(
        "/tmp/lora/b", [np.asarray(tok.encode(s), np.int32) for s in seqs_b]
    )
    batches_b = stream(ds_b, 8, 64, seed=1)
    b0 = next(batches_b)
    base_loss = float(model.loss_fn(base, b0)[0])

    # --- LoRA adaptation (base frozen, ~1% trainable) ---
    adapters = lora.init_adapters(base, rank=8, key=jax.random.PRNGKey(7))
    n_base = sum(x.size for x in jax.tree.leaves(base))
    print(f"\ntrainable: {lora.count_trainable(adapters):,} / {n_base:,} "
          f"({100*lora.count_trainable(adapters)/n_base:.2f}%)")
    loss_fn = lora.make_lora_loss(model, base)
    opt = adamw.init_state(adapters)
    tc_ft = TrainConfig(learning_rate=2e-3, weight_decay=0.0)

    @jax.jit
    def step(adapters, opt, batch):
        (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(adapters, batch)
        adapters, opt = adamw.apply_updates(adapters, g, opt, jnp.float32(2e-3), tc_ft)
        return adapters, opt, loss

    losses = []
    for i in range(60):
        adapters, opt, loss = step(adapters, opt, next(batches_b))
        losses.append(float(loss))
        if i % 20 == 0:
            print(f"ft step {i:3d} loss {losses[-1]:.4f}")

    merged = lora.merged_params(base, adapters)
    ft_loss = float(model.loss_fn(merged, b0)[0])
    print(f"\ndomain-B loss: frozen base {base_loss:.4f} -> LoRA {ft_loss:.4f}")
    assert ft_loss < base_loss, "LoRA adaptation failed to improve"


if __name__ == "__main__":
    main()
