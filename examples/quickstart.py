"""Quickstart: build a model from the zoo, train it briefly on synthetic
protein data, then embed sequences — the BioNeMo 'hello world'.

    PYTHONPATH=src python examples/quickstart.py
"""
import tempfile

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config, list_archs
from repro.core.config import TrainConfig
from repro.data.dataset import build_synthetic_protein_memmap
from repro.data.pipeline import MLMBatches
from repro.models.model import build_model
from repro.training.loop import run_training


def main() -> None:
    print("model zoo:", ", ".join(list_archs()))

    # 1. pick a recipe (reduced ESM-2 so the demo runs on CPU in seconds)
    cfg = get_smoke_config("esm2-650m")
    model = build_model(cfg)
    print(f"\narch={cfg.name} family={cfg.family} params≈{cfg.param_count():,}")

    # 2. data: memmap protein store + MLM pipeline
    with tempfile.TemporaryDirectory() as d:
        ds, tok = build_synthetic_protein_memmap(f"{d}/prot", n=500)
        tc = TrainConfig(global_batch=8, seq_len=64, total_steps=40,
                         learning_rate=3e-3, warmup_steps=4, decay_steps=4,
                         log_every=10)
        batches = iter(MLMBatches(ds, tok, None, tc.global_batch, tc.seq_len))

        # 3. train
        state, history = run_training(model, tc, batches)
        print(f"\nloss: {history[0]['loss']:.3f} -> {history[-1]['loss']:.3f}")

        # 4. embed: mean-pooled final hidden states (frozen encoder)
        batch = next(batches)
        x, _ = model._decoder_input(model_params := state.params, batch, "train")
        h, _, _ = model._backbone(model_params, x, mode="train")
        emb = h.mean(axis=1)
        print(f"embeddings: {emb.shape} (norm {float(jnp.linalg.norm(emb[0])):.2f})")


if __name__ == "__main__":
    main()
