"""Continuous-batching serving through the Generation API v2 ``LLM``
facade: submit a stream of variable-length protein prompts, each with its
own ``SamplingParams``, and watch per-request latency — requests are
admitted/released at iteration granularity, never padded to each other.

Runs the same greedy stream under three engine configurations and checks
they agree token-for-token:

  * ``dense`` — one (slots, max_len) buffer per layer, O(B·T) decode write;
  * ``paged`` — block-table pages over a shared pool (the production
    path: O(page) Pallas scatter writes, paged-attention decode reads,
    page reuse across requests);
  * ``paged + prefix cache + chunked prefill`` — full prompt blocks are
    content-hashed and shared across requests (refcounted pages,
    copy-on-write), so the repeated task preamble in front of every
    prompt prefills once and is reused; prefill runs in bounded chunks
    interleaved with decode steps so long prompts never stall in-flight
    decodes.

then demos the v2 surface: a mixed greedy/sampled batch (per-request
temperature/top-k/top-p/seed, sampled on device by the fused kernel),
token-level streaming, and the unified telemetry hookup — a
``MetricsRegistry`` + ``TraceRecorder`` threaded into the engine, with
``on_step`` emitting a one-line health/exposition digest every N engine
steps so a stall is visible *while* it is happening, not post-mortem.

    PYTHONPATH=src python examples/serve_continuous.py
"""
import numpy as np
import jax

from repro.configs import get_smoke_config
from repro.models.model import build_model
from repro.obs import MetricsRegistry, TraceRecorder
from repro.serving.api import LLM
from repro.serving.sampling import SamplingParams


def serve(model, params, requests, layout, **kw):
    llm = LLM(model, params, slots=4, max_len=96,
              cache_layout=layout, page_size=16, **kw)
    prompts = [p for _, p, _ in requests]
    plist = [SamplingParams(max_new=n) for _, _, n in requests]
    outs = llm.generate(prompts, plist)
    eng = llm.engine
    tag = layout + ("+prefix" if kw.get("prefix_cache") else "")
    print(f"[{tag}] served {len(outs)} requests on {eng.B} slots")
    for c in outs:
        print(f"  req {c.index}: prompt={len(prompts[c.index]):2d} "
              f"new={len(c.tokens):2d} ttft={c.ttft_s * 1e3:7.1f}ms "
              f"total={c.latency_s * 1e3:7.1f}ms [{c.finish_reason}]")
    if layout == "paged":
        eng.alloc.check_invariants()
        print(f"  page pool: {eng.alloc.num_pages - 1} usable pages of "
              f"{eng.alloc.page_size}, all references returned")
        if kw.get("prefix_cache"):
            st = eng.alloc.stats
            print(f"  prefix cache: {st['hit_tokens']} tokens reused, "
                  f"{st['cow_copies']} COW copies, {st['evictions']} evictions")
    return {requests[c.index][0]: c.tokens for c in outs}


def main() -> None:
    cfg = get_smoke_config("qwen2-7b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    # a fixed task preamble (the shared scaffold sequence every request
    # carries in protein/chemistry serving) + a unique per-request tail
    preamble = rng.integers(5, cfg.vocab_size, size=32).astype(np.int32)
    n_req = 10
    requests = []
    for i in range(n_req):
        tail = rng.integers(5, cfg.vocab_size,
                            size=int(rng.integers(4, 24))).astype(np.int32)
        requests.append((
            i,
            np.concatenate([preamble, tail]),
            int(rng.integers(4, 12)),
        ))

    dense = serve(model, params, requests, "dense")
    paged = serve(model, params, requests, "paged")
    prefix = serve(model, params, requests, "paged",
                   prefix_cache=True, prefill_chunk=16)
    assert len(dense) == len(paged) == len(prefix) == n_req
    assert dense == paged, "paged layout diverged from dense"
    assert dense == prefix, "prefix caching / chunked prefill changed tokens"
    print("dense, paged, and prefix-cached engines produced identical tokens")

    # ---- v2 surface: heterogeneous per-request sampling in ONE batch ----
    llm = LLM(model, params, slots=4, max_len=96)
    prompts = [p for _, p, _ in requests[:4]]
    mixed = [
        SamplingParams(max_new=8),                                  # greedy
        SamplingParams(temperature=1.0, top_k=20, seed=1, max_new=8),
        SamplingParams(temperature=0.7, top_p=0.9, seed=2, max_new=8,
                       logprobs=True),
        SamplingParams(temperature=1.2, top_k=40, top_p=0.95, seed=3,
                       max_new=8),
    ]
    outs = llm.generate(prompts, mixed)
    print("\nmixed greedy/sampled batch (fused on-device sampler):")
    for c in outs:
        lp = (f" logp[0]={c.logprobs[0]:.2f}" if c.logprobs else "")
        print(f"  req {c.index}: {c.tokens}{lp}")
    # fixed seeds are reproducible regardless of batch composition
    again = llm.generate(prompts[2:3], mixed[2:3])
    assert again[0].tokens == outs[2].tokens, "fixed-seed sampling not reproducible"
    print("fixed-seed request reproduced identically outside the batch")

    # ---- v2 surface: token-level streaming ----
    print("\nstreaming (tokens interleave across requests as decoded):")
    line = []
    for ch in llm.stream(prompts[:2], SamplingParams(max_new=6)):
        line.append(f"r{ch.index}:{ch.token}{'#' if ch.done else ''}")
    print("  " + " ".join(line))

    # ---- unified telemetry: live health every N steps + lifecycle trace ----
    # The registry and health() count through the same increments, so the
    # periodic line below is exactly what /metrics exposition would show.
    print("\ntelemetry (health digest every 4 engine steps):")
    reg, tracer = MetricsRegistry(), TraceRecorder(capacity=1024)

    def on_step(eng, every=4):
        if eng.steps % every:
            return
        h = eng.health()
        print(f"  step {h.steps:3d}: queue={h.queue_depth} "
              f"active={h.active_slots} "
              f"completed={h.counters['completed']}")

    obs_llm = LLM(model, params, slots=4, max_len=96, cache_layout="paged",
                  page_size=16, metrics=reg, trace=tracer, on_step=on_step)
    obs_llm.generate([p for _, p, _ in requests],
                     [SamplingParams(max_new=n) for _, _, n in requests])
    # registry counters are the same numbers health() reports
    fam = reg.get("engine_requests_total")
    eng = obs_llm.engine
    assert all(fam.labels(k).value == v
               for k, v in eng.health().counters.items())
    p95 = reg.get("engine_ttft_seconds").quantile(0.95)
    print(f"  p95 TTFT {p95 * 1e3:.1f}ms over "
          f"{reg.get('engine_ttft_seconds').count} requests")
    ev = [e["event"] for e in tracer.events()]
    print(f"  trace: {len(ev)} lifecycle events "
          f"(submit={ev.count('submit')} prefill={ev.count('prefill')} "
          f"decode={ev.count('decode')} finish={ev.count('finish')})")
    print("\nfirst 120 chars of Prometheus exposition:")
    print("  " + reg.to_prometheus()[:120].replace("\n", "\n  "))


if __name__ == "__main__":
    main()
