"""Continuous-batching serving: submit a stream of variable-length protein
prompts to the slot engine and watch per-request latency — requests are
admitted/released at iteration granularity, never padded to each other.

Runs the same stream under three configurations and checks they agree:

  * ``dense`` — one (slots, max_len) buffer per layer, O(B·T) decode write;
  * ``paged`` — block-table pages over a shared pool (the production
    path: O(page) Pallas scatter writes, paged-attention decode reads,
    page reuse across requests);
  * ``paged + prefix cache + chunked prefill`` — full prompt blocks are
    content-hashed and shared across requests (refcounted pages,
    copy-on-write), so the repeated task preamble in front of every
    prompt prefills once and is reused; prefill runs in bounded chunks
    interleaved with decode steps so long prompts never stall in-flight
    decodes.

    PYTHONPATH=src python examples/serve_continuous.py
"""
import numpy as np
import jax

from repro.configs import get_smoke_config
from repro.models.model import build_model
from repro.serving.engine import Engine, Request


def serve(model, params, requests, layout, **kw):
    eng = Engine(model, params, slots=4, max_len=96,
                 cache_layout=layout, page_size=16, **kw)
    for uid, prompt, max_new in requests:
        eng.submit(Request(uid=uid, prompt=prompt, max_new=max_new))
    done = eng.run()
    tag = layout + ("+prefix" if kw.get("prefix_cache") else "")
    print(f"[{tag}] served {len(done)} requests on {eng.B} slots")
    for r in sorted(done, key=lambda r: r.uid):
        lat = (r.t_done - r.t_submit) * 1e3
        ttft = (r.t_first - r.t_submit) * 1e3
        print(f"  req {r.uid}: prompt={len(r.prompt):2d} new={len(r.output):2d} "
              f"ttft={ttft:7.1f}ms total={lat:7.1f}ms")
    if layout == "paged":
        eng.alloc.check_invariants()
        print(f"  page pool: {eng.alloc.num_pages - 1} usable pages of "
              f"{eng.alloc.page_size}, all references returned")
        if kw.get("prefix_cache"):
            st = eng.alloc.stats
            print(f"  prefix cache: {st['hit_tokens']} tokens reused, "
                  f"{st['cow_copies']} COW copies, {st['evictions']} evictions")
    return {r.uid: r.output for r in done}


def main() -> None:
    cfg = get_smoke_config("qwen2-7b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    # a fixed task preamble (the shared scaffold sequence every request
    # carries in protein/chemistry serving) + a unique per-request tail
    preamble = rng.integers(5, cfg.vocab_size, size=32).astype(np.int32)
    n_req = 10
    requests = []
    for i in range(n_req):
        tail = rng.integers(5, cfg.vocab_size,
                            size=int(rng.integers(4, 24))).astype(np.int32)
        requests.append((
            i,
            np.concatenate([preamble, tail]),
            int(rng.integers(4, 12)),
        ))

    dense = serve(model, params, requests, "dense")
    paged = serve(model, params, requests, "paged")
    prefix = serve(model, params, requests, "paged",
                   prefix_cache=True, prefill_chunk=16)
    assert len(dense) == len(paged) == len(prefix) == n_req
    assert dense == paged, "paged layout diverged from dense"
    assert dense == prefix, "prefix caching / chunked prefill changed tokens"
    print("dense, paged, and prefix-cached engines produced identical tokens")


if __name__ == "__main__":
    main()
