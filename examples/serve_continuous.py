"""Continuous-batching serving: submit a stream of variable-length protein
prompts to the slot engine and watch per-request latency — requests are
admitted/released at iteration granularity, never padded to each other.

    PYTHONPATH=src python examples/serve_continuous.py
"""
import numpy as np
import jax

from repro.configs import get_smoke_config
from repro.models.model import build_model
from repro.serving.engine import Engine, Request


def main() -> None:
    cfg = get_smoke_config("qwen2-7b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    eng = Engine(model, params, slots=4, max_len=96)
    n_req = 10
    for i in range(n_req):
        L = int(rng.integers(4, 24))
        eng.submit(Request(
            uid=i,
            prompt=rng.integers(5, cfg.vocab_size, size=L).astype(np.int32),
            max_new=int(rng.integers(4, 12)),
        ))
    done = eng.run()
    print(f"served {len(done)} requests on {eng.B} slots")
    for r in sorted(done, key=lambda r: r.uid):
        lat = (r.t_done - r.t_submit) * 1e3
        ttft = (r.t_first - r.t_submit) * 1e3
        print(f"  req {r.uid}: prompt={len(r.prompt):2d} new={len(r.output):2d} "
              f"ttft={ttft:7.1f}ms total={lat:7.1f}ms")
    assert len(done) == n_req


if __name__ == "__main__":
    main()
