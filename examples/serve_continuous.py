"""Continuous-batching serving: submit a stream of variable-length protein
prompts to the slot engine and watch per-request latency — requests are
admitted/released at iteration granularity, never padded to each other.

Runs the same stream under both KV-cache layouts and checks they agree:

  * ``dense`` — one (slots, max_len) buffer per layer, O(B·T) decode write;
  * ``paged`` — block-table pages over a shared pool (the production
    path: O(page) Pallas scatter writes, paged-attention decode reads,
    page reuse across requests).

    PYTHONPATH=src python examples/serve_continuous.py
"""
import numpy as np
import jax

from repro.configs import get_smoke_config
from repro.models.model import build_model
from repro.serving.engine import Engine, Request


def serve(model, params, requests, layout):
    eng = Engine(model, params, slots=4, max_len=96,
                 cache_layout=layout, page_size=16)
    for uid, prompt, max_new in requests:
        eng.submit(Request(uid=uid, prompt=prompt, max_new=max_new))
    done = eng.run()
    print(f"[{layout}] served {len(done)} requests on {eng.B} slots")
    for r in sorted(done, key=lambda r: r.uid):
        lat = (r.t_done - r.t_submit) * 1e3
        ttft = (r.t_first - r.t_submit) * 1e3
        print(f"  req {r.uid}: prompt={len(r.prompt):2d} new={len(r.output):2d} "
              f"ttft={ttft:7.1f}ms total={lat:7.1f}ms")
    if layout == "paged":
        eng.alloc.check_invariants()
        print(f"  page pool: {eng.alloc.num_pages - 1} usable pages of "
              f"{eng.alloc.page_size}, all returned to the free list")
    return {r.uid: r.output for r in done}


def main() -> None:
    cfg = get_smoke_config("qwen2-7b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    n_req = 10
    requests = []
    for i in range(n_req):
        L = int(rng.integers(4, 24))
        requests.append((
            i,
            rng.integers(5, cfg.vocab_size, size=L).astype(np.int32),
            int(rng.integers(4, 12)),
        ))

    dense = serve(model, params, requests, "dense")
    paged = serve(model, params, requests, "paged")
    assert len(dense) == len(paged) == n_req
    assert dense == paged, "paged layout diverged from dense"
    print("dense and paged layouts produced identical tokens")


if __name__ == "__main__":
    main()
