"""Batched serving example: train a small SMILES seq2seq (MolMIM-class)
briefly, then serve a batch of requests — prefill + greedy decode with the
framework's KV-cache path (the same decode_step the 32k/500k dry-run shapes
lower).

    PYTHONPATH=src python examples/serve_generate.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core.config import TrainConfig
from repro.data.dataset import MemmapTokenDataset, synthetic_smiles_sequences
from repro.data.tokenizer import SmilesTokenizer
from repro.models.model import build_model
from repro.training.loop import run_training


def main() -> None:
    cfg = get_smoke_config("molmim-65m")
    model = build_model(cfg)
    tok = SmilesTokenizer()
    print(f"arch={cfg.name} (enc-dec) vocab={tok.vocab_size}")

    # brief training so generations aren't pure noise
    seqs = synthetic_smiles_sequences(800, seed=0)
    enc = [np.asarray(tok.encode(s), np.int32) for s in seqs]
    ds = MemmapTokenDataset.write("/tmp/smiles/d", enc)

    def batches():
        rng = np.random.default_rng(0)
        while True:
            idx = rng.integers(0, len(ds), size=8)
            toks = np.zeros((8, 48), np.int32)
            for r, i in enumerate(idx):
                s = ds[int(i)][:48]
                toks[r, :len(s)] = s
            yield {"tokens": toks, "src_tokens": toks}

    tc = TrainConfig(global_batch=8, seq_len=48, total_steps=60,
                     learning_rate=3e-3, warmup_steps=5, decay_steps=5,
                     log_every=20)
    state, hist = run_training(model, tc, batches())

    # ---- serve a batch of 4 requests ----
    prompts = synthetic_smiles_sequences(4, seed=7)
    toks = jnp.asarray(tok.encode_batch(prompts, 24), jnp.int32)
    batch = {"tokens": toks[:, :8], "src_tokens": toks}
    prefill = jax.jit(lambda p, b: model.prefill(p, b, 48))
    decode = jax.jit(model.decode_step)
    logits, cache = prefill(state.params, batch)
    out = []
    t0 = time.time()
    for _ in range(16):
        nxt = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        out.append(nxt)
        logits, cache = decode(state.params, cache, nxt)
    dt = time.time() - t0
    gen = np.asarray(jnp.concatenate(out, axis=1))
    print(f"\nserved 4 requests, 16 tokens each, {4 * 16 / dt:.1f} tok/s")
    for i, p in enumerate(prompts):
        print(f"  prompt={p[:20]!r:24s} -> {tok.decode(gen[i])!r}")


if __name__ == "__main__":
    main()
