"""End-to-end driver: train an ESM-2-style protein LM with the full
substrate — memmap dataset, UniRef-style cluster sampling, MLM pipeline,
AdamW + WSD schedule, checkpointing, loss history to JSON.

Default preset trains a ~11M-param model for 200 steps on CPU (minutes).
``--preset full`` selects the real esm2-650m recipe + production-scale
hyperparameters — the identical code path a TPU mesh would run.

    PYTHONPATH=src python examples/train_protein_lm.py --steps 200
"""
import argparse
import json
import os

import jax

from repro.configs import get_config
from repro.core.config import ModelConfig, TrainConfig
from repro.data.dataset import build_synthetic_protein_memmap
from repro.data.pipeline import MLMBatches
from repro.data.sampler import ClusterSampler, greedy_length_clusters
from repro.models.model import build_model
from repro.training.loop import run_training


def small_esm2() -> ModelConfig:
    """~11M params — trainable for a few hundred steps on this CPU."""
    return ModelConfig(
        name="esm2-11m", family="bio_bert", num_layers=6, d_model=320,
        num_heads=8, num_kv_heads=8, head_dim=40, d_ff=1280, vocab_size=33,
        causal=False, objective="mlm", act="gelu", norm_type="layernorm",
        qkv_bias=True, attn_out_bias=True, mlp_bias=True, tie_embeddings=True,
        dtype="float32",
    )


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--preset", default="small", choices=["small", "full"])
    p.add_argument("--steps", type=int, default=200)
    p.add_argument("--batch", type=int, default=16)
    p.add_argument("--seq", type=int, default=128)
    p.add_argument("--lr", type=float, default=1e-3)
    p.add_argument("--data-dir", default="/tmp/repro_data")
    p.add_argument("--out", default="/tmp/protein_lm")
    a = p.parse_args()

    cfg = small_esm2() if a.preset == "small" else get_config("esm2-650m")
    model = build_model(cfg)
    print(f"arch={cfg.name} params≈{cfg.param_count():,}")

    ds, tok = build_synthetic_protein_memmap(f"{a.data_dir}/prot", n=4000)
    lengths = [len(ds[i]) for i in range(len(ds))]
    sampler = ClusterSampler(greedy_length_clusters(lengths, 128))
    tc = TrainConfig(
        global_batch=a.batch, seq_len=a.seq, total_steps=a.steps,
        learning_rate=a.lr, warmup_steps=max(a.steps // 10, 1),
        decay_steps=max(a.steps // 5, 1), schedule="wsd", log_every=10,
        ckpt_dir=os.path.join(a.out, "ckpt"), ckpt_every=max(a.steps // 2, 1),
    )
    batches = iter(
        MLMBatches(ds, tok, sampler, tc.global_batch, tc.seq_len, cfg.mlm_mask_prob)
    )
    state, history = run_training(model, tc, batches)

    os.makedirs(a.out, exist_ok=True)
    with open(os.path.join(a.out, "history.json"), "w") as f:
        json.dump(history, f, indent=1)
    drop = history[0]["loss"] - history[-1]["loss"]
    print(f"\nloss {history[0]['loss']:.3f} -> {history[-1]['loss']:.3f} "
          f"(Δ {drop:.3f}); checkpoints + history in {a.out}")
    assert drop > 0, "training did not reduce loss"


if __name__ == "__main__":
    main()
