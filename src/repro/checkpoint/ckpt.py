"""Sharded checkpointing (BioNeMo distributed-checkpoint analogue).

Each leaf is saved as its own ``.npy`` under a directory keyed by its tree
path; a ``manifest.json`` records the tree structure, shapes, dtypes and
the saving step.  On restore, leaves are loaded lazily and (optionally)
``device_put`` against target shardings — so a checkpoint written on one
mesh restores onto another (the resharding restore BioNeMo gets from
Megatron dist-ckpt).  ``save_train_state`` / ``restore_train_state`` extend
the scheme to the FULL training state: params + AdamW moments + optimizer
step, plus a JSON sidecar (``extra.json``) for host-side state such as the
data-iterator cursor — the pieces ``Trainer.resume_from`` needs for a
bit-exact resume (tests/test_trainer_distributed.py).

Non-numpy dtypes (bfloat16, float8_*) are stored as their raw bit pattern
(an unsigned view) with the logical dtype recorded in the manifest, so
``np.save`` never sees an ml_dtypes scalar type.

Writes are ATOMIC at directory granularity: leaves land in a hidden
sibling temp dir, ``manifest.json`` is written last (it doubles as the
completeness sentinel), and the temp dir is ``os.replace``d into place.
A crash mid-save leaves either the previous complete checkpoint or a
hidden ``.*.tmp.*`` orphan — never a half-written ``step_N`` that
``latest_step`` / ``--resume=auto`` could pick up; ``latest_step``
additionally requires the sentinel, so even a pre-atomic partial dir is
skipped rather than crashing the resume.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree: Any, path=()):
    if isinstance(tree, dict):
        for k in sorted(tree):
            yield from _flatten(tree[k], path + (str(k),))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from _flatten(v, path + (str(i),))
    else:
        yield path, tree


def _unflatten_into(skeleton: Any, values: Dict[str, Any], path=()):
    if isinstance(skeleton, dict):
        return {k: _unflatten_into(v, values, path + (str(k),)) for k, v in skeleton.items()}
    if isinstance(skeleton, (list, tuple)):
        t = [ _unflatten_into(v, values, path + (str(i),)) for i, v in enumerate(skeleton) ]
        return type(skeleton)(t) if not hasattr(skeleton, "_fields") else type(skeleton)(*t)
    return values["/".join(path)]


def _is_native(dtype: np.dtype) -> bool:
    # ml_dtypes types (bfloat16, float8_*) report kind 'V' (void): np.save
    # would store them as raw void records that np.load can't retype.
    return dtype.kind in "biufc"


def save(ckpt_dir: str, tree: Any, step: int = 0, *,
         extra_files: Optional[Dict[str, Any]] = None) -> None:
    """Atomically write `tree` as a leaf-per-file checkpoint directory.

    Everything is staged in a hidden temp dir next to the target
    (``.{name}.tmp.{pid}`` — hidden so no directory listing pattern can
    mistake it for a checkpoint), ``manifest.json`` is written LAST as
    the completeness sentinel, and one ``os.replace`` publishes the
    whole thing.  ``extra_files`` maps extra JSON sidecar names (e.g.
    ``"extra.json"``) to serializable payloads that must land inside the
    same atomic unit — writing them after the rename would reopen the
    crash window the rename closed."""
    parent = os.path.dirname(os.path.abspath(ckpt_dir))
    os.makedirs(parent, exist_ok=True)
    tmp = os.path.join(
        parent, f".{os.path.basename(ckpt_dir)}.tmp.{os.getpid()}"
    )
    if os.path.isdir(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    manifest = {"step": step, "leaves": {}}
    for path, leaf in _flatten(tree):
        key = "/".join(path)
        arr = np.asarray(jax.device_get(leaf))
        meta = {
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
        }
        if not _is_native(arr.dtype):
            meta["bits"] = True  # stored as a raw uN bit-pattern view
            arr = arr.view(np.dtype(f"u{arr.dtype.itemsize}"))
        fname = key.replace("/", "__") + ".npy"
        np.save(os.path.join(tmp, fname), arr)
        meta["file"] = fname
        manifest["leaves"][key] = meta
    for name, payload in (extra_files or {}).items():
        with open(os.path.join(tmp, name), "w") as f:
            json.dump(payload, f)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    # os.replace only overwrites an existing EMPTY dir; drop a stale
    # complete checkpoint of the same name first (worst case after a
    # crash between these two lines: no step_N, previous steps intact)
    if os.path.isdir(ckpt_dir):
        shutil.rmtree(ckpt_dir)
    os.replace(tmp, ckpt_dir)


def restore(
    ckpt_dir: str,
    skeleton: Any,
    shardings: Optional[Any] = None,
) -> Any:
    with open(os.path.join(ckpt_dir, "manifest.json")) as f:
        manifest = json.load(f)
    values: Dict[str, Any] = {}
    shard_map = {}
    if shardings is not None:
        shard_map = {"/".join(p): s for p, s in _flatten(shardings)}
    for key, meta in manifest["leaves"].items():
        arr = np.load(os.path.join(ckpt_dir, meta["file"]))
        if meta.get("bits"):
            arr = arr.view(getattr(jnp, meta["dtype"]))
        sh = shard_map.get(key)
        values[key] = jax.device_put(arr, sh) if sh is not None else arr
    return _unflatten_into(skeleton, values)


# ------------------------------------------------------- full train state
def save_train_state(
    ckpt_dir: str, state: Any, step: int, *, extra: Optional[Dict] = None
) -> None:
    """Full-state checkpoint: params + AdamW moments + optimizer step in
    the leaf-per-file layout, with ``extra`` (JSON-serializable host state,
    e.g. the data-iterator cursor) riding alongside in ``extra.json`` —
    inside the same atomic rename as the tensors, so a resume can never
    see new params with a stale data cursor (or vice versa)."""
    save(
        ckpt_dir, {"params": state.params, "opt": state.opt}, step,
        extra_files=({"extra.json": extra} if extra is not None else None),
    )


def restore_train_state(
    ckpt_dir: str,
    abstract_state: Any,
    shardings: Optional[Any] = None,
) -> Tuple[Any, int, Dict]:
    """Restore a full TrainState; returns ``(state, step, extra)``.

    ``abstract_state`` comes from ``train_step.abstract_train_state(model)``;
    ``shardings`` (a TrainState of NamedShardings, e.g.
    ``train_step.state_shardings(model)``) makes the restore sharding-aware:
    every leaf is ``device_put`` against its target sharding, so a
    checkpoint written on one mesh shape restores onto another.
    """
    skel = {"params": abstract_state.params, "opt": abstract_state.opt}
    sh = None
    if shardings is not None:
        sh = {"params": shardings.params, "opt": shardings.opt}
    tree = restore(ckpt_dir, skel, sh)
    with open(os.path.join(ckpt_dir, "manifest.json")) as f:
        step = int(json.load(f)["step"])
    extra: Dict = {}
    ep = os.path.join(ckpt_dir, "extra.json")
    if os.path.exists(ep):
        with open(ep) as f:
            extra = json.load(f)
    from repro.training.train_step import TrainState  # lazy: no import cycle

    return TrainState(tree["params"], tree["opt"]), step, extra


def latest_step(ckpt_root: str) -> Optional[str]:
    """Newest COMPLETE checkpoint dir under `ckpt_root`, or None.

    Completeness = the ``manifest.json`` sentinel exists (it is written
    last inside the atomic temp dir).  Hidden ``.*.tmp.*`` orphans from
    a crashed save never match ``step_*``; a half-written legacy dir
    without the sentinel is skipped instead of crashing the resume."""
    if not os.path.isdir(ckpt_root):
        return None
    steps = [
        d for d in os.listdir(ckpt_root)
        if d.startswith("step_")
        and os.path.exists(os.path.join(ckpt_root, d, "manifest.json"))
    ]
    if not steps:
        return None
    return os.path.join(ckpt_root, max(steps, key=lambda s: int(s.split("_")[1])))
