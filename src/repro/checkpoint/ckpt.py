"""Sharded checkpointing (BioNeMo distributed-checkpoint analogue).

Each leaf is saved as its own ``.npy`` under a directory keyed by its tree
path; a ``manifest.json`` records the tree structure, shapes, dtypes and
the saving step.  On restore, leaves are loaded lazily and (optionally)
``device_put`` against target shardings — so a checkpoint written on one
mesh restores onto another (the resharding restore BioNeMo gets from
Megatron dist-ckpt).
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten(tree: Any, path=()):
    if isinstance(tree, dict):
        for k in sorted(tree):
            yield from _flatten(tree[k], path + (str(k),))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from _flatten(v, path + (str(i),))
    else:
        yield path, tree


def _unflatten_into(skeleton: Any, values: Dict[str, Any], path=()):
    if isinstance(skeleton, dict):
        return {k: _unflatten_into(v, values, path + (str(k),)) for k, v in skeleton.items()}
    if isinstance(skeleton, (list, tuple)):
        t = [ _unflatten_into(v, values, path + (str(i),)) for i, v in enumerate(skeleton) ]
        return type(skeleton)(t) if not hasattr(skeleton, "_fields") else type(skeleton)(*t)
    return values["/".join(path)]


def save(ckpt_dir: str, tree: Any, step: int = 0) -> None:
    os.makedirs(ckpt_dir, exist_ok=True)
    manifest = {"step": step, "leaves": {}}
    for path, leaf in _flatten(tree):
        key = "/".join(path)
        arr = np.asarray(jax.device_get(leaf))
        fname = key.replace("/", "__") + ".npy"
        np.save(os.path.join(ckpt_dir, fname), arr)
        manifest["leaves"][key] = {
            "file": fname,
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
        }
    with open(os.path.join(ckpt_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)


def restore(
    ckpt_dir: str,
    skeleton: Any,
    shardings: Optional[Any] = None,
) -> Any:
    with open(os.path.join(ckpt_dir, "manifest.json")) as f:
        manifest = json.load(f)
    values: Dict[str, Any] = {}
    shard_map = {}
    if shardings is not None:
        shard_map = {"/".join(p): s for p, s in _flatten(shardings)}
    for key, meta in manifest["leaves"].items():
        arr = np.load(os.path.join(ckpt_dir, meta["file"]))
        sh = shard_map.get(key)
        values[key] = jax.device_put(arr, sh) if sh is not None else arr
    return _unflatten_into(skeleton, values)


def latest_step(ckpt_root: str) -> Optional[str]:
    if not os.path.isdir(ckpt_root):
        return None
    steps = [d for d in os.listdir(ckpt_root) if d.startswith("step_")]
    if not steps:
        return None
    return os.path.join(ckpt_root, max(steps, key=lambda s: int(s.split("_")[1])))
