"""Model-zoo registry: one module per architecture (``--arch <id>``).

Every config cites its source in ``citation``.  ``get_config(name)`` returns
the full config; ``get_smoke_config(name)`` the reduced same-family variant
used by CPU smoke tests.

Serving the large end of the zoo needs the mesh: at serving precision no
single device holds the weights + KV pool of ``llama3-405b``,
``llama4-maverick-400b-a17b``, ``jamba-1.5-large-398b``,
``command-r-35b``, ``qwen1.5-32b``, ``internvl2-26b``, or (for big-batch
embedding extraction) ``esm2-3b``.  Tensor-parallel serving
(``launch/serve.py --mesh DxM``; ``serving/README.md`` §"Sharded
serving") shards their attention/FFN weights and paged KV pools over the
``model`` axis, which is what makes those ``--arch`` ids servable rather
than config-only entries.
"""
from __future__ import annotations

import importlib
from typing import Callable, Dict, List

from repro.core.config import ModelConfig, reduced

_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}

_MODULES = [
    "command_r_35b",
    "mamba2_2p7b",
    "qwen1p5_32b",
    "llama4_scout_17b_a16e",
    "whisper_medium",
    "internvl2_26b",
    "qwen2_7b",
    "llama3_405b",
    "llama4_maverick_400b_a17b",
    "jamba_1p5_large_398b",
    # paper's own bio recipes
    "esm2_650m",
    "esm2_3b",
    "geneformer_106m",
    "molmim_65m",
]


def register(fn: Callable[[], ModelConfig]) -> Callable[[], ModelConfig]:
    cfg = fn()
    _REGISTRY[cfg.name] = fn
    return fn


def _load_all() -> None:
    for m in _MODULES:
        importlib.import_module(f"repro.configs.{m}")


def list_archs() -> List[str]:
    _load_all()
    return sorted(_REGISTRY)


def get_config(name: str) -> ModelConfig:
    _load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def get_smoke_config(name: str) -> ModelConfig:
    return reduced(get_config(name))
