"""Command-R 35B [hf:CohereForAI/c4ai-command-r-v01].

Dense 40L, d_model 8192, 64 q-heads / 8 kv-heads (GQA), d_ff 22528,
vocab 256000.  Cohere specifics: parallel attention+FFN residual, LayerNorm
without bias, no QKV bias, tied embeddings."""
from repro.configs import register
from repro.core.config import ModelConfig


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="command-r-35b",
        family="dense",
        num_layers=40,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        head_dim=128,
        d_ff=22528,
        vocab_size=256000,
        act="swiglu",
        norm_type="layernorm_nobias",
        parallel_residual=True,
        tie_embeddings=True,
        rope_theta=8_000_000.0,
        citation="hf:CohereForAI/c4ai-command-r-v01",
    )
