"""ESM-2 3B — the BioNeMo paper's large protein-LM throughput config."""
from repro.configs import register
from repro.core.config import ModelConfig


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="esm2-3b",
        family="bio_bert",
        num_layers=36,
        d_model=2560,
        num_heads=40,
        num_kv_heads=40,
        head_dim=64,
        d_ff=10240,
        vocab_size=33,
        causal=False,
        objective="mlm",
        act="gelu",
        norm_type="layernorm",
        qkv_bias=True,
        attn_out_bias=True,
        mlp_bias=True,
        tie_embeddings=True,
        citation="BioNeMo / ESM-2 (Lin et al. 2022)",
    )
