"""ESM-2 650M — the BioNeMo paper's flagship protein-LM recipe.

BERT-style bidirectional encoder, MLM objective, 33L, d_model 1280,
20 heads, d_ff 5120, 33-token amino-acid vocab, RoPE."""
from repro.configs import register
from repro.core.config import ModelConfig


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="esm2-650m",
        family="bio_bert",
        num_layers=33,
        d_model=1280,
        num_heads=20,
        num_kv_heads=20,
        head_dim=64,
        d_ff=5120,
        vocab_size=33,
        causal=False,
        objective="mlm",
        act="gelu",
        norm_type="layernorm",
        qkv_bias=True,
        attn_out_bias=True,
        mlp_bias=True,
        tie_embeddings=True,
        citation="BioNeMo / ESM-2 (Lin et al. 2022)",
    )
