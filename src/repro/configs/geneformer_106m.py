"""Geneformer 106M — BioNeMo's single-cell foundation-model recipe.

BERT over rank-value-encoded gene tokens: 12L, d_model 768, 12 heads,
gene vocab ~25k, learned positions (rank encoding), MLM objective."""
from repro.configs import register
from repro.core.config import ModelConfig


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="geneformer-106m",
        family="bio_bert",
        num_layers=12,
        d_model=768,
        num_heads=12,
        num_kv_heads=12,
        head_dim=64,
        d_ff=3072,
        vocab_size=25426,
        causal=False,
        objective="mlm",
        act="gelu",
        norm_type="layernorm",
        qkv_bias=True,
        attn_out_bias=True,
        mlp_bias=True,
        use_rope=False,
        max_pos=4096,
        tie_embeddings=True,
        citation="BioNeMo / Geneformer (Theodoris et al. 2023)",
    )
