"""InternVL2-26B [arXiv:2404.16821] — VLM: InternViT + InternLM2 backbone.

The language backbone: 48L, d_model 6144, 48 q / 8 kv heads, d_ff 16384,
vocab 92553 (padded 92672).  The InternViT vision encoder + MLP projector
frontend is a STUB per the task carve-out: ``input_specs`` provides 256
precomputed patch embeddings per image, projected into the LM stream."""
from repro.configs import register
from repro.core.config import ModelConfig


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-26b",
        family="vlm",
        num_layers=48,
        d_model=6144,
        num_heads=48,
        num_kv_heads=8,
        head_dim=128,
        d_ff=16384,
        vocab_size=92553,
        frontend="vision_stub",
        num_frontend_tokens=256,
        act="swiglu",
        norm_type="rmsnorm",
        rope_theta=1_000_000.0,
        citation="arXiv:2404.16821",
    )
