"""Jamba-1.5-Large 398B [arXiv:2403.19887] — hybrid Mamba + attention + MoE.

72L, d_model 8192, 1 attention : 7 mamba interleave (9 groups of 8,
attention mid-group), 64 q / 8 kv heads, MoE 16 experts top-2 every other
layer with d_ff 24576, vocab 65536.  SSM blocks use the Mamba-2/SSD form
(DESIGN.md notes the Mamba-1→SSD substitution): d_inner 16384, headdim 64
(256 SSD heads), state 128."""
from repro.configs import register
from repro.core.config import ModelConfig


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="jamba-1.5-large-398b",
        family="hybrid",
        num_layers=72,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        head_dim=128,
        d_ff=24576,
        vocab_size=65536,
        num_experts=16,
        num_experts_per_tok=2,
        moe_layer_period=2,
        attn_layer_period=8,
        ssm_state=128,
        ssm_headdim=64,
        ssm_expand=2,
        ssm_chunk=128,
        ssm_ngroups=1,
        act="swiglu",
        norm_type="rmsnorm",
        citation="arXiv:2403.19887",
    )
