"""Llama-3 405B [arXiv:2407.21783].

Dense 126L, d_model 16384, 128 q / 8 kv heads (GQA), d_ff 53248,
vocab 128256 (128k).  The largest dense arch in the zoo — exercises
FSDP over (pod, data), vocab TP, and scan-over-layers lowering."""
from repro.configs import register
from repro.core.config import ModelConfig


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="llama3-405b",
        family="dense",
        num_layers=126,
        d_model=16384,
        num_heads=128,
        num_kv_heads=8,
        head_dim=128,
        d_ff=53248,
        vocab_size=128256,
        act="swiglu",
        norm_type="rmsnorm",
        rope_theta=500_000.0,
        citation="arXiv:2407.21783",
    )
