"""Llama-4 Scout 17B-active / 16 experts [hf:meta-llama/Llama-4-Scout-17B-16E].

MoE 48L, d_model 5120, 40 q / 8 kv heads, expert d_ff 8192, 16 experts
top-1 + 1 shared expert on every layer, vocab 202048.  Chunked attention
(modeled as sliding window 8192) → runs the long_500k decode shape."""
from repro.configs import register
from repro.core.config import ModelConfig


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="llama4-scout-17b-a16e",
        family="moe",
        num_layers=48,
        d_model=5120,
        num_heads=40,
        num_kv_heads=8,
        head_dim=128,
        d_ff=8192,
        vocab_size=202048,
        num_experts=16,
        num_experts_per_tok=1,
        moe_layer_period=1,
        n_shared_experts=1,
        act="swiglu",
        norm_type="rmsnorm",
        sliding_window=8192,
        rope_theta=500_000.0,
        citation="hf:meta-llama/Llama-4-Scout-17B-16E",
    )
