"""Mamba2-2.7B [arXiv:2405.21060] — SSD (state-space duality), attention-free.

64L, d_model 2560, d_inner 5120 (expand 2), 80 SSD heads (headdim 64),
state 128, vocab 50280 (padded to 50432 for 16-way vocab TP)."""
from repro.configs import register
from repro.core.config import ModelConfig


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-2.7b",
        family="ssm",
        num_layers=64,
        d_model=2560,
        num_heads=32,          # unused (attention-free); kept for config shape
        num_kv_heads=32,
        d_ff=0,                # no FFN: pure mamba blocks
        vocab_size=50280,
        ssm_state=128,
        ssm_headdim=64,
        ssm_expand=2,
        ssm_chunk=128,
        ssm_ngroups=1,
        norm_type="rmsnorm",
        tie_embeddings=True,
        citation="arXiv:2405.21060",
    )
