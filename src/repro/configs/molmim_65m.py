"""MolMIM-class 65M molecular seq2seq — BioNeMo's small-molecule recipe
(MegaMolBART/MolMIM lineage): 6+6 enc-dec, d_model 512, 8 heads,
d_ff 2048, 523-token SMILES vocab."""
from repro.configs import register
from repro.core.config import ModelConfig


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="molmim-65m",
        family="bio_encdec",
        num_layers=6,
        d_model=512,
        num_heads=8,
        num_kv_heads=8,
        head_dim=64,
        d_ff=2048,
        vocab_size=523,
        is_encoder_decoder=True,
        encoder_layers=6,
        objective="seq2seq",
        act="gelu",
        norm_type="layernorm",
        qkv_bias=True,
        attn_out_bias=True,
        mlp_bias=True,
        use_rope=True,
        citation="BioNeMo / MolMIM (Reidenbach et al. 2023)",
    )
