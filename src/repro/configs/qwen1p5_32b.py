"""Qwen1.5-32B [hf:Qwen/Qwen1.5-0.5B family].

Dense 64L, d_model 5120, 40 heads (GQA kv=40 — i.e. MHA), d_ff 27392,
vocab 152064, QKV bias.  40 heads % 16 != 0 → the framework auto-selects
context-parallel attention on the 16-way model axis."""
from repro.configs import register
from repro.core.config import ModelConfig


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-32b",
        family="dense",
        num_layers=64,
        d_model=5120,
        num_heads=40,
        num_kv_heads=40,
        head_dim=128,
        d_ff=27392,
        vocab_size=152064,
        qkv_bias=True,
        act="swiglu",
        norm_type="rmsnorm",
        rope_theta=1_000_000.0,
        citation="hf:Qwen/Qwen1.5-0.5B",
    )
