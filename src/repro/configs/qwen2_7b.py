"""Qwen2-7B [arXiv:2407.10671].

Dense 28L, d_model 3584, 28 q / 4 kv heads (GQA), d_ff 18944, vocab 152064,
QKV bias.  28 heads % 16 != 0 → context-parallel attention path."""
from repro.configs import register
from repro.core.config import ModelConfig


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-7b",
        family="dense",
        num_layers=28,
        d_model=3584,
        num_heads=28,
        num_kv_heads=4,
        head_dim=128,
        d_ff=18944,
        vocab_size=152064,
        qkv_bias=True,
        act="swiglu",
        norm_type="rmsnorm",
        rope_theta=1_000_000.0,
        citation="arXiv:2407.10671",
    )
