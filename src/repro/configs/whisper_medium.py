"""Whisper-medium [arXiv:2212.04356] — encoder-decoder, audio.

24+24L, d_model 1024, 16 heads (MHA), d_ff 4096, vocab 51865 (padded
51968).  The mel-spectrogram + conv frontend is a STUB per the task
carve-out: ``input_specs`` provides precomputed frame embeddings
(1500 frames × d_model).  LayerNorm + bias, GELU, learned positions."""
from repro.configs import register
from repro.core.config import ModelConfig


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-medium",
        family="audio",
        num_layers=24,
        d_model=1024,
        num_heads=16,
        num_kv_heads=16,
        head_dim=64,
        d_ff=4096,
        vocab_size=51865,
        is_encoder_decoder=True,
        encoder_layers=24,
        frontend="audio_stub",
        num_frontend_tokens=1500,
        norm_type="layernorm",
        act="gelu",
        qkv_bias=True,
        attn_out_bias=True,
        mlp_bias=True,
        use_rope=False,
        max_pos=32768,          # extended decoder positions for decode_32k
        objective="seq2seq",
        citation="arXiv:2212.04356",
    )
