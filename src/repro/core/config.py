"""Core configuration dataclasses for the repro framework.

BioNeMo-style modularity: every model in the zoo is a ``ModelConfig`` plus the
shared substrate.  Configs are plain frozen dataclasses so they hash, print,
and serialize cleanly; ``replace()`` (dataclasses.replace) is the sanctioned
way to derive variants (reduced smoke configs, sliding-window variants, ...).
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from typing import Any, Optional, Tuple


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


VOCAB_DIVISOR = 256  # Megatron make_vocab_size_divisible_by — faithful.


@dataclass(frozen=True)
class ModelConfig:
    """Architecture description.  One instance per model-zoo entry."""

    name: str
    family: str  # dense | ssm | moe | hybrid | audio | vlm | bio_bert | bio_encdec
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads

    # --- attention options ---
    qkv_bias: bool = False
    attn_out_bias: bool = False
    rope_theta: float = 10000.0
    use_rope: bool = True
    max_pos: int = 0                   # learned absolute positions (use_rope=False)
    sliding_window: int = 0            # 0 = full attention
    causal: bool = True
    attn_logit_softcap: float = 0.0

    # --- block options ---
    norm_type: str = "rmsnorm"         # rmsnorm | layernorm | layernorm_nobias
    act: str = "swiglu"                # swiglu | gelu | geglu | relu
    mlp_bias: bool = False
    parallel_residual: bool = False    # command-r style parallel attn+ffn
    tie_embeddings: bool = False
    logit_softcap: float = 0.0

    # --- MoE ---
    num_experts: int = 0
    num_experts_per_tok: int = 1
    moe_layer_period: int = 1          # apply MoE every k-th layer
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # entropy-deficit coefficient (log E − mean router entropy): pushes the
    # router toward exploration; 0 keeps the legacy loss exactly
    router_entropy_coef: float = 0.0

    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 64
    ssm_ngroups: int = 1
    ssm_conv: int = 4
    attn_layer_period: int = 0         # hybrid: 1 attention layer per k layers

    # --- encoder/decoder & modality frontends ---
    is_encoder_decoder: bool = False
    encoder_layers: int = 0
    frontend: str = ""                 # "" | audio_stub | vision_stub
    num_frontend_tokens: int = 0       # patch/frame tokens provided by the stub
    cross_attn_heads: int = 0          # 0 -> num_heads

    # --- objective (bio recipes) ---
    objective: str = "clm"             # clm | mlm | seq2seq
    mlm_mask_prob: float = 0.15

    # --- numerics / kernels ---
    dtype: str = "bfloat16"            # activation/compute dtype
    param_dtype: str = "float32"       # stored parameter dtype
    # hot-path kernel implementation for attention + fused cross-entropy:
    # auto (pallas on TPU, xla elsewhere) | pallas | xla | naive |
    # pallas_interpret (Pallas fwd+bwd kernels interpreted on any backend —
    # the CPU-verifiable training path).  See kernels/README.md.
    kernel_impl: str = "auto"
    citation: str = ""

    # ------------------------------------------------------------------ #
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def padded_vocab(self) -> int:
        return _round_up(self.vocab_size, VOCAB_DIVISOR)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_headdim

    def is_moe_layer(self, layer_idx: int) -> bool:
        if self.num_experts == 0:
            return False
        return (layer_idx % self.moe_layer_period) == (self.moe_layer_period - 1)

    def is_attn_layer(self, layer_idx: int) -> bool:
        """Hybrid (jamba) interleave: one attention layer per attn_layer_period."""
        if self.family == "ssm":
            return False
        if self.family != "hybrid":
            return True
        p = self.attn_layer_period
        return (layer_idx % p) == (p // 2)  # jamba places attn mid-group

    def param_count(self) -> int:
        """Analytic parameter count (embedding included once; MoE counts all experts)."""
        d, hd = self.d_model, self.resolved_head_dim
        nq, nkv = self.num_heads, self.num_kv_heads
        att = d * hd * (nq + 2 * nkv) + nq * hd * d
        if self.act in ("swiglu", "geglu"):
            mlp_dense = 3 * d * self.d_ff
        else:
            mlp_dense = 2 * d * self.d_ff
        total = 0
        for i in range(self.num_layers):
            is_attn = self.is_attn_layer(i)
            if is_attn:
                total += att
            else:  # mamba block
                di, ns = self.d_inner, self.ssm_state
                total += d * (2 * di + 2 * self.ssm_ngroups * ns + self.ssm_nheads)
                total += di * d  # out proj
                total += 3 * self.ssm_nheads  # A, D, dt_bias
            if self.is_moe_layer(i):
                total += (self.num_experts + self.n_shared_experts) * mlp_dense
                total += d * self.num_experts  # router
            elif self.d_ff > 0:
                total += mlp_dense
            total += 2 * d  # norms
        total += self.padded_vocab * d  # embedding
        if not self.tie_embeddings:
            total += self.padded_vocab * d
        if self.is_encoder_decoder:
            enc = self.encoder_layers * (att + mlp_dense + 2 * d)
            xattn = self.num_layers * (att + d)
            total += enc + xattn
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed experts)."""
        if self.num_experts == 0:
            return self.param_count()
        d = self.d_model
        if self.act in ("swiglu", "geglu"):
            mlp_dense = 3 * d * self.d_ff
        else:
            mlp_dense = 2 * d * self.d_ff
        inactive = 0
        for i in range(self.num_layers):
            if self.is_moe_layer(i):
                unused = self.num_experts - self.num_experts_per_tok
                inactive += unused * mlp_dense
        return self.param_count() - inactive

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), indent=2)


@dataclass(frozen=True)
class ParallelConfig:
    """How a model maps onto the mesh.

    attention_parallelism:
      * "head_tp"  — Megatron convention: q-heads sharded over `model`
                     (requires num_heads % tp == 0); KV replicated over
                     `model` when num_kv_heads % tp != 0.
      * "context"  — sequence dim sharded over `model`, GQA KV all-gathered
                     (Llama-3-style CP).  No head-divisibility constraint.
    """

    attention_parallelism: str = "head_tp"   # head_tp | context
    fsdp_axes: Tuple[str, ...] = ("data",)   # axes weights are FSDP-sharded over
    expert_axis: str = "model"
    remat_policy: str = "block"              # none | block | dots | full
    shard_cache_seq: bool = True             # decode: shard KV cache over seq
    scan_layers: bool = True
    optimizer_state_dtype: str = "float32"   # float32 | bfloat16
    donate_params: bool = True

    def validate(self, mc: ModelConfig, tp: int) -> "ParallelConfig":
        """Auto-downgrade head_tp -> context when heads don't divide tp."""
        if self.attention_parallelism == "head_tp" and mc.num_heads % tp != 0:
            return dataclasses.replace(self, attention_parallelism="context")
        return self


@dataclass(frozen=True)
class TrainConfig:
    global_batch: int = 256
    seq_len: int = 4096
    # microbatch gradient accumulation: each optimizer step scans
    # accum_steps microbatches of global_batch/accum_steps rows with fp32
    # grad accumulators; accum_steps=N is numerically equivalent to one
    # N×-larger batch (token-weighted — see training/train_step.py)
    accum_steps: int = 1
    learning_rate: float = 1e-3
    min_lr: float = 1e-5
    weight_decay: float = 0.01
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    warmup_steps: int = 100
    decay_steps: int = 1000
    total_steps: int = 1000
    schedule: str = "wsd"      # wsd | cosine | noam | const
    seed: int = 0
    log_every: int = 10
    ckpt_every: int = 0        # 0 = disabled
    ckpt_dir: str = ""
    # non-finite guard (training/loop.py): a step whose loss or global
    # grad-norm is non-finite applies NO update (params/opt state keep
    # their old values, opt.step does not advance) and is counted in the
    # metrics as a skip; this many CONSECUTIVE skips aborts the run with
    # the offending step number instead of silently training on garbage
    max_nonfinite_skips: int = 10


@dataclass(frozen=True)
class ServeConfig:
    max_seq_len: int = 32768
    batch_size: int = 128
    # default sampling knobs, mapped into a default SamplingParams by the
    # LLM facade (serving/api.py); individual requests override them with
    # their own per-request SamplingParams
    temperature: float = 0.0   # 0 = greedy
    top_k: int = 0             # 0 = disabled
    top_p: float = 1.0         # 1.0 = disabled
    seed: int = 0              # keys the counter-based sampling PRNG
    # KV-cache layout for the continuous-batching engine: "dense" per-slot
    # buffers, or "paged" block-table pages over a shared pool
    # (serving/paged_cache.py + kernels/paged_attention.py)
    cache_layout: str = "dense"
    page_size: int = 16        # tokens per page in the paged layout
    # paged-layout serving features (serving/README.md):
    #   prefix_cache — content-addressed sharing of full prompt blocks
    #   (refcounted pages, copy-on-write, LRU eviction of unreferenced
    #   cached pages); prefill skips hash-hit blocks entirely.
    #   prefill_chunk — bound each prefill step to N tokens, interleaved
    #   with decode iterations (0 = prefill the suffix in one chunk).
    prefix_cache: bool = False
    prefill_chunk: int = 0
    # fault tolerance (serving/README.md "Failure semantics"):
    #   max_queue — bounded admission queue; 0 = unbounded.  A full queue
    #   rejects at submit with the typed retriable EngineOverloaded
    #   instead of growing TTFT for everyone.
    #   preempt — under page pressure, evict the newest in-flight decode
    #   and replay it later (token-identical resume) instead of
    #   head-of-line blocking the queue.  Paged layout only.
    #   deadline_ms — default per-request wall-clock SLO from submit
    #   (None = no deadline); individual SamplingParams override it.
    max_queue: int = 0
    preempt: bool = False
    deadline_ms: Optional[float] = None


def reduced(mc: ModelConfig, **over: Any) -> ModelConfig:
    """Smoke-test variant of a config: <=2 layers, d_model<=256, <=4 experts.

    Keeps the *family wiring* (GQA ratios, MoE periods, hybrid interleave)
    so smoke tests exercise the same code paths as the full config.
    """
    d_model = min(mc.d_model, 256)
    nh = max(2, min(mc.num_heads, 4))
    nkv = max(1, min(mc.num_kv_heads, nh))
    while nh % nkv:
        nkv -= 1
    layers = min(mc.num_layers, 2)
    if mc.family == "hybrid":
        layers = mc.attn_layer_period  # one full interleave group
    kw = dict(
        num_layers=layers,
        d_model=d_model,
        num_heads=nh,
        num_kv_heads=nkv,
        head_dim=d_model // nh,
        d_ff=min(mc.d_ff, 512) if mc.d_ff else 0,
        vocab_size=min(mc.vocab_size, 512),
        num_experts=min(mc.num_experts, 4) if mc.num_experts else 0,
        encoder_layers=min(mc.encoder_layers, 2) if mc.encoder_layers else 0,
        num_frontend_tokens=min(mc.num_frontend_tokens, 16) if mc.num_frontend_tokens else 0,
        ssm_headdim=32 if mc.ssm_state else mc.ssm_headdim,
        ssm_state=min(mc.ssm_state, 16) if mc.ssm_state else 0,
        ssm_chunk=8 if mc.ssm_state else mc.ssm_chunk,
        sliding_window=min(mc.sliding_window, 64) if mc.sliding_window else 0,
        dtype="float32",
        param_dtype="float32",
    )
    kw.update(over)
    return dataclasses.replace(mc, **kw)
