"""Minimal parameter-definition system (no flax dependency).

A model is described as a nested dict of :class:`P` (param defs).  One walker
materializes parameters (with per-leaf PRNG folding), another produces the
matching ``PartitionSpec`` tree from logical axis names, so initialization and
sharding live in one place — the BioNeMo/Megatron "model-parallel aware init"
behavior.

Logical axis vocabulary (mapped to mesh axes by ``repro.parallel.sharding``):
  fsdp      — weight dim sharded over the FSDP axes (ZeRO-3 style)
  tp        — weight dim sharded over the `model` axis (tensor parallel)
  experts   — expert dim (maps to `model`: expert parallel)
  layers    — scan-stacked layer dim (never sharded)
  None      — replicated
"""
from __future__ import annotations

import math
import zlib
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp

Initializer = Callable[[jax.Array, Tuple[int, ...], Any], jax.Array]


def _normal(scale: float) -> Initializer:
    def init(key, shape, dtype):
        return (scale * jax.random.normal(key, shape, jnp.float32)).astype(dtype)

    return init


def _zeros(key, shape, dtype):
    return jnp.zeros(shape, dtype)


def _ones(key, shape, dtype):
    return jnp.ones(shape, dtype)


def fan_in_init(fan_in: int) -> Initializer:
    return _normal(1.0 / math.sqrt(max(fan_in, 1)))


@dataclass(frozen=True)
class P:
    """Single parameter definition."""

    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: Union[str, Initializer] = "fan_in"
    fan_in: int = 0        # for "fan_in" init; 0 -> infer from shape[-2] or shape[0]
    scale: float = 0.02    # for "normal" init
    dtype: Optional[str] = None

    def initializer(self) -> Initializer:
        if callable(self.init):
            return self.init
        if self.init == "zeros":
            return _zeros
        if self.init == "ones":
            return _ones
        if self.init == "normal":
            return _normal(self.scale)
        if self.init == "fan_in":
            fi = self.fan_in
            if fi == 0:
                fi = self.shape[-2] if len(self.shape) >= 2 else self.shape[0]
            return fan_in_init(fi)
        raise ValueError(f"unknown init {self.init!r}")


def stacked(p: P, n: int) -> P:
    """Prepend a scan `layers` dimension to a param def."""
    return P(
        shape=(n, *p.shape),
        axes=("layers", *p.axes),
        init=p.init,
        fan_in=p.fan_in or (p.shape[-2] if len(p.shape) >= 2 else p.shape[0]),
        scale=p.scale,
        dtype=p.dtype,
    )


def stack_tree(tree: Any, n: int) -> Any:
    return jax.tree.map(lambda p: stacked(p, n), tree, is_leaf=lambda x: isinstance(x, P))


def _walk(tree: Any, path: Tuple[str, ...] = ()):
    if isinstance(tree, P):
        yield path, tree
    elif isinstance(tree, dict):
        for k in sorted(tree):
            yield from _walk(tree[k], path + (k,))
    else:
        raise TypeError(f"bad node at {path}: {type(tree)}")


def materialize(defs: Any, key: jax.Array, param_dtype) -> Any:
    """Instantiate a P-tree into a parameter pytree (deterministic per path)."""

    def build(tree, path=()):
        if isinstance(tree, P):
            k = key
            for name in path:
                # zlib.crc32, NOT hash(): str hash is salted per process
                # (PYTHONHASHSEED), which would make the "same" seed yield
                # different weights in every subprocess / relaunch.
                k = jax.random.fold_in(k, zlib.crc32(name.encode()) % (2**31))
            dt = jnp.dtype(tree.dtype) if tree.dtype else param_dtype
            return tree.initializer()(k, tree.shape, dt)
        return {k: build(v, path + (k,)) for k, v in tree.items()}

    return build(defs)


def abstract(defs: Any, param_dtype) -> Any:
    """ShapeDtypeStruct pytree matching materialize() — for AOT lowering."""

    def build(tree):
        if isinstance(tree, P):
            dt = jnp.dtype(tree.dtype) if tree.dtype else param_dtype
            return jax.ShapeDtypeStruct(tree.shape, dt)
        return {k: build(v) for k, v in tree.items()}

    return build(defs)


def spec_tree(defs: Any, rules: Dict[str, Any]):
    """PartitionSpec pytree from logical axes via `rules` (see parallel.sharding)."""
    from jax.sharding import PartitionSpec

    def one(p: P):
        phys = []
        for ax in p.axes:
            m = rules.get(ax) if ax is not None else None
            phys.append(m)
        # trim trailing Nones for tidier specs
        while phys and phys[-1] is None:
            phys.pop()
        return PartitionSpec(*phys)

    return jax.tree.map(one, defs, is_leaf=lambda x: isinstance(x, P))


def count_params(params: Any) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


def param_bytes(params: Any) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(params))
