"""Mixed-precision policy (BioNeMo/Megatron convention).

Parameters are stored in ``param_dtype`` (fp32 master by default), compute
runs in ``compute_dtype`` (bf16), and reductions/losses in fp32.  The policy
is a tiny pure object; models call ``policy.cast_compute`` on params entering
a matmul and ``policy.cast_output`` on residual-stream outputs.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp

_DTYPES = {
    "float32": jnp.float32,
    "bfloat16": jnp.bfloat16,
    "float16": jnp.float16,
}


def dtype_of(name: str):
    return _DTYPES[name]


@dataclass(frozen=True)
class Policy:
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    reduce_dtype: str = "float32"

    @property
    def pdt(self):
        return _DTYPES[self.param_dtype]

    @property
    def cdt(self):
        return _DTYPES[self.compute_dtype]

    @property
    def rdt(self):
        return _DTYPES[self.reduce_dtype]

    def cast_compute(self, tree):
        import jax

        return jax.tree.map(
            lambda x: x.astype(self.cdt) if jnp.issubdtype(x.dtype, jnp.floating) else x,
            tree,
        )

    def cast_reduce(self, x):
        return x.astype(self.rdt)


def compute_view(policy: Policy, params):
    """Compute-dtype view of the master params (Megatron bf16 recipe).

    The trainer keeps the fp32 master copy in ``TrainState`` (the optimizer
    updates it in full precision) and casts the whole tree to the compute
    dtype ONCE per step before the forward pass; ``jax.grad`` through the
    cast accumulates gradients back in the master dtype.  No-op when the
    two dtypes coincide (CPU fp32 unit tests), so numerics are unchanged
    off the mixed-precision path.
    """
    if policy.pdt == policy.cdt:
        return params
    return policy.cast_compute(params)


def policy_for(model_cfg) -> Policy:
    return Policy(param_dtype=model_cfg.param_dtype, compute_dtype=model_cfg.dtype)
