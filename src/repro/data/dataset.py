"""Memory-mapped token datasets + synthetic corpora (BioNeMo data substrate).

``MemmapTokenDataset`` mirrors BioNeMo/Megatron's indexed binary datasets:
a flat ``.bin`` of token ids plus an ``.idx`` of (offset, length) records —
random access to any sequence without loading the corpus.

``SyntheticProteinCorpus`` / ``SyntheticSmilesCorpus`` generate structured
random sequences (with motif repetition so small models have learnable
signal) and can write themselves into memmap format.
"""
from __future__ import annotations

import os
from typing import Iterator, List, Optional, Sequence, Tuple

import numpy as np

from repro.data.tokenizer import ProteinTokenizer, SmilesTokenizer


class MemmapTokenDataset:
    """Flat token store with an index; O(1) random sequence access."""

    MAGIC = 0x42494F4E  # "BION"

    def __init__(self, prefix: str):
        self.prefix = prefix
        idx = np.fromfile(prefix + ".idx", dtype=np.int64)
        assert idx[0] == self.MAGIC, "bad index file"
        n = int(idx[1])
        self.offsets = idx[2 : 2 + n + 1]
        self.tokens = np.memmap(prefix + ".bin", dtype=np.int32, mode="r")

    def __len__(self) -> int:
        return len(self.offsets) - 1

    def __getitem__(self, i: int) -> np.ndarray:
        a, b = int(self.offsets[i]), int(self.offsets[i + 1])
        return np.asarray(self.tokens[a:b])

    def lengths(self) -> np.ndarray:
        """Per-sequence token counts from the index alone — no token
        bytes touched (size-aware batching wants all lengths up front)."""
        return np.diff(self.offsets).astype(np.int64)

    @classmethod
    def write(cls, prefix: str, sequences: Sequence[np.ndarray]) -> "MemmapTokenDataset":
        os.makedirs(os.path.dirname(prefix) or ".", exist_ok=True)
        offsets = [0]
        with open(prefix + ".bin", "wb") as f:
            for s in sequences:
                np.asarray(s, np.int32).tofile(f)
                offsets.append(offsets[-1] + len(s))
        hdr = np.array([cls.MAGIC, len(sequences)] + offsets, dtype=np.int64)
        hdr.tofile(prefix + ".idx")
        return cls(prefix)


def synthetic_protein_sequences(
    n: int, min_len: int = 40, max_len: int = 200, seed: int = 0, n_motifs: int = 32
) -> List[str]:
    """Random AA sequences built from a shared motif library (learnable)."""
    rng = np.random.default_rng(seed)
    aas = ProteinTokenizer.AAS[:20]
    motifs = [
        "".join(rng.choice(list(aas), size=rng.integers(4, 9))) for _ in range(n_motifs)
    ]
    seqs = []
    for _ in range(n):
        L = int(rng.integers(min_len, max_len))
        parts = []
        while sum(map(len, parts)) < L:
            parts.append(motifs[int(rng.integers(n_motifs))])
        seqs.append("".join(parts)[:L])
    return seqs


def synthetic_smiles_sequences(n: int, seed: int = 0) -> List[str]:
    rng = np.random.default_rng(seed)
    frags = ["C", "CC", "C(=O)O", "c1ccccc1", "N", "O", "CN", "C(N)=O", "S", "F"]
    out = []
    for _ in range(n):
        k = int(rng.integers(2, 8))
        out.append("".join(rng.choice(frags) for _ in range(k)))
    return out


def build_synthetic_protein_memmap(
    prefix: str, n: int = 2000, seed: int = 0
) -> Tuple[MemmapTokenDataset, ProteinTokenizer]:
    tok = ProteinTokenizer()
    seqs = synthetic_protein_sequences(n, seed=seed)
    enc = [np.asarray(tok.encode(s), np.int32) for s in seqs]
    return MemmapTokenDataset.write(prefix, enc), tok


def build_synthetic_protein_store(
    root: str, n: int = 2000, seed: int = 0, shard_tokens: int = 1 << 16
):
    """Sharded-store twin of :func:`build_synthetic_protein_memmap` —
    identical sequences for a given (n, seed), stored across shards."""
    from repro.data.store import ShardedTokenStore

    tok = ProteinTokenizer()
    seqs = synthetic_protein_sequences(n, seed=seed)
    enc = [np.asarray(tok.encode(s), np.int32) for s in seqs]
    return ShardedTokenStore.write(root, enc, shard_tokens=shard_tokens), tok
