"""Batch pipelines: MLM (ESM-2/Geneformer recipe) and CLM packing.

Pure numpy on the host (BioNeMo uses CPU dataloader workers); outputs are
ready-to-``device_put`` dicts matching ``Model.loss_fn`` batch contracts.
"""
from __future__ import annotations

from typing import Dict, Iterator, Optional

import numpy as np

from repro.data.dataset import MemmapTokenDataset
from repro.data.sampler import ClusterSampler
from repro.data.tokenizer import _CharTokenizer


def mlm_corrupt(
    tokens: np.ndarray,       # (B, S) int32, padded
    tokenizer: _CharTokenizer,
    rng: np.random.Generator,
    mask_prob: float = 0.15,
) -> Dict[str, np.ndarray]:
    """BERT/ESM-2 corruption: of selected positions 80% <mask>, 10% random,
    10% kept; loss only on selected positions."""
    B, S = tokens.shape
    special = tokens < 5
    pick = (rng.random((B, S)) < mask_prob) & ~special
    # guarantee >=1 target per row (avoids empty-loss rows)
    none = ~pick.any(axis=1)
    if none.any():
        first_real = np.argmax(~special, axis=1)
        pick[np.where(none)[0], first_real[none]] = ~special[np.where(none)[0], first_real[none]]
    r = rng.random((B, S))
    corrupted = tokens.copy()
    corrupted[pick & (r < 0.8)] = tokenizer.mask_id
    rand_ids = rng.integers(5, tokenizer.vocab_size, size=(B, S))
    sel_rand = pick & (r >= 0.8) & (r < 0.9)
    corrupted[sel_rand] = rand_ids[sel_rand]
    return {
        "tokens": corrupted.astype(np.int32),
        "targets": tokens.astype(np.int32),
        "loss_mask": pick.astype(np.float32),
    }


class MLMBatches:
    """ESM-2-style stream: cluster-sample -> pad -> corrupt."""

    def __init__(
        self,
        ds: MemmapTokenDataset,
        tokenizer: _CharTokenizer,
        sampler: Optional[ClusterSampler],
        batch: int,
        seq_len: int,
        mask_prob: float = 0.15,
        seed: int = 0,
    ):
        self.ds, self.tok, self.sampler = ds, tokenizer, sampler
        self.batch, self.seq_len, self.mask_prob = batch, seq_len, mask_prob
        self.rng = np.random.default_rng(seed)

    def state_dict(self) -> Dict:
        """Resumable cursor (JSON-serializable): the numpy Generator state
        (+ sampler state).  Checkpointed by the Trainer so a resumed run
        draws the exact batch sequence the interrupted run would have."""
        st: Dict = {"rng": self.rng.bit_generator.state}
        if self.sampler is not None:
            st["sampler"] = self.sampler.state_dict()
        return st

    def load_state_dict(self, st: Dict) -> None:
        self.rng.bit_generator.state = st["rng"]
        if self.sampler is not None and "sampler" in st:
            self.sampler.load_state_dict(st["sampler"])

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        L = self.seq_len
        while True:
            if self.sampler is not None:
                idx = self.sampler.sample(self.batch)
            else:
                idx = self.rng.integers(0, len(self.ds), size=self.batch)
            # host hot path: one concatenate + one masked scatter instead of
            # a per-row Python assignment loop
            seqs = [self.ds[int(i)][:L] for i in idx]
            lens = np.fromiter((len(s) for s in seqs), np.int64, count=len(seqs))
            toks = np.zeros((self.batch, L), np.int32)
            toks[np.arange(L)[None, :] < lens[:, None]] = np.concatenate(seqs)
            yield mlm_corrupt(toks, self.tok, self.rng, self.mask_prob)


class CLMBatches:
    """Packed causal-LM stream (documents concatenated to fixed windows)."""

    def __init__(
        self, ds: MemmapTokenDataset, batch: int, seq_len: int, seed: int = 0
    ):
        self.ds, self.batch, self.seq_len = ds, batch, seq_len
        self.rng = np.random.default_rng(seed)
        self._buf = np.empty((0,), np.int32)

    def state_dict(self) -> Dict:
        """Resumable cursor: Generator state + the packing carry buffer."""
        return {
            "rng": self.rng.bit_generator.state,
            "buf": np.asarray(self._buf, np.int32).tolist(),
        }

    def load_state_dict(self, st: Dict) -> None:
        self.rng.bit_generator.state = st["rng"]
        self._buf = np.asarray(st["buf"], np.int32)

    def _fill(self, need: int):
        chunks = [self._buf]
        have = len(self._buf)
        while have < need:
            s = self.ds[int(self.rng.integers(len(self.ds)))]
            chunks.append(s)
            have += len(s)
        self._buf = np.concatenate(chunks)

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        need = self.batch * self.seq_len
        while True:
            self._fill(need)
            flat = self._buf[:need]
            self._buf = self._buf[need:]
            yield {"tokens": flat.reshape(self.batch, self.seq_len).astype(np.int32)}
