"""Batch pipelines: MLM (ESM-2/Geneformer recipe) and CLM packing.

Pure numpy on the host (BioNeMo uses CPU dataloader workers); outputs are
ready-to-``device_put`` dicts matching ``Model.loss_fn`` batch contracts.
"""
from __future__ import annotations

from typing import Dict, Iterator, Optional

import numpy as np

from repro.data.dataset import MemmapTokenDataset
from repro.data.sampler import ClusterSampler
from repro.data.tokenizer import _CharTokenizer


def mlm_corrupt(
    tokens: np.ndarray,       # (B, S) int32, padded
    tokenizer: _CharTokenizer,
    rng: np.random.Generator,
    mask_prob: float = 0.15,
) -> Dict[str, np.ndarray]:
    """BERT/ESM-2 corruption: of selected positions 80% <mask>, 10% random,
    10% kept; loss only on selected positions."""
    B, S = tokens.shape
    special = tokens < 5
    pick = (rng.random((B, S)) < mask_prob) & ~special
    # guarantee >=1 target per row (avoids empty-loss rows)
    none = ~pick.any(axis=1)
    if none.any():
        first_real = np.argmax(~special, axis=1)
        pick[np.where(none)[0], first_real[none]] = ~special[np.where(none)[0], first_real[none]]
    r = rng.random((B, S))
    corrupted = tokens.copy()
    corrupted[pick & (r < 0.8)] = tokenizer.mask_id
    rand_ids = rng.integers(5, tokenizer.vocab_size, size=(B, S))
    sel_rand = pick & (r >= 0.8) & (r < 0.9)
    corrupted[sel_rand] = rand_ids[sel_rand]
    return {
        "tokens": corrupted.astype(np.int32),
        "targets": tokens.astype(np.int32),
        "loss_mask": pick.astype(np.float32),
    }


class MLMBatches:
    """ESM-2-style stream: cluster-sample -> pad -> corrupt.

    ``sampler`` may be a plain index sampler (``ClusterSampler`` — fixed
    ``(batch, seq_len)`` shapes) or a batch sampler exposing
    ``sample_batch() -> (indices, padded_len)`` (``SizeAwareSampler`` —
    variable rows, bucketed lengths, token budget respected).  Duck-typed
    on ``sample_batch`` so the two compose without a flag.
    """

    def __init__(
        self,
        ds: MemmapTokenDataset,
        tokenizer: _CharTokenizer,
        sampler: Optional[ClusterSampler],
        batch: int,
        seq_len: int,
        mask_prob: float = 0.15,
        seed: int = 0,
    ):
        self.ds, self.tok, self.sampler = ds, tokenizer, sampler
        self.batch, self.seq_len, self.mask_prob = batch, seq_len, mask_prob
        self.rng = np.random.default_rng(seed)

    def state_dict(self) -> Dict:
        """Resumable cursor (JSON-serializable): the numpy Generator state
        (+ sampler state).  Checkpointed by the Trainer so a resumed run
        draws the exact batch sequence the interrupted run would have."""
        st: Dict = {"rng": self.rng.bit_generator.state}
        if self.sampler is not None:
            st["sampler"] = self.sampler.state_dict()
        return st

    def load_state_dict(self, st: Dict) -> None:
        self.rng.bit_generator.state = st["rng"]
        if self.sampler is not None and "sampler" in st:
            self.sampler.load_state_dict(st["sampler"])

    def _pad(self, idx: np.ndarray, L: int) -> np.ndarray:
        # host hot path: one concatenate + one masked scatter instead of
        # a per-row Python assignment loop
        seqs = [self.ds[int(i)][:L] for i in idx]
        lens = np.fromiter((len(s) for s in seqs), np.int64, count=len(seqs))
        toks = np.zeros((len(seqs), L), np.int32)
        toks[np.arange(L)[None, :] < lens[:, None]] = np.concatenate(seqs)
        return toks

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        if self.sampler is not None and hasattr(self.sampler, "sample_batch"):
            # bucketed path: the sampler owns batch size AND padded length
            while True:
                idx, L = self.sampler.sample_batch()
                toks = self._pad(idx, min(int(L), self.seq_len))
                yield mlm_corrupt(toks, self.tok, self.rng, self.mask_prob)
        L = self.seq_len
        while True:
            if self.sampler is not None:
                idx = self.sampler.sample(self.batch)
            else:
                idx = self.rng.integers(0, len(self.ds), size=self.batch)
            yield mlm_corrupt(self._pad(idx, L), self.tok, self.rng,
                              self.mask_prob)


class CLMBatches:
    """Packed causal-LM stream (documents concatenated to fixed windows).

    ``eos_id`` (when set) is inserted between packed documents so the
    causal model sees an explicit document boundary instead of silently
    attending across unrelated sequences.  ``sampler`` (duck-typed on
    ``sample_batch``, e.g. ``SizeAwareSampler``) switches to a bucketed
    per-document mode: variable-row batches padded to the bucket length,
    with a ``loss_mask`` zeroing the padding.
    """

    def __init__(
        self, ds: MemmapTokenDataset, batch: int, seq_len: int, seed: int = 0,
        eos_id: Optional[int] = None, sampler=None,
    ):
        self.ds, self.batch, self.seq_len = ds, batch, seq_len
        self.eos_id = eos_id
        self.sampler = sampler
        self.rng = np.random.default_rng(seed)
        self._buf = np.empty((0,), np.int32)

    def state_dict(self) -> Dict:
        """Resumable cursor: Generator state + the packing carry buffer."""
        st: Dict = {
            "rng": self.rng.bit_generator.state,
            "buf": np.asarray(self._buf, np.int32).tolist(),
        }
        if self.sampler is not None:
            st["sampler"] = self.sampler.state_dict()
        return st

    def load_state_dict(self, st: Dict) -> None:
        self.rng.bit_generator.state = st["rng"]
        self._buf = np.asarray(st["buf"], np.int32)
        if self.sampler is not None and "sampler" in st:
            self.sampler.load_state_dict(st["sampler"])

    def _fill(self, need: int):
        # the RNG stream is untouched by the separator, so cursors taken
        # with and without eos_id replay identically-ordered documents
        chunks = [self._buf]
        have = len(self._buf)
        sep = (
            None if self.eos_id is None
            else np.asarray([self.eos_id], np.int32)
        )
        while have < need:
            s = self.ds[int(self.rng.integers(len(self.ds)))]
            chunks.append(s)
            have += len(s)
            if sep is not None:
                chunks.append(sep)
                have += 1
        self._buf = np.concatenate(chunks)

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        if self.sampler is not None and hasattr(self.sampler, "sample_batch"):
            # bucketed per-document mode: no packing, loss on real tokens
            while True:
                idx, L = self.sampler.sample_batch()
                L = min(int(L), self.seq_len)
                seqs = [self.ds[int(i)][:L] for i in idx]
                lens = np.fromiter(
                    (len(s) for s in seqs), np.int64, count=len(seqs)
                )
                real = np.arange(L)[None, :] < lens[:, None]
                toks = np.zeros((len(seqs), L), np.int32)
                toks[real] = np.concatenate(seqs)
                yield {
                    "tokens": toks,
                    "loss_mask": real.astype(np.float32),
                }
        need = self.batch * self.seq_len
        while True:
            self._fill(need)
            flat = self._buf[:need]
            self._buf = self._buf[need:]
            yield {"tokens": flat.reshape(self.batch, self.seq_len).astype(np.int32)}
