"""Background batch producer: overlap host-side tokenize/corrupt with the
device step.

The host pipelines (``MLMBatches``/``CLMBatches``) are pure numpy — at
scale their sampling + padding + corruption cost sits squarely in the
device step's shadow *if* someone computes batch N+1 while step N runs.
The trainer's ``_DevicePrefetch`` already overlaps the host->device
*transfer*; :class:`BackgroundProducer` moves the batch *construction*
itself onto a worker thread behind a bounded queue (numpy releases the
GIL in the hot concatenate/corrupt ops, so the overlap is real).

Contracts:

* **Deterministic ordering** — ONE worker thread drains ``iter(pipeline)``
  sequentially; the consumer sees exactly the batch sequence the bare
  pipeline would have produced.
* **Resumable cursor** — the worker snapshots ``pipeline.state_dict()``
  after each draw and the snapshot rides the queue with its batch;
  ``state_dict()`` returns the cursor of the last CONSUMED batch (plus
  the consumed count), so a checkpoint never leaks prefetch depth: a
  restore replays from the first unconsumed batch, bit-exact — the same
  per-consumed-batch discipline as ``_DevicePrefetch``.
* **Clean shutdown** — ``close()`` (or the context manager) stops the
  worker promptly even when it is blocked on the bounded queue; worker
  exceptions re-raise in the consumer, not silently in a thread.
"""
from __future__ import annotations

import queue
import threading
from typing import Any, Dict, Iterator, Optional

_STOP_POLL_S = 0.1


class BackgroundProducer:
    """Threaded prefetch in front of a host batch pipeline.

    ``depth`` bounds the queue: the worker stays at most ``depth``
    batches ahead, so memory is bounded and the cursor gap stays small.
    Call ``load_state_dict`` BEFORE iteration begins (the worker starts
    lazily on first ``__next__``).
    """

    def __init__(self, pipeline, *, depth: int = 4):
        if depth < 1:
            raise ValueError(f"depth must be >= 1 (got {depth})")
        self.pipeline = pipeline
        self.depth = int(depth)
        self._q: queue.Queue = queue.Queue(maxsize=self.depth)
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self.consumed = 0
        self._cursor = self._snapshot()   # pipeline state before any draw
        self._closed = False
        self._ended = False

    def _snapshot(self):
        sd = getattr(self.pipeline, "state_dict", None)
        return sd() if callable(sd) else None

    # ------------------------------------------------------------- cursor
    def state_dict(self) -> Dict:
        """Cursor of the last consumed batch: restoring it replays the
        stream from the first batch this consumer has NOT seen, even
        though the worker has drawn ``depth`` batches further ahead."""
        return {"consumed": self.consumed, "pipeline": self._cursor}

    def load_state_dict(self, st: Dict) -> None:
        if self._thread is not None:
            raise RuntimeError(
                "load_state_dict after iteration started — the worker has "
                "already advanced the pipeline past the cursor"
            )
        self.consumed = int(st.get("consumed", 0))
        cur = st.get("pipeline")
        if cur is not None:
            if not hasattr(self.pipeline, "load_state_dict"):
                raise ValueError(
                    "cursor carries pipeline state but the wrapped "
                    "pipeline has no load_state_dict"
                )
            self.pipeline.load_state_dict(cur)
            self._cursor = cur

    # ------------------------------------------------------------- worker
    def _work(self) -> None:
        try:
            it = iter(self.pipeline)
            while not self._stop.is_set():
                try:
                    b = next(it)
                except StopIteration:
                    self._put(("end", None, None))
                    return
                cur = self._snapshot()
                if not self._put(("batch", b, cur)):
                    return      # stopped while blocked on a full queue
        except BaseException as e:  # noqa: BLE001 — re-raised in consumer
            self._put(("error", e, None))

    def _put(self, item) -> bool:
        """Bounded put that stays responsive to ``close()``."""
        while not self._stop.is_set():
            try:
                self._q.put(item, timeout=_STOP_POLL_S)
                return True
            except queue.Full:
                continue
        return False

    def _ensure_started(self) -> None:
        if self._closed:
            raise RuntimeError("producer is closed")
        if self._thread is None:
            self._thread = threading.Thread(
                target=self._work, name="batch-producer", daemon=True
            )
            self._thread.start()

    # ----------------------------------------------------------- consumer
    def __iter__(self) -> Iterator[Any]:
        return self

    def __next__(self) -> Any:
        if self._ended:
            raise StopIteration
        self._ensure_started()
        while True:
            try:
                kind, payload, cur = self._q.get(timeout=_STOP_POLL_S)
                break
            except queue.Empty:
                if not self._thread.is_alive() and self._q.empty():
                    raise RuntimeError(
                        "producer worker died without a terminal item"
                    ) from None
        if kind == "end":
            self._ended = True
            raise StopIteration
        if kind == "error":
            raise payload
        if cur is not None:
            self._cursor = cur
        self.consumed += 1
        return payload

    # ----------------------------------------------------------- shutdown
    def close(self) -> None:
        """Stop the worker and drop buffered batches.  Idempotent."""
        self._closed = True
        self._stop.set()
        t = self._thread
        if t is not None:
            # drain so a worker blocked on put() can observe the stop
            try:
                while True:
                    self._q.get_nowait()
            except queue.Empty:
                pass
            t.join(timeout=5.0)
            self._thread = None

    def __enter__(self) -> "BackgroundProducer":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()
