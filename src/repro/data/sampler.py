"""UniRef50/90-style cluster sampling (ESM-2 recipe, BioNeMo substrate).

ESM-2 training samples a UniRef50 *cluster* uniformly, then a UniRef90
*member* of that cluster uniformly — down-weighting over-represented
families.  ``ClusterSampler`` reproduces that two-level scheme over any
membership table and is validated statistically in tests (per-cluster hit
rates ~ uniform regardless of cluster size).
"""
from __future__ import annotations

from typing import Dict, Iterator, List, Sequence

import numpy as np


class ClusterSampler:
    def __init__(self, cluster_members: Sequence[Sequence[int]], seed: int = 0):
        """cluster_members[c] = dataset indices belonging to cluster c."""
        self.members = [np.asarray(m, np.int64) for m in cluster_members]
        assert all(len(m) > 0 for m in self.members), "empty cluster"
        self.rng = np.random.default_rng(seed)
        # flat member table for vectorized sampling: cluster c occupies
        # _flat[_off[c] : _off[c] + _sizes[c]]
        self._sizes = np.asarray([len(m) for m in self.members], np.int64)
        self._off = np.concatenate([[0], np.cumsum(self._sizes[:-1])])
        self._flat = np.concatenate(self.members)

    def state_dict(self) -> Dict:
        """Resumable cursor (JSON-serializable Generator state)."""
        return {"rng": self.rng.bit_generator.state}

    def load_state_dict(self, st: Dict) -> None:
        self.rng.bit_generator.state = st["rng"]

    def sample(self, n: int) -> np.ndarray:
        cl = self.rng.integers(0, len(self.members), size=n)
        # broadcast high array consumes the Generator's bit stream
        # identically to the former per-item scalar calls, so draws are
        # preserved for any fixed seed (regression-tested)
        k = self.rng.integers(0, self._sizes[cl])
        return self._flat[self._off[cl] + k]

    def __iter__(self) -> Iterator[int]:
        while True:
            yield int(self.sample(1)[0])


def greedy_length_clusters(lengths: Sequence[int], n_clusters: int) -> List[List[int]]:
    """Toy clustering by length bucket — stands in for MMseqs2 clustering
    when building synthetic corpora."""
    order = np.argsort(lengths)
    buckets: List[List[int]] = [[] for _ in range(n_clusters)]
    for rank, idx in enumerate(order):
        buckets[rank % n_clusters].append(int(idx))
    return buckets
