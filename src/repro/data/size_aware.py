"""Token-budget batching (BioNeMo ``size-aware-batching`` design).

Fixed-batch padding on a length-skewed protein corpus wastes most of the
token budget: every 40-residue peptide in a batch padded to ``seq_len``
pays for ``seq_len`` tokens of compute.  ``SizeAwareSampler`` replaces
the fixed batch size with a **token budget**: sequences are bucketed by
length, and a batch is emitted per bucket with as many rows as fit under
``max_tokens_per_batch`` at that bucket's padded length — short
sequences travel in wide batches, long ones in narrow batches, and the
padded-token count of every batch stays under the budget.

Determinism + resume contract (PR 5 cursor protocol):

* the draw stream is a deterministic function of the base sampler state
  (a composed :class:`~repro.data.sampler.ClusterSampler`, or this
  sampler's own ``numpy`` Generator);
* draws accumulate into per-bucket pending lists; a bucket reaching its
  row capacity emits a batch — pure bookkeeping over the draw stream;
* ``state_dict`` captures the RNG/base-sampler state plus the pending
  and ready queues, so ``load_state_dict`` resumes the exact batch
  sequence mid-epoch, bit-for-bit (property-tested).

Shape discipline: every batch is padded to its bucket's upper bound, so
a corpus yields at most ``len(boundaries)`` distinct ``(rows, len)``
shapes — the trainer compiles once per shape, never per step.
"""
from __future__ import annotations

import collections
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np


def length_buckets(max_len: int, *, min_len: int = 16,
                   growth: float = 1.3) -> np.ndarray:
    """Geometric bucket upper bounds ``[min_len, ..., max_len]``.

    Consecutive bounds grow by ``growth``, which caps per-row padding
    waste inside a bucket at roughly ``1 - 1/growth`` (~23% at the
    default) — the price of a small, bounded set of batch shapes.
    """
    if not (max_len >= min_len >= 1):
        raise ValueError(f"need max_len >= min_len >= 1, got "
                         f"({max_len}, {min_len})")
    if growth <= 1.0:
        raise ValueError(f"growth must be > 1 (got {growth})")
    bounds = [min_len]
    while bounds[-1] < max_len:
        bounds.append(min(int(np.ceil(bounds[-1] * growth)), max_len))
    return np.asarray(bounds, np.int64)


class SizeAwareSampler:
    """Variable-size, budget-bounded batch sampler over known lengths.

    Parameters
    ----------
    lengths: per-sequence token counts (clip to the pipeline's
        ``seq_len`` BEFORE constructing — the sampler buckets on the
        length that will actually be materialized).
    max_tokens: padded-token budget per batch; every emitted batch
        satisfies ``rows * padded_len <= max_tokens``.
    base: optional composed index sampler (e.g. ``ClusterSampler``) —
        when set, IT owns the draw stream and this sampler only buckets;
        when ``None``, indices draw uniformly from this sampler's seed.
    boundaries: explicit bucket upper bounds (default: geometric via
        :func:`length_buckets` up to ``max(lengths)``).
    round_to: row capacities round DOWN to a multiple of this (min one
        multiple) — set to the mesh's data-axis size so sharded
        placement always divides.
    """

    def __init__(self, lengths: Sequence[int], max_tokens: int, *,
                 base=None, boundaries: Optional[Sequence[int]] = None,
                 seed: int = 0, min_len: int = 16, growth: float = 1.3,
                 round_to: int = 1, draw_chunk: int = 64):
        self.lengths = np.asarray(lengths, np.int64)
        if len(self.lengths) == 0:
            raise ValueError("empty corpus")
        self.max_tokens = int(max_tokens)
        self.base = base
        self.rng = np.random.default_rng(seed)
        self.round_to = max(int(round_to), 1)
        self.draw_chunk = max(int(draw_chunk), 1)
        lmax = int(self.lengths.max())
        if boundaries is None:
            self.boundaries = length_buckets(
                lmax, min_len=min(min_len, lmax), growth=growth
            )
        else:
            self.boundaries = np.asarray(sorted(boundaries), np.int64)
            if lmax > self.boundaries[-1]:
                raise ValueError(
                    f"longest sequence ({lmax}) exceeds the top bucket "
                    f"boundary ({self.boundaries[-1]})"
                )
        # capacity = rows under budget at the bucket's padded length,
        # rounded to round_to; a budget smaller than one (rounded) row of
        # the top bucket can never emit a legal batch — reject up front
        caps = self.max_tokens // self.boundaries
        caps = (caps // self.round_to) * self.round_to
        if (caps < 1).any():
            b = int(self.boundaries[(caps < 1).argmax()])
            raise ValueError(
                f"max_tokens={self.max_tokens} cannot fit "
                f"{self.round_to} row(s) of bucket len {b}"
            )
        self.capacity = caps.astype(np.int64)
        # bucket id per sequence: first boundary >= length
        self.bucket_of = np.searchsorted(
            self.boundaries, self.lengths, side="left"
        ).astype(np.int64)
        self._pending: List[List[int]] = [
            [] for _ in range(len(self.boundaries))
        ]
        self._ready: collections.deque = collections.deque()

    # -------------------------------------------------------------- cursor
    def state_dict(self) -> Dict:
        """JSON-serializable cursor: draw-stream state + the exact
        bookkeeping queues.  Restoring reproduces the future batch
        sequence bit-for-bit."""
        st: Dict = {
            "pending": [list(map(int, p)) for p in self._pending],
            "ready": [
                (list(map(int, idx)), int(L)) for idx, L in self._ready
            ],
        }
        if self.base is not None:
            st["base"] = self.base.state_dict()
        else:
            st["rng"] = self.rng.bit_generator.state
        return st

    def load_state_dict(self, st: Dict) -> None:
        self._pending = [list(p) for p in st["pending"]]
        if len(self._pending) != len(self.boundaries):
            raise ValueError(
                f"cursor has {len(self._pending)} buckets, sampler has "
                f"{len(self.boundaries)} — bucket config changed?"
            )
        self._ready = collections.deque(
            (np.asarray(idx, np.int64), int(L)) for idx, L in st["ready"]
        )
        if self.base is not None:
            self.base.load_state_dict(st["base"])
        else:
            self.rng.bit_generator.state = st["rng"]

    # ------------------------------------------------------------ sampling
    def _draw(self, n: int) -> np.ndarray:
        if self.base is not None:
            return np.asarray(self.base.sample(n), np.int64)
        return self.rng.integers(0, len(self.lengths), size=n)

    def sample_batch(self) -> Tuple[np.ndarray, int]:
        """Next ``(indices, padded_len)`` batch under the token budget.

        Draws are consumed in chunks but processed strictly in order, so
        the emitted batch sequence is a pure function of the cursor.
        """
        while not self._ready:
            for i in self._draw(self.draw_chunk):
                b = int(self.bucket_of[i])
                pend = self._pending[b]
                pend.append(int(i))
                if len(pend) == int(self.capacity[b]):
                    self._ready.append(
                        (np.asarray(pend, np.int64),
                         int(self.boundaries[b]))
                    )
                    self._pending[b] = []
        return self._ready.popleft()

    def __iter__(self) -> Iterator[Tuple[np.ndarray, int]]:
        while True:
            yield self.sample_batch()
