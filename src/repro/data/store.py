"""Sharded memmap token store (BioNeMo SCDL / Megatron indexed-dataset
analogue, grown past the single-file ``MemmapTokenDataset``).

Layout on disk — one directory per store:

    store/
      manifest.json          # committed LAST, os.replace-atomic
      shard_00000.bin        # flat little-endian token ids (dtype below)
      shard_00000.idx.npy    # int64 offsets, len = n_seqs + 1
      shard_00001.bin
      ...

``manifest.json`` schema (version 1)::

    {"version": 1, "dtype": "int32",
     "total_sequences": N, "total_tokens": T,
     "shards": [{"bin": "shard_00000.bin", "index": "shard_00000.idx.npy",
                 "sequences": n0, "tokens": t0}, ...]}

Design points, mirroring the rest of the repo:

* **Zero-copy reads** — every shard's ``.bin`` is an ``np.memmap``;
  ``__getitem__`` returns a view into the mapping, never a copy of the
  corpus.  Shards are mapped lazily on first touch, so opening a
  thousand-shard store costs one JSON parse.
* **Atomic commit** — the writer stages shard files first and writes the
  manifest via tmp + ``os.replace`` LAST (the ``checkpoint/ckpt.py``
  discipline): a crash mid-write leaves either a readable previous store
  or no manifest at all, never a manifest pointing at truncated shards.
* **Global index** — sequence ``i`` resolves to ``(shard, local)``
  through a cumulative-count ``searchsorted``; O(log shards) per access
  with no per-sequence table.
* **Worker sharding** — ``reader(worker=w, num_workers=W)`` iterates the
  shards assigned round-robin to worker ``w`` with a resumable
  ``state_dict`` cursor (assigned-shard position + local index), so a
  multi-process loader never has two workers touching the same shard.
"""
from __future__ import annotations

import json
import os
from typing import Dict, Iterator, List, Optional, Sequence

import numpy as np

MANIFEST = "manifest.json"
STORE_VERSION = 1


def _shard_names(i: int) -> tuple:
    return f"shard_{i:05d}.bin", f"shard_{i:05d}.idx.npy"


class ShardedStoreWriter:
    """Streaming writer: ``add()`` sequences, shards flush at a token
    threshold, ``finalize()`` commits the manifest atomically.

    Usable as a context manager; exiting without an exception finalizes::

        with ShardedStoreWriter(root, shard_tokens=1 << 20) as w:
            for seq in corpus:
                w.add(seq)
    """

    def __init__(self, root: str, *, shard_tokens: int = 1 << 22,
                 dtype: str = "int32"):
        if shard_tokens < 1:
            raise ValueError(f"shard_tokens must be >= 1 (got {shard_tokens})")
        self.root = root
        self.shard_tokens = int(shard_tokens)
        self.dtype = np.dtype(dtype)
        os.makedirs(root, exist_ok=True)
        self.shards: List[Dict] = []
        self._buf: List[np.ndarray] = []     # pending sequences
        self._buf_tokens = 0
        self.total_sequences = 0
        self.total_tokens = 0
        self._finalized = False

    def add(self, seq: Sequence[int]) -> int:
        """Append one sequence; returns its global index.  The current
        shard flushes once it holds >= ``shard_tokens`` tokens."""
        if self._finalized:
            raise RuntimeError("writer already finalized")
        a = np.ascontiguousarray(np.asarray(seq, self.dtype))
        if a.ndim != 1 or len(a) == 0:
            raise ValueError(f"sequences must be non-empty 1-D (got {a.shape})")
        i = self.total_sequences
        self._buf.append(a)
        self._buf_tokens += len(a)
        self.total_sequences += 1
        self.total_tokens += len(a)
        if self._buf_tokens >= self.shard_tokens:
            self._flush_shard()
        return i

    def _flush_shard(self) -> None:
        if not self._buf:
            return
        bin_name, idx_name = _shard_names(len(self.shards))
        offsets = np.zeros((len(self._buf) + 1,), np.int64)
        with open(os.path.join(self.root, bin_name), "wb") as f:
            for j, s in enumerate(self._buf):
                s.tofile(f)
                offsets[j + 1] = offsets[j] + len(s)
        np.save(os.path.join(self.root, idx_name), offsets)
        self.shards.append({
            "bin": bin_name, "index": idx_name,
            "sequences": len(self._buf), "tokens": int(offsets[-1]),
        })
        self._buf = []
        self._buf_tokens = 0

    def finalize(self) -> "ShardedTokenStore":
        """Flush the tail shard and commit the manifest (tmp +
        ``os.replace`` — the store becomes visible atomically)."""
        if self._finalized:
            raise RuntimeError("writer already finalized")
        self._flush_shard()
        if not self.shards:
            raise ValueError("cannot finalize an empty store")
        manifest = {
            "version": STORE_VERSION,
            "dtype": self.dtype.name,
            "total_sequences": self.total_sequences,
            "total_tokens": self.total_tokens,
            "shards": self.shards,
        }
        path = os.path.join(self.root, MANIFEST)
        tmp = f"{path}.tmp.{os.getpid()}"
        with open(tmp, "w") as f:
            json.dump(manifest, f, indent=1)
            f.write("\n")
        os.replace(tmp, path)
        self._finalized = True
        return ShardedTokenStore(self.root)

    def __enter__(self) -> "ShardedStoreWriter":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if exc_type is None and not self._finalized:
            self.finalize()


class ShardedTokenStore:
    """Multi-shard memmap token store; O(1) zero-copy random access.

    Duck-types the ``MemmapTokenDataset`` surface the pipelines consume
    (``__len__`` / ``__getitem__`` / ``lengths()``), so every existing
    batcher — ``MLMBatches``, ``CLMBatches``, ``SizeAwareSampler`` —
    feeds from it unchanged.
    """

    def __init__(self, root: str):
        self.root = root
        path = os.path.join(root, MANIFEST)
        with open(path) as f:
            m = json.load(f)
        if m.get("version") != STORE_VERSION:
            raise ValueError(
                f"{path}: unsupported store version {m.get('version')!r} "
                f"(want {STORE_VERSION})"
            )
        self.manifest = m
        self.dtype = np.dtype(m["dtype"])
        self.shards = m["shards"]
        counts = np.asarray([s["sequences"] for s in self.shards], np.int64)
        # cum_seqs[k] = first global index of shard k
        self.cum_seqs = np.concatenate([[0], np.cumsum(counts)])
        self.total_tokens = int(m["total_tokens"])
        # lazy per-shard mappings: opening the store must not mmap every
        # shard up front
        self._tokens: List[Optional[np.memmap]] = [None] * len(self.shards)
        self._offsets: List[Optional[np.ndarray]] = [None] * len(self.shards)

    # ------------------------------------------------------------- access
    def __len__(self) -> int:
        return int(self.cum_seqs[-1])

    @property
    def num_shards(self) -> int:
        return len(self.shards)

    def _shard_tokens(self, k: int) -> np.memmap:
        t = self._tokens[k]
        if t is None:
            t = np.memmap(
                os.path.join(self.root, self.shards[k]["bin"]),
                dtype=self.dtype, mode="r",
            )
            self._tokens[k] = t
        return t

    def _shard_offsets(self, k: int) -> np.ndarray:
        o = self._offsets[k]
        if o is None:
            o = np.load(os.path.join(self.root, self.shards[k]["index"]))
            self._offsets[k] = o
        return o

    def locate(self, i: int) -> tuple:
        """Global index -> (shard, local) via the cumulative count table."""
        n = len(self)
        if not 0 <= i < n:
            raise IndexError(f"sequence {i} out of range [0, {n})")
        k = int(np.searchsorted(self.cum_seqs, i, side="right")) - 1
        return k, i - int(self.cum_seqs[k])

    def __getitem__(self, i: int) -> np.ndarray:
        k, j = self.locate(int(i))
        off = self._shard_offsets(k)
        a, b = int(off[j]), int(off[j + 1])
        # a slice of a memmap is a view into the mapping — zero-copy
        return np.asarray(self._shard_tokens(k)[a:b])

    def lengths(self) -> np.ndarray:
        """Per-sequence token counts for ALL sequences, derived from the
        shard offset tables alone — no token bytes are touched (the
        size-aware sampler wants every length up front)."""
        return np.concatenate([
            np.diff(self._shard_offsets(k)) for k in range(self.num_shards)
        ]).astype(np.int64)

    # ------------------------------------------------------------ readers
    def shard_assignment(self, worker: int, num_workers: int) -> List[int]:
        """Round-robin shard ownership for multi-process loading: worker
        ``w`` of ``W`` owns shards ``w, w+W, w+2W, ...`` — disjoint by
        construction, and adding workers never reorders a worker's own
        shard sequence."""
        if not 0 <= worker < num_workers:
            raise ValueError(f"worker {worker} not in [0, {num_workers})")
        return list(range(worker, self.num_shards, num_workers))

    def reader(self, *, worker: int = 0, num_workers: int = 1
               ) -> "ShardReader":
        return ShardReader(self, self.shard_assignment(worker, num_workers))

    # ------------------------------------------------------------ writing
    @classmethod
    def write(cls, root: str, sequences: Sequence[np.ndarray], *,
              shard_tokens: int = 1 << 22, dtype: str = "int32"
              ) -> "ShardedTokenStore":
        with ShardedStoreWriter(root, shard_tokens=shard_tokens,
                                dtype=dtype) as w:
            for s in sequences:
                w.add(s)
        return cls(root)


class ShardReader:
    """Sequential reader over an assigned shard list with a resumable
    cursor (PR 5 ``state_dict``/``load_state_dict`` protocol).

    Iterates each assigned shard in order, each sequence in shard order —
    one epoch, then ``StopIteration``.  The cursor is the pair
    ``(assigned-shard position, local sequence index)``: restoring it
    mid-epoch replays the exact remaining sequence stream bit-for-bit.
    """

    def __init__(self, store: ShardedTokenStore, shard_ids: List[int]):
        self.store = store
        self.shard_ids = list(shard_ids)
        self._pos = 0       # position in the assigned shard list
        self._local = 0     # next sequence within the current shard

    def state_dict(self) -> Dict:
        return {"pos": self._pos, "local": self._local}

    def load_state_dict(self, st: Dict) -> None:
        self._pos = int(st["pos"])
        self._local = int(st["local"])

    def __len__(self) -> int:
        return sum(
            self.store.shards[k]["sequences"] for k in self.shard_ids
        )

    def __iter__(self) -> Iterator[np.ndarray]:
        return self

    def __next__(self) -> np.ndarray:
        while self._pos < len(self.shard_ids):
            k = self.shard_ids[self._pos]
            if self._local < self.store.shards[k]["sequences"]:
                g = int(self.store.cum_seqs[k]) + self._local
                self._local += 1
                return self.store[g]
            self._pos += 1
            self._local = 0
        raise StopIteration
