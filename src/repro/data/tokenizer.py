"""Tokenizers for the bio recipes (BioNeMo substrate).

* ProteinTokenizer — ESM-2 amino-acid vocabulary (33 tokens: 20 canonical
  AAs + ambiguity codes + specials), character-level.
* SmilesTokenizer — regex-free character tokenizer over the SMILES alphabet
  (a practical stand-in for BioNeMo's 523-token RegEx tokenizer).
* ByteTokenizer — generic fallback for synthetic corpora.
"""
from __future__ import annotations

from typing import Dict, List, Sequence

import numpy as np

_SPECIALS = ["<pad>", "<cls>", "<eos>", "<unk>", "<mask>"]


class _CharTokenizer:
    def __init__(self, alphabet: Sequence[str]):
        self.vocab: List[str] = list(_SPECIALS) + list(alphabet)
        self.tok2id: Dict[str, int] = {t: i for i, t in enumerate(self.vocab)}
        self.pad_id = 0
        self.cls_id = 1
        self.eos_id = 2
        self.unk_id = 3
        self.mask_id = 4

    @property
    def vocab_size(self) -> int:
        return len(self.vocab)

    def encode(self, text: str, add_special: bool = True) -> List[int]:
        ids = [self.tok2id.get(c, self.unk_id) for c in text]
        if add_special:
            ids = [self.cls_id] + ids + [self.eos_id]
        return ids

    def decode(self, ids: Sequence[int]) -> str:
        return "".join(self.vocab[i] for i in ids if i >= len(_SPECIALS))

    def encode_batch(self, texts: Sequence[str], max_len: int) -> np.ndarray:
        out = np.full((len(texts), max_len), self.pad_id, np.int32)
        for r, t in enumerate(texts):
            ids = self.encode(t)[:max_len]
            out[r, : len(ids)] = ids
        return out


class ProteinTokenizer(_CharTokenizer):
    """ESM-2 amino-acid alphabet."""

    AAS = "LAGVSERTIDPKQNFYMHWCXBUZO"

    def __init__(self):
        super().__init__(self.AAS)


class SmilesTokenizer(_CharTokenizer):
    ALPHABET = list("CNOPSFIHBcnops()[]=#+-\\/@.123456789%lr")

    def __init__(self):
        super().__init__(self.ALPHABET)


class ByteTokenizer(_CharTokenizer):
    def __init__(self):
        super().__init__([chr(i) for i in range(32, 127)])
