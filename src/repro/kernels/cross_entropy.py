"""Pallas TPU fused vocab-softmax cross-entropy (forward + backward).

For 128k–256k vocabularies the (tokens × vocab) logits tensor is the single
largest training activation (llama3-405b train_4k: 1M × 128k fp32 = 0.5 TB
globally).  This kernel fuses the output projection with an online
log-sum-exp so full logits never reach HBM:

  grid (token_blocks, vocab_blocks) — vocab innermost; per step:
    logits_blk = h_blk @ W_blk            (bt × bv on the MXU)
    online max / sumexp update            (VMEM scratch, fp32)
    gather target logit if it falls in this vocab block
  final step emits per-token  loss = lse - logit[target].

VMEM per step: bt·D + D·bv + bt·bv fp32 ≈ (128·4096 + 4096·512 + 128·512)·4
≈ 10.5 MB at D=4096 — tiles shrink automatically for larger D.

Backward: the O(T) residual is the per-token LSE; block logits are
recomputed on the MXU and the softmax gradient

  dlogits = (g_loss + g_lse)·softmax − g_loss·onehot(target)

is contracted immediately, so the (tokens × vocab) gradient never
materializes alongside full logits.  Two kernels (TPU grids revisit an
output block only along the innermost dim, so each contraction gets the
loop order that makes its accumulator VMEM-resident):

  * ``_ce_dh_kernel``  — grid (token_blocks, vocab_blocks): dH += dlogits Wᵀ
  * ``_ce_dw_kernel``  — grid (vocab_blocks, token_blocks): dW += Hᵀ dlogits

The custom-VJP dispatch wiring lives in ``ops.py``; the jnp blockwise
implementation there remains the CPU/fallback training path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels.tiling import pad_dim, pick_block

NEG_INF = -1e30


def _ce_kernel(
    h_ref, w_ref, tgt_ref,
    loss_ref, lse_ref,
    m_scr, l_scr, t_scr,
    *,
    block_t: int,
    block_v: int,
    v_steps: int,
    vocab: int,
):
    vi = pl.program_id(1)

    @pl.when(vi == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        t_scr[...] = jnp.full_like(t_scr, NEG_INF)

    h = h_ref[...].astype(jnp.float32)              # (bt, D)
    w = w_ref[...].astype(jnp.float32)              # (D, bv)
    logits = jax.lax.dot_general(
        h, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                                # (bt, bv)
    # mask vocab padding (last block may cover padded ids)
    col = vi * block_v + jax.lax.broadcasted_iota(jnp.int32, (block_t, block_v), 1)
    logits = jnp.where(col < vocab, logits, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(logits, axis=-1, keepdims=True))
    p = jnp.exp(logits - m_new)
    l_scr[...] = jnp.exp(m_prev - m_new) * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
    m_scr[...] = m_new

    tgt = tgt_ref[...]                               # (bt,)
    hit = col == tgt[:, None]
    t_here = jnp.max(jnp.where(hit, logits, NEG_INF), axis=-1, keepdims=True)
    t_scr[...] = jnp.maximum(t_scr[...], t_here)

    @pl.when(vi == v_steps - 1)
    def _final():
        lse = m_scr[...] + jnp.log(jnp.maximum(l_scr[...], 1e-30))
        loss_ref[...] = (lse - t_scr[...])[:, 0]
        lse_ref[...] = lse[:, 0]


def fused_cross_entropy(
    hidden: jax.Array,     # (T, D)
    w_out: jax.Array,      # (D, Vpad)
    targets: jax.Array,    # (T,) int32
    *,
    vocab: int = 0,        # true vocab (<= Vpad); 0 -> Vpad
    block_t: int = 128,
    block_v: int = 512,
    interpret: bool = False,
):
    T, D = hidden.shape
    Vp = w_out.shape[1]
    vocab = vocab or Vp
    # non-multiple dims: zero-pad token rows (outputs sliced below) and
    # vocab columns (masked in-kernel via col < vocab)
    block_t, Tp = pick_block(T, block_t)
    block_v, Vpp = pick_block(Vp, block_v)
    v_steps = Vpp // block_v
    hidden_p = pad_dim(hidden, 0, Tp)
    w_p = pad_dim(w_out, 1, Vpp)
    tgt_p = pad_dim(targets, 0, Tp)
    kernel = functools.partial(
        _ce_kernel,
        block_t=block_t,
        block_v=block_v,
        v_steps=v_steps,
        vocab=vocab,
    )
    loss, lse = pl.pallas_call(
        kernel,
        grid=(Tp // block_t, v_steps),
        in_specs=[
            pl.BlockSpec((block_t, D), lambda ti, vi: (ti, 0)),
            pl.BlockSpec((D, block_v), lambda ti, vi: (0, vi)),
            pl.BlockSpec((block_t,), lambda ti, vi: (ti,)),
        ],
        out_specs=[
            pl.BlockSpec((block_t,), lambda ti, vi: (ti,)),
            pl.BlockSpec((block_t,), lambda ti, vi: (ti,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Tp,), jnp.float32),
            jax.ShapeDtypeStruct((Tp,), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_t, 1), jnp.float32),
            pltpu.VMEM((block_t, 1), jnp.float32),
            pltpu.VMEM((block_t, 1), jnp.float32),
        ],
        interpret=interpret,
    )(hidden_p, w_p, tgt_p)
    return loss[:T], lse[:T]


# --------------------------------------------------------------------- #
# backward
# --------------------------------------------------------------------- #
def _block_dlogits(h, w, tgt, lse, gl, glse, vi, *, block_t, block_v, vocab):
    """Recompute one (bt, bv) logits block from the saved LSE and form the
    fused softmax gradient  (g_loss + g_lse)·p − g_loss·onehot  (fp32)."""
    logits = jax.lax.dot_general(
        h, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    col = vi * block_v + jax.lax.broadcasted_iota(jnp.int32, (block_t, block_v), 1)
    valid = col < vocab
    # exponent clamped at 0 (p <= 1 mathematically) so padded token rows —
    # whose lse slot is zero-padded but whose g_loss/g_lse are zero — stay
    # finite instead of overflowing
    p = jnp.where(
        valid, jnp.exp(jnp.minimum(jnp.where(valid, logits, 0.0) - lse, 0.0)), 0.0
    )
    onehot = jnp.where(valid & (col == tgt[:, None]), 1.0, 0.0)
    return (gl + glse) * p - gl * onehot


def _ce_dh_kernel(
    h_ref, w_ref, tgt_ref, lse_ref, gl_ref, glse_ref,
    dh_ref,
    acc_scr,
    *,
    block_t: int,
    block_v: int,
    v_steps: int,
    vocab: int,
):
    vi = pl.program_id(1)

    @pl.when(vi == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    h = h_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    dlogits = _block_dlogits(
        h, w, tgt_ref[...], lse_ref[...][:, None],
        gl_ref[...][:, None], glse_ref[...][:, None], vi,
        block_t=block_t, block_v=block_v, vocab=vocab,
    )
    acc_scr[...] += jax.lax.dot_general(
        dlogits, w, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                                # (bt, D)

    @pl.when(vi == v_steps - 1)
    def _final():
        dh_ref[...] = acc_scr[...].astype(dh_ref.dtype)


def _ce_dw_kernel(
    h_ref, w_ref, tgt_ref, lse_ref, gl_ref, glse_ref,
    dw_ref,
    acc_scr,
    *,
    block_t: int,
    block_v: int,
    t_steps: int,
    vocab: int,
):
    vi = pl.program_id(0)
    ti = pl.program_id(1)

    @pl.when(ti == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    h = h_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    dlogits = _block_dlogits(
        h, w, tgt_ref[...], lse_ref[...][:, None],
        gl_ref[...][:, None], glse_ref[...][:, None], vi,
        block_t=block_t, block_v=block_v, vocab=vocab,
    )
    acc_scr[...] += jax.lax.dot_general(
        h, dlogits, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                                # (D, bv)

    @pl.when(ti == t_steps - 1)
    def _final():
        dw_ref[...] = acc_scr[...].astype(dw_ref.dtype)


def fused_cross_entropy_bwd(
    hidden: jax.Array,     # (T, D)
    w_out: jax.Array,      # (D, Vpad)
    targets: jax.Array,    # (T,) int32
    lse: jax.Array,        # (T,) fp32 forward residual
    g_loss: jax.Array,     # (T,) cotangent of per-token loss
    g_lse: jax.Array,      # (T,) cotangent of the lse output
    *,
    vocab: int = 0,
    block_t: int = 128,
    block_v: int = 512,
    interpret: bool = False,
):
    """Returns (dh (T, D), dw (D, Vpad)) in the input dtypes."""
    T, D = hidden.shape
    Vp = w_out.shape[1]
    vocab = vocab or Vp
    block_t, Tp = pick_block(T, block_t)
    block_v, Vpp = pick_block(Vp, block_v)
    t_steps = Tp // block_t
    v_steps = Vpp // block_v
    # padded token rows carry zero loss/lse cotangents -> zero dlogits;
    # padded vocab columns are masked via col < vocab
    hidden = pad_dim(hidden, 0, Tp)
    w_pad = pad_dim(w_out, 1, Vpp)
    targets = pad_dim(targets, 0, Tp)
    lse = pad_dim(lse, 0, Tp)
    gl = pad_dim(g_loss.astype(jnp.float32), 0, Tp)
    glse = pad_dim(g_lse.astype(jnp.float32), 0, Tp)

    dh_kernel = functools.partial(
        _ce_dh_kernel,
        block_t=block_t, block_v=block_v, v_steps=v_steps, vocab=vocab,
    )
    dh = pl.pallas_call(
        dh_kernel,
        grid=(t_steps, v_steps),
        in_specs=[
            pl.BlockSpec((block_t, D), lambda ti, vi: (ti, 0)),
            pl.BlockSpec((D, block_v), lambda ti, vi: (0, vi)),
            pl.BlockSpec((block_t,), lambda ti, vi: (ti,)),
            pl.BlockSpec((block_t,), lambda ti, vi: (ti,)),
            pl.BlockSpec((block_t,), lambda ti, vi: (ti,)),
            pl.BlockSpec((block_t,), lambda ti, vi: (ti,)),
        ],
        out_specs=pl.BlockSpec((block_t, D), lambda ti, vi: (ti, 0)),
        out_shape=jax.ShapeDtypeStruct((Tp, D), hidden.dtype),
        scratch_shapes=[pltpu.VMEM((block_t, D), jnp.float32)],
        interpret=interpret,
    )(hidden, w_pad, targets, lse, gl, glse)

    dw_kernel = functools.partial(
        _ce_dw_kernel,
        block_t=block_t, block_v=block_v, t_steps=t_steps, vocab=vocab,
    )
    dw = pl.pallas_call(
        dw_kernel,
        grid=(v_steps, t_steps),
        in_specs=[
            pl.BlockSpec((block_t, D), lambda vi, ti: (ti, 0)),
            pl.BlockSpec((D, block_v), lambda vi, ti: (0, vi)),
            pl.BlockSpec((block_t,), lambda vi, ti: (ti,)),
            pl.BlockSpec((block_t,), lambda vi, ti: (ti,)),
            pl.BlockSpec((block_t,), lambda vi, ti: (ti,)),
            pl.BlockSpec((block_t,), lambda vi, ti: (ti,)),
        ],
        out_specs=pl.BlockSpec((D, block_v), lambda vi, ti: (0, vi)),
        out_shape=jax.ShapeDtypeStruct((D, Vpp), w_out.dtype),
        scratch_shapes=[pltpu.VMEM((D, block_v), jnp.float32)],
        interpret=interpret,
    )(hidden, w_pad, targets, lse, gl, glse)
    return dh[:T], dw[:, :Vp]
