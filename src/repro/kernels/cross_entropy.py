"""Pallas TPU fused vocab-softmax cross-entropy.

For 128k–256k vocabularies the (tokens × vocab) logits tensor is the single
largest training activation (llama3-405b train_4k: 1M × 128k fp32 = 0.5 TB
globally).  This kernel fuses the output projection with an online
log-sum-exp so full logits never reach HBM:

  grid (token_blocks, vocab_blocks) — vocab innermost; per step:
    logits_blk = h_blk @ W_blk            (bt × bv on the MXU)
    online max / sumexp update            (VMEM scratch, fp32)
    gather target logit if it falls in this vocab block
  final step emits per-token  loss = lse - logit[target].

VMEM per step: bt·D + D·bv + bt·bv fp32 ≈ (128·4096 + 4096·512 + 128·512)·4
≈ 10.5 MB at D=4096 — tiles shrink automatically for larger D.

The training path uses the jnp blockwise implementation in ``ops.py``
(autodiff-able); this kernel is the TPU serving/eval path and the subject of
the allclose sweep vs ``ref.cross_entropy_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

NEG_INF = -1e30


def _ce_kernel(
    h_ref, w_ref, tgt_ref,
    loss_ref, lse_ref,
    m_scr, l_scr, t_scr,
    *,
    block_t: int,
    block_v: int,
    v_steps: int,
    vocab: int,
):
    vi = pl.program_id(1)

    @pl.when(vi == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        t_scr[...] = jnp.full_like(t_scr, NEG_INF)

    h = h_ref[...].astype(jnp.float32)              # (bt, D)
    w = w_ref[...].astype(jnp.float32)              # (D, bv)
    logits = jax.lax.dot_general(
        h, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                                # (bt, bv)
    # mask vocab padding (last block may cover padded ids)
    col = vi * block_v + jax.lax.broadcasted_iota(jnp.int32, (block_t, block_v), 1)
    logits = jnp.where(col < vocab, logits, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(logits, axis=-1, keepdims=True))
    p = jnp.exp(logits - m_new)
    l_scr[...] = jnp.exp(m_prev - m_new) * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
    m_scr[...] = m_new

    tgt = tgt_ref[...]                               # (bt,)
    hit = col == tgt[:, None]
    t_here = jnp.max(jnp.where(hit, logits, NEG_INF), axis=-1, keepdims=True)
    t_scr[...] = jnp.maximum(t_scr[...], t_here)

    @pl.when(vi == v_steps - 1)
    def _final():
        lse = m_scr[...] + jnp.log(jnp.maximum(l_scr[...], 1e-30))
        loss_ref[...] = (lse - t_scr[...])[:, 0]
        lse_ref[...] = lse[:, 0]


def fused_cross_entropy(
    hidden: jax.Array,     # (T, D)
    w_out: jax.Array,      # (D, Vpad)
    targets: jax.Array,    # (T,) int32
    *,
    vocab: int = 0,        # true vocab (<= Vpad); 0 -> Vpad
    block_t: int = 128,
    block_v: int = 512,
    interpret: bool = False,
):
    T, D = hidden.shape
    Vp = w_out.shape[1]
    vocab = vocab or Vp
    block_t = min(block_t, T)
    block_v = min(block_v, Vp)
    assert T % block_t == 0 and Vp % block_v == 0, (T, Vp, block_t, block_v)
    v_steps = Vp // block_v
    kernel = functools.partial(
        _ce_kernel,
        block_t=block_t,
        block_v=block_v,
        v_steps=v_steps,
        vocab=vocab,
    )
    loss, lse = pl.pallas_call(
        kernel,
        grid=(T // block_t, v_steps),
        in_specs=[
            pl.BlockSpec((block_t, D), lambda ti, vi: (ti, 0)),
            pl.BlockSpec((D, block_v), lambda ti, vi: (0, vi)),
            pl.BlockSpec((block_t,), lambda ti, vi: (ti,)),
        ],
        out_specs=[
            pl.BlockSpec((block_t,), lambda ti, vi: (ti,)),
            pl.BlockSpec((block_t,), lambda ti, vi: (ti,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T,), jnp.float32),
            jax.ShapeDtypeStruct((T,), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_t, 1), jnp.float32),
            pltpu.VMEM((block_t, 1), jnp.float32),
            pltpu.VMEM((block_t, 1), jnp.float32),
        ],
        interpret=interpret,
    )(hidden, w_out, targets)
    return loss, lse
