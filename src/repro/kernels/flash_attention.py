"""Pallas TPU flash attention (forward + backward).

TPU-native adaptation of TransformerEngine-class fused attention:
  * grid (batch·heads, q_blocks, kv_blocks) — kv innermost so VMEM scratch
    accumulators (running max / denom / out) carry across kv steps, using
    the sequential-grid semantics of TPU Pallas.
  * BlockSpec tiles: (block_q × head_dim) for Q/out, (block_k × head_dim)
    for K/V — MXU-aligned (multiples of 128 when the sequence allows;
    head_dim 64/128 are native MXU widths).  VMEM working set per step is
    bq·D + 2·bk·D + bq·bk + bq·(D+2) fp32 ≈ 0.25 MB at 128×128×128 —
    far below the ~16 MB VMEM budget, leaving room for double buffering.
  * online softmax in fp32; GQA handled in the K/V index_map (no
    jnp.repeat — each kv tile is re-fetched per group member by the DMA
    engine, the natural TPU analogue of TE's GQA kernels).
  * supports causal masking, sliding window, logit softcap, and a q-position
    offset for decode.

Backward pass (FlashAttention-2 style, three kernels):
  * ``_fa_delta_kernel``  — Δ_i = Σ_d dO_id·O_id per q row (precompute).
  * ``_fa_dq_kernel``     — grid (B·H, q_blocks, kv_blocks); recomputes
    block probabilities from the saved per-row LSE and accumulates dQ in
    VMEM scratch across kv steps.
  * ``_fa_dkv_kernel``    — grid (B·Hkv, kv_blocks, group·q_blocks); the
    innermost dim sweeps every q block of every query head in the GQA
    group so dK/dV accumulate directly in grouped-head form — the dK/dV
    tensors never materialize at (B, H, T, D).
  All passes recompute S = QKᵀ on the MXU instead of saving the (S × T)
  probability matrix — O(S) residuals (LSE, Δ), exactly like the fwd.

Validated against ``ref.attention_ref`` (values) and its jax.grad
(cotangents) in interpret mode; see tests/test_kernels.py and
tests/test_grads.py.  The custom-VJP dispatch lives in ``ops.py``.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels.tiling import pad_dim, pick_block

NEG_INF = -1e30


def _block_mask(qi, ki, *, block_q, block_k, causal, window, q_offset, kv_len):
    """(block_q, block_k) validity mask for the (qi, ki) tile."""
    q_pos = (
        qi * block_q
        + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        + q_offset
    )
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = k_pos < kv_len
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > (q_pos - window)
    return mask


def _block_live(qi, ki, *, block_q, block_k, causal, window, q_offset):
    """Scalar predicate: does tile (qi, ki) contain any unmasked entry?

    Used to skip recompute work for tiles that are fully masked under
    causal/window structure (the DMA still runs; the MXU work doesn't).
    """
    conds = []
    if causal:
        # last q row of the tile must reach the first k column
        conds.append(ki * block_k <= qi * block_q + block_q - 1 + q_offset)
    if window > 0:
        # last k column must be inside the window of the last q row
        conds.append(ki * block_k + block_k - 1 > qi * block_q + q_offset - window)
    if not conds:
        return None
    live = conds[0]
    for c in conds[1:]:
        live = jnp.logical_and(live, c)
    return live


# --------------------------------------------------------------------- #
# forward
# --------------------------------------------------------------------- #
def _fa_kernel(
    q_ref, k_ref, v_ref,       # VMEM input tiles
    o_ref, lse_ref,            # VMEM output tiles
    m_scr, l_scr, acc_scr,     # VMEM scratch (carried across kv grid steps)
    *,
    scale: float,
    causal: bool,
    window: int,
    softcap: float,
    block_q: int,
    block_k: int,
    kv_steps: int,
    q_offset: int,
    kv_len: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)           # (bq, D)
    k = k_ref[0].astype(jnp.float32)           # (bk, D)
    v = v_ref[0].astype(jnp.float32)           # (bk, D)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                                   # (bq, bk)
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)

    mask = _block_mask(
        qi, ki, block_q=block_q, block_k=block_k, causal=causal,
        window=window, q_offset=q_offset, kv_len=kv_len,
    )
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                         # (bq, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    # fully-masked rows (can happen under causal/window): keep them inert
    p = jnp.where(m_new <= NEG_INF / 2, 0.0, p)
    alpha = jnp.exp(m_prev - m_new)
    m_scr[...] = m_new
    l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(ki == kv_steps - 1)
    def _final():
        l = l_scr[...]
        denom = jnp.maximum(l, 1e-30)
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)
        # per-row logsumexp residual for the backward pass (fully-masked
        # rows get lse ≈ NEG_INF, which the bwd kernels treat as inert)
        lse_ref[0] = (m_scr[...] + jnp.log(denom))[:, 0]


def _head_major(x):
    """(B, S, H, D) -> (B*H, S, D)."""
    B, S, H, D = x.shape
    return jnp.transpose(x, (0, 2, 1, 3)).reshape(B * H, S, D)


def flash_attention_fwd(
    q: jax.Array,              # (B, S, H, D)
    k: jax.Array,              # (B, T, Hkv, D)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    q_offset: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
):
    """Forward kernel returning (out (B,S,H,D), lse (B*H, S) fp32)."""
    B, S, H, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    assert H % Hkv == 0, (H, Hkv)
    group = H // Hkv
    # non-multiple dims: zero-pad q rows (outputs sliced below) and kv rows
    # (masked in-kernel via kv_len) rather than shrinking the block
    block_q, Sp = pick_block(S, block_q)
    block_k, Tp = pick_block(T, block_k)
    kv_steps = Tp // block_k
    scale = 1.0 / math.sqrt(D)

    # (B, H) collapsed into the leading grid dim; head-major layout
    qh = pad_dim(_head_major(q), 1, Sp)
    kh = pad_dim(_head_major(k), 1, Tp)
    vh = pad_dim(_head_major(v), 1, Tp)

    def q_map(b, qi, ki):
        return (b, qi, 0)

    def kv_map(b, qi, ki):
        batch = b // H
        head = b % H
        return (batch * Hkv + head // group, ki, 0)

    def lse_map(b, qi, ki):
        return (b, qi)

    kernel = functools.partial(
        _fa_kernel,
        scale=scale,
        causal=causal,
        window=window,
        softcap=softcap,
        block_q=block_q,
        block_k=block_k,
        kv_steps=kv_steps,
        q_offset=q_offset,
        kv_len=T,
    )
    out, lse = pl.pallas_call(
        kernel,
        grid=(B * H, Sp // block_q, kv_steps),
        in_specs=[
            pl.BlockSpec((1, block_q, D), q_map),
            pl.BlockSpec((1, block_k, D), kv_map),
            pl.BlockSpec((1, block_k, D), kv_map),
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, D), q_map),
            pl.BlockSpec((1, block_q), lse_map),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, Sp, D), q.dtype),
            jax.ShapeDtypeStruct((B * H, Sp), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(qh, kh, vh)
    out, lse = out[:, :S], lse[:, :S]
    return jnp.transpose(out.reshape(B, H, S, D), (0, 2, 1, 3)), lse


def flash_attention(
    q: jax.Array,              # (B, S, H, D)
    k: jax.Array,              # (B, T, Hkv, D)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    q_offset: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    out, _ = flash_attention_fwd(
        q, k, v, causal=causal, window=window, softcap=softcap,
        q_offset=q_offset, block_q=block_q, block_k=block_k,
        interpret=interpret,
    )
    return out


# --------------------------------------------------------------------- #
# backward
# --------------------------------------------------------------------- #
def _fa_delta_kernel(o_ref, do_ref, delta_ref):
    o = o_ref[0].astype(jnp.float32)
    do = do_ref[0].astype(jnp.float32)
    delta_ref[0] = jnp.sum(o * do, axis=-1)


def _recompute_p_ds(
    q, k, v, do, lse, delta, qi, ki, *,
    scale, causal, window, softcap, block_q, block_k, q_offset, kv_len,
):
    """Shared bwd math: recompute probabilities + pre-softcap score grads.

    Returns (p, ds) both (bq, bk) fp32; ds already includes the logit
    scale so dq = ds @ k and dk = dsᵀ @ q need no further scaling.
    """
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                                   # pre-softcap scores
    if softcap > 0.0:
        t = jnp.tanh(s / softcap)
        z = softcap * t                         # logits
    else:
        z = s
    mask = _block_mask(
        qi, ki, block_q=block_q, block_k=block_k, causal=causal,
        window=window, q_offset=q_offset, kv_len=kv_len,
    )
    # p = exp(z - lse) on valid entries.  The mask (not the NEG_INF trick)
    # must gate this: a fully-masked row has lse ≈ NEG_INF and exp(z - lse)
    # would be exp(0) = 1 at its masked entries.  The exponent is clamped at
    # 0 (p <= 1 mathematically) so garbage lse rows — fully-masked or
    # padded q rows, whose dO is zero — stay finite instead of overflowing.
    p = jnp.where(mask, jnp.exp(jnp.minimum(jnp.where(mask, z, 0.0) - lse, 0.0)), 0.0)
    dp = jax.lax.dot_general(
        do, v, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                            # (bq, bk)
    dz = p * (dp - delta)
    if softcap > 0.0:
        dz = dz * (1.0 - t * t)                  # through the softcap tanh
    return p, dz * scale


def _fa_dq_kernel(
    q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
    dq_ref,
    dq_scr,
    *,
    scale: float,
    causal: bool,
    window: int,
    softcap: float,
    block_q: int,
    block_k: int,
    kv_steps: int,
    q_offset: int,
    kv_len: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        dq_scr[...] = jnp.zeros_like(dq_scr)

    def _accumulate():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        do = do_ref[0].astype(jnp.float32)
        lse = lse_ref[0][:, None]                # (bq, 1)
        delta = delta_ref[0][:, None]
        _, ds = _recompute_p_ds(
            q, k, v, do, lse, delta, qi, ki,
            scale=scale, causal=causal, window=window, softcap=softcap,
            block_q=block_q, block_k=block_k, q_offset=q_offset, kv_len=kv_len,
        )
        dq_scr[...] += jax.lax.dot_general(
            ds, k, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )

    live = _block_live(
        qi, ki, block_q=block_q, block_k=block_k, causal=causal,
        window=window, q_offset=q_offset,
    )
    if live is None:
        _accumulate()
    else:
        pl.when(live)(_accumulate)

    @pl.when(ki == kv_steps - 1)
    def _final():
        dq_ref[0] = dq_scr[...].astype(dq_ref.dtype)


def _fa_dkv_kernel(
    q_ref, do_ref, lse_ref, delta_ref, k_ref, v_ref,
    dk_ref, dv_ref,
    dk_scr, dv_scr,
    *,
    scale: float,
    causal: bool,
    window: int,
    softcap: float,
    block_q: int,
    block_k: int,
    q_steps: int,
    inner_steps: int,     # group * q_steps
    q_offset: int,
    kv_len: int,
):
    ki = pl.program_id(1)
    j = pl.program_id(2)
    qi = j % q_steps

    @pl.when(j == 0)
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    def _accumulate():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        do = do_ref[0].astype(jnp.float32)
        lse = lse_ref[0][:, None]
        delta = delta_ref[0][:, None]
        p, ds = _recompute_p_ds(
            q, k, v, do, lse, delta, qi, ki,
            scale=scale, causal=causal, window=window, softcap=softcap,
            block_q=block_q, block_k=block_k, q_offset=q_offset, kv_len=kv_len,
        )
        dv_scr[...] += jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )                                        # (bk, D)
        dk_scr[...] += jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )

    live = _block_live(
        qi, ki, block_q=block_q, block_k=block_k, causal=causal,
        window=window, q_offset=q_offset,
    )
    if live is None:
        _accumulate()
    else:
        pl.when(live)(_accumulate)

    @pl.when(j == inner_steps - 1)
    def _final():
        dk_ref[0] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_scr[...].astype(dv_ref.dtype)


def flash_attention_bwd(
    q: jax.Array,              # (B, S, H, D)
    k: jax.Array,              # (B, T, Hkv, D)
    v: jax.Array,
    out: jax.Array,            # (B, S, H, D) forward output
    lse: jax.Array,            # (B*H, S) fp32 forward residual
    do: jax.Array,             # (B, S, H, D) output cotangent
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    q_offset: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
):
    """Returns (dq, dk, dv) in the input dtypes."""
    B, S, H, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    group = H // Hkv
    # padded q rows carry zero dO (and zero Δ), so they contribute exactly
    # nothing to dK/dV; padded kv rows are masked in-kernel via kv_len
    block_q, Sp = pick_block(S, block_q)
    block_k, Tp = pick_block(T, block_k)
    q_steps = Sp // block_q
    kv_steps = Tp // block_k
    scale = 1.0 / math.sqrt(D)

    qh = pad_dim(_head_major(q), 1, Sp)
    kh = pad_dim(_head_major(k), 1, Tp)
    vh = pad_dim(_head_major(v), 1, Tp)
    oh = pad_dim(_head_major(out), 1, Sp)
    doh = pad_dim(_head_major(do), 1, Sp)
    lse = pad_dim(lse, 1, Sp)

    # Δ = rowsum(dO ⊙ O) precompute
    delta = pl.pallas_call(
        _fa_delta_kernel,
        grid=(B * H, q_steps),
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda b, qi: (b, qi, 0)),
            pl.BlockSpec((1, block_q, D), lambda b, qi: (b, qi, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q), lambda b, qi: (b, qi)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sp), jnp.float32),
        interpret=interpret,
    )(oh, doh)

    # ---- dQ: grid (B·H, q, kv), kv innermost accumulates into scratch ----
    def q_map(b, qi, ki):
        return (b, qi, 0)

    def kv_map(b, qi, ki):
        batch = b // H
        head = b % H
        return (batch * Hkv + head // group, ki, 0)

    def row_map(b, qi, ki):
        return (b, qi)

    dq_kernel = functools.partial(
        _fa_dq_kernel,
        scale=scale, causal=causal, window=window, softcap=softcap,
        block_q=block_q, block_k=block_k, kv_steps=kv_steps,
        q_offset=q_offset, kv_len=T,
    )
    dq = pl.pallas_call(
        dq_kernel,
        grid=(B * H, q_steps, kv_steps),
        in_specs=[
            pl.BlockSpec((1, block_q, D), q_map),
            pl.BlockSpec((1, block_k, D), kv_map),
            pl.BlockSpec((1, block_k, D), kv_map),
            pl.BlockSpec((1, block_q, D), q_map),
            pl.BlockSpec((1, block_q), row_map),
            pl.BlockSpec((1, block_q), row_map),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), q_map),
        out_shape=jax.ShapeDtypeStruct((B * H, Sp, D), q.dtype),
        scratch_shapes=[pltpu.VMEM((block_q, D), jnp.float32)],
        interpret=interpret,
    )(qh, kh, vh, doh, lse, delta)

    # ---- dK/dV: grid (B·Hkv, kv, group·q) — the innermost dim walks every
    # q block of every head in the GQA group, so dK/dV accumulate directly
    # in grouped-head form (never materializing (B, H, T, D)). ----
    inner_steps = group * q_steps

    def q_map2(b, ki, j):
        batch = b // Hkv
        kvh = b % Hkv
        g = j // q_steps
        qi = j % q_steps
        return (batch * H + kvh * group + g, qi, 0)

    def row_map2(b, ki, j):
        batch = b // Hkv
        kvh = b % Hkv
        g = j // q_steps
        qi = j % q_steps
        return (batch * H + kvh * group + g, qi)

    def kv_map2(b, ki, j):
        return (b, ki, 0)

    dkv_kernel = functools.partial(
        _fa_dkv_kernel,
        scale=scale, causal=causal, window=window, softcap=softcap,
        block_q=block_q, block_k=block_k, q_steps=q_steps,
        inner_steps=inner_steps, q_offset=q_offset, kv_len=T,
    )
    dk, dv = pl.pallas_call(
        dkv_kernel,
        grid=(B * Hkv, kv_steps, inner_steps),
        in_specs=[
            pl.BlockSpec((1, block_q, D), q_map2),
            pl.BlockSpec((1, block_q, D), q_map2),
            pl.BlockSpec((1, block_q), row_map2),
            pl.BlockSpec((1, block_q), row_map2),
            pl.BlockSpec((1, block_k, D), kv_map2),
            pl.BlockSpec((1, block_k, D), kv_map2),
        ],
        out_specs=[
            pl.BlockSpec((1, block_k, D), kv_map2),
            pl.BlockSpec((1, block_k, D), kv_map2),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * Hkv, Tp, D), k.dtype),
            jax.ShapeDtypeStruct((B * Hkv, Tp, D), v.dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_k, D), jnp.float32),
            pltpu.VMEM((block_k, D), jnp.float32),
        ],
        interpret=interpret,
    )(qh, doh, lse, delta, kh, vh)

    dq = jnp.transpose(dq[:, :S].reshape(B, H, S, D), (0, 2, 1, 3))
    dk = jnp.transpose(dk[:, :T].reshape(B, Hkv, T, D), (0, 2, 1, 3))
    dv = jnp.transpose(dv[:, :T].reshape(B, Hkv, T, D), (0, 2, 1, 3))
    return dq, dk, dv
