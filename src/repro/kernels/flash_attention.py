"""Pallas TPU flash attention (forward).

TPU-native adaptation of TransformerEngine-class fused attention:
  * grid (batch·heads, q_blocks, kv_blocks) — kv innermost so VMEM scratch
    accumulators (running max / denom / out) carry across kv steps, using
    the sequential-grid semantics of TPU Pallas.
  * BlockSpec tiles: (block_q × head_dim) for Q/out, (block_k × head_dim)
    for K/V — MXU-aligned (multiples of 128 when the sequence allows;
    head_dim 64/128 are native MXU widths).  VMEM working set per step is
    bq·D + 2·bk·D + bq·bk + bq·(D+2) fp32 ≈ 0.25 MB at 128×128×128 —
    far below the ~16 MB VMEM budget, leaving room for double buffering.
  * online softmax in fp32; GQA handled in the K/V index_map (no
    jnp.repeat — each kv tile is re-fetched per group member by the DMA
    engine, the natural TPU analogue of TE's GQA kernels).
  * supports causal masking, sliding window, logit softcap, and a q-position
    offset for decode.

Validated against ``ref.attention_ref`` in interpret mode (tests sweep
shapes/dtypes).  The jit'd wrapper lives in ``ops.py``.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(
    q_ref, k_ref, v_ref,       # VMEM input tiles
    o_ref,                     # VMEM output tile
    m_scr, l_scr, acc_scr,     # VMEM scratch (carried across kv grid steps)
    *,
    scale: float,
    causal: bool,
    window: int,
    softcap: float,
    block_q: int,
    block_k: int,
    kv_steps: int,
    q_offset: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)           # (bq, D)
    k = k_ref[0].astype(jnp.float32)           # (bk, D)
    v = v_ref[0].astype(jnp.float32)           # (bk, D)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                                   # (bq, bk)
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)

    q_pos = (
        qi * block_q
        + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        + q_offset
    )
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > (q_pos - window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                         # (bq, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    # fully-masked rows (can happen under causal/window): keep them inert
    p = jnp.where(m_new <= NEG_INF / 2, 0.0, p)
    alpha = jnp.exp(m_prev - m_new)
    m_scr[...] = m_new
    l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(ki == kv_steps - 1)
    def _final():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,              # (B, S, H, D)
    k: jax.Array,              # (B, T, Hkv, D)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    q_offset: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, S, H, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    assert H % Hkv == 0, (H, Hkv)
    group = H // Hkv
    block_q = min(block_q, S)
    block_k = min(block_k, T)
    assert S % block_q == 0 and T % block_k == 0, (S, T, block_q, block_k)
    kv_steps = T // block_k
    scale = 1.0 / math.sqrt(D)

    # (B, H) collapsed into the leading grid dim; head-major layout
    qh = jnp.transpose(q, (0, 2, 1, 3)).reshape(B * H, S, D)
    kh = jnp.transpose(k, (0, 2, 1, 3)).reshape(B * Hkv, T, D)
    vh = jnp.transpose(v, (0, 2, 1, 3)).reshape(B * Hkv, T, D)

    def q_map(b, qi, ki):
        return (b, qi, 0)

    def kv_map(b, qi, ki):
        batch = b // H
        head = b % H
        return (batch * Hkv + head // group, ki, 0)

    kernel = functools.partial(
        _fa_kernel,
        scale=scale,
        causal=causal,
        window=window,
        softcap=softcap,
        block_q=block_q,
        block_k=block_k,
        kv_steps=kv_steps,
        q_offset=q_offset,
    )
    out = pl.pallas_call(
        kernel,
        grid=(B * H, S // block_q, kv_steps),
        in_specs=[
            pl.BlockSpec((1, block_q, D), q_map),
            pl.BlockSpec((1, block_k, D), kv_map),
            pl.BlockSpec((1, block_k, D), kv_map),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), q_map),
        out_shape=jax.ShapeDtypeStruct((B * H, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(qh, kh, vh)
    return jnp.transpose(out.reshape(B, H, S, D), (0, 2, 1, 3))
