"""Pallas TPU flash-decoding: single-token attention over a long KV cache,
split over cache blocks with running log-sum-exp combine.

This is the kernel behind the decode shapes (decode_32k, long_500k): one
query token per sequence against a 32k–512k cache.  The GPU original
(flash-decoding) splits the cache across thread blocks and combines with a
second kernel; the TPU-native form makes the cache-block dim the innermost
sequential grid axis so the combine state (m, l, acc) lives in VMEM scratch
— no second pass, no HBM round-trips for partials.

Grid (batch, kv_heads, cache_blocks); each step loads a
(block_t × head_dim) K/V tile and all `group` query heads that share it
(GQA: q tile (group × head_dim)).  MXU work per step is a
(group × block_t) logit panel — group=4..16, so block_t is kept large
(512) to keep the MXU busy.

Validated against ``ref.attention_ref`` (q_offset/masked) in interpret
mode; the distributed version shards the cache-seq dim over the `model`
mesh axis and GSPMD reduces the per-shard (m, l, acc) partials — the same
math this kernel does locally.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels import tiling

NEG_INF = -1e30


def _fd_kernel(
    q_ref,       # (1, 1, group, D)
    k_ref,       # (1, block_t, 1, D)
    v_ref,
    len_ref,     # (1,) valid length for this batch row
    o_ref,       # (1, 1, group, D)
    m_scr, l_scr, acc_scr,
    *,
    scale: float,
    block_t: int,
    t_steps: int,
    softcap: float,
):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0, 0].astype(jnp.float32)         # (group, D)
    k = k_ref[0, :, 0].astype(jnp.float32)         # (block_t, D)
    v = v_ref[0, :, 0].astype(jnp.float32)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                                       # (group, block_t)
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)

    pos = ti * block_t + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(pos < len_ref[0], s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    p = jnp.where(m_new <= NEG_INF / 2, 0.0, p)
    alpha = jnp.exp(m_prev - m_new)
    m_scr[...] = m_new
    l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(ti == t_steps - 1)
    def _final():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_decode(
    q: jax.Array,         # (B, 1, H, D)
    k_cache: jax.Array,   # (B, T, Hkv, D)
    v_cache: jax.Array,
    lengths: jax.Array,   # (B,) int32 valid cache length
    *,
    softcap: float = 0.0,
    block_t: int = 512,
    interpret: bool = False,
) -> jax.Array:
    B, _, H, D = q.shape
    T, Hkv = k_cache.shape[1], k_cache.shape[2]
    assert H % Hkv == 0
    group = H // Hkv
    # non-multiple tails: zero-pad the cache up to a block multiple; the
    # in-kernel `pos < length` mask (length <= T) drops the padded rows
    block_t, Tp = tiling.pick_block(T, block_t)
    k_cache = tiling.pad_dim(k_cache, 1, Tp)
    v_cache = tiling.pad_dim(v_cache, 1, Tp)
    t_steps = Tp // block_t
    scale = 1.0 / math.sqrt(D)

    qg = q.reshape(B, 1, Hkv, group, D)

    kernel = functools.partial(
        _fd_kernel,
        scale=scale, block_t=block_t, t_steps=t_steps, softcap=softcap,
    )
    out = pl.pallas_call(
        kernel,
        grid=(B, Hkv, t_steps),
        in_specs=[
            pl.BlockSpec((1, 1, 1, group, D), lambda b, h, ti: (b, 0, h, 0, 0)),
            pl.BlockSpec((1, block_t, 1, D), lambda b, h, ti: (b, ti, h, 0)),
            pl.BlockSpec((1, block_t, 1, D), lambda b, h, ti: (b, ti, h, 0)),
            pl.BlockSpec((1,), lambda b, h, ti: (b,)),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, 1, group, D), lambda b, h, ti: (b, 0, h, 0, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((B, 1, Hkv, group, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, D), jnp.float32),
        ],
        interpret=interpret,
    )(qg, k_cache, v_cache, lengths)
    return out.reshape(B, 1, H, D)
