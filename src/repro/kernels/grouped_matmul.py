"""Pallas TPU ragged grouped matmul (megablocks-style MoE expert GEMMs).

``gmm`` computes ``y[i] = x[i] @ w[g(i)]`` where rows of ``x`` are sorted
by group and ``group_sizes[g]`` (dynamic, varies per step) gives each
group's contiguous row count — the expert-FFN shape after sort-by-expert
dispatch (``models/moe.py``).  ``gmm_dw`` is the ragged weight gradient
``dw[g] = x_g.T @ dy_g``.  Together with ``dx = gmm(dy, w.swapaxes(1, 2))``
they form the custom-VJP triple wired in ``kernels/ops.grouped_matmul``.

The ragged structure never materializes a dense ``(M, E)`` one-hot: tile
metadata is computed OUTSIDE the kernel from ``group_sizes`` (static
shapes, dynamic values) and rides in through ``PrefetchScalarGridSpec`` so
BlockSpec index maps can steer every grid step:

  * the flattened tile list visits each group's m-tiles in order; a group
    whose rows span ``t`` tiles gets ``t`` entries and an EMPTY group gets
    none — empty experts cost zero compute (tile-level skip);
  * a static bound ``L = num_m_tiles + E`` covers the worst case (every
    group boundary splits a tile); unused entries replay the last valid
    tile with an empty row-mask, which rewrites identical bytes;
  * tiles sharing an output block are consecutive, so the block stays
    resident in VMEM across them (the standard Pallas revisiting
    contract) and each visitor read-modify-writes only its group's rows;
    the first visitor zero-fills the rows no group owns, which also
    zeroes rows past ``sum(group_sizes)``.

Grid is ``(n_tiles, L)`` — the ragged axis is minor, so the output block
index changes only when the tile list moves on.  K is kept whole in VMEM
(MoE d_model/d_ff fit comfortably); M/N/K are zero-padded to tile
multiples and sliced back.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels import tiling


def _round_up(n: int, m: int) -> int:
    return n + (-n % m)


# --------------------------------------------------------------------- #
# tile metadata (jnp, traced values / static shapes)
# --------------------------------------------------------------------- #
def gmm_metadata(
    group_sizes: jax.Array,  # (E,) int32
    num_m_tiles: int,
    block_m: int,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array, jax.Array]:
    """Flattened per-tile schedule for the forward kernel.

    Returns ``(gid, mid, lo, hi, first)``, each ``(L,)`` int32 with
    ``L = num_m_tiles + E``: the group whose weight block tile ``l``
    loads, the m-tile it writes, the [lo, hi) global-row interval its
    rows must fall in, and whether it is the first writer of its output
    block (first writers zero-fill foreign rows).  A virtual tail group
    covers m-tiles past ``sum(group_sizes)`` with an empty mask so those
    output rows are zeroed, not garbage.
    """
    E = group_sizes.shape[0]
    sizes = group_sizes.astype(jnp.int32)
    ends_g = jnp.cumsum(sizes)
    starts_g = ends_g - sizes
    total = ends_g[-1]
    first_t = starts_g // block_m
    last_t = jnp.maximum(ends_g - 1, starts_g) // block_m
    ntiles = jnp.where(sizes > 0, last_t - first_t + 1, 0)      # (E,)
    tile_total = (total + block_m - 1) // block_m
    cnt = jnp.concatenate([ntiles, (num_m_tiles - tile_total)[None]])
    csum = jnp.cumsum(cnt)                                       # (E+1,)
    n_valid = csum[-1]

    L = num_m_tiles + E
    li = jnp.arange(L, dtype=jnp.int32)
    g = jnp.searchsorted(csum, li, side="right").astype(jnp.int32)
    g = jnp.minimum(g, E)                   # E = virtual tail group
    off = li - (csum[g] - cnt[g])           # tile index within the group
    gid = jnp.minimum(g, E - 1)             # w block (tail reads any; masked)
    is_tail = g == E
    mid = jnp.where(is_tail, tile_total, first_t[gid]) + off
    lo = jnp.where(is_tail, 1, starts_g[gid])
    hi = jnp.where(is_tail, 0, ends_g[gid])

    valid = li < n_valid
    last = jnp.maximum(n_valid - 1, 0)
    mid = jnp.where(valid, mid, mid[last])  # replay last tile, empty mask
    gid = jnp.where(valid, gid, gid[last])
    lo = jnp.where(valid, lo, 1)
    hi = jnp.where(valid, hi, 0)
    first = jnp.concatenate(
        [jnp.ones((1,), bool), mid[1:] != mid[:-1]]
    ) & valid
    return gid, mid, lo, hi, first.astype(jnp.int32)


def tgmm_metadata(
    group_sizes: jax.Array,  # (E,) int32
    num_m_tiles: int,
    block_m: int,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array, jax.Array]:
    """Schedule for the ragged dW kernel (output indexed by GROUP).

    Same flattened layout, but every group gets at least one entry —
    an empty group's degenerate entry has an empty row-mask and, being
    its group's first (and only) writer, zero-fills that expert's
    gradient block.  No tail entries: rows past the total belong to no
    group and must not contribute.
    """
    E = group_sizes.shape[0]
    sizes = group_sizes.astype(jnp.int32)
    ends_g = jnp.cumsum(sizes)
    starts_g = ends_g - sizes
    first_t = starts_g // block_m
    last_t = jnp.maximum(ends_g - 1, starts_g) // block_m
    ntiles = jnp.maximum(jnp.where(sizes > 0, last_t - first_t + 1, 1), 1)
    csum = jnp.cumsum(ntiles)                                    # (E,)
    n_valid = csum[-1]

    L = num_m_tiles + E
    li = jnp.arange(L, dtype=jnp.int32)
    g = jnp.searchsorted(csum, li, side="right").astype(jnp.int32)
    gid = jnp.minimum(g, E - 1)
    off = li - (csum[gid] - ntiles[gid])
    mid = jnp.minimum(first_t[gid] + off, num_m_tiles - 1)

    valid = li < n_valid
    lo = jnp.where(valid, starts_g[gid], 1)
    hi = jnp.where(valid, ends_g[gid], 0)
    first = jnp.concatenate(
        [jnp.ones((1,), bool), gid[1:] != gid[:-1]]
    ) & valid
    return gid, mid, lo, hi, first.astype(jnp.int32)


# --------------------------------------------------------------------- #
# forward kernel: y (M, N) = x (M, K) @ w[group] (K, N), ragged groups
# --------------------------------------------------------------------- #
def _gmm_kernel(
    gid_ref, mid_ref, lo_ref, hi_ref, first_ref,   # scalar-prefetch (L,)
    x_ref,   # (bm, K)  — the m-tile picked by the index map
    w_ref,   # (1, K, bn) — the group's weight tile
    o_ref,   # (bm, bn)
    *,
    block_m: int,
):
    l = pl.program_id(1)
    rows = mid_ref[l] * block_m + jax.lax.broadcasted_iota(
        jnp.int32, (block_m, 1), 0
    )
    mask = (rows >= lo_ref[l]) & (rows < hi_ref[l])              # (bm, 1)
    acc = jax.lax.dot_general(
        x_ref[...], w_ref[0],
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(first_ref[l] == 1)
    def _init():
        o_ref[...] = jnp.where(mask, acc, 0.0).astype(o_ref.dtype)

    @pl.when(first_ref[l] == 0)
    def _update():
        o_ref[...] = jnp.where(mask, acc.astype(o_ref.dtype), o_ref[...])


def gmm(
    x: jax.Array,            # (M, K) rows sorted by group
    w: jax.Array,            # (E, K, N) per-group weights
    group_sizes: jax.Array,  # (E,) int32 contiguous row counts
    *,
    block_m: int = 128,
    block_n: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Ragged grouped matmul.  Rows past ``sum(group_sizes)`` yield zeros."""
    M, K = x.shape
    E, _, N = w.shape
    bm, Mp = tiling.pick_block(M, block_m)
    bm = max(8, bm)
    Mp = _round_up(Mp, bm)
    bn, Np = tiling.pick_block(N, block_n)
    bn = _round_up(bn, 128)
    Np = _round_up(Np, bn)
    Kp = _round_up(K, 128)
    xp = tiling.pad_dim(tiling.pad_dim(x, 0, Mp), 1, Kp)
    wp = tiling.pad_dim(tiling.pad_dim(w, 1, Kp), 2, Np)
    num_m_tiles = Mp // bm
    gid, mid, lo, hi, first = gmm_metadata(group_sizes, num_m_tiles, bm)
    L = num_m_tiles + E

    out = pl.pallas_call(
        functools.partial(_gmm_kernel, block_m=bm),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=5,   # gid, mid, lo, hi, first
            grid=(Np // bn, L),
            in_specs=[
                pl.BlockSpec(
                    (bm, Kp), lambda n, l, gid, mid, lo, hi, fi: (mid[l], 0)
                ),
                pl.BlockSpec(
                    (1, Kp, bn),
                    lambda n, l, gid, mid, lo, hi, fi: (gid[l], 0, n),
                ),
            ],
            out_specs=pl.BlockSpec(
                (bm, bn), lambda n, l, gid, mid, lo, hi, fi: (mid[l], n)
            ),
        ),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), x.dtype),
        interpret=interpret,
    )(gid, mid, lo, hi, first, xp, wp)
    return out[:M, :N]


# --------------------------------------------------------------------- #
# ragged weight gradient: dw (E, K, N) = segment_e( x_e.T @ dy_e )
# --------------------------------------------------------------------- #
def _tgmm_kernel(
    gid_ref, mid_ref, lo_ref, hi_ref, first_ref,
    x_ref,    # (bm, K)
    dy_ref,   # (bm, bn)
    o_ref,    # (1, K, bn) fp32 — the group's gradient tile
    *,
    block_m: int,
):
    l = pl.program_id(1)
    rows = mid_ref[l] * block_m + jax.lax.broadcasted_iota(
        jnp.int32, (block_m, 1), 0
    )
    mask = (rows >= lo_ref[l]) & (rows < hi_ref[l])              # (bm, 1)
    xm = jnp.where(mask, x_ref[...], 0)
    contrib = jax.lax.dot_general(
        xm, dy_ref[...],
        (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                            # (K, bn)

    @pl.when(first_ref[l] == 1)
    def _init():
        o_ref[0] = contrib

    @pl.when(first_ref[l] == 0)
    def _acc():
        o_ref[0] = o_ref[0] + contrib


def gmm_dw(
    x: jax.Array,            # (M, K) rows sorted by group
    dy: jax.Array,           # (M, N) output cotangent, same row order
    group_sizes: jax.Array,  # (E,) int32
    *,
    block_m: int = 128,
    block_n: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Per-group ``x_g.T @ dy_g`` → (E, K, N) float32 (empty groups: zeros)."""
    M, K = x.shape
    E = group_sizes.shape[0]
    N = dy.shape[1]
    bm, Mp = tiling.pick_block(M, block_m)
    bm = max(8, bm)
    Mp = _round_up(Mp, bm)
    bn, Np = tiling.pick_block(N, block_n)
    bn = _round_up(bn, 128)
    Np = _round_up(Np, bn)
    Kp = _round_up(K, 128)
    xp = tiling.pad_dim(tiling.pad_dim(x, 0, Mp), 1, Kp)
    dyp = tiling.pad_dim(tiling.pad_dim(dy, 0, Mp), 1, Np)
    num_m_tiles = Mp // bm
    gid, mid, lo, hi, first = tgmm_metadata(group_sizes, num_m_tiles, bm)
    L = num_m_tiles + E

    out = pl.pallas_call(
        functools.partial(_tgmm_kernel, block_m=bm),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=5,
            grid=(Np // bn, L),
            in_specs=[
                pl.BlockSpec(
                    (bm, Kp), lambda n, l, gid, mid, lo, hi, fi: (mid[l], 0)
                ),
                pl.BlockSpec(
                    (bm, bn), lambda n, l, gid, mid, lo, hi, fi: (mid[l], n)
                ),
            ],
            out_specs=pl.BlockSpec(
                (1, Kp, bn), lambda n, l, gid, mid, lo, hi, fi: (gid[l], 0, n)
            ),
        ),
        out_shape=jax.ShapeDtypeStruct((E, Kp, Np), jnp.float32),
        interpret=interpret,
    )(gid, mid, lo, hi, first, xp, dyp)
    return out[:, :K, :N]
