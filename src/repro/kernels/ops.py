"""Kernel dispatch + memory-bounded jnp implementations.

Three implementations exist for each hot-spot:
  * ``pallas``  — the TPU kernel (``flash_attention.py`` etc.), used on TPU.
                  Attention and cross-entropy are differentiable end-to-end:
                  ``jax.custom_vjp`` wrappers here pair the forward kernels
                  with their Pallas backward kernels, so ``impl="pallas"``
                  (and ``auto`` on TPU) is trainable.
  * ``xla``     — blockwise/scanned jnp with the same O(block) memory
                  behavior, autodiff-able; used on CPU, in the dry-run
                  lowering (keeps HLO memory honest) and as the CPU/fallback
                  training path.
  * ``naive``   — the oracle in ``ref.py`` (tests only).

``impl="auto"`` resolves to pallas on TPU, xla elsewhere.
``impl="pallas_interpret"`` runs the Pallas kernels (fwd + bwd) in
interpret mode on any backend — the CPU-verifiable training path used by
the gradient test sweeps.  See kernels/README.md for the dispatch table.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels import tiling
from repro.kernels import flash_attention as _fa
from repro.kernels import cross_entropy as _ce
from repro.kernels.rmsnorm import layernorm as _ln_pallas
from repro.kernels.rmsnorm import rmsnorm as _rms_pallas
from repro.kernels.ssd_scan import ssd_scan as _ssd_pallas

NEG_INF = -1e30


import os


_IMPLS = ("auto", "pallas", "pallas_interpret", "xla", "naive")


def _resolve(impl: str, interpret: bool) -> Tuple[str, bool]:
    forced = os.environ.get("REPRO_FORCE_IMPL", "")
    if forced:
        impl = forced  # benchmark harness: force naive/xla/pallas globally
    if impl not in _IMPLS:
        raise ValueError(f"unknown kernel impl {impl!r}; expected one of {_IMPLS}")
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "xla"
    if impl == "pallas_interpret":
        return "pallas", True
    return impl, interpret


# --------------------------------------------------------------------- #
# attention
# --------------------------------------------------------------------- #
def _blockwise_attention_xla(
    q, k, v, *, causal, window, softcap, q_offset, block_k=0
):
    """Flash-attention semantics as a lax.scan over kv blocks (O(S·block) mem).

    Tuning knobs found via dry-run traffic analysis (EXPERIMENTS.md §Perf
    scout iter-3):
      * block_k defaults to 2048 (env REPRO_ATTN_BLOCK_K) — the fp32
        (m, l, acc) scan carries round-trip HBM once per kv block, so
        carry traffic scales 1/block_k;
      * probability blocks are cast to the input dtype (bf16) before the
        PV matmul with fp32 accumulation — halves the largest per-block
        buffer, mirroring what the MXU kernel does;
      * GQA K/V are NOT repeated — the einsum runs in grouped-head form.
    """
    B, S, H, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    group = H // Hkv
    if block_k <= 0:
        block_k = int(os.environ.get("REPRO_ATTN_BLOCK_K", "2048"))
    # zero-pad the kv tail block; masked below via k_pos < T
    block_k, Tp = tiling.pick_block(T, block_k)
    k = tiling.pad_dim(k, 1, Tp)
    v = tiling.pad_dim(v, 1, Tp)
    nblk = Tp // block_k
    scale = 1.0 / jnp.sqrt(jnp.asarray(D, jnp.float32))
    qg = q.reshape(B, S, Hkv, group, D)
    q_pos = jnp.arange(S) + q_offset

    kb = k.reshape(B, nblk, block_k, Hkv, D)
    vb = v.reshape(B, nblk, block_k, Hkv, D)

    def body(carry, blk):
        m, l, acc = carry                          # (B,Hkv,g,S), ..., (B,Hkv,g,S,D)
        kblk, vblk, ki = blk                       # (B,bk,Hkv,D)
        s = jnp.einsum(
            "bshgd,bthd->bhgst", qg, kblk, preferred_element_type=jnp.float32
        ) * scale                                   # (B,Hkv,g,S,bk) fp32
        if softcap > 0.0:
            s = softcap * jnp.tanh(s / softcap)
        k_pos = ki * block_k + jnp.arange(block_k)
        mask = jnp.broadcast_to(k_pos[None, :] < T, (S, block_k))
        if causal:
            mask &= k_pos[None, :] <= q_pos[:, None]
        if window > 0:
            mask &= k_pos[None, :] > (q_pos[:, None] - window)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(m_new[..., None] <= NEG_INF / 2, 0.0, p)
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhgst,bthd->bhgsd", p.astype(q.dtype), vblk,
            preferred_element_type=jnp.float32,
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Hkv, group, S), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, group, S), jnp.float32)
    a0 = jnp.zeros((B, Hkv, group, S, D), jnp.float32)
    xs = (
        jnp.moveaxis(kb, 1, 0),
        jnp.moveaxis(vb, 1, 0),
        jnp.arange(nblk),
    )
    (m, l, acc), _ = jax.lax.scan(jax.checkpoint(body), (m0, l0, a0), xs)
    out = acc / jnp.maximum(l, 1e-30)[..., None]          # (B,Hkv,g,S,D)
    out = out.reshape(B, H, S, D)
    return jnp.transpose(out, (0, 2, 1, 3)).astype(q.dtype)


class _AttnCfg(NamedTuple):
    """Hashable static config for the pallas attention custom-VJP."""

    causal: bool
    window: int
    softcap: float
    q_offset: int
    block_q: int
    block_k: int
    interpret: bool


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _attention_pallas(cfg: _AttnCfg, q, k, v):
    out, _ = _fa.flash_attention_fwd(
        q, k, v, causal=cfg.causal, window=cfg.window, softcap=cfg.softcap,
        q_offset=cfg.q_offset, block_q=cfg.block_q, block_k=cfg.block_k,
        interpret=cfg.interpret,
    )
    return out


def _attention_pallas_fwd(cfg: _AttnCfg, q, k, v):
    out, lse = _fa.flash_attention_fwd(
        q, k, v, causal=cfg.causal, window=cfg.window, softcap=cfg.softcap,
        q_offset=cfg.q_offset, block_q=cfg.block_q, block_k=cfg.block_k,
        interpret=cfg.interpret,
    )
    return out, (q, k, v, out, lse)


def _attention_pallas_bwd(cfg: _AttnCfg, res, do):
    q, k, v, out, lse = res
    return _fa.flash_attention_bwd(
        q, k, v, out, lse, do,
        causal=cfg.causal, window=cfg.window, softcap=cfg.softcap,
        q_offset=cfg.q_offset, block_q=cfg.block_q, block_k=cfg.block_k,
        interpret=cfg.interpret,
    )


_attention_pallas.defvjp(_attention_pallas_fwd, _attention_pallas_bwd)


def attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    q_offset: int = 0,
    impl: str = "auto",
    interpret: bool = False,
) -> jax.Array:
    impl, interpret = _resolve(impl, interpret)
    if impl == "pallas":
        cfg = _AttnCfg(
            causal=causal, window=window, softcap=softcap, q_offset=q_offset,
            block_q=128, block_k=128, interpret=interpret,
        )
        return _attention_pallas(cfg, q, k, v)
    if impl == "naive":
        return ref.attention_ref(
            q, k, v, causal=causal, window=window, softcap=softcap, q_offset=q_offset
        )
    return _blockwise_attention_xla(
        q, k, v, causal=causal, window=window, softcap=softcap, q_offset=q_offset
    )


def decode_attention(
    q: jax.Array,         # (B, 1, H, D)
    k_cache: jax.Array,   # (B, T, Hkv, D)  — seq dim may be mesh-sharded
    v_cache: jax.Array,
    length: jax.Array,    # (B,) valid cache length per sequence
    *,
    softcap: float = 0.0,
    impl: str = "auto",
    interpret: bool = False,
) -> jax.Array:
    """Single-token attention over a (possibly sequence-sharded) KV cache.

    Written as plain reductions over the cache sequence dim: under GSPMD a
    `model`-sharded cache turns max/sum into small all-reduces of per-shard
    statistics — the collective structure of flash-decoding, for free.
    """
    impl, interpret = _resolve(impl, interpret)
    if impl == "pallas":
        from repro.kernels.flash_decode import flash_decode

        return flash_decode(
            q, k_cache, v_cache, length.astype(jnp.int32),
            softcap=softcap, interpret=interpret,
        )
    return _decode_attention_xla(q, k_cache, v_cache, length, softcap=softcap)


def _decode_attention_xla(q, k_cache, v_cache, length, *, softcap=0.0):
    B, _, H, D = q.shape
    T, Hkv = k_cache.shape[1], k_cache.shape[2]
    group = H // Hkv
    scale = 1.0 / jnp.sqrt(jnp.asarray(D, jnp.float32))
    # grouped-head form: never jnp.repeat the cache (repeating reads the
    # 32k/500k cache `group`× in fp32 — found via decode traffic analysis)
    qg = q[:, 0].reshape(B, Hkv, group, D)
    s = jnp.einsum(
        "bhgd,bthd->bhgt", qg, k_cache, preferred_element_type=jnp.float32
    ) * scale                                          # (B, Hkv, g, T) fp32
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)
    valid = jnp.arange(T)[None, :] < length[:, None]   # (B, T)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    # empty caches (length 0, e.g. an idle serving slot) yield zeros, the
    # same semantics as the Pallas decode kernels' masked-row guard
    p = jnp.where(m <= NEG_INF / 2, 0.0, p)
    denom = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum(
        "bhgt,bthd->bhgd",
        (p / jnp.maximum(denom, 1e-30)).astype(q.dtype),
        v_cache,
        preferred_element_type=jnp.float32,
    )
    return out.reshape(B, 1, H, D).astype(q.dtype)


# --------------------------------------------------------------------- #
# paged KV cache (serving): block-table attention + per-token scatter
# --------------------------------------------------------------------- #
def _gather_pages(pool: jax.Array, block_table: jax.Array) -> jax.Array:
    """(num_pages, page, Hkv, D) + (B, n) table -> dense (B, n·page, Hkv, D)."""
    B, n = block_table.shape
    page, Hkv, D = pool.shape[1:]
    flat = jnp.take(pool, block_table.reshape(-1), axis=0)
    return flat.reshape(B, n * page, Hkv, D)


def paged_decode_attention(
    q: jax.Array,            # (B, 1, H, D)
    k_pool: jax.Array,       # (num_pages, page, Hkv, D)
    v_pool: jax.Array,
    block_table: jax.Array,  # (B, pages_per_seq) int32
    length: jax.Array,       # (B,) valid cache length per sequence
    *,
    softcap: float = 0.0,
    impl: str = "auto",
    interpret: bool = False,
) -> jax.Array:
    """Single-token attention through a block-table paged KV pool.

    ``pallas`` gathers K/V page tiles by indexing the pool through the
    prefetched block table inside the kernel grid — the (B, T) dense
    cache never materializes.  The ``xla``/``naive`` fallback gathers
    pages into a dense cache and reuses the blockwise decode math
    (correct everywhere, O(B·T) gather — the CPU/testing path).
    """
    impl, interpret = _resolve(impl, interpret)
    if impl == "pallas":
        from repro.kernels.paged_attention import paged_flash_decode

        return paged_flash_decode(
            q, k_pool, v_pool, block_table, length.astype(jnp.int32),
            softcap=softcap, interpret=interpret,
        )
    k_cache = _gather_pages(k_pool, block_table)
    v_cache = _gather_pages(v_pool, block_table)
    return _decode_attention_xla(q, k_cache, v_cache, length, softcap=softcap)


def paged_prefill_attention(
    q: jax.Array,            # (B, S, H, D) chunk queries
    k_pool: jax.Array,       # (num_pages, page, Hkv, D)
    v_pool: jax.Array,
    block_table: jax.Array,  # (B, pages_per_seq) int32
    starts: jax.Array,       # (B,) logical position of each chunk's row 0
    lengths: jax.Array,      # (B,) total valid context length (start+valid)
    *,
    softcap: float = 0.0,
    impl: str = "auto",
    interpret: bool = False,
) -> jax.Array:
    """Chunk/suffix prefill attention through a block-table paged KV pool.

    Query row ``i`` of batch ``b`` sits at logical position
    ``starts[b] + i`` and attends causally to every cache position
    ``<= starts[b] + i`` (and ``< lengths[b]``) through the block table —
    this is the read side of prefix caching (the chunk attends straight
    into pages shared from the hash index) and of chunked prefill (each
    chunk attends to all previously written chunks plus itself; the
    chunk's own K/V must already be scattered into the pool, see
    ``paged_kv_update_rows``).

    ``pallas`` gathers K/V page tiles through the prefetched block table
    inside the kernel grid; the ``xla``/``naive`` fallback gathers pages
    into a dense cache and applies the shifted causal mask explicitly
    (O(B·S·T) scores — the CPU/testing path; chunks are short).
    """
    impl, interpret = _resolve(impl, interpret)
    if impl == "pallas":
        from repro.kernels.paged_attention import paged_flash_prefill

        return paged_flash_prefill(
            q, k_pool, v_pool, block_table,
            starts.astype(jnp.int32), lengths.astype(jnp.int32),
            softcap=softcap, interpret=interpret,
        )
    B, S, H, D = q.shape
    Hkv = k_pool.shape[2]
    group = H // Hkv
    scale = 1.0 / jnp.sqrt(jnp.asarray(D, jnp.float32))
    k_cache = _gather_pages(k_pool, block_table)       # (B, T, Hkv, D)
    v_cache = _gather_pages(v_pool, block_table)
    T = k_cache.shape[1]
    qg = q.reshape(B, S, Hkv, group, D)
    s = jnp.einsum(
        "bshgd,bthd->bhgst", qg, k_cache, preferred_element_type=jnp.float32
    ) * scale                                          # (B, Hkv, g, S, T)
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)
    q_pos = starts[:, None] + jnp.arange(S)[None, :]   # (B, S)
    k_pos = jnp.arange(T)
    mask = (
        (k_pos[None, None, :] <= q_pos[:, :, None])
        & (k_pos[None, None, :] < lengths[:, None, None])
    )                                                  # (B, S, T)
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    p = jnp.where(m <= NEG_INF / 2, 0.0, p)
    denom = jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum(
        "bhgst,bthd->bhgsd", (p / denom).astype(q.dtype), v_cache,
        preferred_element_type=jnp.float32,
    )                                                  # (B, Hkv, g, S, D)
    out = jnp.transpose(out, (0, 3, 1, 2, 4)).reshape(B, S, H, D)
    return out.astype(q.dtype)


def paged_kv_update_rows(
    k_pool: jax.Array,     # (num_pages, page, Hkv, D)
    v_pool: jax.Array,
    k_new: jax.Array,      # (S, Hkv, D) chunk K rows (batch-1 chunk)
    v_new: jax.Array,
    page_idx: jax.Array,   # (S,) physical page per row (null page = masked)
    row: jax.Array,        # (S,) row within each page
) -> Tuple[jax.Array, jax.Array]:
    """Scatter a prefill chunk's K/V rows into the page pool.

    O(S) rows of data move regardless of impl, so the jnp scatter IS the
    efficient form on every backend (unlike the per-token decode write,
    where the dense layout's masked select touches O(B·T) and the Pallas
    page rewrite wins).  Masked rows target the null page 0; collisions
    there are harmless garbage.
    """
    k_pool = k_pool.at[page_idx, row].set(k_new.astype(k_pool.dtype))
    v_pool = v_pool.at[page_idx, row].set(v_new.astype(v_pool.dtype))
    return k_pool, v_pool


def paged_kv_update(
    k_pool: jax.Array,     # (num_pages, page, Hkv, D)
    v_pool: jax.Array,
    k_new: jax.Array,      # (B, 1, Hkv, D) decode-token K per slot
    v_new: jax.Array,
    page_idx: jax.Array,   # (B,) physical page holding each slot's write pos
    row: jax.Array,        # (B,) row within the page (pos % page)
    *,
    impl: str = "auto",
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Insert one decode token per slot at (page_idx, row): O(B·page).

    Replaces the dense layout's O(B·T) one-hot masked select
    (``models/attention.py``).  ``pallas`` rewrites exactly one pool page
    per slot in place (donated pools); ``xla``/``naive`` is the
    equivalent jnp scatter.
    """
    impl, interpret = _resolve(impl, interpret)
    if impl == "pallas":
        from repro.kernels.paged_attention import paged_kv_write

        return paged_kv_write(
            k_pool, v_pool, k_new, v_new, page_idx, row, interpret=interpret
        )
    k_pool = k_pool.at[page_idx, row].set(k_new[:, 0].astype(k_pool.dtype))
    v_pool = v_pool.at[page_idx, row].set(v_new[:, 0].astype(v_pool.dtype))
    return k_pool, v_pool


# --------------------------------------------------------------------- #
# fused sampling (serving): per-slot top-k/top-p filter + categorical
# --------------------------------------------------------------------- #
def sample_tokens(
    logits: jax.Array,       # (B, V) last-position logits
    temperature: jax.Array,  # (B,) f32; <= 0 means greedy argmax
    top_k: jax.Array,        # (B,) i32; 0 disables the top-k filter
    top_p: jax.Array,        # (B,) f32; 1.0 disables the top-p filter
    seed: jax.Array,         # (B,) per-request PRNG seed
    step: jax.Array,         # (B,) generation index (tokens emitted so far)
    *,
    impl: str = "auto",
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Sample one token per row with heterogeneous per-row params.

    Returns ``(tok (B,) i32, logp (B,) f32)`` — the chosen token and its
    log-probability under the filtered, temperature-scaled, renormalized
    distribution (greedy rows: under the full T=1 softmax).

    Selection runs entirely on device: ``pallas`` is the fused VMEM
    kernel (dual-bisection thresholds + counter-based gumbel-max,
    ``sampling.py``), ``xla`` is the same row math batched over B (the
    two agree token-for-token — the noise stream is a pure integer hash
    of (seed, step, vocab id), not backend PRNG state).  ``naive`` is the
    sort-based oracle in ``ref.py``.  Called inside the serving engine's
    jitted decode step so token selection adds zero host syncs.
    """
    impl, interpret = _resolve(impl, interpret)
    from repro.kernels import sampling as _sp

    if impl == "pallas":
        return _sp.fused_sample(
            logits, temperature, top_k, top_p, seed, step, interpret=interpret
        )
    if impl == "naive":
        return ref.sample_ref(logits, temperature, top_k, top_p, seed, step)
    return _sp.sample_xla(logits, temperature, top_k, top_p, seed, step)


# --------------------------------------------------------------------- #
# norms
#
# The xla paths use custom VJPs engineered so every FULL-SIZE fusion output
# stays in the input dtype (bf16); only per-row statistics are fp32.  The
# autodiff'd fp32-math norm materializes fp32 residual-stream buffers in
# fwd+bwd+remat — found via the dry-run traffic breakdown (llama3-405b:
# 48% of HBM traffic; EXPERIMENTS.md §Perf llama3 iter-2).  This mirrors
# what the fused Pallas/Apex norm kernels do on real hardware.
# --------------------------------------------------------------------- #
@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def _rmsnorm_xla(x, w, eps):
    return ref.rmsnorm_ref(x, w, eps)


def _rms_fwd(x, w, eps):
    xf = x.astype(jnp.float32)
    rstd = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)  # (..,1)
    y = (xf * rstd * w.astype(jnp.float32)).astype(x.dtype)
    return y, (x, w, rstd)


def _rms_bwd(eps, res, dy):
    x, w, rstd = res
    D = x.shape[-1]
    dyw = (dy * w).astype(jnp.float32)          # fused: read dy,w -> temp
    xf = x.astype(jnp.float32)
    # per-row scalar: (dy.w . xhat) / D
    c = jnp.sum(dyw * xf, axis=-1, keepdims=True) * (rstd * rstd) / D   # (..,1)
    dx = ((dyw - xf * c) * rstd).astype(x.dtype)
    dw = jnp.sum((dy.astype(jnp.float32)) * xf * rstd, axis=tuple(range(x.ndim - 1)))
    return dx, dw.astype(w.dtype)


_rmsnorm_xla.defvjp(_rms_fwd, _rms_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _layernorm_xla(x, w, b, eps):
    return ref.layernorm_ref(x, w, b, eps)


def _ln_fwd(x, w, b, eps):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    xc = xf - mu
    rstd = jax.lax.rsqrt(jnp.mean(xc * xc, axis=-1, keepdims=True) + eps)
    y = xc * rstd * w.astype(jnp.float32)
    if b is not None:
        y = y + b.astype(jnp.float32)
    return y.astype(x.dtype), (x, w, mu, rstd)


def _ln_bwd(eps, res, dy):
    x, w, mu, rstd = res
    D = x.shape[-1]
    xhat_f = (x.astype(jnp.float32) - mu) * rstd
    dyw = (dy * w).astype(jnp.float32)
    c1 = jnp.mean(dyw, axis=-1, keepdims=True)
    c2 = jnp.mean(dyw * xhat_f, axis=-1, keepdims=True)
    dx = ((dyw - c1 - xhat_f * c2) * rstd).astype(x.dtype)
    dyf = dy.astype(jnp.float32)
    axes = tuple(range(x.ndim - 1))
    dw = jnp.sum(dyf * xhat_f, axis=axes).astype(w.dtype)
    db = jnp.sum(dyf, axis=axes).astype(w.dtype)
    return dx, dw, db


_layernorm_xla.defvjp(_ln_fwd, _ln_bwd)


# pallas norm kernels are forward-only; pair them with the hand-written
# xla backward formulas above so the pallas paths stay trainable (per-row
# statistics are recomputed in bwd — cheaper than saving them from VMEM)
@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def _rmsnorm_pallas(x, w, eps, interpret):
    return _rms_pallas(x, w, eps, interpret=interpret)


def _rms_pallas_fwd(x, w, eps, interpret):
    return _rmsnorm_pallas(x, w, eps, interpret), (x, w)


def _rms_pallas_bwd(eps, interpret, res, dy):
    x, w = res
    xf = x.astype(jnp.float32)
    rstd = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return _rms_bwd(eps, (x, w, rstd), dy)


_rmsnorm_pallas.defvjp(_rms_pallas_fwd, _rms_pallas_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _layernorm_pallas(x, w, b, eps, interpret):
    return _ln_pallas(x, w, b, eps, interpret=interpret)


def _ln_pallas_fwd(x, w, b, eps, interpret):
    return _layernorm_pallas(x, w, b, eps, interpret), (x, w, b)


def _ln_pallas_bwd(eps, interpret, res, dy):
    x, w, b = res
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    xc = xf - mu
    rstd = jax.lax.rsqrt(jnp.mean(xc * xc, axis=-1, keepdims=True) + eps)
    dx, dw, db = _ln_bwd(eps, (x, w, mu, rstd), dy)
    return dx, dw, (None if b is None else db)


_layernorm_pallas.defvjp(_ln_pallas_fwd, _ln_pallas_bwd)


def rmsnorm(x, w, eps: float = 1e-5, *, impl: str = "auto", interpret: bool = False):
    impl, interpret = _resolve(impl, interpret)
    if impl == "pallas":
        return _rmsnorm_pallas(x, w, eps, interpret)
    if impl == "naive":
        return ref.rmsnorm_ref(x, w, eps)
    return _rmsnorm_xla(x, w, eps)


def layernorm(x, w, b=None, eps: float = 1e-5, *, impl: str = "auto", interpret: bool = False):
    impl, interpret = _resolve(impl, interpret)
    if impl == "pallas":
        return _layernorm_pallas(x, w, b, eps, interpret)
    if impl == "naive":
        return ref.layernorm_ref(x, w, b, eps)
    if b is None:
        # reuse the 3-arg vjp with a zero bias to keep one code path
        return _layernorm_xla(x, w, jnp.zeros_like(w), eps)
    return _layernorm_xla(x, w, b, eps)


# --------------------------------------------------------------------- #
# fused cross-entropy
# --------------------------------------------------------------------- #
def _blockwise_ce_xla(hidden, w_out, targets, *, vocab, block_v=2048):
    """lse via checkpointed scan over vocab blocks; logits never materialize.

    The matmuls run in the input dtype with fp32 ACCUMULATION
    (preferred_element_type) instead of upcasting `hidden` to fp32 — an
    upfront fp32 cast makes the hidden cotangent fp32 and cascades fp32
    residual-stream buffers through the entire backward pass (found via
    the dry-run traffic breakdown; EXPERIMENTS.md §Perf llama3 iter-1)."""
    T, D = hidden.shape
    Vp = w_out.shape[1]
    # zero-pad the vocab tail; masked below via col < vocab
    block_v, Vpp = tiling.pick_block(Vp, block_v)
    w_pad = tiling.pad_dim(w_out, 1, Vpp)
    nblk = Vpp // block_v
    wb = jnp.moveaxis(w_pad.reshape(D, nblk, block_v), 1, 0)  # (nblk, D, bv)

    def body(_, blk):
        wblk, vi = blk
        logits = jax.lax.dot_general(
            hidden, wblk, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                                      # (T, bv) fp32
        col = vi * block_v + jnp.arange(block_v)
        logits = jnp.where(col[None, :] < vocab, logits, NEG_INF)
        blk_lse = jax.scipy.special.logsumexp(logits, axis=-1)  # (T,)
        return None, blk_lse

    _, blk_lses = jax.lax.scan(jax.checkpoint(body), None, (wb, jnp.arange(nblk)))
    lse = jax.scipy.special.logsumexp(blk_lses, axis=0)         # (T,)
    w_tgt = jnp.take(w_out, targets, axis=1)                    # (D, T)
    tgt_logit = jnp.einsum(
        "td,dt->t", hidden, w_tgt, preferred_element_type=jnp.float32
    )
    return lse - tgt_logit, lse


class _CECfg(NamedTuple):
    """Hashable static config for the pallas cross-entropy custom-VJP."""

    vocab: int
    block_t: int
    block_v: int
    interpret: bool


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _cross_entropy_pallas(cfg: _CECfg, hidden, w_out, targets):
    return _ce.fused_cross_entropy(
        hidden, w_out, targets, vocab=cfg.vocab,
        block_t=cfg.block_t, block_v=cfg.block_v, interpret=cfg.interpret,
    )


def _cross_entropy_pallas_fwd(cfg: _CECfg, hidden, w_out, targets):
    loss, lse = _cross_entropy_pallas(cfg, hidden, w_out, targets)
    return (loss, lse), (hidden, w_out, targets, lse)


def _cross_entropy_pallas_bwd(cfg: _CECfg, res, g):
    hidden, w_out, targets, lse = res
    g_loss, g_lse = g
    dh, dw = _ce.fused_cross_entropy_bwd(
        hidden, w_out, targets, lse, g_loss, g_lse, vocab=cfg.vocab,
        block_t=cfg.block_t, block_v=cfg.block_v, interpret=cfg.interpret,
    )
    return dh, dw, None  # targets are integer — no cotangent


_cross_entropy_pallas.defvjp(_cross_entropy_pallas_fwd, _cross_entropy_pallas_bwd)


def cross_entropy(
    hidden: jax.Array,
    w_out: jax.Array,
    targets: jax.Array,
    *,
    vocab: int = 0,
    impl: str = "auto",
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    vocab = vocab or w_out.shape[1]
    impl, interpret = _resolve(impl, interpret)
    if impl == "pallas":
        cfg = _CECfg(vocab=vocab, block_t=128, block_v=512, interpret=interpret)
        return _cross_entropy_pallas(cfg, hidden, w_out, targets)
    if impl == "naive":
        return ref.cross_entropy_ref(hidden, w_out[:, :vocab], targets)
    return _blockwise_ce_xla(hidden, w_out, targets, vocab=vocab)


# --------------------------------------------------------------------- #
# Mamba-2 SSD
# --------------------------------------------------------------------- #
def _ssd_chunked_xla(x, dt, A, Bm, Cm, D, *, chunk=64, init_state=None):
    """Chunked dual form as jnp (mirrors the kernel math), scan over chunks."""
    Bsz, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    group = H // G
    chunk = min(chunk, S)
    if S % chunk:
        chunk = S
    nc = S // chunk

    xf = x.astype(jnp.float32).reshape(Bsz, nc, chunk, H, P)
    dtf = dt.astype(jnp.float32).reshape(Bsz, nc, chunk, H)
    Bf = jnp.repeat(Bm.astype(jnp.float32), group, axis=2).reshape(Bsz, nc, chunk, H, N)
    Cf = jnp.repeat(Cm.astype(jnp.float32), group, axis=2).reshape(Bsz, nc, chunk, H, N)
    Af = A.astype(jnp.float32)

    causal = jnp.tril(jnp.ones((chunk, chunk), bool))

    def body(h, blk):
        xc, dtc, bc, cc = blk  # (B,chunk,H,*)
        da = dtc * Af[None, None, :]                 # (B,L,H)
        cum = jnp.cumsum(da, axis=1)
        seg = cum[:, -1]                             # (B,H)
        diff = cum[:, :, None, :] - cum[:, None, :, :]   # (B,L,L,H)
        decay = jnp.where(causal[None, :, :, None], jnp.exp(diff), 0.0)
        scores = jnp.einsum("blhn,bshn->blsh", cc, bc)
        # the (L × L) attention-like weights feed an MXU matmul: store them
        # in the input dtype with fp32 accumulation (EXPERIMENTS §Perf
        # jamba iter-4) — decay statistics stay fp32.
        att = (scores * decay * dtc[:, None, :, :]).astype(x.dtype)
        y = jnp.einsum(
            "blsh,bshp->blhp", att, xc.astype(x.dtype),
            preferred_element_type=jnp.float32,
        )
        decay_in = jnp.exp(cum)                      # (B,L,H)
        y += jnp.einsum("blhn,bhpn,blh->blhp", cc, h, decay_in)
        decay_out = jnp.exp(seg[:, None, :] - cum)   # (B,L,H)
        xw = xc * (dtc * decay_out)[..., None]
        h = h * jnp.exp(seg)[..., None, None] + jnp.einsum("blhp,blhn->bhpn", xw, bc)
        return h, y

    h0 = (
        jnp.zeros((Bsz, H, P, N), jnp.float32)
        if init_state is None
        else init_state.astype(jnp.float32)
    )
    xs = (
        jnp.moveaxis(xf, 1, 0),
        jnp.moveaxis(dtf, 1, 0),
        jnp.moveaxis(Bf, 1, 0),
        jnp.moveaxis(Cf, 1, 0),
    )
    hT, ys = jax.lax.scan(jax.checkpoint(body), h0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(Bsz, S, H, P)
    y = y + x.astype(jnp.float32) * D.astype(jnp.float32)[None, None, :, None]
    return y.astype(x.dtype), hT


def ssd(
    x, dt, A, Bm, Cm, D, *, chunk: int = 64, impl: str = "auto", interpret: bool = False
):
    impl, interpret = _resolve(impl, interpret)
    if impl == "pallas":
        return _ssd_pallas(x, dt, A, Bm, Cm, D, chunk=chunk, interpret=interpret)
    if impl == "naive":
        return ref.ssd_ref(x, dt, A, Bm, Cm, D)
    return _ssd_chunked_xla(x, dt, A, Bm, Cm, D, chunk=chunk)


def ssd_decode_step(
    x: jax.Array,      # (B, 1, H, P)
    dt: jax.Array,     # (B, 1, H)
    A: jax.Array,      # (H,)
    Bm: jax.Array,     # (B, 1, G, N)
    Cm: jax.Array,     # (B, 1, G, N)
    D: jax.Array,      # (H,)
    state: jax.Array,  # (B, H, P, N)
) -> Tuple[jax.Array, jax.Array]:
    """One recurrent SSD step (serving).  Returns (y (B,1,H,P), new_state)."""
    H = x.shape[2]
    G = Bm.shape[2]
    group = H // G
    xf = x[:, 0].astype(jnp.float32)               # (B,H,P)
    dtf = dt[:, 0].astype(jnp.float32)             # (B,H)
    bf = jnp.repeat(Bm[:, 0].astype(jnp.float32), group, axis=1)  # (B,H,N)
    cf = jnp.repeat(Cm[:, 0].astype(jnp.float32), group, axis=1)
    decay = jnp.exp(dtf * A.astype(jnp.float32)[None, :])[..., None, None]
    upd = (dtf[..., None] * xf)[..., :, None] * bf[..., None, :]
    new_state = state.astype(jnp.float32) * decay + upd
    y = jnp.einsum("bhpn,bhn->bhp", new_state, cf)
    y = y + xf * D.astype(jnp.float32)[None, :, None]
    return y[:, None].astype(x.dtype), new_state.astype(state.dtype)


# --------------------------------------------------------------------- #
# ragged grouped matmul (MoE expert GEMMs)
# --------------------------------------------------------------------- #
class _GmmCfg(NamedTuple):
    """Hashable static config for the pallas grouped-matmul custom-VJP."""

    block_m: int
    block_n: int
    interpret: bool


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _gmm_pallas(cfg: _GmmCfg, x, w, group_sizes):
    from repro.kernels import grouped_matmul as _gm

    return _gm.gmm(
        x, w, group_sizes,
        block_m=cfg.block_m, block_n=cfg.block_n, interpret=cfg.interpret,
    )


def _gmm_pallas_fwd(cfg: _GmmCfg, x, w, group_sizes):
    return _gmm_pallas(cfg, x, w, group_sizes), (x, w, group_sizes)


def _gmm_pallas_bwd(cfg: _GmmCfg, res, dy):
    from repro.kernels import grouped_matmul as _gm

    x, w, gs = res
    dx = _gm.gmm(
        dy, jnp.swapaxes(w, 1, 2), gs,
        block_m=cfg.block_m, block_n=cfg.block_n, interpret=cfg.interpret,
    ).astype(x.dtype)
    dw = _gm.gmm_dw(
        x, dy, gs,
        block_m=cfg.block_m, block_n=cfg.block_n, interpret=cfg.interpret,
    ).astype(w.dtype)
    return dx, dw, None  # group sizes are integer — no cotangent


_gmm_pallas.defvjp(_gmm_pallas_fwd, _gmm_pallas_bwd)


def _gmm_xla(x, w, group_sizes):
    """XLA fallback: ``lax.ragged_dot`` (differentiable, CPU/GPU/TPU).

    Rows past ``sum(group_sizes)`` are masked to zero to match the
    kernel contract (the dropped-token tail in ``models/moe.py``)."""
    y = jax.lax.ragged_dot(
        x, w, group_sizes.astype(jnp.int32),
        preferred_element_type=jnp.float32,
    )
    rows = jnp.arange(x.shape[0], dtype=jnp.int32)
    total = jnp.sum(group_sizes.astype(jnp.int32))
    y = jnp.where((rows < total)[:, None], y, 0.0)
    return y.astype(x.dtype)


def _gmm_xla_bounded(x, w, group_sizes, max_size: int):
    """XLA fallback when a static per-group row bound is known (MoE always
    has one: the capacity).  Scatters rows into a static ``(E, max_size,
    K)`` buffer and runs ONE batched GEMM — ``O(E·max_size·K·N)`` FLOPs,
    independent of E for fixed total capacity, where ``lax.ragged_dot``
    lowers to a dense masked loop (``O(M·E·K·N)``) on CPU/GPU.  Rows of a
    group beyond ``max_size`` (contract violation) come back zero, as do
    rows past ``sum(group_sizes)``.  Natively differentiable."""
    E = w.shape[0]
    m = jnp.arange(x.shape[0], dtype=jnp.int32)
    sizes = group_sizes.astype(jnp.int32)
    ends = jnp.cumsum(sizes)
    gid = jnp.searchsorted(ends, m, side="right")
    g = jnp.minimum(gid, E - 1)
    rank = m - (ends - sizes)[g]
    valid = (m < ends[-1]) & (rank < max_size)
    xe = jnp.zeros((E, max_size, x.shape[1]), x.dtype)
    xe = xe.at[jnp.where(valid, g, E), rank].set(x, mode="drop")
    ye = jnp.einsum(
        "eck,ekn->ecn", xe, w, preferred_element_type=jnp.float32
    )
    y = jnp.where(valid[:, None], ye[g, rank], 0.0)
    return y.astype(x.dtype)


def grouped_matmul(
    x: jax.Array,            # (M, K) rows sorted by group
    w: jax.Array,            # (E, K, N) per-group (expert) weights
    group_sizes: jax.Array,  # (E,) int32 contiguous row counts (dynamic)
    *,
    impl: str = "auto",
    interpret: bool = False,
    max_group_size: Optional[int] = None,
) -> jax.Array:
    """Ragged grouped matmul ``y[i] = x[i] @ w[g(i)]`` — the MoE expert
    FFN after sort-by-expert dispatch.  Differentiable end-to-end on
    every impl: ``pallas`` pairs the ragged forward kernel with the
    ragged dX/dW backward kernels via ``jax.custom_vjp``
    (``grouped_matmul.py``), ``xla`` is ``lax.ragged_dot`` — or, when
    the caller supplies ``max_group_size`` (a static upper bound on every
    group, e.g. the MoE capacity), the capacity-batched GEMM
    ``_gmm_xla_bounded`` whose cost does not grow with E — and ``naive``
    the (M, K, N) gather oracle in ``ref.py``.  Rows past
    ``sum(group_sizes)`` (capacity-dropped slots) come back zero."""
    impl, interpret = _resolve(impl, interpret)
    if impl == "pallas":
        cfg = _GmmCfg(block_m=128, block_n=128, interpret=interpret)
        return _gmm_pallas(cfg, x, w, group_sizes)
    if impl == "naive":
        return ref.grouped_matmul_ref(x, w, group_sizes)
    if max_group_size is not None:
        return _gmm_xla_bounded(x, w, group_sizes, int(max_group_size))
    return _gmm_xla(x, w, group_sizes)
