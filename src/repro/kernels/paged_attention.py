"""Pallas TPU paged-attention decode/prefill + paged KV scatter write.

The serving engine's paged KV cache stores tokens in fixed-size pages of a
shared pool (``(num_pages, page, Hkv, D)``); a per-slot block table maps
logical cache positions to physical pages (``serving/paged_cache.py``).
Three kernels make that layout a first-class serving path:

``paged_flash_decode``
    The flash-decoding combine of ``flash_decode.py`` with the contiguous
    cache replaced by block-table indirection: grid
    (batch, kv_heads, pages_per_seq), and the K/V *page* tile for grid
    step ``(b, h, p)`` is gathered straight out of the pool by the
    BlockSpec index map reading the prefetched block table
    (``PrefetchScalarGridSpec``) — the gather is the DMA, no
    materialized (B, T) cache ever exists.  Combine state (m, l, acc)
    lives in VMEM scratch across the sequential page axis, exactly like
    the contiguous kernel.

``paged_flash_prefill``
    Chunked/suffix prefill attention through the same block table: the
    query block is a whole *chunk* of ``S`` tokens sitting at logical
    positions ``starts[b] + i`` (``starts`` supports prefix-cache skips
    and chunked prefill — the chunk attends to every already-written
    page, including pages shared from the prefix cache, plus itself,
    under a causal mask shifted by the query offset).  Same grid and
    VMEM running-LSE combine as the decode kernel, with (S·group) query
    rows instead of ``group``.

``paged_kv_write``
    Per-token decode cache insert: grid (B,), each step rewrites ONE page
    (the page holding ``pos``) with the new token placed at row
    ``pos % page``.  The pool rides through ``input_output_aliases`` so
    the op is an in-place O(B·page) scatter — replacing the O(B·T)
    one-hot masked select the dense per-slot layout needs
    (``models/attention.py``).

Unallocated block-table entries point at the reserved null page 0; slots
with ``length == 0`` read (and may write) only that page, so collisions
there are harmless garbage — page 0 is never attributed to a sequence.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

NEG_INF = -1e30


# --------------------------------------------------------------------- #
# decode attention through the block table
# --------------------------------------------------------------------- #
def _pa_kernel(
    bt_ref,      # (B, pages_per_seq) scalar-prefetch block table
    len_ref,     # (B,) scalar-prefetch valid lengths
    q_ref,       # (1, 1, 1, group, D)
    k_ref,       # (1, page, 1, D)  — the page picked by the index map
    v_ref,
    o_ref,       # (1, 1, 1, group, D)
    m_scr, l_scr, acc_scr,
    *,
    scale: float,
    page: int,
    p_steps: int,
    softcap: float,
):
    b = pl.program_id(0)
    pi = pl.program_id(2)

    @pl.when(pi == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0, 0].astype(jnp.float32)         # (group, D)
    k = k_ref[0, :, 0].astype(jnp.float32)         # (page, D)
    v = v_ref[0, :, 0].astype(jnp.float32)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                                       # (group, page)
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)

    # logical position of each page row; pages past the valid length are
    # the null page — masked out entirely (m stays NEG_INF for len==0).
    pos = pi * page + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(pos < len_ref[b], s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    p = jnp.where(m_new <= NEG_INF / 2, 0.0, p)
    alpha = jnp.exp(m_prev - m_new)
    m_scr[...] = m_new
    l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(pi == p_steps - 1)
    def _final():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def paged_flash_decode(
    q: jax.Array,            # (B, 1, H, D)
    k_pool: jax.Array,       # (num_pages, page, Hkv, D)
    v_pool: jax.Array,
    block_table: jax.Array,  # (B, pages_per_seq) int32 physical page ids
    lengths: jax.Array,      # (B,) int32 valid cache length
    *,
    softcap: float = 0.0,
    interpret: bool = False,
) -> jax.Array:
    B, _, H, D = q.shape
    page, Hkv = k_pool.shape[1], k_pool.shape[2]
    pages_per_seq = block_table.shape[1]
    assert H % Hkv == 0
    group = H // Hkv
    scale = 1.0 / math.sqrt(D)

    qg = q.reshape(B, 1, Hkv, group, D)

    kernel = functools.partial(
        _pa_kernel,
        scale=scale, page=page, p_steps=pages_per_seq, softcap=softcap,
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,      # block_table, lengths
            grid=(B, Hkv, pages_per_seq),
            in_specs=[
                pl.BlockSpec(
                    (1, 1, 1, group, D), lambda b, h, pi, bt, ln: (b, 0, h, 0, 0)
                ),
                pl.BlockSpec(
                    (1, page, 1, D), lambda b, h, pi, bt, ln: (bt[b, pi], 0, h, 0)
                ),
                pl.BlockSpec(
                    (1, page, 1, D), lambda b, h, pi, bt, ln: (bt[b, pi], 0, h, 0)
                ),
            ],
            out_specs=pl.BlockSpec(
                (1, 1, 1, group, D), lambda b, h, pi, bt, ln: (b, 0, h, 0, 0)
            ),
            scratch_shapes=[
                pltpu.VMEM((group, 1), jnp.float32),
                pltpu.VMEM((group, 1), jnp.float32),
                pltpu.VMEM((group, D), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, 1, Hkv, group, D), q.dtype),
        interpret=interpret,
    )(block_table.astype(jnp.int32), lengths.astype(jnp.int32), qg, k_pool, v_pool)
    return out.reshape(B, 1, H, D)


# --------------------------------------------------------------------- #
# chunked/suffix prefill attention through the block table
# --------------------------------------------------------------------- #
def _pp_kernel(
    bt_ref,      # (B, pages_per_seq) scalar-prefetch block table
    start_ref,   # (B,) scalar-prefetch query offset (first query's position)
    len_ref,     # (B,) scalar-prefetch total valid context length
    q_ref,       # (1, S, 1, group, D)
    k_ref,       # (1, page, 1, D)  — the page picked by the index map
    v_ref,
    o_ref,       # (1, S, 1, group, D)
    m_scr, l_scr, acc_scr,    # (S·group, 1/1/D)
    *,
    scale: float,
    page: int,
    p_steps: int,
    group: int,
    softcap: float,
):
    b = pl.program_id(0)
    pi = pl.program_id(2)

    @pl.when(pi == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    S = q_ref.shape[1]
    q = q_ref[0, :, 0].astype(jnp.float32).reshape(S * group, -1)  # (S·g, D)
    k = k_ref[0, :, 0].astype(jnp.float32)                         # (page, D)
    v = v_ref[0, :, 0].astype(jnp.float32)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                                                      # (S·g, page)
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)

    # causal mask shifted by the query offset: query row r (token index
    # r // group within the chunk) sits at logical position start + r//group
    # and may attend to k positions <= its own; pages past the valid
    # length (incl. the null page in unallocated entries) are masked out.
    q_pos = start_ref[b] + (
        jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) // group
    )
    k_pos = pi * page + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where((k_pos <= q_pos) & (k_pos < len_ref[b]), s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    p = jnp.where(m_new <= NEG_INF / 2, 0.0, p)
    alpha = jnp.exp(m_prev - m_new)
    m_scr[...] = m_new
    l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(pi == p_steps - 1)
    def _final():
        denom = jnp.maximum(l_scr[...], 1e-30)
        out = (acc_scr[...] / denom).reshape(S, group, -1)
        o_ref[0, :, 0] = out.astype(o_ref.dtype)


def paged_flash_prefill(
    q: jax.Array,            # (B, S, H, D) chunk queries
    k_pool: jax.Array,       # (num_pages, page, Hkv, D)
    v_pool: jax.Array,
    block_table: jax.Array,  # (B, pages_per_seq) int32 physical page ids
    starts: jax.Array,       # (B,) int32 logical position of query row 0
    lengths: jax.Array,      # (B,) int32 total valid context (start + valid)
    *,
    softcap: float = 0.0,
    interpret: bool = False,
) -> jax.Array:
    B, S, H, D = q.shape
    page, Hkv = k_pool.shape[1], k_pool.shape[2]
    pages_per_seq = block_table.shape[1]
    assert H % Hkv == 0
    group = H // Hkv
    scale = 1.0 / math.sqrt(D)

    qg = q.reshape(B, S, Hkv, group, D)

    kernel = functools.partial(
        _pp_kernel,
        scale=scale, page=page, p_steps=pages_per_seq, group=group,
        softcap=softcap,
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,      # block_table, starts, lengths
            grid=(B, Hkv, pages_per_seq),
            in_specs=[
                pl.BlockSpec(
                    (1, S, 1, group, D),
                    lambda b, h, pi, bt, st, ln: (b, 0, h, 0, 0),
                ),
                pl.BlockSpec(
                    (1, page, 1, D),
                    lambda b, h, pi, bt, st, ln: (bt[b, pi], 0, h, 0),
                ),
                pl.BlockSpec(
                    (1, page, 1, D),
                    lambda b, h, pi, bt, st, ln: (bt[b, pi], 0, h, 0),
                ),
            ],
            out_specs=pl.BlockSpec(
                (1, S, 1, group, D),
                lambda b, h, pi, bt, st, ln: (b, 0, h, 0, 0),
            ),
            scratch_shapes=[
                pltpu.VMEM((S * group, 1), jnp.float32),
                pltpu.VMEM((S * group, 1), jnp.float32),
                pltpu.VMEM((S * group, D), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, S, Hkv, group, D), q.dtype),
        interpret=interpret,
    )(
        block_table.astype(jnp.int32), starts.astype(jnp.int32),
        lengths.astype(jnp.int32), qg, k_pool, v_pool,
    )
    return out.reshape(B, S, H, D)


# --------------------------------------------------------------------- #
# per-token scatter write
# --------------------------------------------------------------------- #
def _kv_write_kernel(
    page_idx_ref,   # (B,) scalar-prefetch physical page per slot
    row_ref,        # (B,) scalar-prefetch row (pos % page) per slot
    kn_ref,         # (1, 1, Hkv, D) new K token for this slot
    vn_ref,
    kin_ref,        # (1, page, Hkv, D) current page content (aliased pool)
    vin_ref,
    kout_ref,       # (1, page, Hkv, D) rewritten page
    vout_ref,
    *,
    page: int,
):
    b = pl.program_id(0)
    r = row_ref[b]
    rows = jax.lax.broadcasted_iota(jnp.int32, (page, 1, 1), 0)
    hit = rows == r
    kout_ref[0] = jnp.where(hit, kn_ref[0].astype(kout_ref.dtype), kin_ref[0])
    vout_ref[0] = jnp.where(hit, vn_ref[0].astype(vout_ref.dtype), vin_ref[0])


def paged_kv_write(
    k_pool: jax.Array,     # (num_pages, page, Hkv, D)
    v_pool: jax.Array,
    k_new: jax.Array,      # (B, 1, Hkv, D)
    v_new: jax.Array,
    page_idx: jax.Array,   # (B,) physical page holding each slot's write pos
    row: jax.Array,        # (B,) row within that page (pos % page)
    *,
    interpret: bool = False,
):
    """In-place O(B·page) decode-token insert; returns the updated pools.

    Each grid step rewrites exactly the page its slot owns at the write
    position; pages of distinct active slots are disjoint by construction
    (the allocator hands a page to one sequence), so steps never race on
    live data.  Inactive slots all target the null page 0 — those writes
    may collide, but page 0 holds no sequence.
    """
    B = k_new.shape[0]
    P, page, Hkv, D = k_pool.shape
    kernel = functools.partial(_kv_write_kernel, page=page)
    new_k, new_v = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,      # page_idx, row
            grid=(B,),
            in_specs=[
                pl.BlockSpec((1, 1, Hkv, D), lambda b, pi, ri: (b, 0, 0, 0)),
                pl.BlockSpec((1, 1, Hkv, D), lambda b, pi, ri: (b, 0, 0, 0)),
                pl.BlockSpec((1, page, Hkv, D), lambda b, pi, ri: (pi[b], 0, 0, 0)),
                pl.BlockSpec((1, page, Hkv, D), lambda b, pi, ri: (pi[b], 0, 0, 0)),
            ],
            out_specs=[
                pl.BlockSpec((1, page, Hkv, D), lambda b, pi, ri: (pi[b], 0, 0, 0)),
                pl.BlockSpec((1, page, Hkv, D), lambda b, pi, ri: (pi[b], 0, 0, 0)),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct(k_pool.shape, k_pool.dtype),
            jax.ShapeDtypeStruct(v_pool.shape, v_pool.dtype),
        ],
        # pools are donated: operand indices count the scalar-prefetch args
        # (page_idx=0, row=1, k_new=2, v_new=3, k_pool=4, v_pool=5)
        input_output_aliases={4: 0, 5: 1},
        interpret=interpret,
    )(
        page_idx.astype(jnp.int32), row.astype(jnp.int32),
        k_new, v_new, k_pool, v_pool,
    )
    return new_k, new_v
