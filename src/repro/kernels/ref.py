"""Pure-jnp oracles for every Pallas kernel.

Each function here is the *semantic definition* the kernels are tested
against (tests/test_kernels_*.py sweep shapes/dtypes and assert_allclose).
These are written for clarity, not memory efficiency — the memory-bounded
jnp implementations used in real compute paths live in ``ops.py``.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


# --------------------------------------------------------------------- #
# attention
# --------------------------------------------------------------------- #
def attention_ref(
    q: jax.Array,          # (B, S, H, D)
    k: jax.Array,          # (B, T, Hkv, D)
    v: jax.Array,          # (B, T, Hkv, D)
    *,
    causal: bool = True,
    window: int = 0,       # 0 = full
    softcap: float = 0.0,
    q_offset: int = 0,     # position of q[0] within the kv sequence (decode)
) -> jax.Array:
    B, S, H, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    group = H // Hkv
    kf = jnp.repeat(k, group, axis=2)  # (B, T, H, D)
    vf = jnp.repeat(v, group, axis=2)
    scale = 1.0 / jnp.sqrt(jnp.asarray(D, jnp.float32))
    s = jnp.einsum("bshd,bthd->bhst", q.astype(jnp.float32), kf.astype(jnp.float32)) * scale
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)
    q_pos = jnp.arange(S) + q_offset
    k_pos = jnp.arange(T)
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= k_pos[None, :] <= q_pos[:, None]
    if window > 0:
        mask &= k_pos[None, :] > (q_pos[:, None] - window)
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhst,bthd->bshd", p, vf.astype(jnp.float32))
    return out.astype(q.dtype)


# --------------------------------------------------------------------- #
# norms
# --------------------------------------------------------------------- #
def rmsnorm_ref(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)
    return y.astype(x.dtype)


def layernorm_ref(
    x: jax.Array, w: jax.Array, b: Optional[jax.Array], eps: float = 1e-5
) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)
    if b is not None:
        y = y + b.astype(jnp.float32)
    return y.astype(x.dtype)


# --------------------------------------------------------------------- #
# fused cross-entropy:  loss_t = lse(h_t @ W) - (h_t @ W)[y_t]
# --------------------------------------------------------------------- #
def cross_entropy_ref(
    hidden: jax.Array,     # (T, D)
    w_out: jax.Array,      # (D, V)
    targets: jax.Array,    # (T,) int32
) -> Tuple[jax.Array, jax.Array]:
    """Returns (per-token loss (T,), lse (T,)) in fp32."""
    logits = hidden.astype(jnp.float32) @ w_out.astype(jnp.float32)  # (T, V)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, targets[:, None], axis=-1)[:, 0]
    return lse - tgt, lse


# --------------------------------------------------------------------- #
# Mamba-2 SSD — sequential-scan oracle
#   h_t = exp(dt_t * A) h_{t-1} + dt_t * B_t x_t^T    (per head)
#   y_t = C_t . h_t + D x_t
# --------------------------------------------------------------------- #
def ssd_ref(
    x: jax.Array,      # (B, S, H, P)
    dt: jax.Array,     # (B, S, H)       (already softplus'd, >0)
    A: jax.Array,      # (H,)            (negative)
    Bm: jax.Array,     # (B, S, G, N)
    Cm: jax.Array,     # (B, S, G, N)
    D: jax.Array,      # (H,)
    init_state: Optional[jax.Array] = None,  # (B, H, P, N)
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y (B,S,H,P), final_state (B,H,P,N)). fp32 math."""
    Bsz, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf = jnp.repeat(Bm.astype(jnp.float32), rep, axis=2)  # (B,S,H,N)
    Cf = jnp.repeat(Cm.astype(jnp.float32), rep, axis=2)
    Af = A.astype(jnp.float32)
    h0 = (
        jnp.zeros((Bsz, H, P, N), jnp.float32)
        if init_state is None
        else init_state.astype(jnp.float32)
    )

    def step(h, inp):
        xt, dtt, bt, ct = inp  # (B,H,P),(B,H),(B,H,N),(B,H,N)
        decay = jnp.exp(dtt * Af[None, :])[..., None, None]         # (B,H,1,1)
        upd = (dtt[..., None] * xt)[..., :, None] * bt[..., None, :]  # (B,H,P,N)
        h = h * decay + upd
        y = jnp.einsum("bhpn,bhn->bhp", h, ct)
        return h, y

    xs = (
        jnp.moveaxis(xf, 1, 0),
        jnp.moveaxis(dtf, 1, 0),
        jnp.moveaxis(Bf, 1, 0),
        jnp.moveaxis(Cf, 1, 0),
    )
    hT, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1) + xf * D.astype(jnp.float32)[None, None, :, None]
    return y.astype(x.dtype), hT


# --------------------------------------------------------------------- #
# sampling
# --------------------------------------------------------------------- #
def sample_ref(
    logits: jax.Array,       # (B, V)
    temperature: jax.Array,  # (B,) f32; <= 0 = greedy
    top_k: jax.Array,        # (B,) i32; 0 disables
    top_p: jax.Array,        # (B,) f32; 1.0 disables
    seed: jax.Array,         # (B,)
    step: jax.Array,         # (B,)
) -> Tuple[jax.Array, jax.Array]:
    """Sort-based oracle for ``ops.sample_tokens``.

    Computes the exact top-k / top-p kept set by sorting the scaled
    logits (the textbook definition), then draws the token with the same
    counter-based gumbel noise the fused kernel uses — so on
    non-degenerate inputs (no two logits within bisection resolution of
    the filter boundary) it agrees token-for-token with ``xla``/
    ``pallas``.
    """
    from repro.kernels.sampling import NEG_INF, gumbel_noise

    x = logits.astype(jnp.float32)
    B, V = x.shape
    valid = x > NEG_INF / 2
    greedy = (temperature <= 0)[:, None]
    t = jnp.where(greedy, 1.0, temperature.astype(jnp.float32)[:, None])
    z = jnp.where(valid, x / t, NEG_INF)
    srt = jnp.sort(z, axis=-1)[:, ::-1]                # descending
    k = jnp.where(top_k <= 0, V, jnp.clip(top_k, 1, V))
    kth = jnp.take_along_axis(srt, (k - 1)[:, None], axis=-1)
    probs = jax.nn.softmax(srt, axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    # keep the minimal prefix whose mass reaches top_p (crossing token in)
    keep_sorted = (cum - probs) < jnp.clip(top_p, 1e-9, 1.0)[:, None]
    n = jnp.sum(keep_sorted, axis=-1, keepdims=True)
    pth = jnp.take_along_axis(srt, n - 1, axis=-1)
    tau = jnp.maximum(kth, pth)
    tau = jnp.where(greedy, jnp.float32(NEG_INF), tau)
    keep = valid & (z >= tau)

    idx = jnp.broadcast_to(jnp.arange(V, dtype=jnp.int32)[None], (B, V))
    g = jnp.where(
        greedy,
        0.0,
        gumbel_noise(
            seed.astype(jnp.uint32)[:, None],
            step.astype(jnp.uint32)[:, None],
            idx.astype(jnp.uint32),
        ),
    )
    y = jnp.where(keep, z + g, NEG_INF)
    tok = jnp.argmax(y, axis=-1)
    m = jnp.max(z, axis=-1)
    z_tok = jnp.take_along_axis(z, tok[:, None], axis=-1)[:, 0]
    Zf = jnp.sum(jnp.where(keep, jnp.exp(z - m[:, None]), 0.0), axis=-1)
    logp = z_tok - m - jnp.log(jnp.maximum(Zf, 1e-30))
    return tok.astype(jnp.int32), logp


def grouped_matmul_ref(
    x: jax.Array,            # (M, K) rows sorted by group
    w: jax.Array,            # (E, K, N)
    group_sizes: jax.Array,  # (E,) int32
) -> jax.Array:
    """Gather/scatter oracle for the ragged grouped matmul: materializes a
    per-row weight gather (M, K, N) — tests only.  Rows past
    ``sum(group_sizes)`` are zeroed, matching the kernel contract."""
    M = x.shape[0]
    E = w.shape[0]
    ends = jnp.cumsum(group_sizes.astype(jnp.int32))
    rows = jnp.arange(M, dtype=jnp.int32)
    gid = jnp.searchsorted(ends, rows, side="right")
    w_row = jnp.take(w, jnp.minimum(gid, E - 1), axis=0)     # (M, K, N)
    y = jnp.einsum(
        "mk,mkn->mn", x.astype(jnp.float32), w_row.astype(jnp.float32)
    )
    y = jnp.where((rows < ends[-1])[:, None], y, 0.0)
    return y.astype(x.dtype)
