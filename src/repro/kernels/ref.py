"""Pure-jnp oracles for every Pallas kernel.

Each function here is the *semantic definition* the kernels are tested
against (tests/test_kernels_*.py sweep shapes/dtypes and assert_allclose).
These are written for clarity, not memory efficiency — the memory-bounded
jnp implementations used in real compute paths live in ``ops.py``.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


# --------------------------------------------------------------------- #
# attention
# --------------------------------------------------------------------- #
def attention_ref(
    q: jax.Array,          # (B, S, H, D)
    k: jax.Array,          # (B, T, Hkv, D)
    v: jax.Array,          # (B, T, Hkv, D)
    *,
    causal: bool = True,
    window: int = 0,       # 0 = full
    softcap: float = 0.0,
    q_offset: int = 0,     # position of q[0] within the kv sequence (decode)
) -> jax.Array:
    B, S, H, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    group = H // Hkv
    kf = jnp.repeat(k, group, axis=2)  # (B, T, H, D)
    vf = jnp.repeat(v, group, axis=2)
    scale = 1.0 / jnp.sqrt(jnp.asarray(D, jnp.float32))
    s = jnp.einsum("bshd,bthd->bhst", q.astype(jnp.float32), kf.astype(jnp.float32)) * scale
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)
    q_pos = jnp.arange(S) + q_offset
    k_pos = jnp.arange(T)
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= k_pos[None, :] <= q_pos[:, None]
    if window > 0:
        mask &= k_pos[None, :] > (q_pos[:, None] - window)
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhst,bthd->bshd", p, vf.astype(jnp.float32))
    return out.astype(q.dtype)


# --------------------------------------------------------------------- #
# norms
# --------------------------------------------------------------------- #
def rmsnorm_ref(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)
    return y.astype(x.dtype)


def layernorm_ref(
    x: jax.Array, w: jax.Array, b: Optional[jax.Array], eps: float = 1e-5
) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)
    if b is not None:
        y = y + b.astype(jnp.float32)
    return y.astype(x.dtype)


# --------------------------------------------------------------------- #
# fused cross-entropy:  loss_t = lse(h_t @ W) - (h_t @ W)[y_t]
# --------------------------------------------------------------------- #
def cross_entropy_ref(
    hidden: jax.Array,     # (T, D)
    w_out: jax.Array,      # (D, V)
    targets: jax.Array,    # (T,) int32
) -> Tuple[jax.Array, jax.Array]:
    """Returns (per-token loss (T,), lse (T,)) in fp32."""
    logits = hidden.astype(jnp.float32) @ w_out.astype(jnp.float32)  # (T, V)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, targets[:, None], axis=-1)[:, 0]
    return lse - tgt, lse


# --------------------------------------------------------------------- #
# Mamba-2 SSD — sequential-scan oracle
#   h_t = exp(dt_t * A) h_{t-1} + dt_t * B_t x_t^T    (per head)
#   y_t = C_t . h_t + D x_t
# --------------------------------------------------------------------- #
def ssd_ref(
    x: jax.Array,      # (B, S, H, P)
    dt: jax.Array,     # (B, S, H)       (already softplus'd, >0)
    A: jax.Array,      # (H,)            (negative)
    Bm: jax.Array,     # (B, S, G, N)
    Cm: jax.Array,     # (B, S, G, N)
    D: jax.Array,      # (H,)
    init_state: Optional[jax.Array] = None,  # (B, H, P, N)
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y (B,S,H,P), final_state (B,H,P,N)). fp32 math."""
    Bsz, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf = jnp.repeat(Bm.astype(jnp.float32), rep, axis=2)  # (B,S,H,N)
    Cf = jnp.repeat(Cm.astype(jnp.float32), rep, axis=2)
    Af = A.astype(jnp.float32)
    h0 = (
        jnp.zeros((Bsz, H, P, N), jnp.float32)
        if init_state is None
        else init_state.astype(jnp.float32)
    )

    def step(h, inp):
        xt, dtt, bt, ct = inp  # (B,H,P),(B,H),(B,H,N),(B,H,N)
        decay = jnp.exp(dtt * Af[None, :])[..., None, None]         # (B,H,1,1)
        upd = (dtt[..., None] * xt)[..., :, None] * bt[..., None, :]  # (B,H,P,N)
        h = h * decay + upd
        y = jnp.einsum("bhpn,bhn->bhp", h, ct)
        return h, y

    xs = (
        jnp.moveaxis(xf, 1, 0),
        jnp.moveaxis(dtf, 1, 0),
        jnp.moveaxis(Bf, 1, 0),
        jnp.moveaxis(Cf, 1, 0),
    )
    hT, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1) + xf * D.astype(jnp.float32)[None, None, :, None]
    return y.astype(x.dtype), hT
