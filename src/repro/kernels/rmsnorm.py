"""Pallas TPU fused RMSNorm / LayerNorm (Apex-class fused norm).

Row-tiled: grid over blocks of tokens; each step loads a (block_rows ×
d_model) VMEM tile, computes the moments and normalizes in one pass (fp32
math), writes the tile back.  d_model up to 16384 → tile ≤ 16384·8·4B =
0.5 MB fp32 at block_rows=8, comfortably inside VMEM; for small d_model the
row block is widened.

Oracles: ``ref.rmsnorm_ref`` / ``ref.layernorm_ref``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * w_ref[...].astype(jnp.float32)[None, :]
    o_ref[...] = y.astype(o_ref.dtype)


def _layernorm_kernel(x_ref, w_ref, b_ref, o_ref, *, eps: float, use_bias: bool):
    x = x_ref[...].astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    xc = x - mu
    var = jnp.mean(xc * xc, axis=-1, keepdims=True)
    y = xc * jax.lax.rsqrt(var + eps) * w_ref[...].astype(jnp.float32)[None, :]
    if use_bias:
        y = y + b_ref[...].astype(jnp.float32)[None, :]
    o_ref[...] = y.astype(o_ref.dtype)


def _block_rows(n_rows: int, d: int) -> int:
    # target ~1 MB fp32 tiles
    target = max(1, (1 << 18) // max(d, 1))
    b = 1
    while b * 2 <= target and n_rows % (b * 2) == 0:
        b *= 2
    return b


def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-5, *, interpret: bool = False):
    orig_shape = x.shape
    d = orig_shape[-1]
    xf = x.reshape(-1, d)
    rows = xf.shape[0]
    br = _block_rows(rows, d)
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(rows // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=interpret,
    )(xf, w)
    return out.reshape(orig_shape)


def layernorm(
    x: jax.Array,
    w: jax.Array,
    b: Optional[jax.Array] = None,
    eps: float = 1e-5,
    *,
    interpret: bool = False,
):
    orig_shape = x.shape
    d = orig_shape[-1]
    xf = x.reshape(-1, d)
    rows = xf.shape[0]
    br = _block_rows(rows, d)
    use_bias = b is not None
    bb = b if use_bias else jnp.zeros((d,), x.dtype)
    out = pl.pallas_call(
        functools.partial(_layernorm_kernel, eps=eps, use_bias=use_bias),
        grid=(rows // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=interpret,
    )(xf, w, bb)
    return out.reshape(orig_shape)
