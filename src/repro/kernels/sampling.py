"""Pallas TPU fused sampling: per-slot top-k/top-p filter + categorical.

One kernel call samples the next token for every serving slot from a
(B, V) logit panel, with *heterogeneous* per-slot sampling params —
temperature, top-k, top-p and PRNG state are (B,) vectors, so a batch can
mix greedy protein-embedding traffic with high-temperature molecule
sampling (the MolMIM workload) in a single jitted decode step.  Grid is
(B,); each step owns one slot's full (padded) vocab row in VMEM and
writes two scalars: the sampled token id and its log-probability.

Three design points make this a single fused pass with no sort and no
host involvement:

* **Dual bisection thresholds.**  Top-k and top-p both reduce to "keep
  ``z >= tau``" for a per-row threshold.  Instead of sorting the vocab
  (no Mosaic lowering, O(V log V)), ``tau_k`` / ``tau_p`` are found by a
  fixed 32-iteration bisection over the logit range, maintaining the
  invariants ``count(z >= lo_k) >= k`` and ``mass(z >= lo_p) >= p·Z``
  — each iteration is two masked VMEM reductions over the row.  32
  f32 halvings exhaust float resolution, so the kept set matches the
  sort-based oracle (``ref.sample_ref``) except for values within one
  ulp of the k-th/top-p boundary.

* **Counter-based hash PRNG.**  Noise for slot ``b`` at generation step
  ``t`` is ``fmix32(fmix32(seed_b + C0) ^ t·C1) ^ i·C2`` pushed through
  the murmur3 finalizer — a pure function of (request seed, token index,
  vocab id).  No carried PRNG state, no dependence on batch composition
  or slot index: the same request sampled in any slot of any batch mix
  reproduces the same tokens, and the identical integer math runs in the
  XLA fallback, so ``xla`` and ``pallas`` agree token-for-token.

* **Gumbel-max selection.**  ``argmax(z + g)`` over the kept set samples
  the renormalized categorical without ever normalizing — one more VMEM
  reduction.  Greedy rows (``temperature <= 0``) take the same path with
  zero noise and no filter, which degrades exactly to first-index
  ``argmax`` (bit-identical to ``jnp.argmax`` greedy decoding).

The row math lives in ``_sample_rows`` and is shared verbatim by the
kernel body (rows=1) and the batched XLA fallback (rows=B), keeping the
two implementations in lockstep by construction.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30
_BISECT_ITERS = 32


# --------------------------------------------------------------------- #
# counter-based noise (murmur3 fmix32 stream)
# --------------------------------------------------------------------- #
def _fmix32(h: jax.Array) -> jax.Array:
    """murmur3 finalizer: full-avalanche 32-bit mix (uint32 in/out)."""
    h = h ^ (h >> jnp.uint32(16))
    h = h * jnp.uint32(0x85EBCA6B)
    h = h ^ (h >> jnp.uint32(13))
    h = h * jnp.uint32(0xC2B2AE35)
    h = h ^ (h >> jnp.uint32(16))
    return h


def gumbel_noise(seed: jax.Array, step: jax.Array, idx: jax.Array) -> jax.Array:
    """Gumbel(0,1) noise as a pure function of (seed, step, vocab idx).

    ``seed``/``step``: (R, 1) uint32; ``idx``: (R, V) uint32.  The same
    (seed, step, idx) triple yields the same noise on every backend and
    in every batch composition — this is what makes fixed-seed sampling
    reproducible regardless of which slots share the decode step.
    """
    h = _fmix32(seed + jnp.uint32(0x9E3779B9))
    h = _fmix32(h ^ (step * jnp.uint32(0x85EBCA77)))
    u = _fmix32(h ^ (idx * jnp.uint32(0x9E3779B1)))
    # top 24 bits -> uniform strictly inside (0, 1); +0.5 keeps log finite
    uf = ((u >> jnp.uint32(8)).astype(jnp.float32) + 0.5) * (1.0 / (1 << 24))
    return -jnp.log(-jnp.log(uf))


# --------------------------------------------------------------------- #
# shared row math (kernel body with rows=1, XLA fallback with rows=B)
# --------------------------------------------------------------------- #
def _sample_rows(x, temp, top_k, top_p, seed, step, idx, *,
                 iters: int = _BISECT_ITERS):
    """Sample one token per row of ``x``.

    ``x``: (R, V) f32 raw logits (padded / masked-vocab entries at
    ``NEG_INF``); ``temp``/``top_p`` (R, 1) f32, ``top_k`` (R, 1) i32
    (``0`` disables), ``seed``/``step`` (R, 1) uint32, ``idx`` (R, V)
    i32 vocab ids.  Returns ``(tok (R,1) i32, logp (R,1) f32)`` where
    ``logp`` is the log-probability of the chosen token under the
    filtered, temperature-scaled, renormalized distribution (for greedy
    rows: under the full T=1 softmax).
    """
    V = x.shape[-1]
    valid = x > NEG_INF / 2
    greedy = temp <= 0.0
    t = jnp.where(greedy, 1.0, temp)
    z = jnp.where(valid, x / t, NEG_INF)
    m = jnp.max(z, axis=-1, keepdims=True)
    mn = jnp.min(jnp.where(valid, z, m), axis=-1, keepdims=True)
    e = jnp.where(valid, jnp.exp(z - m), 0.0)
    Z = jnp.sum(e, axis=-1, keepdims=True)

    k = jnp.where(top_k <= 0, jnp.int32(V), jnp.clip(top_k, 1, V))
    k = k.astype(jnp.float32)
    p = jnp.clip(top_p, 1e-9, 1.0)
    pZ = p * Z
    hi0 = m + 1.0

    def body(_, c):
        lo_k, hi_k, lo_p, hi_p = c
        mid = 0.5 * (lo_k + hi_k)
        cnt = jnp.sum(jnp.where(z >= mid, 1.0, 0.0), axis=-1, keepdims=True)
        ok = cnt >= k
        lo_k = jnp.where(ok, mid, lo_k)
        hi_k = jnp.where(ok, hi_k, mid)
        mid = 0.5 * (lo_p + hi_p)
        mass = jnp.sum(jnp.where(z >= mid, e, 0.0), axis=-1, keepdims=True)
        ok = mass >= pZ
        lo_p = jnp.where(ok, mid, lo_p)
        hi_p = jnp.where(ok, hi_p, mid)
        return lo_k, hi_k, lo_p, hi_p

    def _filtered(_):
        lo_k, _, lo_p, _ = jax.lax.fori_loop(0, iters, body, (mn, hi0, mn, hi0))
        # the intersection of both filters; never excludes the argmax token
        tau = jnp.minimum(jnp.maximum(lo_k, lo_p), m)
        return tau, gumbel_noise(seed, step, idx.astype(jnp.uint32))

    def _argmax_only(_):
        return mn, jnp.zeros_like(x)

    # all-greedy rows (the Pallas kernel sees one row per grid step, the
    # XLA path a whole batch): skip the bisection sweeps and the noise
    # hash entirely — greedy decode costs what argmax costs
    tau, g = jax.lax.cond(jnp.all(greedy), _argmax_only, _filtered, None)
    tau = jnp.where(greedy, mn, tau)
    g = jnp.where(greedy, 0.0, g)
    keep = valid & (z >= tau)
    y = jnp.where(keep, z + g, NEG_INF)
    ymax = jnp.max(y, axis=-1, keepdims=True)
    # first index attaining the max — jnp.argmax's tie-break, so the
    # greedy path is bit-identical to argmax decoding
    tok = jnp.min(
        jnp.where(y == ymax, idx, jnp.int32(V)), axis=-1, keepdims=True
    )
    z_tok = jnp.max(jnp.where(idx == tok, z, NEG_INF), axis=-1, keepdims=True)
    Zf = jnp.sum(jnp.where(keep, e, 0.0), axis=-1, keepdims=True)
    logp = z_tok - m - jnp.log(jnp.maximum(Zf, 1e-30))
    return tok.astype(jnp.int32), logp


def sample_xla(logits, temperature, top_k, top_p, seed, step):
    """Batched XLA fallback: the shared row math over all rows at once."""
    B, V = logits.shape
    idx = jnp.broadcast_to(jnp.arange(V, dtype=jnp.int32)[None], (B, V))
    tok, logp = _sample_rows(
        logits.astype(jnp.float32),
        temperature.astype(jnp.float32)[:, None],
        top_k.astype(jnp.int32)[:, None],
        top_p.astype(jnp.float32)[:, None],
        seed.astype(jnp.uint32)[:, None],
        step.astype(jnp.uint32)[:, None],
        idx,
    )
    return tok[:, 0], logp[:, 0]


# --------------------------------------------------------------------- #
# Pallas kernel
# --------------------------------------------------------------------- #
def _sample_kernel(x_ref, temp_ref, topk_ref, topp_ref, seed_ref, step_ref,
                   tok_ref, logp_ref):
    x = x_ref[...]                                        # (1, Vp) f32
    idx = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
    tok, logp = _sample_rows(
        x,
        temp_ref[...].reshape(1, 1),
        topk_ref[...].reshape(1, 1),
        topp_ref[...].reshape(1, 1),
        seed_ref[...].reshape(1, 1),
        step_ref[...].reshape(1, 1),
        idx,
    )
    tok_ref[...] = tok
    logp_ref[...] = logp


def fused_sample(
    logits: jax.Array,       # (B, V) — any float dtype
    temperature: jax.Array,  # (B,) f32; <= 0 means greedy argmax
    top_k: jax.Array,        # (B,) i32; 0 disables
    top_p: jax.Array,        # (B,) f32; 1.0 disables
    seed: jax.Array,         # (B,) per-request PRNG seed
    step: jax.Array,         # (B,) generation index (tokens emitted so far)
    *,
    interpret: bool = False,
):
    """Fused per-slot filter + categorical: one kernel, (B,) heterogeneous
    params, returns ``(tok (B,) i32, logp (B,) f32)``.

    The whole (padded) vocab row sits in VMEM per grid step — fp32 rows
    up to ~1M vocab fit the 16MB budget comfortably.  Padding columns are
    ``NEG_INF`` so they are invisible to the filter, the softmax mass and
    the gumbel argmax.
    """
    B, V = logits.shape
    Vp = max(128, V + (-V % 128))
    x = logits.astype(jnp.float32)
    if Vp != V:
        x = jnp.pad(x, ((0, 0), (0, Vp - V)), constant_values=NEG_INF)

    tok, logp = pl.pallas_call(
        _sample_kernel,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, Vp), lambda b: (b, 0)),
            pl.BlockSpec((1,), lambda b: (b,)),
            pl.BlockSpec((1,), lambda b: (b,)),
            pl.BlockSpec((1,), lambda b: (b,)),
            pl.BlockSpec((1,), lambda b: (b,)),
            pl.BlockSpec((1,), lambda b: (b,)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1), lambda b: (b, 0)),
            pl.BlockSpec((1, 1), lambda b: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, 1), jnp.int32),
            jax.ShapeDtypeStruct((B, 1), jnp.float32),
        ],
        interpret=interpret,
    )(
        x,
        temperature.astype(jnp.float32),
        top_k.astype(jnp.int32),
        top_p.astype(jnp.float32),
        seed.astype(jnp.uint32),
        step.astype(jnp.uint32),
    )
    return tok[:, 0], logp[:, 0]
