"""Pallas TPU Mamba-2 SSD (state-space duality) chunked scan.

The assigned SSM/hybrid architectures (mamba2-2.7b, jamba-1.5-large) spend
their inner-loop FLOPs here.  GPU implementations lean on warp-level scans;
the TPU-native formulation is the *chunked dual form* (arXiv:2405.21060),
which converts the recurrence into MXU-friendly matmuls:

  per (batch, head), grid innermost over chunks of length L (sequential on
  TPU, so the (P × N) inter-chunk state lives in VMEM scratch and is carried
  across grid steps — no HBM round-trips for the recurrent state):

    intra-chunk:  Y_intra = ((C B^T) ∘ decay_mask) X        (L×L quadratic)
    state in:     Y_state = (C h_in) ∘ decay_in
    state update: h_out   = h_in·exp(seg_sum) + (dt·X)^T (B ∘ decay_out)

  All matmuls are (L × N)·(N × L), (L × L)·(L × P), (P × L)·(L × N) — MXU
  shapes; L=64/128 and N=128, P=64 are hardware-aligned.

VMEM per step ≈ L·(P+2N+2) + P·N fp32 ≈ 0.2 MB at L=128,P=64,N=128.

Oracle: ``ref.ssd_ref`` (pure sequential scan).  The jnp chunked
implementation used in the training path lives in ``ops.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu


def _ssd_kernel(
    x_ref,      # (1, L, 1, P)
    dt_ref,     # (1, L, 1)
    a_ref,      # (1,)
    b_ref,      # (1, L, 1, N)
    c_ref,      # (1, L, 1, N)
    d_ref,      # (1,)
    y_ref,      # (1, L, 1, P)
    hout_ref,   # (1, 1, P, N)  final state
    h_scr,      # VMEM (P, N) carried state
    *,
    L: int,
    n_chunks: int,
):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0, :, 0, :].astype(jnp.float32)     # (L, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)      # (L,)
    a = a_ref[0].astype(jnp.float32)              # scalar
    bm = b_ref[0, :, 0, :].astype(jnp.float32)    # (L, N)
    cm = c_ref[0, :, 0, :].astype(jnp.float32)    # (L, N)
    dsc = d_ref[0].astype(jnp.float32)

    da = dt * a                                   # (L,) decay log-increments
    cum = jnp.cumsum(da)                          # inclusive cumsum
    seg = cum[-1]

    # intra-chunk quadratic term: decay(t<-s) = exp(cum_t - cum_s) for s<=t
    diff = cum[:, None] - cum[None, :]            # (L, L)
    causal = (
        jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
        >= jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    )
    decay_mat = jnp.where(causal, jnp.exp(diff), 0.0)
    scores = jax.lax.dot_general(
        cm, bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                              # (L, L)  C_t · B_s
    att = scores * decay_mat * dt[None, :]         # weight by dt_s
    y = jax.lax.dot_general(
        att, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                              # (L, P)

    # contribution of the carried inter-chunk state
    h_in = h_scr[...]                              # (P, N)
    decay_in = jnp.exp(cum)[:, None]               # (L, 1)
    y += jax.lax.dot_general(
        cm * decay_in, h_in, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                              # (L, P)

    # state update: h_out = h_in * exp(seg) + sum_s exp(seg - cum_s) dt_s x_s B_s^T
    decay_out = jnp.exp(seg - cum)                 # (L,)
    xw = x * (dt * decay_out)[:, None]             # (L, P)
    h_new = h_in * jnp.exp(seg) + jax.lax.dot_general(
        xw, bm, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                              # (P, N)
    h_scr[...] = h_new

    y_ref[0, :, 0, :] = (y + x * dsc).astype(y_ref.dtype)

    @pl.when(ci == n_chunks - 1)
    def _final():
        hout_ref[0, 0] = h_new.astype(hout_ref.dtype)


def ssd_scan(
    x: jax.Array,    # (B, S, H, P)
    dt: jax.Array,   # (B, S, H)  positive
    A: jax.Array,    # (H,) negative
    Bm: jax.Array,   # (B, S, G, N)
    Cm: jax.Array,   # (B, S, G, N)
    D: jax.Array,    # (H,)
    *,
    chunk: int = 64,
    interpret: bool = False,
):
    """Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    B, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    group = H // G
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    n_chunks = S // chunk

    kernel = functools.partial(_ssd_kernel, L=chunk, n_chunks=n_chunks)

    def xmap(b, h, ci):
        return (b, ci, h, 0)

    def dtmap(b, h, ci):
        return (b, ci, h)

    def bcmap(b, h, ci):
        return (b, ci, h // group, 0)

    def amap(b, h, ci):
        return (h,)

    y, hout = pl.pallas_call(
        kernel,
        grid=(B, H, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, P), xmap),
            pl.BlockSpec((1, chunk, 1), dtmap),
            pl.BlockSpec((1,), amap),
            pl.BlockSpec((1, chunk, 1, N), bcmap),
            pl.BlockSpec((1, chunk, 1, N), bcmap),
            pl.BlockSpec((1,), amap),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, P), xmap),
            pl.BlockSpec((1, 1, P, N), lambda b, h, ci: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, H, P), x.dtype),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, Bm, Cm, D)
    return y, hout
