"""Shared tiling helpers for the Pallas kernel wrappers.

Kernels tile exactly (grid = padded_dim // block), so non-multiple
dimensions are zero-padded up to a block multiple and masked inside the
kernel (kv_len / vocab bounds) or sliced off the outputs — the block size
itself never silently shrinks to a pathological divisor.
"""
from __future__ import annotations

import jax.numpy as jnp


def pick_block(n: int, block: int):
    """Returns (block, padded_n): block capped at n, n rounded up to a
    block multiple."""
    block = min(block, n)
    return block, n + (-n % block)


def pad_dim(x, axis: int, target: int):
    """Zero-pad `axis` of x up to length `target` (no-op if already there)."""
    if x.shape[axis] == target:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, target - x.shape[axis])
    return jnp.pad(x, widths)
