import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch × input-shape × mesh)
combination against placeholder devices, and extract the roofline terms.

MUST be run as its own process (the two lines above run before any other
import so jax sees 512 host devices):

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-7b \
        --shape train_4k --mesh single --out experiments/dryrun

Outputs one JSON per combination with:
  * memory_analysis (bytes/device: args, outputs, temps)
  * cost_analysis   (per-device HLO FLOPs + bytes accessed)
  * per-collective byte totals parsed from the compiled HLO
  * derived roofline terms vs TPU v5e constants (see benchmarks/roofline.py)
"""
import argparse
import dataclasses
import json
import re
import sys
import time
from typing import Any, Dict

import jax

from repro.configs import get_config, list_archs
from repro.core.config import ModelConfig, ParallelConfig, TrainConfig
from repro.launch.mesh import make_production_mesh
from repro.launch.shapes import SHAPES, applicable, dryrun_bundle

# ----------------------------------------------------------------- v5e constants
PEAK_FLOPS = 197e12          # bf16 TFLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (per-direction, approx)

_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\)|\S+))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
)
_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|f8\w*|s32|u32|s16|u16|s8|u8|pred)\[([0-9,]*)\]")
_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
    "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
}
# effective traffic multiplier per algorithm (ring), in units of buffer bytes
_COLL_FACTOR = {
    "all-gather": 1.0,        # each device receives (g-1)/g of the full buffer
    "all-reduce": 2.0,        # reduce-scatter + all-gather
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def parse_collectives(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Sum result-buffer bytes per collective kind from per-device HLO."""
    out: Dict[str, Dict[str, float]] = {}
    for m in _COLL_RE.finditer(hlo_text):
        result_type, kind = m.group(1), m.group(2)
        if kind.endswith("-done"):
            continue
        nbytes = 0
        for dm in _SHAPE_RE.finditer(result_type):
            dt, dims = dm.group(1), dm.group(2)
            size = 1
            if dims:
                for d in dims.split(","):
                    size *= int(d)
            nbytes += size * (1 if dt.startswith("f8") else _DTYPE_BYTES.get(dt, 2))
        rec = out.setdefault(kind, {"count": 0, "bytes": 0.0, "traffic": 0.0})
        rec["count"] += 1
        rec["bytes"] += nbytes
        rec["traffic"] += nbytes * _COLL_FACTOR[kind]
    return out


def roofline_terms(
    cfg: ModelConfig, flops: float, hbm_bytes: float, coll: Dict[str, Dict[str, float]],
    n_chips: int, shape_name: str,
) -> Dict[str, Any]:
    coll_traffic = sum(v["traffic"] for v in coll.values())
    t_compute = flops / PEAK_FLOPS            # per-device flops already
    t_memory = hbm_bytes / HBM_BW
    t_coll = coll_traffic / ICI_BW
    terms = {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": max(
            [("compute", t_compute), ("memory", t_memory), ("collective", t_coll)],
            key=lambda kv: kv[1],
        )[0],
    }
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        D = shape.seq_len * shape.global_batch
        model_flops = 6 * cfg.active_param_count() * D / n_chips
    elif shape.kind == "prefill":
        D = shape.seq_len * shape.global_batch
        model_flops = 2 * cfg.active_param_count() * D / n_chips
    else:
        model_flops = 2 * cfg.active_param_count() * shape.global_batch / n_chips
    terms["model_flops_per_chip"] = model_flops
    terms["useful_flop_ratio"] = model_flops / flops if flops else 0.0
    return terms


def run_one(
    arch: str,
    shape_name: str,
    multi_pod: bool,
    pc: ParallelConfig,
    out_dir: str,
    variant: str = "",
    tag: str = "",
) -> Dict[str, Any]:
    cfg = get_config(arch)
    if variant == "sliding_window" and not cfg.sliding_window:
        cfg = dataclasses.replace(cfg, sliding_window=8192)
    shape = SHAPES[shape_name]
    ok, why = applicable(cfg, shape)
    mesh_name = "multi" if multi_pod else "single"
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "variant": variant, "tag": tag,
        "parallel": dataclasses.asdict(pc),
    }
    if not ok:
        rec["status"] = "skipped"
        rec["reason"] = why
        _dump(rec, out_dir)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    t0 = time.time()
    try:
        fn, args, in_sh, meta = dryrun_bundle(cfg, shape, mesh, pc)
        with mesh:
            lowered = jax.jit(fn, in_shardings=in_sh).lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis()
        hlo = compiled.as_text()
        # scan-aware extraction (XLA cost_analysis counts while bodies once)
        from repro.launch.hlo_cost import analyze as hlo_analyze

        h = hlo_analyze(hlo, breakdown=True)
        coll = h["collectives"]
        flops = float(h["flops"])
        hbm_bytes = float(h["hbm_bytes"])
        rec.update(
            status="ok",
            lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            n_chips=n_chips,
            memory={
                "argument_bytes": ma.argument_size_in_bytes,
                "output_bytes": ma.output_size_in_bytes,
                "temp_bytes": ma.temp_size_in_bytes,
                "alias_bytes": ma.alias_size_in_bytes,
                "total_per_device": ma.argument_size_in_bytes
                + ma.output_size_in_bytes
                + ma.temp_size_in_bytes
                - ma.alias_size_in_bytes,
            },
            cost={
                "flops_per_device": flops,
                "hbm_bytes_per_device": hbm_bytes,
                "hbm_bytes_f32_large": float(h.get("hbm_bytes_f32_large", 0.0)),
                "xla_flops_scan_body_once": float(ca.get("flops", 0.0)),
                "xla_bytes_scan_body_once": float(ca.get("bytes accessed", 0.0)),
            },
            collectives=coll,
            traffic_top=h.get("traffic_top", {}),
            roofline=roofline_terms(cfg, flops, hbm_bytes, coll, n_chips, shape_name),
        )
    except Exception as e:  # noqa: BLE001 — record the failure, don't crash the sweep
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"[:2000]
        rec["elapsed_s"] = round(time.time() - t0, 1)
    _dump(rec, out_dir)
    return rec


def _dump(rec: Dict[str, Any], out_dir: str) -> None:
    os.makedirs(out_dir, exist_ok=True)
    tag = f"_{rec['tag']}" if rec.get("tag") else ""
    var = f"_{rec['variant']}" if rec.get("variant") else ""
    path = os.path.join(
        out_dir, f"{rec['arch']}_{rec['shape']}_{rec['mesh']}{var}{tag}.json"
    )
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    r = rec.get("roofline", {})
    print(
        f"[{rec['status']:7s}] {rec['arch']:28s} {rec['shape']:12s} "
        f"{rec['mesh']:6s} "
        + (
            f"compute={r['t_compute_s']:.3e}s memory={r['t_memory_s']:.3e}s "
            f"coll={r['t_collective_s']:.3e}s dom={r['dominant']}"
            if r
            else rec.get("reason", rec.get("error", ""))[:100]
        ),
        flush=True,
    )


def parallel_from_args(a) -> ParallelConfig:
    kw: Dict[str, Any] = {}
    if a.attn != "auto":
        kw["attention_parallelism"] = a.attn
    if a.fsdp == "pod_data":
        kw["fsdp_axes"] = ("pod", "data")
    elif a.fsdp == "data":
        kw["fsdp_axes"] = ("data",)
    elif a.fsdp == "none":
        kw["fsdp_axes"] = ()
    if a.remat:
        kw["remat_policy"] = a.remat
    if a.opt_dtype:
        kw["optimizer_state_dtype"] = a.opt_dtype
    return ParallelConfig(**kw)


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", default="all")
    p.add_argument("--shape", default="all", choices=["all", *SHAPES])
    p.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    p.add_argument("--out", default="experiments/dryrun")
    p.add_argument("--variant", default="", choices=["", "sliding_window"])
    p.add_argument("--tag", default="")
    p.add_argument("--attn", default="auto", choices=["auto", "head_tp", "context"])
    p.add_argument("--fsdp", default="data", choices=["data", "pod_data", "none"])
    p.add_argument("--remat", default="", choices=["", "none", "block", "dots", "full"])
    p.add_argument("--opt-dtype", dest="opt_dtype", default="",
                   choices=["", "float32", "bfloat16"])
    a = p.parse_args()

    assigned = [
        "command-r-35b", "mamba2-2.7b", "qwen1.5-32b", "llama4-scout-17b-a16e",
        "whisper-medium", "internvl2-26b", "qwen2-7b", "llama3-405b",
        "llama4-maverick-400b-a17b", "jamba-1.5-large-398b",
    ]
    archs = assigned if a.arch == "all" else [a.arch]
    # "all" = the four assigned shapes; bio recipe shapes run explicitly
    assigned_shapes = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
    shapes = assigned_shapes if a.shape == "all" else [a.shape]
    meshes = ["single", "multi"] if a.mesh == "both" else [a.mesh]
    pc = parallel_from_args(a)

    failures = 0
    for arch in archs:
        for sh in shapes:
            for m in meshes:
                rec = run_one(arch, sh, m == "multi", pc, a.out, a.variant, a.tag)
                failures += rec["status"] == "error"
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
