"""Scan-aware HLO cost extraction.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE, so any
scan-over-layers module under-reports FLOPs / bytes / collectives by the
trip count.  This module re-derives the three roofline inputs directly from
the compiled HLO text, walking the computation call graph with multipliers
from ``backend_config={"known_trip_count":{"n":...}}``:

  * flops            — 2 · prod(result_dims) · prod(contracting_dims) per
                       ``dot`` (MXU ops dominate; elementwise ignored)
  * hbm_bytes        — per *top-level* instruction: operand + result buffer
                       sizes (XLA's own traffic model), skipping
                       composite/no-traffic ops and fusion-internal ops
  * collective bytes — result-buffer bytes per collective × ring factor

Operand shapes are resolved through a per-computation symbol table (HLO
text prints operand *names*, not types).  Validated against
cost_analysis() on unrolled modules (tests/test_dryrun_small.py).
"""
from __future__ import annotations

import re
from typing import Any, Dict, List, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "c64": 8, "c128": 16,
}
_SHAPE_RE = re.compile(
    r"(f64|f32|bf16|f16|f8\w*|s64|u64|s32|u32|s16|u16|s8|u8|pred|c64|c128)"
    r"\[([0-9,]*)\]"
)
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*((?:\([^={]*\)|\S+))\s+([\w\-]+)\((.*)$"
)
_WHILE_RE = re.compile(
    r"while\(.*?body=%?([\w.\-]+).*?known_trip_count\":\{\"n\":\"(\d+)\"",
)
_CALL_RE = re.compile(r"(?:calls|to_apply|body|condition)=%?([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_DOT_DIMS_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")

_SKIP_MEM = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "while", "conditional", "call",
    "get-dimension-size", "partition-id", "replica-id",
    "rng-get-and-update-state", "iota",
}
_COLLECTIVES = {
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
}
_COLL_FACTOR = {
    "all-gather": 1.0, "all-reduce": 2.0, "reduce-scatter": 1.0,
    "all-to-all": 1.0, "collective-permute": 1.0,
}


def _type_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        size = 1
        if dims:
            for d in dims.split(","):
                size *= int(d)
        total += size * (1 if dt.startswith("f8") else _DTYPE_BYTES.get(dt, 2))
    return total


def _type_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",")] if m.group(2) else []


def split_computations(txt: str) -> Tuple[Dict[str, List[str]], str]:
    comps: Dict[str, List[str]] = {}
    entry = ""
    cur = None
    for line in txt.splitlines():
        if line and not line[0].isspace() and "{" in line and "(" in line:
            m = re.match(r"(ENTRY\s+)?%?([\w.\-]+)\s*\(", line)
            if m:
                cur = m.group(2)
                comps[cur] = []
                if m.group(1):
                    entry = cur
                continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is not None:
            comps[cur].append(line)
    return comps, entry


def _multipliers(comps: Dict[str, List[str]], entry: str) -> Dict[str, float]:
    mult: Dict[str, float] = {c: 0.0 for c in comps}
    if entry not in comps:
        return {c: 1.0 for c in comps}
    mult[entry] = 1.0
    for _ in range(64):  # call graph is a DAG; fixpoint in few passes
        changed = False
        for c, lines in comps.items():
            if mult[c] == 0.0:
                continue
            for line in lines:
                trips: Dict[str, int] = {}
                wm = _WHILE_RE.search(line)
                if wm:
                    trips[wm.group(1)] = int(wm.group(2))
                for callee in _CALL_RE.findall(line):
                    if callee not in comps:
                        continue
                    want = mult[c] * trips.get(callee, 1)
                    if mult[callee] < want:
                        mult[callee] = want
                        changed = True
                bm = _BRANCH_RE.search(line)
                if bm:
                    for b in re.findall(r"%?([\w.\-]+)", bm.group(1)):
                        if b in comps and mult[b] < mult[c]:
                            mult[b] = mult[c]
                            changed = True
        if not changed:
            break
    return mult


def _fusion_bodies(comps: Dict[str, List[str]]) -> set:
    bodies = set()
    for lines in comps.values():
        for line in lines:
            if " fusion(" in line:
                m = re.search(r"calls=%?([\w.\-]+)", line)
                if m:
                    bodies.add(m.group(1))
    return bodies


def _inplace_fusion_traffic(comps: Dict[str, List[str]]) -> Dict[str, float]:
    """Fusion bodies rooted at dynamic-(update-)slice are in-place: their
    real traffic is the SLICE, not the full (possibly scan-stacked) buffer.
    Returns body-name -> traffic bytes override (0 means 'use default')."""
    out: Dict[str, float] = {}
    for cname, lines in comps.items():
        table = _symbols(lines)
        for line in lines:
            if not line.lstrip().startswith("ROOT"):
                continue
            m = _INSTR_RE.match(line)
            if not m:
                continue
            name, rt, op, rest = m.groups()
            if op == "dynamic-update-slice":
                ops_names = _OPERAND_RE.findall(rest.split(")")[0])
                upd = table.get(ops_names[1], "") if len(ops_names) > 1 else ""
                out[cname] = 2.0 * _type_bytes(upd)
            elif op == "dynamic-slice":
                out[cname] = 2.0 * _type_bytes(rt)
    return out


def _symbols(lines: List[str]) -> Dict[str, str]:
    """instruction name -> result type string (per computation)."""
    table: Dict[str, str] = {}
    for line in lines:
        m = _INSTR_RE.match(line)
        if m:
            table[m.group(1)] = m.group(2)
    # computation parameters appear in the header, not handled here; HLO
    # text also declares them as explicit parameter instructions, covered.
    return table


def analyze(txt: str, breakdown: bool = False) -> Dict[str, Any]:
    comps, entry = split_computations(txt)
    mult = _multipliers(comps, entry)
    fusion_bodies = _fusion_bodies(comps)
    inplace = _inplace_fusion_traffic(comps)

    flops = 0.0
    hbm = 0.0
    hbm_f32_large = 0.0  # traffic of >=1MB fp32 buffers: XLA-CPU computes
    # bf16 dots/fusions in fp32 (no native bf16 matmul); on the TPU target
    # these buffers are bf16 — roofline reports a TPU-adjusted memory term.
    coll: Dict[str, Dict[str, float]] = {}
    by_shape: Dict[str, float] = {}

    for cname, lines in comps.items():
        w = mult.get(cname, 0.0)
        if w == 0.0:
            continue
        in_fusion = cname in fusion_bodies
        table = _symbols(lines)
        for line in lines:
            m = _INSTR_RE.match(line)
            if not m:
                continue
            name, result_type, op, rest = m.groups()
            base = op.replace("-start", "").replace("-done", "")

            if base == "dot":
                res = _type_dims(result_type)
                # lhs operand: first %name inside the paren args
                args_part = rest.split(")")[0]
                ops_names = _OPERAND_RE.findall(args_part)
                lhs = _type_dims(table.get(ops_names[0], "")) if ops_names else []
                cd = _DOT_DIMS_RE.search(line)
                csize = 1
                if cd and cd.group(1):
                    for d in cd.group(1).split(","):
                        if int(d) < len(lhs):
                            csize *= lhs[int(d)]
                rsize = 1
                for d in res:
                    rsize *= d
                flops += w * 2.0 * rsize * csize

            if in_fusion:
                continue
            if base in _SKIP_MEM or op.endswith("-done"):
                continue

            args_part = rest.split(")")[0]
            operands = _OPERAND_RE.findall(args_part)
            if base == "fusion":
                cm = re.search(r"calls=%?([\w.\-]+)", line)
                if cm and cm.group(1) in inplace:
                    io = inplace[cm.group(1)]
                    if base in _COLLECTIVES:
                        pass
                    hbm += w * io
                    sm = _SHAPE_RE.search(result_type)
                    if (
                        sm and sm.group(1) == "f32"
                        and _type_bytes(sm.group(0)) >= 1 << 20
                    ):
                        hbm_f32_large += w * io
                    if breakdown and io > 0:
                        sig = f"fusion-inplace:{sm.group(0) if sm else '?'}"
                        by_shape[sig] = by_shape.get(sig, 0.0) + w * io
                    continue
            if base in ("dynamic-slice", "slice", "gather"):
                # traffic = slice read + result write, NOT the full operand
                io_bytes = 2 * _type_bytes(result_type)
            elif base in ("dynamic-update-slice", "scatter"):
                # traffic = update read + region write (+ small indices)
                upd = table.get(operands[1], "") if len(operands) > 1 else ""
                io_bytes = 2 * _type_bytes(upd)
            elif base == "broadcast":
                io_bytes = _type_bytes(result_type)
            else:
                operand_bytes = sum(_type_bytes(table.get(o, "")) for o in operands)
                io_bytes = _type_bytes(result_type) + operand_bytes

            if base in _COLLECTIVES:
                nbytes = _type_bytes(result_type)
                rec = coll.setdefault(
                    base, {"count": 0, "bytes": 0.0, "traffic": 0.0}
                )
                rec["count"] += w
                rec["bytes"] += w * nbytes
                rec["traffic"] += w * nbytes * _COLL_FACTOR[base]
            hbm += w * io_bytes
            sm = _SHAPE_RE.search(result_type)
            if sm and sm.group(1) == "f32" and _type_bytes(sm.group(0)) >= 1 << 20:
                hbm_f32_large += w * io_bytes
            if breakdown and io_bytes > 0:
                sig = f"{base}:{sm.group(0) if sm else '?'}"
                by_shape[sig] = by_shape.get(sig, 0.0) + w * io_bytes

    out: Dict[str, Any] = {
        "flops": flops,
        "hbm_bytes": hbm,
        "hbm_bytes_f32_large": hbm_f32_large,
        "collectives": coll,
    }
    if breakdown:
        out["traffic_top"] = dict(
            sorted(by_shape.items(), key=lambda kv: -kv[1])[:15]
        )
    return out
