"""Production meshes.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state.  Shapes:

  single-pod:  (16, 16)      axes (data, model)        — 256 chips (v5e pod)
  multi-pod:   (2, 16, 16)   axes (pod, data, model)   — 512 chips

The `model` axis stays intra-pod (ICI); `pod` carries only data-parallel
gradient all-reduce (+ optional FSDP, see ParallelConfig.fsdp_axes).

For CPU development the same mesh machinery runs against simulated host
devices (``XLA_FLAGS=--xla_force_host_platform_device_count=8``):
``make_test_mesh`` is the 8-device integration-test shape, and
``launch/train.py --mesh DxM`` builds arbitrary (data, model) shapes for
the distributed Trainer (tests/test_trainer_distributed.py).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(shape=(2, 4), axes=("data", "model")):
    """Small mesh for 8-host-device integration tests."""
    return jax.make_mesh(shape, axes)
