"""Production meshes.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state.  Shapes:

  single-pod:  (16, 16)      axes (data, model)        — 256 chips (v5e pod)
  multi-pod:   (2, 16, 16)   axes (pod, data, model)   — 512 chips

The `model` axis stays intra-pod (ICI); `pod` carries only data-parallel
gradient all-reduce (+ optional FSDP, see ParallelConfig.fsdp_axes).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(shape=(2, 4), axes=("data", "model")):
    """Small mesh for 8-host-device integration tests."""
    return jax.make_mesh(shape, axes)
