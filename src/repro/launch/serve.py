"""Batched serving launcher: prefill a batch of prompts, decode greedily.

    PYTHONPATH=src python -m repro.launch.serve --arch molmim-65m --smoke \
        --batch 4 --prompt-len 16 --gen 16

Continuous-batching mode drives the slot engine instead of a static
batch; ``--cache-layout paged`` serves from the paged KV cache (block
tables + Pallas paged attention / scatter writes):

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --smoke \
        --continuous --cache-layout paged --page-size 16 --requests 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.core.config import ParallelConfig, ServeConfig
from repro.models.model import build_model


def generate(
    model, params, batch, *, max_len: int, steps: int, temperature: float = 0.0,
    seed: int = 0,
):
    """Greedy (or sampled) generation loop; returns (tokens (B, steps), toks/s)."""
    prefill = jax.jit(lambda p, b: model.prefill(p, b, max_len))
    decode = jax.jit(model.decode_step)
    logits, cache = prefill(params, batch)
    key = jax.random.PRNGKey(seed)
    outs = []
    t0 = time.time()
    for i in range(steps):
        if temperature > 0:
            key, sub = jax.random.split(key)
            nxt = jax.random.categorical(sub, logits[:, -1] / temperature)[:, None]
        else:
            nxt = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        outs.append(nxt)
        logits, cache = decode(params, cache, nxt.astype(jnp.int32))
    toks = jnp.concatenate(outs, axis=1)
    dt = time.time() - t0
    return toks, (toks.size / dt)


def serve_continuous(model, params, sc: ServeConfig, *, gen: int,
                     prompt_len: int, requests: int) -> None:
    """Drive the continuous-batching engine (dense or paged KV cache)."""
    from repro.serving.engine import Engine, Request

    cfg = model.cfg
    rng = np.random.default_rng(0)
    eng = Engine(
        model, params, slots=sc.batch_size, max_len=sc.max_seq_len,
        cache_layout=sc.cache_layout, page_size=sc.page_size,
    )
    t0 = time.time()
    for i in range(requests):
        L = int(rng.integers(max(1, prompt_len // 2), prompt_len + 1))
        eng.submit(Request(
            uid=i,
            prompt=rng.integers(5, cfg.vocab_size, size=L).astype(np.int32),
            max_new=gen,
        ))
    done = eng.run()
    wall = time.time() - t0
    toks = sum(len(r.output) for r in done)
    ttft = np.mean([r.t_first - r.t_submit for r in done]) * 1e3
    itl = np.mean([
        (r.t_done - r.t_first) / max(len(r.output) - 1, 1) for r in done
    ]) * 1e3
    print(
        f"[{sc.cache_layout}] served {len(done)} requests / {toks} tokens "
        f"on {eng.B} slots: {toks / wall:.1f} tok/s, "
        f"ttft {ttft:.1f}ms, itl {itl:.2f}ms"
    )


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="molmim-65m")
    p.add_argument("--smoke", action="store_true")
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=16)
    p.add_argument("--gen", type=int, default=16)
    p.add_argument("--temperature", type=float, default=0.0)
    p.add_argument("--continuous", action="store_true",
                   help="continuous-batching engine instead of a static batch")
    p.add_argument("--cache-layout", choices=("dense", "paged"),
                   default="dense")
    p.add_argument("--page-size", type=int, default=16)
    p.add_argument("--requests", type=int, default=16)
    a = p.parse_args()

    cfg = get_smoke_config(a.arch) if a.smoke else get_config(a.arch)
    model = build_model(cfg, ParallelConfig(), None)
    params = model.init(jax.random.PRNGKey(0))
    if a.continuous:
        sc = ServeConfig(
            max_seq_len=a.prompt_len + a.gen + cfg.num_frontend_tokens + 1,
            batch_size=a.batch, temperature=a.temperature,
            cache_layout=a.cache_layout, page_size=a.page_size,
        )
        serve_continuous(model, params, sc, gen=a.gen,
                         prompt_len=a.prompt_len, requests=a.requests)
        return
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(
            rng.integers(5, cfg.vocab_size, size=(a.batch, a.prompt_len)), jnp.int32
        )
    }
    if cfg.is_encoder_decoder:
        if cfg.frontend == "audio_stub":
            batch["enc_embeds"] = jnp.asarray(
                rng.normal(size=(a.batch, cfg.num_frontend_tokens, cfg.d_model)),
                jnp.float32,
            )
        else:
            batch["src_tokens"] = batch["tokens"]
    if cfg.frontend == "vision_stub":
        batch["img_embeds"] = jnp.asarray(
            rng.normal(size=(a.batch, cfg.num_frontend_tokens, cfg.d_model)),
            jnp.float32,
        )
    toks, tps = generate(
        model, params, batch,
        max_len=a.prompt_len + a.gen + cfg.num_frontend_tokens + 1,
        steps=a.gen, temperature=a.temperature,
    )
    print(f"generated {toks.shape} tokens at {tps:.1f} tok/s")
    print(toks[:, :12])


if __name__ == "__main__":
    main()
