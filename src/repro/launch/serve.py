"""Batched serving launcher: prefill a batch of prompts, decode greedily.

    PYTHONPATH=src python -m repro.launch.serve --arch molmim-65m --smoke \
        --batch 4 --prompt-len 16 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.core.config import ParallelConfig
from repro.models.model import build_model


def generate(
    model, params, batch, *, max_len: int, steps: int, temperature: float = 0.0,
    seed: int = 0,
):
    """Greedy (or sampled) generation loop; returns (tokens (B, steps), toks/s)."""
    prefill = jax.jit(lambda p, b: model.prefill(p, b, max_len))
    decode = jax.jit(model.decode_step)
    logits, cache = prefill(params, batch)
    key = jax.random.PRNGKey(seed)
    outs = []
    t0 = time.time()
    for i in range(steps):
        if temperature > 0:
            key, sub = jax.random.split(key)
            nxt = jax.random.categorical(sub, logits[:, -1] / temperature)[:, None]
        else:
            nxt = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        outs.append(nxt)
        logits, cache = decode(params, cache, nxt.astype(jnp.int32))
    toks = jnp.concatenate(outs, axis=1)
    dt = time.time() - t0
    return toks, (toks.size / dt)


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="molmim-65m")
    p.add_argument("--smoke", action="store_true")
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=16)
    p.add_argument("--gen", type=int, default=16)
    p.add_argument("--temperature", type=float, default=0.0)
    a = p.parse_args()

    cfg = get_smoke_config(a.arch) if a.smoke else get_config(a.arch)
    model = build_model(cfg, ParallelConfig(), None)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(
            rng.integers(5, cfg.vocab_size, size=(a.batch, a.prompt_len)), jnp.int32
        )
    }
    if cfg.is_encoder_decoder:
        if cfg.frontend == "audio_stub":
            batch["enc_embeds"] = jnp.asarray(
                rng.normal(size=(a.batch, cfg.num_frontend_tokens, cfg.d_model)),
                jnp.float32,
            )
        else:
            batch["src_tokens"] = batch["tokens"]
    if cfg.frontend == "vision_stub":
        batch["img_embeds"] = jnp.asarray(
            rng.normal(size=(a.batch, cfg.num_frontend_tokens, cfg.d_model)),
            jnp.float32,
        )
    toks, tps = generate(
        model, params, batch,
        max_len=a.prompt_len + a.gen + cfg.num_frontend_tokens + 1,
        steps=a.gen, temperature=a.temperature,
    )
    print(f"generated {toks.shape} tokens at {tps:.1f} tok/s")
    print(toks[:, :12])


if __name__ == "__main__":
    main()
