"""Batched serving launcher: prefill a batch of prompts, decode greedily.

    PYTHONPATH=src python -m repro.launch.serve --arch molmim-65m --smoke \
        --batch 4 --prompt-len 16 --gen 16

Continuous-batching mode drives the slot engine instead of a static
batch; ``--cache-layout paged`` serves from the paged KV cache (block
tables + Pallas paged attention / scatter writes), and ``--prefix-cache``
/ ``--prefill-chunk N`` enable content-addressed prefix sharing and
bounded chunked prefill on top of it:

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --smoke \
        --continuous --cache-layout paged --page-size 16 --requests 16 \
        --prefix-cache --prefill-chunk 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.core.config import ParallelConfig, ServeConfig
from repro.models.model import build_model


def generate(
    model, params, batch, *, max_len: int, steps: int, temperature: float = 0.0,
    seed: int = 0,
):
    """Greedy (or sampled) generation loop; returns (tokens (B, steps), toks/s)."""
    prefill = jax.jit(lambda p, b: model.prefill(p, b, max_len))
    decode = jax.jit(model.decode_step)
    logits, cache = prefill(params, batch)
    key = jax.random.PRNGKey(seed)
    outs = []
    t0 = time.time()
    for i in range(steps):
        if temperature > 0:
            key, sub = jax.random.split(key)
            nxt = jax.random.categorical(sub, logits[:, -1] / temperature)[:, None]
        else:
            nxt = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        outs.append(nxt)
        logits, cache = decode(params, cache, nxt.astype(jnp.int32))
    toks = jnp.concatenate(outs, axis=1)
    dt = time.time() - t0
    return toks, (toks.size / dt)


def serve_continuous(model, params, sc: ServeConfig, *, gen: int,
                     prompt_len: int, requests: int) -> None:
    """Drive the continuous-batching engine (dense or paged KV cache)."""
    from repro.serving.engine import Engine, Request

    cfg = model.cfg
    rng = np.random.default_rng(0)
    eng = Engine(
        model, params, slots=sc.batch_size, max_len=sc.max_seq_len,
        cache_layout=sc.cache_layout, page_size=sc.page_size,
        prefix_cache=sc.prefix_cache, prefill_chunk=sc.prefill_chunk,
    )
    t0 = time.time()
    # a shared task preamble on half the requests exercises the prefix
    # cache the way protein/chemistry serving does (fixed scaffolds);
    # at least one full page long, else no block can ever hash-hit
    preamble = rng.integers(
        5, cfg.vocab_size, size=max(sc.page_size, prompt_len // 2)
    ).astype(np.int32)
    for i in range(requests):
        L = int(rng.integers(max(1, prompt_len // 2), prompt_len + 1))
        prompt = rng.integers(5, cfg.vocab_size, size=L).astype(np.int32)
        if sc.prefix_cache and i % 2 == 0:
            prompt = np.concatenate([preamble, prompt])[: sc.max_seq_len - gen - 1]
        eng.submit(Request(uid=i, prompt=prompt, max_new=gen))
    done = eng.run()
    wall = time.time() - t0
    toks = sum(len(r.output) for r in done)
    ttft = np.mean([r.t_first - r.t_submit for r in done]) * 1e3
    itl = np.mean([
        (r.t_done - r.t_first) / max(len(r.output) - 1, 1) for r in done
    ]) * 1e3
    extra = ""
    if eng.alloc is not None and sc.prefix_cache:
        st = eng.alloc.stats
        extra = (
            f", prefix-cache: {st['hit_tokens']} tokens reused, "
            f"{st['evictions']} evictions, {st['cow_copies']} COW copies"
        )
    print(
        f"[{sc.cache_layout}] served {len(done)} requests / {toks} tokens "
        f"on {eng.B} slots: {toks / wall:.1f} tok/s, "
        f"ttft {ttft:.1f}ms, itl {itl:.2f}ms{extra}"
    )


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="molmim-65m")
    p.add_argument("--smoke", action="store_true")
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=16)
    p.add_argument("--gen", type=int, default=16)
    p.add_argument("--temperature", type=float, default=0.0)
    p.add_argument("--continuous", action="store_true",
                   help="continuous-batching engine instead of a static batch")
    p.add_argument("--cache-layout", choices=("dense", "paged"),
                   default="dense")
    p.add_argument("--page-size", type=int, default=16)
    p.add_argument("--requests", type=int, default=16)
    p.add_argument("--prefix-cache", action="store_true",
                   help="content-addressed prefix sharing (paged layout)")
    p.add_argument("--prefill-chunk", type=int, default=0,
                   help="bound prefill to N-token chunks interleaved with "
                        "decode steps (paged layout; 0 = one chunk)")
    a = p.parse_args()

    cfg = get_smoke_config(a.arch) if a.smoke else get_config(a.arch)
    model = build_model(cfg, ParallelConfig(), None)
    params = model.init(jax.random.PRNGKey(0))
    if a.continuous:
        max_prompt = a.prompt_len * (2 if a.prefix_cache else 1)
        sc = ServeConfig(
            max_seq_len=max_prompt + a.gen + cfg.num_frontend_tokens + 1,
            batch_size=a.batch, temperature=a.temperature,
            cache_layout=a.cache_layout, page_size=a.page_size,
            prefix_cache=a.prefix_cache, prefill_chunk=a.prefill_chunk,
        )
        serve_continuous(model, params, sc, gen=a.gen,
                         prompt_len=a.prompt_len, requests=a.requests)
        return
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(
            rng.integers(5, cfg.vocab_size, size=(a.batch, a.prompt_len)), jnp.int32
        )
    }
    if cfg.is_encoder_decoder:
        if cfg.frontend == "audio_stub":
            batch["enc_embeds"] = jnp.asarray(
                rng.normal(size=(a.batch, cfg.num_frontend_tokens, cfg.d_model)),
                jnp.float32,
            )
        else:
            batch["src_tokens"] = batch["tokens"]
    if cfg.frontend == "vision_stub":
        batch["img_embeds"] = jnp.asarray(
            rng.normal(size=(a.batch, cfg.num_frontend_tokens, cfg.d_model)),
            jnp.float32,
        )
    toks, tps = generate(
        model, params, batch,
        max_len=a.prompt_len + a.gen + cfg.num_frontend_tokens + 1,
        steps=a.gen, temperature=a.temperature,
    )
    print(f"generated {toks.shape} tokens at {tps:.1f} tok/s")
    print(toks[:, :12])


if __name__ == "__main__":
    main()
