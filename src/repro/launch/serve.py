"""Serving launcher over the Generation API v2 ``LLM`` facade.

Continuous-batching mode (decoder-only archs) drives the slot engine
through ``serving/api.py::LLM``; ``--cache-layout paged`` serves from the
paged KV cache, ``--prefix-cache`` / ``--prefill-chunk N`` layer
content-addressed prefix sharing and bounded chunked prefill on top, and
``--temperature/--top-k/--top-p/--seed`` set the per-request sampling
params (greedy by default — fused on-device sampling either way):

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --smoke \
        --continuous --cache-layout paged --page-size 16 --requests 16 \
        --prefix-cache --prefill-chunk 32 --temperature 0.8 --top-k 40

``--mesh DxM`` (e.g. ``--mesh 2x4``) serves tensor-parallel on a
(data, model) device mesh: K/V storage shards over the model axis while
the page allocator stays global, and per-request sampling is
token-reproducible, so the output stream is identical to single-device
(see serving/README.md "Sharded serving").  On CPU, virtual devices come
from ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.

Telemetry (``repro.obs``, see ``src/repro/obs/README.md``):
``--health-every N`` prints the engine health snapshot every N steps
while serving (default 64 — a wedged engine is visible as the watchdog
climbs, not only at exit); ``--metrics-dir DIR`` refreshes a Prometheus
exposition + JSON snapshot there on the same cadence; ``--trace PATH``
writes the request-lifecycle JSONL at exit; ``--profile DIR`` captures
a ``jax.profiler`` trace of the whole serving run.

The static-batch path (``generate``) remains for encoder-decoder /
vision-frontend archs the slot engine does not admit; it is a deprecated
shim for decoder-only callers.
"""
from __future__ import annotations

import argparse
import time
import warnings

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.core.config import ParallelConfig, ServeConfig
from repro.models.model import build_model


def generate(
    model, params, batch, *, max_len: int, steps: int, temperature: float = 0.0,
    seed: int = 0, top_k: int = 0, top_p: float = 1.0,
):
    """Static-batch generation loop; returns (tokens (B, steps), toks/s).

    .. deprecated:: Generation API v2
        Decoder-only serving should use ``serving.api.LLM`` (per-request
        ``SamplingParams``, continuous batching, streaming).  This shim
        stays for encoder-decoder / vision-frontend static batches; its
        token selection now runs through the same fused on-device
        sampler as the engine (``ops.sample_tokens``), so greedy output
        is unchanged and sampled output is seed-reproducible.
    """
    warnings.warn(
        "launch.serve.generate is a legacy static-batch path; use "
        "serving.api.LLM for decoder-only serving",
        DeprecationWarning, stacklevel=2,
    )
    from repro.kernels import ops

    B = batch["tokens"].shape[0]
    impl = model.cfg.kernel_impl
    prefill = jax.jit(lambda p, b: model.prefill(p, b, max_len))

    def step_fn(p, cache, logits, gen_idx):
        tok, _ = ops.sample_tokens(
            logits[:, -1],
            jnp.full((B,), temperature, jnp.float32),
            jnp.full((B,), top_k, jnp.int32),
            jnp.full((B,), top_p, jnp.float32),
            jnp.arange(B, dtype=jnp.uint32) + jnp.uint32(seed),
            jnp.full((B,), gen_idx, jnp.uint32),
            impl=impl,
        )
        logits, cache = model.decode_step(p, cache, tok[:, None])
        return tok, logits, cache

    step = jax.jit(step_fn)
    logits, cache = prefill(params, batch)
    outs = []
    t0 = time.time()
    for i in range(steps):
        tok, logits, cache = step(params, cache, logits, i)
        outs.append(tok[:, None])
    toks = jnp.concatenate(outs, axis=1)
    jax.block_until_ready(toks)
    dt = time.time() - t0
    return toks, (toks.size / dt)


def _health_line(h) -> str:
    return (
        f"steps={h.steps} queue={h.queue_depth} "
        f"active={h.active_slots}/{h.slots} "
        f"free_pages={h.free_pages}/{h.total_pages} "
        f"stalled_steps={h.steps_since_progress} counters={h.counters}"
    )


def serve_continuous(model, params, sc: ServeConfig, *, gen: int,
                     prompt_len: int, requests: int,
                     health_every: int = 0, metrics_dir: str = "",
                     trace_path: str = "", profile: bool = False) -> None:
    """Drive the continuous-batching engine through the LLM facade.

    Telemetry: ``health_every=N`` prints the health snapshot every N
    engine steps WHILE serving (a stall is visible as the watchdog
    climbs, not just in the exit summary) and, with ``metrics_dir``,
    refreshes the Prometheus exposition + JSON snapshot there on the
    same cadence.  ``trace_path`` writes the lifecycle JSONL at exit;
    ``profile`` turns on the jax.profiler annotations around the jitted
    prefill/decode dispatches."""
    from repro.obs import MetricsRegistry, TraceRecorder
    from repro.serving.api import LLM
    from repro.serving.sampling import SamplingParams

    reg = MetricsRegistry() if (metrics_dir or health_every) else None
    tracer = TraceRecorder(capacity=16384) if trace_path else None

    def _dump_metrics() -> None:
        if reg is not None and metrics_dir:
            import os

            os.makedirs(metrics_dir, exist_ok=True)
            reg.write_prometheus(os.path.join(metrics_dir, "serve.prom"))
            reg.dump_json(os.path.join(metrics_dir, "serve_metrics.json"))

    def _on_step(eng) -> None:
        # periodic liveness emission: stalls show up while the watchdog
        # climbs, not only in the exit summary
        if health_every and eng.steps % health_every == 0:
            print(f"  [step {eng.steps}] {_health_line(eng.health())}")
            _dump_metrics()

    cfg = model.cfg
    rng = np.random.default_rng(0)
    llm = LLM.from_config(model, params, sc, metrics=reg, trace=tracer,
                          profile=profile,
                          on_step=_on_step if health_every else None)
    # a shared task preamble on half the requests exercises the prefix
    # cache the way protein/chemistry serving does (fixed scaffolds);
    # at least one full page long, else no block can ever hash-hit
    preamble = rng.integers(
        5, cfg.vocab_size, size=max(sc.page_size, prompt_len // 2)
    ).astype(np.int32)
    prompts, plist = [], []
    for i in range(requests):
        L = int(rng.integers(max(1, prompt_len // 2), prompt_len + 1))
        prompt = rng.integers(5, cfg.vocab_size, size=L).astype(np.int32)
        if sc.prefix_cache and i % 2 == 0:
            prompt = np.concatenate([preamble, prompt])[: sc.max_seq_len - gen - 1]
        prompts.append(prompt)
        plist.append(SamplingParams(
            temperature=sc.temperature, top_k=sc.top_k, top_p=sc.top_p,
            seed=sc.seed + i, max_new=gen, deadline_ms=sc.deadline_ms,
        ))
    t0 = time.time()
    outs = llm.generate(prompts, plist)
    wall = time.time() - t0
    eng = llm.engine
    served = [c for c in outs if c.finish_reason in ("stop", "length")]
    degraded = [c for c in outs if c.finish_reason not in ("stop", "length")]
    toks = sum(len(c.tokens) for c in outs)
    ttft = float(np.mean([c.ttft_s for c in served])) * 1e3 if served else 0.0
    itl = float(np.mean([
        (c.latency_s - c.ttft_s) / max(len(c.tokens) - 1, 1) for c in served
    ])) * 1e3 if served else 0.0
    extra = ""
    if eng.alloc is not None and sc.prefix_cache:
        st = eng.alloc.stats
        extra = (
            f", prefix-cache: {st['hit_tokens']} tokens reused, "
            f"{st['evictions']} evictions, {st['cow_copies']} COW copies"
        )
    print(
        f"[{sc.cache_layout}] served {len(served)}/{len(outs)} requests / "
        f"{toks} tokens on {eng.B} slots: {toks / wall:.1f} tok/s, "
        f"ttft {ttft:.1f}ms, itl {itl:.2f}ms{extra}"
    )
    if degraded:
        by_reason: dict = {}
        for c in degraded:
            by_reason[c.finish_reason] = by_reason.get(c.finish_reason, 0) + 1
        print("  degraded outcomes: "
              + ", ".join(f"{k}={v}" for k, v in sorted(by_reason.items())))
    print(f"  health: {_health_line(eng.health())}")
    _dump_metrics()
    if tracer is not None:
        tracer.write(trace_path)
        print(f"  trace: {len(tracer)} lifecycle events -> {trace_path}"
              + (f" ({tracer.dropped} older events dropped)"
                 if tracer.dropped else ""))
    if profile and eng.step_timer is not None and eng.step_timer.totals:
        print("  step timer:")
        for line in eng.step_timer.report().splitlines():
            print(f"    {line}")


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="molmim-65m")
    p.add_argument("--smoke", action="store_true")
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=16)
    p.add_argument("--gen", type=int, default=16)
    p.add_argument("--temperature", type=float, default=0.0)
    p.add_argument("--top-k", type=int, default=0,
                   help="per-request top-k filter (0 = disabled)")
    p.add_argument("--top-p", type=float, default=1.0,
                   help="per-request nucleus filter (1.0 = disabled)")
    p.add_argument("--seed", type=int, default=0,
                   help="sampling PRNG seed (request i uses seed+i)")
    p.add_argument("--continuous", action="store_true",
                   help="continuous-batching engine instead of a static batch")
    p.add_argument("--mesh", default="",
                   help="serve tensor-parallel on a DATAxMODEL device mesh, "
                        "e.g. --mesh 2x4 (K/V storage shards over the model "
                        "axis; sampling stays token-reproducible, so output "
                        "is identical to single-device).  Requires "
                        "data*model visible jax devices — on CPU set "
                        "XLA_FLAGS=--xla_force_host_platform_device_count=N")
    p.add_argument("--cache-layout", choices=("dense", "paged"),
                   default="dense")
    p.add_argument("--page-size", type=int, default=16)
    p.add_argument("--requests", type=int, default=16)
    p.add_argument("--prefix-cache", action="store_true",
                   help="content-addressed prefix sharing (paged layout)")
    p.add_argument("--prefill-chunk", type=int, default=0,
                   help="bound prefill to N-token chunks interleaved with "
                        "decode steps (paged layout; 0 = one chunk)")
    p.add_argument("--max-queue", type=int, default=0,
                   help="bounded admission queue; overflow submits are "
                        "rejected with a typed retriable error (0 = unbounded)")
    p.add_argument("--preempt", action="store_true",
                   help="under page pressure, preempt-and-requeue the newest "
                        "in-flight decode instead of head-of-line blocking "
                        "(paged layout; resumed output is token-identical)")
    p.add_argument("--deadline-ms", type=float, default=None,
                   help="default per-request deadline from submit; expired "
                        "requests finish with finish_reason='timeout'")
    p.add_argument("--health-every", type=int, default=64,
                   help="print Engine.health() (and refresh --metrics-dir) "
                        "every N engine steps while serving (0 = exit-only)")
    p.add_argument("--metrics-dir", default="",
                   help="write Prometheus exposition + JSON metric snapshots "
                        "here (refreshed on the --health-every cadence)")
    p.add_argument("--trace", default="", dest="trace_path",
                   help="write the request-lifecycle JSONL trace to this "
                        "path at exit")
    p.add_argument("--profile", default="",
                   help="capture a jax.profiler trace of the serving run "
                        "into this directory (also enables the engine's "
                        "step annotations/timers)")
    a = p.parse_args()

    cfg = get_smoke_config(a.arch) if a.smoke else get_config(a.arch)
    mesh = None
    if a.mesh:
        try:
            d, m = (int(x) for x in a.mesh.lower().split("x"))
        except ValueError:
            raise SystemExit(f"--mesh wants DATAxMODEL (e.g. 2x4), got {a.mesh!r}")
        if d * m > len(jax.devices()):
            raise SystemExit(
                f"--mesh {a.mesh} needs {d * m} devices, "
                f"{len(jax.devices())} visible (on CPU set XLA_FLAGS="
                f"--xla_force_host_platform_device_count={d * m})"
            )
        mesh = jax.make_mesh((d, m), ("data", "model"))
    model = build_model(cfg, ParallelConfig(), mesh)
    params = model.init(jax.random.PRNGKey(0))
    if a.continuous:
        max_prompt = a.prompt_len * (2 if a.prefix_cache else 1)
        sc = ServeConfig(
            max_seq_len=max_prompt + a.gen + cfg.num_frontend_tokens + 1,
            batch_size=a.batch, temperature=a.temperature,
            top_k=a.top_k, top_p=a.top_p, seed=a.seed,
            cache_layout=a.cache_layout, page_size=a.page_size,
            prefix_cache=a.prefix_cache, prefill_chunk=a.prefill_chunk,
            max_queue=a.max_queue, preempt=a.preempt,
            deadline_ms=a.deadline_ms,
        )
        from repro.obs.profile import trace_ctx

        with trace_ctx(a.profile):
            serve_continuous(model, params, sc, gen=a.gen,
                             prompt_len=a.prompt_len, requests=a.requests,
                             health_every=a.health_every,
                             metrics_dir=a.metrics_dir,
                             trace_path=a.trace_path,
                             profile=bool(a.profile))
        return
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(
            rng.integers(5, cfg.vocab_size, size=(a.batch, a.prompt_len)), jnp.int32
        )
    }
    if cfg.is_encoder_decoder:
        if cfg.frontend == "audio_stub":
            batch["enc_embeds"] = jnp.asarray(
                rng.normal(size=(a.batch, cfg.num_frontend_tokens, cfg.d_model)),
                jnp.float32,
            )
        else:
            batch["src_tokens"] = batch["tokens"]
    if cfg.frontend == "vision_stub":
        batch["img_embeds"] = jnp.asarray(
            rng.normal(size=(a.batch, cfg.num_frontend_tokens, cfg.d_model)),
            jnp.float32,
        )
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)  # shim, by design
        toks, tps = generate(
            model, params, batch,
            max_len=a.prompt_len + a.gen + cfg.num_frontend_tokens + 1,
            steps=a.gen, temperature=a.temperature, seed=a.seed,
            top_k=a.top_k, top_p=a.top_p,
        )
    print(f"generated {toks.shape} tokens at {tps:.1f} tok/s")
    print(toks[:, :12])


if __name__ == "__main__":
    main()
