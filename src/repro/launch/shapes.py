"""Assigned input shapes + ShapeDtypeStruct stand-ins for every model input.

``input_specs`` builds weak-type-correct, shardable abstract inputs (no
device allocation) for each (arch × shape × step-kind); ``dryrun_bundle``
packages (fn, abstract args, in_shardings) ready for ``jit(...).lower()``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

from repro.core.config import ModelConfig, ParallelConfig, TrainConfig
from repro.models.model import Model, build_model
from repro.parallel.sharding import axis_rules, spec


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
    # the paper's own pretraining workloads (BioNeMo recipes)
    "mlm_1k": InputShape("mlm_1k", 1024, 2048, "train"),      # ESM-2 recipe
    "mlm_2k": InputShape("mlm_2k", 2048, 1024, "train"),      # Geneformer
}

# archs that legitimately run long_500k (sub-quadratic decode memory/compute)
LONG_OK_FAMILIES = ("ssm", "hybrid")


def applicable(cfg: ModelConfig, shape: InputShape) -> Tuple[bool, str]:
    if shape.name != "long_500k":
        return True, ""
    if cfg.family in LONG_OK_FAMILIES:
        return True, "ssm/hybrid state decode"
    if cfg.sliding_window:
        return True, f"sliding-window {cfg.sliding_window} decode cache"
    return False, (
        "pure full-attention arch: 500k-token decode cache is quadratic-"
        "regime; skipped per DESIGN.md (run with --variant sliding_window "
        "to force a windowed variant)"
    )


def _i32(shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def _f(shape, dt=jnp.bfloat16):
    return jax.ShapeDtypeStruct(shape, dt)


def train_batch_specs(cfg: ModelConfig, shape: InputShape):
    B, S = shape.global_batch, shape.seq_len
    batch: Dict[str, Any] = {}
    if cfg.frontend == "vision_stub":
        batch["tokens"] = _i32((B, S - cfg.num_frontend_tokens))
        batch["img_embeds"] = _f((B, cfg.num_frontend_tokens, cfg.d_model))
    elif cfg.frontend == "audio_stub":
        batch["tokens"] = _i32((B, S))
        batch["enc_embeds"] = _f((B, cfg.num_frontend_tokens, cfg.d_model))
    elif cfg.is_encoder_decoder:
        batch["tokens"] = _i32((B, S))
        batch["src_tokens"] = _i32((B, S))
    elif cfg.objective == "mlm":
        batch["tokens"] = _i32((B, S))
        batch["targets"] = _i32((B, S))
        batch["loss_mask"] = _f((B, S), jnp.float32)
    else:
        batch["tokens"] = _i32((B, S))
    return batch


def batch_shardings(cfg, shape: InputShape, mesh, rules) -> Any:
    b_ax = rules.get("batch")

    def sh(sds):
        nd = len(sds.shape)
        if nd == 0:
            return NamedSharding(mesh, PartitionSpec())
        return NamedSharding(mesh, PartitionSpec(b_ax, *([None] * (nd - 1))))

    return jax.tree.map(sh, train_batch_specs(cfg, shape))


def _cache_sharding_tree(model: Model, cache_abs, mesh, rules, wide_seq: bool):
    """Sharding for the decode cache pytree (stacked over scan units)."""
    batch_ax = rules.get("batch")
    seq_ax = ("data", "model") if wide_seq else rules.get("cache_seq")
    model_ax = rules.get("tp")
    b_ax = None if wide_seq else batch_ax  # batch=1 cannot shard

    def walk(tree, keys=()):
        if isinstance(tree, dict):
            return {k: walk(v, keys + (k,)) for k, v in tree.items()}
        nd = len(tree.shape)
        if "xattn" in keys:
            if keys[-1] in ("k", "v"):
                return NamedSharding(mesh, PartitionSpec(None, b_ax))
            return NamedSharding(mesh, PartitionSpec(None, b_ax))
        if keys[-1] in ("k", "v"):       # (units, B, T, Hkv, hd)
            return NamedSharding(mesh, PartitionSpec(None, b_ax, seq_ax))
        if keys[-1] == "state":          # (units, B, H, P, N)
            return NamedSharding(mesh, PartitionSpec(None, b_ax, model_ax))
        if keys[-1] == "conv":           # (units, B, kw-1, conv_dim)
            return NamedSharding(mesh, PartitionSpec(None, b_ax, None, model_ax))
        if keys[-1] == "len":
            return NamedSharding(mesh, PartitionSpec(None, b_ax))
        if keys[-1] == "pos":
            return NamedSharding(mesh, PartitionSpec())
        return NamedSharding(mesh, PartitionSpec())

    return walk(cache_abs)


def dryrun_bundle(
    cfg: ModelConfig,
    shape: InputShape,
    mesh,
    pc: ParallelConfig,
    tc: Optional[TrainConfig] = None,
):
    """Returns (fn, abstract_args tuple, in_shardings tuple, meta dict)."""
    from repro.training import train_step as TS

    model = build_model(cfg, pc, mesh)
    rules = model.ctx.rules
    tc = tc or TrainConfig(global_batch=shape.global_batch, seq_len=shape.seq_len)

    if shape.kind == "train":
        state_abs = TS.abstract_train_state(model)
        state_specs = TS.train_state_specs(model)
        state_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), state_specs)
        batch_abs = train_batch_specs(cfg, shape)
        batch_sh = batch_shardings(cfg, shape, mesh, rules)
        fn = TS.make_train_step(model, tc)
        return fn, (state_abs, batch_abs), (state_sh, batch_sh), {"model": model}

    params_abs = model.abstract_params()
    params_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), model.param_specs())

    if shape.kind == "prefill":
        batch_abs = train_batch_specs(cfg, shape)
        batch_abs.pop("targets", None)
        batch_abs.pop("loss_mask", None)
        batch_sh = batch_shardings(cfg, shape, mesh, rules)
        batch_sh = {k: batch_sh[k] for k in batch_abs}
        max_len = shape.seq_len
        fn = TS.make_prefill_step(model, max_len)
        return fn, (params_abs, batch_abs), (params_sh, batch_sh), {"model": model}

    # decode: one new token against a seq_len cache
    B = shape.global_batch
    cross_len = cfg.num_frontend_tokens if cfg.is_encoder_decoder else 0
    cache_abs = jax.eval_shape(
        lambda: model.init_cache(B, shape.seq_len, cross_len=cross_len)
    )
    wide = shape.global_batch == 1
    cache_sh = _cache_sharding_tree(model, cache_abs, mesh, rules, wide)
    tok_abs = _i32((B, 1))
    tok_sh = NamedSharding(
        mesh, PartitionSpec(rules.get("batch")) if not wide else PartitionSpec()
    )
    fn = TS.make_decode_step(model)
    return (
        fn,
        (params_abs, cache_abs, tok_abs),
        (params_sh, cache_sh, tok_sh),
        {"model": model},
    )
