"""End-to-end training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch esm2-650m \
        --steps 200 --batch 8 --seq 128 [--smoke]

On this CPU container ``--smoke`` (reduced config) is the practical mode;
the same launcher drives the full config on a real TPU mesh (it constructs
the production mesh when >1 device is available).
"""
from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.core.config import ParallelConfig, TrainConfig
from repro.data.dataset import MemmapTokenDataset, build_synthetic_protein_memmap
from repro.data.pipeline import CLMBatches, MLMBatches
from repro.data.sampler import ClusterSampler, greedy_length_clusters
from repro.models.model import build_model
from repro.training.loop import run_training


def make_batches(cfg, tc: TrainConfig, data_dir: str, seed: int = 0):
    ds, tok = build_synthetic_protein_memmap(f"{data_dir}/protein", n=2000, seed=seed)
    if cfg.objective == "mlm":
        lengths = [len(ds[i]) for i in range(len(ds))]
        sampler = ClusterSampler(greedy_length_clusters(lengths, 64), seed=seed)
        return iter(
            MLMBatches(ds, tok, sampler, tc.global_batch, tc.seq_len,
                       cfg.mlm_mask_prob, seed)
        )
    if cfg.is_encoder_decoder:
        base = iter(CLMBatches(ds, tc.global_batch, tc.seq_len, seed))

        def gen():
            for b in base:
                b = dict(b)
                b["src_tokens"] = b["tokens"]
                yield b

        return gen()
    return iter(CLMBatches(ds, tc.global_batch, tc.seq_len, seed))


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="esm2-650m")
    p.add_argument("--steps", type=int, default=100)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=128)
    p.add_argument("--lr", type=float, default=1e-3)
    p.add_argument("--smoke", action="store_true", help="reduced config")
    p.add_argument("--data-dir", default="/tmp/repro_data")
    p.add_argument("--ckpt-dir", default="")
    p.add_argument("--history-out", default="")
    a = p.parse_args()

    cfg = get_smoke_config(a.arch) if a.smoke else get_config(a.arch)
    tc = TrainConfig(
        global_batch=a.batch, seq_len=a.seq, learning_rate=a.lr,
        total_steps=a.steps, warmup_steps=max(a.steps // 10, 1),
        decay_steps=max(a.steps // 10, 1),
        ckpt_dir=a.ckpt_dir, ckpt_every=a.steps if a.ckpt_dir else 0,
    )
    mesh = None  # single-device CPU; on TPU: make_production_mesh()
    model = build_model(cfg, ParallelConfig(), mesh)
    print(f"arch={cfg.name} params(analytic)={cfg.param_count():,}")
    batches = make_batches(cfg, tc, a.data_dir)
    state, history = run_training(model, tc, batches)
    if a.history_out:
        with open(a.history_out, "w") as f:
            json.dump(history, f, indent=1)
    print(f"final loss {history[-1]['loss']:.4f} (from {history[0]['loss']:.4f})")


if __name__ == "__main__":
    main()
