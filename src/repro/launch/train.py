"""End-to-end training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch esm2-650m \
        --steps 200 --batch 8 --seq 128 [--smoke] [--accum 4] \
        [--mesh auto|none|DxM] [--resume auto|<ckpt_dir>]

On this CPU container ``--smoke`` (reduced config) is the practical mode;
the same launcher drives the full config on a real TPU mesh.  When more
than one device is present (a real mesh, or CPU simulation via
``XLA_FLAGS=--xla_force_host_platform_device_count=8``) the launcher
constructs a (data, model) mesh and the Trainer runs the sharded train
step; ``--mesh 4x2`` pins the shape explicitly, ``--mesh none`` forces the
single-device path.

Telemetry: ``--metrics-dir DIR`` feeds the unified registry
(``repro.obs``) and refreshes a Prometheus exposition + JSON snapshot
there at every log flush; ``--profile DIR`` captures a ``jax.profiler``
trace of the whole run and prints the host-side per-phase step timer.
"""
from __future__ import annotations

import argparse
import dataclasses
import json

import jax
import numpy as np

from repro.checkpoint import ckpt
from repro.configs import get_config, get_smoke_config
from repro.core.config import ParallelConfig, TrainConfig
from repro.data.dataset import (
    MemmapTokenDataset,
    build_synthetic_protein_memmap,
    build_synthetic_protein_store,
)
from repro.data.pipeline import CLMBatches, MLMBatches
from repro.data.producer import BackgroundProducer
from repro.data.sampler import ClusterSampler, greedy_length_clusters
from repro.data.size_aware import SizeAwareSampler
from repro.models.model import build_model
from repro.training.loop import Trainer


class Seq2SeqBatches:
    """CLM packing with a ``src_tokens`` mirror (enc-dec archs), delegating
    the resume cursor to the underlying pipeline."""

    def __init__(self, base: CLMBatches):
        self.base = base

    def state_dict(self):
        return self.base.state_dict()

    def load_state_dict(self, st):
        self.base.load_state_dict(st)

    def __iter__(self):
        for b in self.base:
            b = dict(b)
            b["src_tokens"] = b["tokens"]
            yield b


def make_batches(cfg, tc: TrainConfig, data_dir: str, seed: int = 0, *,
                 sharded: bool = False, max_tokens: int = 0,
                 producer_depth: int = 0, round_to: int = 1):
    """Returns the pipeline OBJECT (not an iterator) so the Trainer can
    checkpoint/restore its cursor (``state_dict``/``load_state_dict``).

    ``sharded`` feeds from the multi-shard memmap store instead of the
    single-file dataset; ``max_tokens`` > 0 switches to size-aware
    (token-budget) batching with per-bucket shapes, ``round_to`` keeping
    every batch's row count divisible by the mesh's data axis;
    ``producer_depth`` > 0 wraps the pipeline in a background producer.
    """
    if sharded:
        ds, tok = build_synthetic_protein_store(
            f"{data_dir}/protein_store", n=2000, seed=seed
        )
    else:
        ds, tok = build_synthetic_protein_memmap(
            f"{data_dir}/protein", n=2000, seed=seed
        )
    lengths = ds.lengths()
    base = ClusterSampler(greedy_length_clusters(lengths, 64), seed=seed)
    if cfg.objective == "mlm":
        if max_tokens:
            sampler = SizeAwareSampler(
                np.minimum(lengths, tc.seq_len), max_tokens,
                base=base, round_to=round_to,
            )
        else:
            sampler = base
        pipe = MLMBatches(ds, tok, sampler, tc.global_batch, tc.seq_len,
                          cfg.mlm_mask_prob, seed)
    elif cfg.is_encoder_decoder:
        pipe = Seq2SeqBatches(
            CLMBatches(ds, tc.global_batch, tc.seq_len, seed,
                       eos_id=tok.eos_id)
        )
    else:
        sampler = (
            SizeAwareSampler(np.minimum(lengths, tc.seq_len), max_tokens,
                             base=base, round_to=round_to)
            if max_tokens else None
        )
        pipe = CLMBatches(ds, tc.global_batch, tc.seq_len, seed,
                          eos_id=tok.eos_id, sampler=sampler)
    if producer_depth:
        pipe = BackgroundProducer(pipe, depth=producer_depth)
    return pipe


def build_mesh(spec: str):
    """"auto" = (n_devices, 1) data-parallel mesh when >1 device is
    visible; "none" = single-device; "DxM" = explicit (data, model)."""
    n = jax.device_count()
    if spec == "none":
        return None
    if spec == "auto":
        return jax.make_mesh((n, 1), ("data", "model")) if n > 1 else None
    d, m = (int(x) for x in spec.lower().split("x"))
    return jax.make_mesh((d, m), ("data", "model"))


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="esm2-650m")
    p.add_argument("--steps", type=int, default=100)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=128)
    p.add_argument("--lr", type=float, default=1e-3)
    p.add_argument("--warmup", type=int, default=0,
                   help="warmup steps (0 = steps//10)")
    p.add_argument("--accum", type=int, default=1,
                   help="gradient-accumulation microbatches per step")
    p.add_argument("--mesh", default="auto",
                   help="auto | none | DxM, e.g. 4x2 = (data=4, model=2)")
    p.add_argument("--smoke", action="store_true", help="reduced config")
    p.add_argument("--data-dir", default="/tmp/repro_data")
    p.add_argument("--sharded-data", action="store_true",
                   help="feed from the multi-shard memmap store "
                        "(repro.data.store) instead of the single-file "
                        "dataset")
    p.add_argument("--max-tokens-per-batch", type=int, default=0,
                   help="enable size-aware (token-budget) batching: "
                        "variable-row batches padded per length bucket, "
                        "every batch under this many padded tokens "
                        "(0 = fixed --batch x --seq shapes)")
    p.add_argument("--producer", type=int, default=0,
                   help="background-producer prefetch depth (0 = build "
                        "batches inline on the consumer thread)")
    p.add_argument("--ckpt-dir", default="")
    p.add_argument("--ckpt-every", type=int, default=0,
                   help="checkpoint period in steps (0 = final-only when "
                        "--ckpt-dir is set)")
    p.add_argument("--resume", default="",
                   help="checkpoint dir to resume from, or 'auto' = latest "
                        "step_* under --ckpt-dir")
    p.add_argument("--history-out", default="")
    p.add_argument("--metrics-dir", default="",
                   help="write Prometheus exposition + JSON metric snapshots "
                        "here (refreshed every log flush via a trainer hook)")
    p.add_argument("--profile", default="",
                   help="capture a jax.profiler trace of the run into this "
                        "directory (also enables step annotations/timers)")
    a = p.parse_args()

    cfg = get_smoke_config(a.arch) if a.smoke else get_config(a.arch)
    tc = TrainConfig(
        global_batch=a.batch, seq_len=a.seq, learning_rate=a.lr,
        accum_steps=a.accum,
        total_steps=a.steps,
        warmup_steps=a.warmup or max(a.steps // 10, 1),
        decay_steps=max(a.steps // 10, 1),
        ckpt_dir=a.ckpt_dir,
        ckpt_every=a.ckpt_every or (a.steps if a.ckpt_dir else 0),
    )
    print("resolved TrainConfig:")
    print(json.dumps(dataclasses.asdict(tc), indent=1))
    mesh = build_mesh(a.mesh)
    model = build_model(cfg, ParallelConfig(), mesh)
    print(
        f"arch={cfg.name} params(analytic)={cfg.param_count():,} "
        f"mesh={dict(zip(mesh.axis_names, mesh.devices.shape)) if mesh else None}"
    )
    # size-aware batches must keep rows divisible by the data axis so
    # sharded placement never sees a ragged leading dim
    data_axis = (
        dict(zip(mesh.axis_names, mesh.devices.shape)).get("data", 1)
        if mesh is not None else 1
    )
    batches = make_batches(
        cfg, tc, a.data_dir,
        sharded=a.sharded_data, max_tokens=a.max_tokens_per_batch,
        producer_depth=a.producer, round_to=data_axis,
    )
    resume = a.resume
    if resume == "auto":
        resume = ckpt.latest_step(a.ckpt_dir) or ""
        print(f"resume: {resume or '(no checkpoint found — cold start)'}")
    from repro.obs import MetricsRegistry, trace_ctx

    reg = MetricsRegistry() if a.metrics_dir else None
    hooks = []
    if reg is not None:
        import os

        os.makedirs(a.metrics_dir, exist_ok=True)

        def _dump(step, m, _reg=reg, _dir=a.metrics_dir):
            # refreshed at every log flush: mid-run dashboards see live
            # tokens/s / grad-norm, not just the final summary
            _reg.write_prometheus(os.path.join(_dir, "train.prom"))
            _reg.dump_json(os.path.join(_dir, "train_metrics.json"))

        hooks.append(_dump)
    trainer = Trainer(model, tc, hooks=hooks, metrics=reg,
                      profile=bool(a.profile))
    try:
        with trace_ctx(a.profile):
            state, history = trainer.run(batches, resume_from=resume or None)
    finally:
        if hasattr(batches, "close"):
            batches.close()
    if a.profile and trainer.step_timer is not None:
        print("step timer:")
        for line in trainer.step_timer.report().splitlines():
            print(f"  {line}")
    if a.history_out:
        with open(a.history_out, "w") as f:
            json.dump(history, f, indent=1)
    if history:
        print(
            f"final loss {history[-1]['loss']:.4f} "
            f"(from {history[0]['loss']:.4f})  "
            f"{history[-1]['tokens_per_sec']:.0f} tok/s  "
            f"tokens_seen={history[-1]['tokens_seen']:.0f}"
        )


if __name__ == "__main__":
    main()
