"""GQA attention with context-parallel / head-TP activation sharding,
KV caching (prefill + decode), sliding window, and optional cross-attention.

Cache layout: {"k": (B, T, Hkv, D), "v": (B, T, Hkv, D)} with the sequence
dim logically ``cache_seq`` (sharded over `model` when enabled — decode then
lowers to flash-decoding-style partial-stat all-reduces, see ops.decode_attention).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.config import ModelConfig
from repro.core.module import P
from repro.kernels import ops
from repro.models.layers import rope
from repro.parallel.sharding import ShardingCtx


def attention_defs(cfg: ModelConfig, cross: bool = False) -> Dict[str, P]:
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    nq, nkv = cfg.num_heads, cfg.num_kv_heads
    defs: Dict[str, P] = {
        "wq": P((d, nq * hd), ("fsdp", "tp"), fan_in=d),
        "wk": P((d, nkv * hd), ("fsdp", "tp"), fan_in=d),
        "wv": P((d, nkv * hd), ("fsdp", "tp"), fan_in=d),
        "wo": P((nq * hd, d), ("tp", "fsdp"), fan_in=nq * hd),
    }
    if cfg.qkv_bias:
        defs["bq"] = P((nq * hd,), ("tp",), init="zeros")
        defs["bk"] = P((nkv * hd,), ("tp",), init="zeros")
        defs["bv"] = P((nkv * hd,), ("tp",), init="zeros")
    if cfg.attn_out_bias:
        defs["bo"] = P((d,), (None,), init="zeros")
    return defs


def _project_qkv(cfg, params, x, kv_src=None):
    """Returns q (B,S,H,D), k, v (B,T,Hkv,D)."""
    cdt = x.dtype
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    src = x if kv_src is None else kv_src
    T = src.shape[1]
    q = x @ params["wq"].astype(cdt)
    k = src @ params["wk"].astype(cdt)
    v = src @ params["wv"].astype(cdt)
    if "bq" in params:
        q = q + params["bq"].astype(cdt)
        k = k + params["bk"].astype(cdt)
        v = v + params["bv"].astype(cdt)
    q = q.reshape(B, S, cfg.num_heads, hd)
    k = k.reshape(B, T, cfg.num_kv_heads, hd)
    v = v.reshape(B, T, cfg.num_kv_heads, hd)
    return q, k, v


def _out_proj(cfg, ctx: ShardingCtx, params, o: jax.Array) -> jax.Array:
    B, S = o.shape[:2]
    cdt = o.dtype
    o = o.reshape(B, S, cfg.num_heads * cfg.resolved_head_dim)
    out = o @ params["wo"].astype(cdt)
    if "bo" in params:
        out = out + params["bo"].astype(cdt)
    return out


def attention_apply(
    cfg: ModelConfig,
    ctx: ShardingCtx,
    params: Dict[str, Any],
    x: jax.Array,                       # (B, S, d_model)
    *,
    positions: Optional[jax.Array] = None,
    mode: str = "train",                # train | prefill | decode
    cache: Optional[Dict[str, jax.Array]] = None,
    cache_pos: Optional[jax.Array] = None,   # scalar int32 (decode write idx)
    causal: Optional[bool] = None,
    cross_kv: Optional[jax.Array] = None,    # encoder output for cross-attn
    window: Optional[int] = None,
    block_table: Optional[jax.Array] = None,  # (B, pages_per_seq) paged layout
    chunk_valid: Optional[jax.Array] = None,  # scalar: valid rows of a chunk
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    B, S, _ = x.shape
    causal = cfg.causal if causal is None else causal
    window = cfg.sliding_window if window is None else window
    is_cross = cross_kv is not None

    if mode == "decode" and (is_cross or (cache is not None and "len" in cache)):
        # cross-attention KV was precomputed at prefill time and lives in cache
        q, _, _ = _project_qkv(cfg, params, x, kv_src=x[:, :0])
        if cfg.use_rope:
            pass  # no rope on cross-attention
        k, v = cache["k"], cache["v"]
        lengths = cache["len"]
        o = ops.decode_attention(
            q, k, v, lengths, softcap=cfg.attn_logit_softcap, impl=cfg.kernel_impl
        )
        return _out_proj(cfg, ctx, params, o), cache

    q, k, v = _project_qkv(cfg, params, x, kv_src=cross_kv)

    if cfg.use_rope and not is_cross:
        if positions is None:
            if mode == "decode":
                if jnp.ndim(cache_pos) == 0:
                    positions = jnp.full((S,), cache_pos, jnp.int32)
                else:
                    positions = cache_pos[:, None].astype(jnp.int32)  # (B,1)
            else:
                positions = jnp.arange(S)
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)

    if mode == "chunk":
        # chunked / suffix prefill over the paged layout (serving engine):
        # the chunk's S rows sit at logical positions cache_pos + [0, S);
        # rows >= chunk_valid are bucket padding (their K/V is routed to
        # the null page and their outputs are discarded by the caller).
        # Writes only ever touch pages the slot owns exclusively — the
        # engine privatizes shared prefix pages (COW) before chunking.
        assert cache is not None and "k_pool" in cache, \
            "mode='chunk' requires the paged cache layout"
        assert block_table is not None and jnp.ndim(cache_pos) == 0
        assert B == 1, "chunked prefill processes one slot at a time"
        page = cache["k_pool"].shape[1]
        n_tables = block_table.shape[1]
        pos = cache_pos + jnp.arange(S, dtype=jnp.int32)           # (S,)
        valid = jnp.arange(S) < chunk_valid
        page_idx = block_table[0, jnp.clip(pos // page, 0, n_tables - 1)]
        page_idx = jnp.where(valid, page_idx, 0)                   # null page
        k_pool, v_pool = ops.paged_kv_update_rows(
            cache["k_pool"], cache["v_pool"], k[0], v[0],
            page_idx, pos % page,
        )
        k_pool = ctx.cons(k_pool, None, None, "kv_tp", None)
        v_pool = ctx.cons(v_pool, None, None, "kv_tp", None)
        starts = jnp.full((B,), cache_pos, jnp.int32)
        lengths = jnp.full((B,), cache_pos + chunk_valid, jnp.int32)
        o = ops.paged_prefill_attention(
            q, k_pool, v_pool, block_table, starts, lengths,
            softcap=cfg.attn_logit_softcap, impl=cfg.kernel_impl,
        )
        new_cache = {"k_pool": k_pool, "v_pool": v_pool}
        return _out_proj(cfg, ctx, params, o), new_cache

    if mode == "decode" and cache is not None and "k_pool" in cache:
        # paged layout (serving engine): per-slot positions, block-table
        # indirection into the shared page pool.  The token insert is an
        # O(B·page) scatter (ops.paged_kv_update) — not the O(B·T) masked
        # select of the dense per-slot path below.
        assert block_table is not None and jnp.ndim(cache_pos) == 1
        page = cache["k_pool"].shape[1]
        capacity = block_table.shape[1] * page
        cp = jnp.minimum(cache_pos.astype(jnp.int32), capacity - 1)
        page_idx = jnp.take_along_axis(
            block_table, (cp // page)[:, None], axis=1
        )[:, 0]
        k_pool, v_pool = ops.paged_kv_update(
            cache["k_pool"], cache["v_pool"], k, v, page_idx, cp % page,
            impl=cfg.kernel_impl,
        )
        # pool sharding: KV heads over `model` (TP serving) — the page axis
        # stays local so block-table gathers never cross devices
        k_pool = ctx.cons(k_pool, None, None, "kv_tp", None)
        v_pool = ctx.cons(v_pool, None, None, "kv_tp", None)
        lengths = jnp.minimum(cache_pos + 1, jnp.int32(capacity))
        o = ops.paged_decode_attention(
            q, k_pool, v_pool, block_table, lengths,
            softcap=cfg.attn_logit_softcap, impl=cfg.kernel_impl,
        )
        new_cache = {"k_pool": k_pool, "v_pool": v_pool}
        return _out_proj(cfg, ctx, params, o), new_cache

    if mode == "decode":
        assert cache is not None and cache_pos is not None
        # window caches are rolling: write at cache_pos % T
        T = cache["k"].shape[1]
        rolling = bool(window) and window <= T
        if jnp.ndim(cache_pos) == 0:
            widx = cache_pos % T if rolling else cache_pos
            k_cache = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, widx, 0, 0)
            )
            v_cache = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, widx, 0, 0)
            )
            lengths = jnp.minimum(
                jnp.full((B,), cache_pos + 1, jnp.int32), jnp.int32(T)
            )
        else:
            # per-slot positions (continuous-batching engine): masked write.
            # O(B·T) traffic — fine at serving batch sizes; a paged cache /
            # Pallas scatter is the production path (see serving/engine.py).
            widx = (cache_pos % T) if rolling else cache_pos     # (B,)
            onehot = (
                jnp.arange(T)[None, :] == widx[:, None]
            )[..., None, None]                                    # (B,T,1,1)
            k_cache = jnp.where(onehot, k.astype(cache["k"].dtype), cache["k"])
            v_cache = jnp.where(onehot, v.astype(cache["v"].dtype), cache["v"])
            lengths = jnp.minimum(cache_pos + 1, jnp.int32(T))
        k_cache = ctx.cons(k_cache, "cache_batch", "cache_seq")
        v_cache = ctx.cons(v_cache, "cache_batch", "cache_seq")
        o = ops.decode_attention(
            q, k_cache, v_cache, lengths, softcap=cfg.attn_logit_softcap,
            impl=cfg.kernel_impl,
        )
        new_cache = {"k": k_cache, "v": v_cache}
        return _out_proj(cfg, ctx, params, o), new_cache

    # train / prefill: blockwise attention over the full (or encoder) sequence
    if ctx.context_parallel and not is_cross:
        q = ctx.cons(q, "batch", "seq_cp")
        # GQA KV is small: gather it fully (llama3-style CP)
        k = ctx.cons(k, "batch", None)
        v = ctx.cons(v, "batch", None)
    # train / prefill hot path: cfg.kernel_impl="auto" hits the fused Pallas
    # kernels (fwd + custom-VJP bwd) on TPU, the blockwise xla path elsewhere
    o = ops.attention(
        q, k, v,
        causal=causal and not is_cross,
        window=window,
        softcap=cfg.attn_logit_softcap,
        impl=cfg.kernel_impl,
    )
    out = _out_proj(cfg, ctx, params, o)

    new_cache = None
    if mode == "prefill" and not is_cross:
        new_cache = {"k": k, "v": v}
    return out, new_cache


def init_cache(
    cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16
) -> Dict[str, jax.Array]:
    hd = cfg.resolved_head_dim
    T = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    return {
        "k": jnp.zeros((batch, T, cfg.num_kv_heads, hd), dtype),
        "v": jnp.zeros((batch, T, cfg.num_kv_heads, hd), dtype),
    }


def init_paged_cache(
    cfg: ModelConfig, num_pages: int, page_size: int, dtype=jnp.bfloat16
) -> Dict[str, jax.Array]:
    """Shared K/V page pool for one layer (block table lives with the
    engine cache top-level — it is identical across layers)."""
    if cfg.sliding_window:
        raise ValueError(
            "cache_layout='paged' does not support sliding-window (rolling) "
            "caches — use the dense layout"
        )
    hd = cfg.resolved_head_dim
    return {
        "k_pool": jnp.zeros((num_pages, page_size, cfg.num_kv_heads, hd), dtype),
        "v_pool": jnp.zeros((num_pages, page_size, cfg.num_kv_heads, hd), dtype),
    }
