"""Shared layers: norms, rotary embeddings, MLPs, embeddings.

Every layer is a pair (``*_defs`` returning a P-tree, ``*_apply`` pure fn).
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.config import ModelConfig
from repro.core.module import P
from repro.kernels import ops
from repro.parallel.sharding import ShardingCtx


# --------------------------------------------------------------------- #
# norms
# --------------------------------------------------------------------- #
def norm_defs(cfg: ModelConfig, d: int) -> Dict[str, P]:
    defs = {"scale": P((d,), (None,), init="ones")}
    if cfg.norm_type == "layernorm":
        defs["bias"] = P((d,), (None,), init="zeros")
    return defs


def norm_apply(cfg: ModelConfig, params: Dict[str, Any], x: jax.Array) -> jax.Array:
    if cfg.norm_type == "rmsnorm":
        return ops.rmsnorm(x, params["scale"])
    bias = params.get("bias")
    return ops.layernorm(x, params["scale"], bias)


# --------------------------------------------------------------------- #
# rotary position embedding
# --------------------------------------------------------------------- #
def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, D); positions: (S,) or (B, S)."""
    D = x.shape[-1]
    half = D // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        ang = positions.astype(jnp.float32)[None, :, None] * freqs[None, None, :]
        ang = ang[:, :, None, :]                       # (1, S, 1, half)
    else:
        ang = positions.astype(jnp.float32)[:, :, None] * freqs[None, None, :]
        ang = ang[:, :, None, :]                       # (B, S, 1, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------- #
# dense MLP
# --------------------------------------------------------------------- #
def mlp_defs(cfg: ModelConfig, d: int, d_ff: int) -> Dict[str, P]:
    gated = cfg.act in ("swiglu", "geglu")
    defs: Dict[str, P] = {
        "w_in": P((d, d_ff), ("fsdp", "tp"), fan_in=d),
        "w_out": P((d_ff, d), ("tp", "fsdp"), fan_in=d_ff),
    }
    if gated:
        defs["w_gate"] = P((d, d_ff), ("fsdp", "tp"), fan_in=d)
    if cfg.mlp_bias:
        defs["b_in"] = P((d_ff,), ("tp",), init="zeros")
        defs["b_out"] = P((d,), (None,), init="zeros")
    return defs


def _act(name: str, x: jax.Array) -> jax.Array:
    if name in ("swiglu",):
        return jax.nn.silu(x)
    if name in ("gelu", "geglu"):
        return jax.nn.gelu(x)
    return jax.nn.relu(x)


def mlp_apply(
    cfg: ModelConfig, ctx: ShardingCtx, params: Dict[str, Any], x: jax.Array
) -> jax.Array:
    cdt = x.dtype
    h = x @ params["w_in"].astype(cdt)
    if "b_in" in params:
        h = h + params["b_in"].astype(cdt)
    if "w_gate" in params:
        g = x @ params["w_gate"].astype(cdt)
        h = _act(cfg.act, g) * h
    else:
        h = _act(cfg.act, h)
    if ctx.context_parallel:
        h = ctx.cons(h, "batch", "seq_cp", None)
    else:
        h = ctx.cons(h, "batch", "seq", "tp")
    out = h @ params["w_out"].astype(cdt)
    if "b_out" in params:
        out = out + params["b_out"].astype(cdt)
    return out


# --------------------------------------------------------------------- #
# embeddings & lm head
# --------------------------------------------------------------------- #
def embedding_defs(cfg: ModelConfig) -> Dict[str, P]:
    defs = {
        "tok": P((cfg.padded_vocab, cfg.d_model), ("tp", "fsdp"), init="normal", scale=0.02)
    }
    if not cfg.use_rope and cfg.max_pos:
        defs["pos"] = P((cfg.max_pos, cfg.d_model), (None, "fsdp"), init="normal", scale=0.02)
    return defs


def embed_apply(
    cfg: ModelConfig,
    ctx: ShardingCtx,
    params: Dict[str, Any],
    tokens: jax.Array,           # (B, S) int32
    positions: Optional[jax.Array] = None,
    compute_dtype=jnp.bfloat16,
) -> jax.Array:
    x = jnp.take(params["tok"], tokens, axis=0).astype(compute_dtype)
    if "pos" in params:
        if positions is None:
            positions = jnp.arange(tokens.shape[1])
        pe = jnp.take(params["pos"], positions, axis=0).astype(compute_dtype)
        x = x + (pe if pe.ndim == 3 else pe[None])
    return x


def lm_head_defs(cfg: ModelConfig) -> Dict[str, P]:
    if cfg.tie_embeddings:
        return {}
    return {"w": P((cfg.d_model, cfg.padded_vocab), ("fsdp", "tp"), fan_in=cfg.d_model)}


def lm_head_weight(cfg: ModelConfig, params: Dict[str, Any], embed_params) -> jax.Array:
    if cfg.tie_embeddings:
        return embed_params["tok"].T
    return params["w"]
