"""Top-level model: param tree assembly + train/prefill/decode entry points.

``Model`` is the single public handle the launcher, trainer, server, tests
and dry-run all use.  It is architecture-generic: the config decides dense /
MoE / SSM / hybrid / enc-dec / frontend-stub wiring.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.config import ModelConfig, ParallelConfig
from repro.core.module import P, abstract, materialize, spec_tree
from repro.core.precision import policy_for
from repro.kernels import ops
from repro.models import layers as L
from repro.models import transformer as T
from repro.parallel.sharding import ShardingCtx, fit_spec, null_ctx
from repro.parallel.sharding import spec as axis_spec


class Model:
    def __init__(self, cfg: ModelConfig, ctx: Optional[ShardingCtx] = None):
        self.cfg = cfg
        self.ctx = ctx if ctx is not None else null_ctx()
        self.policy = policy_for(cfg)

    # ------------------------------------------------------------ params
    def param_defs(self) -> Dict[str, Any]:
        cfg = self.cfg
        defs: Dict[str, Any] = {
            "embed": L.embedding_defs(cfg),
            "layers": T.stack_defs(cfg, cross=cfg.is_encoder_decoder),
            "final_norm": L.norm_defs(cfg, cfg.d_model),
            "head": L.lm_head_defs(cfg),
        }
        if cfg.is_encoder_decoder:
            import dataclasses

            enc_cfg = dataclasses.replace(
                cfg,
                family="dense",
                num_layers=cfg.encoder_layers,
                num_experts=0,
                causal=False,
                is_encoder_decoder=False,
            )
            self._enc_cfg = enc_cfg
            defs["encoder"] = {
                "layers": T.stack_defs(enc_cfg),
                "final_norm": L.norm_defs(enc_cfg, cfg.d_model),
            }
            if cfg.frontend == "audio_stub" and cfg.max_pos:
                defs["encoder"]["pos"] = P(
                    (cfg.max_pos, cfg.d_model), (None, "fsdp"), init="normal", scale=0.02
                )
        if cfg.frontend == "vision_stub":
            # projector from (stub) vision embeddings into the LM stream
            defs["projector"] = {
                "w": P((cfg.d_model, cfg.d_model), ("fsdp", "tp"), fan_in=cfg.d_model),
                "b": P((cfg.d_model,), (None,), init="zeros"),
            }
        return defs

    def init(self, key: jax.Array):
        return materialize(self.param_defs(), key, self.policy.pdt)

    def abstract_params(self):
        return abstract(self.param_defs(), self.policy.pdt)

    def param_specs(self):
        return spec_tree(self.param_defs(), self.ctx.rules)

    # ------------------------------------------------------------ encoder
    def _encode(self, params, batch) -> jax.Array:
        """Run the encoder (enc-dec archs).  Input: precomputed frame
        embeddings (audio stub) or source tokens (seq2seq)."""
        cfg = self.cfg
        cdt = self.policy.cdt
        if "enc_embeds" in batch:  # audio stub: (B, T_enc, d_model)
            x = batch["enc_embeds"].astype(cdt)
            pos = params["encoder"].get("pos")
            if pos is not None:
                x = x + pos[: x.shape[1]].astype(cdt)[None]
        else:
            x = L.embed_apply(cfg, self.ctx, params["embed"], batch["src_tokens"],
                              compute_dtype=cdt)
        enc_cfg = getattr(self, "_enc_cfg", None)
        if enc_cfg is None:
            self.param_defs()  # populates _enc_cfg
            enc_cfg = self._enc_cfg
        x, _, _ = T.decoder_stack(
            enc_cfg, self.ctx, params["encoder"]["layers"], x,
            mode="train", causal=False,
        )
        return L.norm_apply(cfg, params["encoder"]["final_norm"], x)

    # ------------------------------------------------------------ backbone
    def _decoder_input(self, params, batch, mode: str) -> Tuple[jax.Array, Any]:
        cfg = self.cfg
        cdt = self.policy.cdt
        tokens = batch["tokens"]
        x = L.embed_apply(cfg, self.ctx, params["embed"], tokens, compute_dtype=cdt)
        if cfg.frontend == "vision_stub" and "img_embeds" in batch:
            img = batch["img_embeds"].astype(cdt)
            img = img @ params["projector"]["w"].astype(cdt) + params["projector"][
                "b"
            ].astype(cdt)
            x = jnp.concatenate([img, x], axis=1)
        if self.ctx.context_parallel and mode != "decode":
            x = self.ctx.cons(x, "batch", "seq_cp", None)
        else:
            x = self.ctx.cons(x, "batch", None, None)
        return x, None

    def _backbone(
        self, params, x, *, mode, positions=None, caches=None, cache_pos=None,
        cross_kv=None, block_table=None, chunk_valid=None,
    ):
        cfg = self.cfg
        x, new_caches, aux = T.decoder_stack(
            cfg, self.ctx, params["layers"], x,
            mode=mode, positions=positions, caches=caches,
            cache_pos=cache_pos, cross_kv=cross_kv, block_table=block_table,
            chunk_valid=chunk_valid,
        )
        x = L.norm_apply(cfg, params["final_norm"], x)
        return x, new_caches, aux

    def head_weight(self, params):
        return L.lm_head_weight(self.cfg, params["head"], params["embed"])

    def logits(self, params, hidden: jax.Array) -> jax.Array:
        cfg = self.cfg
        w = self.head_weight(params).astype(hidden.dtype)
        lg = hidden @ w
        if cfg.logit_softcap > 0:
            lg = cfg.logit_softcap * jnp.tanh(lg / cfg.logit_softcap)
        if cfg.padded_vocab != cfg.vocab_size:
            # never sample/argmax into the Megatron vocab padding
            pad_mask = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
            lg = jnp.where(pad_mask, lg, -1e30)
        return lg

    # ------------------------------------------------------------ training
    def loss_fn(self, params, batch) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        cfg = self.cfg
        cross_kv = self._encode(params, batch) if cfg.is_encoder_decoder else None
        x, _, aux = self._backbone(
            params,
            self._decoder_input(params, batch, "train")[0],
            mode="train",
            cross_kv=cross_kv,
        )
        B, S, D = x.shape
        hidden = x.reshape(B * S, D)
        hidden = self.ctx.cons(hidden, "tokens", None)

        if cfg.objective == "mlm":
            targets = batch["targets"].reshape(-1)
            mask = batch["loss_mask"].reshape(-1).astype(jnp.float32)
        else:  # clm / seq2seq / vlm: next-token over text region
            tokens = batch["tokens"]
            n_front = cfg.num_frontend_tokens if cfg.frontend == "vision_stub" else 0
            # hidden covers [front; text]; predict text token t+1 from position t
            hidden = x[:, n_front:, :][:, :-1, :].reshape(-1, D)
            hidden = self.ctx.cons(hidden, "tokens", None)
            targets = tokens[:, 1:].reshape(-1)
            mask = batch.get("loss_mask")
            mask = (
                mask[:, 1:].reshape(-1).astype(jnp.float32)
                if mask is not None
                else jnp.ones_like(targets, jnp.float32)
            )

        w_head = self.head_weight(params).astype(self.policy.cdt)
        # cfg.kernel_impl="auto": fused Pallas CE (fwd + custom-VJP bwd) on
        # TPU so the (tokens × vocab) logits/grad never materialize; block-
        # wise xla elsewhere
        losses, _ = ops.cross_entropy(
            hidden, w_head, targets, vocab=cfg.vocab_size, impl=cfg.kernel_impl
        )
        denom = jnp.maximum(mask.sum(), 1.0)
        loss = (losses * mask).sum() / denom
        metrics = {"ce_loss": loss, "aux_loss": aux, "tokens": denom}
        if cfg.num_experts:
            # aux is the layer-summed router stats vector (moe.aux_shape):
            # [lb_loss, entropy_deficit, dropped, slots, per-expert load…]
            lb, ent_def = aux[0], aux[1]
            loss = (
                loss
                + cfg.router_aux_coef * lb
                + cfg.router_entropy_coef * ent_def
            )
            n_moe = max(T.num_moe_layers(cfg), 1)
            metrics["aux_loss"] = lb
            metrics["router_entropy"] = (
                jnp.log(float(cfg.num_experts)) - ent_def / n_moe
            )
            metrics["router_drop_frac"] = aux[2] / jnp.maximum(aux[3], 1.0)
            load = aux[4:]
            metrics["router_load"] = load / jnp.maximum(load.sum(), 1e-9)
        return loss, metrics

    # ------------------------------------------------------------ serving
    def prefill(self, params, batch, max_len: int, *, length=None):
        """Full-sequence forward; returns (last_logits, cache).

        ``length`` (traced scalar ok): the number of VALID tokens when the
        prompt is right-padded to a bucket (engine prompt bucketing) — the
        returned logits come from row ``length - 1`` and the cache position
        is ``length``.  Right padding is only sound for causal attention
        (pad rows are in the future of every real row); the engine gates
        bucketing accordingly.
        """
        cfg = self.cfg
        cross_kv = self._encode(params, batch) if cfg.is_encoder_decoder else None
        x, _ = self._decoder_input(params, batch, "prefill")
        S = x.shape[1]
        x, caches, _ = self._backbone(
            params, x, mode="prefill", cross_kv=cross_kv
        )
        caches = self._pad_caches(caches, S, max_len)
        if length is None:
            last = x[:, -1:, :]
            pos = jnp.int32(S)
        else:
            pos = jnp.asarray(length, jnp.int32)
            last = jax.lax.dynamic_slice_in_dim(x, pos - 1, 1, axis=1)
        lg = self.logits(params, last)
        cache = {"layers": caches, "pos": pos}
        return lg, cache

    def embed_pool(self, params, batch, lengths: jax.Array) -> jax.Array:
        """Masked mean-pooled sequence embeddings: (B, S) tokens +
        (B,) valid lengths -> (B, d_model) float32.

        Runs the full-sequence forward in ``mode="train"`` — no decode
        cache is built (embedding extraction never decodes), and for
        bidirectional (MLM) models the pad tokens are visible to
        attention exactly as they are during training, so pooled vectors
        match what the model was optimized to produce.  Only positions
        ``< lengths[b]`` enter the mean.
        """
        x, _ = self._decoder_input(params, batch, "train")
        x, _, _ = self._backbone(params, x, mode="train")
        S = x.shape[1]
        mask = (
            jnp.arange(S, dtype=jnp.int32)[None, :]
            < jnp.asarray(lengths, jnp.int32)[:, None]
        )
        x = x.astype(jnp.float32) * mask[..., None]
        denom = jnp.maximum(mask.sum(axis=1), 1).astype(jnp.float32)
        return x.sum(axis=1) / denom[:, None]

    def prefill_chunk(self, params, layers, tokens: jax.Array,
                      block_row: jax.Array, start, n_valid):
        """One bounded chunk of an incremental prefill over the paged
        engine cache (prefix caching + chunked prefill, serving engine).

        ``layers`` is the engine cache's ``"layers"`` pytree (shared page
        pools); ``tokens`` is a (1, C) chunk right-padded to a bucket;
        ``block_row`` is (1, pages_per_seq) — the slot's row of the block
        table; ``start`` (traced scalar) is the logical position of the
        chunk's first token (> 0 when a cached prefix was skipped or an
        earlier chunk already ran); ``n_valid`` (traced scalar, <= C) is
        the number of real rows.  The chunk's K/V rows are scattered into
        the slot's pages and attention runs causally over positions
        [0, start + n_valid) through the block table — including pages
        shared from the prefix cache.

        Returns ``(logits, new_layers)`` where ``logits`` (1, 1, V) come
        from the last valid row (only meaningful on the final chunk).

        Only valid for causal attention-only stacks (the same condition
        as prompt bucketing: SSM state and cross-attention cannot skip or
        pad rows); the serving engine gates accordingly.
        """
        cfg = self.cfg
        C = tokens.shape[1]
        positions = jnp.asarray(start, jnp.int32) + jnp.arange(C, dtype=jnp.int32)
        emb_pos = positions[None] if (not cfg.use_rope and cfg.max_pos) else None
        x = L.embed_apply(
            cfg, self.ctx, params["embed"], tokens,
            positions=emb_pos, compute_dtype=self.policy.cdt,
        )
        x = self.ctx.cons(x, "batch", None, None)
        x, new_layers, _ = self._backbone(
            params, x, mode="chunk", positions=positions,
            caches=layers, cache_pos=jnp.asarray(start, jnp.int32),
            block_table=block_row, chunk_valid=jnp.asarray(n_valid, jnp.int32),
        )
        last = jax.lax.dynamic_slice_in_dim(
            x, jnp.asarray(n_valid, jnp.int32) - 1, 1, axis=1
        )
        return self.logits(params, last), new_layers

    def decode_step(self, params, cache, tokens: jax.Array):
        """One-token step.  tokens: (B, 1).  ``cache["pos"]`` may be a
        scalar (lockstep decoding) or a (B,) vector (continuous batching)."""
        cfg = self.cfg
        pos = cache["pos"]
        vec = jnp.ndim(pos) > 0
        emb_pos = None
        if not cfg.use_rope and cfg.max_pos:
            emb_pos = pos[:, None] if vec else pos[None]
        x = L.embed_apply(
            cfg, self.ctx, params["embed"], tokens,
            positions=emb_pos, compute_dtype=self.policy.cdt,
        )
        x = self.ctx.cons(x, "batch", None, None)
        rope_pos = None if vec else jnp.full((1,), pos, jnp.int32)
        block_table = cache.get("block_table")
        x, new_caches, _ = self._backbone(
            params, x, mode="decode",
            positions=rope_pos,
            caches=cache["layers"], cache_pos=pos, block_table=block_table,
        )
        lg = self.logits(params, x)
        new_cache = {"layers": new_caches, "pos": pos + 1}
        if block_table is not None:
            new_cache["block_table"] = block_table
        return lg, new_cache

    def init_cache(
        self, batch: int, max_len: int, cross_len: int = 0, *,
        layout: str = "dense", page_size: int = 0, num_pages: int = 0,
    ):
        """Preallocated decode cache.

        ``layout="paged"`` builds shared K/V page pools plus a top-level
        ``block_table`` (all-null-page) the serving engine's allocator
        maintains; ``pos`` is per-slot ``(batch,)`` in that layout.

        The cache is built with implicit (single-device) placement even
        when the model carries a mesh ``ShardingCtx`` — this function is
        also called under ``jax.eval_shape`` (launch/shapes.dryrun_bundle)
        where no buffers may be materialized.  Mesh consumers place it
        explicitly via ``cache_shardings``: the serving engine device_puts
        the tree once at construction and pins every per-step jit to the
        same specs.
        """
        if layout == "paged":
            if page_size <= 0 or num_pages <= 1:
                raise ValueError("paged layout needs page_size>0, num_pages>1")
            pages_per_seq = -(-max_len // page_size)
            return {
                "layers": T.init_stack_cache(
                    self.cfg, batch, max_len, self.policy.cdt,
                    cross_len=cross_len, layout="paged",
                    page_size=page_size, num_pages=num_pages,
                ),
                "block_table": jnp.zeros((batch, pages_per_seq), jnp.int32),
                "pos": jnp.zeros((batch,), jnp.int32),
            }
        return {
            "layers": T.init_stack_cache(
                self.cfg, batch, max_len, self.policy.cdt, cross_len=cross_len
            ),
            "pos": jnp.int32(0),
        }

    def cache_specs(self, cache):
        """``PartitionSpec`` tree for a decode cache (same structure as
        ``cache`` — works on concrete arrays or ``jax.eval_shape`` output).

        Mirrors the constraints the layers apply internally
        (models/attention.py, models/ssm.py) so the serving engine can pin
        jit ``in_shardings``/``out_shardings`` without inserting reshard
        collectives into the per-token step: paged ``k_pool``/``v_pool``
        shard over the KV-head (``model``) axis — one logical cache,
        sharded storage — dense K/V over (``cache_batch``,
        ``cache_seq``), SSM state/conv over ``tp``.  The block table and
        positions are host-maintained control state and stay replicated,
        as does the (write-once, batch-1-inserted) cross-attention KV.
        Mesh axes that do not evenly divide a dim are dropped per-dim:
        placement shardings must divide exactly, unlike
        ``with_sharding_constraint``.
        """
        from jax.sharding import PartitionSpec

        ctx = self.ctx
        logical = {
            # (units, P, page, Hkv, D): shared page pools, head-sharded
            "k_pool": (None, None, None, "kv_tp", None),
            "v_pool": (None, None, None, "kv_tp", None),
            # (units, B, T, Hkv, D): dense per-slot KV
            "k": (None, "cache_batch", "cache_seq"),
            "v": (None, "cache_batch", "cache_seq"),
            # (units, B, H, P, N) / (units, B, kw-1, conv_dim)
            "state": (None, "cache_batch", "tp"),
            "conv": (None, "cache_batch", None, "tp"),
        }

        def walk(tree, keys=()):
            if isinstance(tree, dict):
                return {k: walk(v, keys + (k,)) for k, v in tree.items()}
            if ctx.mesh is None:
                return PartitionSpec()
            name = keys[-1] if keys else ""
            axes = () if "xattn" in keys else logical.get(name, ())
            ps = axis_spec(ctx.rules, *axes)
            return fit_spec(tree.shape, ctx.mesh, ps)

        return walk(cache)

    def cache_shardings(self, cache):
        """``NamedSharding`` tree for a decode cache, or ``None`` when the
        model is off-mesh (single-device: placement stays implicit)."""
        from jax.sharding import NamedSharding

        mesh = self.ctx.mesh
        if mesh is None:
            return None
        return jax.tree.map(
            lambda s: NamedSharding(mesh, s), self.cache_specs(cache)
        )

    # -------------------------------------------------------------- utils
    def _pad_caches(self, caches, S: int, max_len: int):
        """Place prefill KV (length S) into preallocated (rolling) buffers."""
        cfg = self.cfg
        W = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len

        def pad_leaf(path_keys, leaf):
            # only attn k/v leaves have a seq dim at axis 2 equal to S
            if leaf.ndim >= 3 and leaf.shape[2] == S and any(
                k in ("k", "v") for k in path_keys
            ) and "xattn" not in path_keys:
                if S <= W:
                    buf = jnp.zeros((leaf.shape[0], leaf.shape[1], W, *leaf.shape[3:]),
                                    leaf.dtype)
                    return jax.lax.dynamic_update_slice(
                        buf, leaf, (0,) * 2 + (0,) * (leaf.ndim - 2)
                    )
                # rolling placement: slot j holds token  S-W + ((j - S) % W)
                slots = jnp.arange(W)
                tok = S - W + ((slots - S) % W)
                return jnp.take(leaf, tok, axis=2)
            return leaf

        def walk(tree, keys=()):
            if isinstance(tree, dict):
                return {k: walk(v, keys + (k,)) for k, v in tree.items()}
            return pad_leaf(keys, tree)

        return walk(caches)


def build_model(
    cfg: ModelConfig, pc: Optional[ParallelConfig] = None, mesh=None
) -> Model:
    pc = pc or ParallelConfig()
    if mesh is not None:
        tp = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
        pc = pc.validate(cfg, tp)
        ctx = ShardingCtx(mesh, pc)
    else:
        ctx = ShardingCtx(None, pc)
    return Model(cfg, ctx)
