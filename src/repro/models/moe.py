"""Mixture-of-Experts FFN — GShard-style capacity-based routing.

Dispatch/combine are expressed as einsums against one-hot dispatch tensors;
with the expert dim sharded on the `model` axis GSPMD lowers these to
all-to-alls (the expert-parallel pattern).  Top-1 (llama4) and top-2 (jamba)
routing with optional shared experts and the standard load-balance aux loss.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.config import ModelConfig
from repro.core.module import P
from repro.models.layers import _act, mlp_apply, mlp_defs
from repro.parallel.sharding import ShardingCtx


def moe_defs(cfg: ModelConfig) -> Dict[str, Any]:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    gated = cfg.act in ("swiglu", "geglu")
    defs: Dict[str, Any] = {
        "router": P((d, e), (None, None), init="normal", scale=0.02),
        "w_in": P((e, d, f), ("experts", "fsdp", None), fan_in=d),
        "w_out": P((e, f, d), ("experts", None, "fsdp"), fan_in=f),
    }
    if gated:
        defs["w_gate"] = P((e, d, f), ("experts", "fsdp", None), fan_in=d)
    if cfg.n_shared_experts:
        defs["shared"] = mlp_defs(cfg, d, cfg.d_ff * cfg.n_shared_experts)
    return defs


def capacity(cfg: ModelConfig, tokens: int) -> int:
    c = int(cfg.capacity_factor * tokens * cfg.num_experts_per_tok / cfg.num_experts)
    return max(8, ((c + 7) // 8) * 8)  # pad to 8 for layout friendliness


def num_groups(ctx: ShardingCtx, T: int) -> int:
    """GShard token grouping: capacity is enforced PER GROUP (≈ per device),
    never globally — global capacity would make the one-hot dispatch tensor
    (T, E, T·cf/E), i.e. quadratic in tokens.  Found via roofline analysis;
    see EXPERIMENTS.md §Perf iteration moe-1."""
    g = ctx.mesh.size if ctx.mesh is not None else 1
    g = min(g, T)
    while T % g:
        g -= 1
    return max(g, 1)


def moe_apply(
    cfg: ModelConfig,
    ctx: ShardingCtx,
    params: Dict[str, Any],
    x: jax.Array,               # (B, S, d)
) -> Tuple[jax.Array, jax.Array]:
    """Returns (out (B,S,d), aux_loss scalar)."""
    B, S, d = x.shape
    cdt = x.dtype
    E, K = cfg.num_experts, cfg.num_experts_per_tok
    T = B * S
    G = num_groups(ctx, T)
    Tg = T // G
    C = capacity(cfg, Tg)
    xt = x.reshape(G, Tg, d)

    logits = (xt.astype(jnp.float32)) @ params["router"].astype(jnp.float32)  # (G,Tg,E)
    probs = jax.nn.softmax(logits, axis=-1)

    # top-k selection
    gate_vals, expert_idx = jax.lax.top_k(probs, K)               # (G,Tg,K)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch/GShard form, averaged over groups)
    me = probs.mean(axis=(0, 1))                                   # (E,)
    ce = jax.nn.one_hot(expert_idx[..., 0], E).mean(axis=(0, 1))
    aux = E * jnp.sum(me * ce)

    # capacity-based position: rank of each (token, k) within its expert,
    # computed independently per group
    flat_expert = expert_idx.reshape(G, Tg * K)                    # (G, Tg*K)
    onehot = jax.nn.one_hot(flat_expert, E, dtype=jnp.int32)       # (G, Tg*K, E)
    pos_in_expert = jnp.cumsum(onehot, axis=1) * onehot - 1
    pos = jnp.max(pos_in_expert, axis=-1)                          # (G, Tg*K)
    keep = pos < C
    gates_flat = gate_vals.reshape(G, Tg * K) * keep.astype(jnp.float32)

    pos_clipped = jnp.clip(pos, 0, C - 1)
    e_hot = jax.nn.one_hot(flat_expert, E, dtype=cdt)              # (G,TgK,E)
    c_hot = jax.nn.one_hot(pos_clipped, C, dtype=cdt)              # (G,TgK,C)
    disp = (e_hot * keep[..., None].astype(cdt))[..., :, None] * c_hot[..., None, :]
    disp = disp.reshape(G, Tg, K, E, C).sum(axis=2)                # (G,Tg,E,C)
    comb = (e_hot.astype(jnp.float32) * gates_flat[..., None])[..., :, None] \
        * c_hot.astype(jnp.float32)[..., None, :]
    comb = comb.reshape(G, Tg, K, E, C).sum(axis=2).astype(cdt)    # (G,Tg,E,C)

    # expert compute: all-to-all emerges from g (data-ish) × e (model) sharding
    xe = jnp.einsum("gtd,gtec->gecd", xt, disp)                    # (G,E,C,d)
    xe = ctx.cons(xe, "batch", "experts", None, None)
    h = jnp.einsum("gecd,edf->gecf", xe, params["w_in"].astype(cdt))
    if "w_gate" in params:
        g_ = jnp.einsum("gecd,edf->gecf", xe, params["w_gate"].astype(cdt))
        h = _act(cfg.act, g_) * h
    else:
        h = _act(cfg.act, h)
    ye = jnp.einsum("gecf,efd->gecd", h, params["w_out"].astype(cdt))
    ye = ctx.cons(ye, "batch", "experts", None, None)
    out = jnp.einsum("gecd,gtec->gtd", ye, comb)

    out = out.reshape(B, S, d)
    if "shared" in params:
        out = out + mlp_apply(cfg, ctx, params["shared"], x)

    return out, aux.astype(jnp.float32)


def moe_ref_dense(cfg: ModelConfig, params: Dict[str, Any], x: jax.Array) -> jax.Array:
    """Oracle: route every token to its top-k experts with no capacity limit.

    Used by tests to bound the dispatch error introduced by capacity drops.
    """
    B, S, d = x.shape
    xt = x.reshape(-1, d).astype(jnp.float32)
    logits = xt @ params["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, cfg.num_experts_per_tok)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)
    w_in = params["w_in"].astype(jnp.float32)
    w_out = params["w_out"].astype(jnp.float32)
    w_gate = params.get("w_gate")
    out = jnp.zeros_like(xt)
    for k in range(cfg.num_experts_per_tok):
        idx = expert_idx[:, k]
        wi = w_in[idx]                                   # (T, d, f)
        h = jnp.einsum("td,tdf->tf", xt, wi)
        if w_gate is not None:
            g = jnp.einsum("td,tdf->tf", xt, w_gate.astype(jnp.float32)[idx])
            h = _act(cfg.act, g) * h
        else:
            h = _act(cfg.act, h)
        y = jnp.einsum("tf,tfd->td", h, w_out[idx])
        out = out + gate_vals[:, k:k + 1] * y
    return out.reshape(B, S, d).astype(x.dtype)
