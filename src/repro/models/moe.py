"""Mixture-of-Experts FFN — sort-by-expert ragged dispatch (megablocks-style).

Routing (always fp32 — see ``_route``) → stable sort of the ``T·K``
(token, choice) slots by expert → capacity truncation (dropped slots are
re-keyed past every real expert so the second stable sort pushes them
beyond ``sum(group_sizes)``, where the ragged kernel returns zeros and
spends no compute) → per-expert GEMMs through
``kernels/ops.grouped_matmul`` (ragged Pallas kernel with custom-VJP
backward on TPU; elsewhere the capacity-batched XLA GEMM selected by the
static ``max_group_size=C`` bound, whose cost is independent of E) →
unsort-and-combine scatter-add in fp32.  No dense ``(T, E)`` one-hot
dispatch/combine tensor ever materializes — the old einsum formulation
built ``(T, E, C)`` tensors on the hot path, quadratic-ish in tokens.

Expert parallelism: on a mesh whose ``experts`` axis divides E
(``ShardingCtx.expert_parallel``), the expert FFN instead scatters kept
slots into a static ``(E, C, d)`` buffer that is sharding-constrained
over the expert axis — GSPMD inserts the all-to-all token exchange at
the group boundary — and each shard runs its local experts' batched
GEMMs.  When experts don't divide the mesh axis the layer degrades to
the replicated ragged path (weight placement falls back to replication
via ``fit_spec``).  Both paths share routing/capacity/drop semantics, so
mesh runs are token/loss-comparable to single-device runs.

Capacity & drops: global capacity ``C = capacity(cfg, T)`` per layer
call; within an expert, slots keep their token order (stable sort), so
earlier tokens win capacity — dropped slots contribute nothing and the
residual stream passes their activations through unchanged.

Aux channel: ``moe_apply`` returns a fixed-shape fp32 vector
(``aux_shape(cfg)``) summed across layers by the transformer scan:
``[load-balance loss, entropy deficit, dropped slots, total slots,
per-expert kept-load fractions…]``.  Entries past the first two are
``stop_gradient``-ed statistics; ``models/model.py`` unpacks them into
router metrics and applies ``router_aux_coef`` / ``router_entropy_coef``.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.config import ModelConfig
from repro.core.module import P
from repro.kernels import ops
from repro.models.layers import _act, mlp_apply, mlp_defs
from repro.parallel.sharding import ShardingCtx

AUX_BASE = 4  # [lb_loss, entropy_deficit, dropped_slots, total_slots]


def aux_shape(cfg: ModelConfig) -> Tuple[int, ...]:
    """Shape of the per-layer aux vector carried through the layer scan.

    Dense models keep the legacy scalar; MoE models carry
    ``(AUX_BASE + E,)`` so per-expert load rides along."""
    return (AUX_BASE + cfg.num_experts,) if cfg.num_experts else ()


def moe_defs(cfg: ModelConfig) -> Dict[str, Any]:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    gated = cfg.act in ("swiglu", "geglu")
    defs: Dict[str, Any] = {
        "router": P((d, e), (None, None), init="normal", scale=0.02),
        "w_in": P((e, d, f), ("experts", "fsdp", None), fan_in=d),
        "w_out": P((e, f, d), ("experts", None, "fsdp"), fan_in=f),
    }
    if gated:
        defs["w_gate"] = P((e, d, f), ("experts", "fsdp", None), fan_in=d)
    if cfg.n_shared_experts:
        defs["shared"] = mlp_defs(cfg, d, cfg.d_ff * cfg.n_shared_experts)
    return defs


def capacity(cfg: ModelConfig, tokens: int) -> int:
    c = int(cfg.capacity_factor * tokens * cfg.num_experts_per_tok / cfg.num_experts)
    return max(8, ((c + 7) // 8) * 8)  # pad to 8 for layout friendliness


def _route(cfg: ModelConfig, params, x2d: jax.Array):
    """fp32 routing: logits, softmax and top-k all run in float32 even
    under the bf16 compute view — half-precision routing flips expert
    assignments between otherwise-equivalent runs (e.g. accum vs
    no-accum microbatching), which capacity truncation then amplifies
    into different outputs.  Returns (probs, renormalized top-k gates,
    expert indices), all fp32/int32."""
    logits = x2d.astype(jnp.float32) @ params["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                    # (T, E) fp32
    gate, idx = jax.lax.top_k(probs, cfg.num_experts_per_tok)  # (T, K)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
    return probs, gate, idx


def _expert_ffn_ragged(cfg, params, xs, sizes, cap, cdt):
    """Per-expert FFN over sorted rows via the ragged grouped matmul.

    ``cap`` (the capacity) is the static per-group bound that lets the
    xla fallback use the E-independent capacity-batched GEMM."""
    gmm = functools.partial(
        ops.grouped_matmul, group_sizes=sizes, impl=cfg.kernel_impl,
        max_group_size=cap,
    )
    h = gmm(xs, params["w_in"].astype(cdt))
    if "w_gate" in params:
        h = _act(cfg.act, gmm(xs, params["w_gate"].astype(cdt))) * h
    else:
        h = _act(cfg.act, h)
    return gmm(h, params["w_out"].astype(cdt))


def _moe_ragged(cfg, params, xf, flat_e, keep, gates, C, cdt):
    """Sort-by-expert → ragged FFN → unsort-and-combine (single shard).

    Dropped slots are re-keyed to the virtual expert E, so the stable
    sort moves them past ``sum(sizes)`` — the kernel's zero tail — and
    they cost no expert FLOPs."""
    T, d = xf.shape
    M = flat_e.shape[0]
    K = cfg.num_experts_per_tok
    E = cfg.num_experts
    key = jnp.where(keep, flat_e, E)
    order = jnp.argsort(key)                        # stable: token order kept
    tok = order // K                                # source token per row
    xs = jnp.take(xf, tok, axis=0)                  # (M, d)
    sizes = jnp.zeros((E,), jnp.int32).at[key].add(1, mode="drop")
    ys = _expert_ffn_ragged(cfg, params, xs, sizes, C, cdt)
    gs = jnp.take(gates, order)
    out = jnp.zeros((T, d), jnp.float32)
    return out.at[tok].add(ys.astype(jnp.float32) * gs[:, None])


def _moe_expert_parallel(cfg, ctx, params, xf, flat_e, rank, keep, gates,
                         C, cdt):
    """Expert-parallel FFN: scatter kept slots to a static (E, C, d)
    buffer constrained onto the expert axis (the all-to-all boundary),
    batched per-expert GEMMs local to each shard, gather-and-combine."""
    T, d = xf.shape
    M = flat_e.shape[0]
    K = cfg.num_experts_per_tok
    E = cfg.num_experts
    tok = jnp.arange(M, dtype=jnp.int32) // K
    e_idx = jnp.where(keep, flat_e, E)              # dropped → OOB, dropped
    c_idx = jnp.minimum(rank, C - 1)
    xe = jnp.zeros((E, C, d), cdt).at[e_idx, c_idx].set(
        jnp.take(xf, tok, axis=0), mode="drop"
    )
    xe = ctx.cons(xe, "experts", None, None)
    h = jnp.einsum("ecd,edf->ecf", xe, params["w_in"].astype(cdt))
    if "w_gate" in params:
        g = jnp.einsum("ecd,edf->ecf", xe, params["w_gate"].astype(cdt))
        h = _act(cfg.act, g) * h
    else:
        h = _act(cfg.act, h)
    ye = jnp.einsum("ecf,efd->ecd", h, params["w_out"].astype(cdt))
    ye = ctx.cons(ye, "experts", None, None)
    y_slot = ye[jnp.minimum(flat_e, E - 1), c_idx]  # (M, d)
    out = jnp.zeros((T, d), jnp.float32)
    return out.at[tok].add(y_slot.astype(jnp.float32) * gates[:, None])


def moe_apply(
    cfg: ModelConfig,
    ctx: ShardingCtx,
    params: Dict[str, Any],
    x: jax.Array,               # (B, S, d)
) -> Tuple[jax.Array, jax.Array]:
    """Returns (out (B,S,d), aux (AUX_BASE+E,) fp32 — see module doc)."""
    B, S, d = x.shape
    cdt = x.dtype
    E, K = cfg.num_experts, cfg.num_experts_per_tok
    T = B * S
    M = T * K
    C = capacity(cfg, T)
    xf = x.reshape(T, d)

    probs, gate, idx = _route(cfg, params, xf)

    # load-balance aux loss (Switch/GShard form) + router entropy deficit
    me = probs.mean(axis=0)                                        # (E,)
    ce = jax.nn.one_hot(idx[:, 0], E, dtype=jnp.float32).mean(axis=0)
    lb = E * jnp.sum(me * ce)
    ent = -jnp.sum(probs * jnp.log(probs + 1e-9), axis=-1).mean()
    ent_def = jnp.log(float(E)) - ent   # ≥ 0, minimized at uniform routing

    # capacity: rank of each slot within its expert (stable sort ⇒ token
    # order), slots at rank ≥ C are dropped
    flat_e = idx.reshape(M)                          # slot s = t·K + k
    counts = jnp.zeros((E,), jnp.int32).at[flat_e].add(1)
    starts = jnp.cumsum(counts) - counts
    order0 = jnp.argsort(flat_e)
    rank_sorted = jnp.arange(M, dtype=jnp.int32) - starts[flat_e[order0]]
    keep_sorted = rank_sorted < C
    rank = jnp.zeros((M,), jnp.int32).at[order0].set(rank_sorted)
    keep = jnp.zeros((M,), bool).at[order0].set(keep_sorted)
    gates = gate.reshape(M) * keep.astype(jnp.float32)

    if ctx.expert_parallel(E):
        out2d = _moe_expert_parallel(
            cfg, ctx, params, xf, flat_e, rank, keep, gates, C, cdt
        )
    else:
        out2d = _moe_ragged(cfg, params, xf, flat_e, keep, gates, C, cdt)

    out = out2d.astype(cdt).reshape(B, S, d)
    if "shared" in params:
        out = out + mlp_apply(cfg, ctx, params["shared"], x)

    kept = jnp.minimum(counts, C).astype(jnp.float32)              # (E,)
    load = kept / jnp.maximum(kept.sum(), 1.0)
    dropped = jnp.float32(M) - kept.sum()
    stats = jax.lax.stop_gradient(
        jnp.concatenate([jnp.stack([dropped, jnp.float32(M)]), load])
    )
    aux = jnp.concatenate([jnp.stack([lb, ent_def]), stats])
    return out, aux.astype(jnp.float32)


def moe_ref_dense(cfg: ModelConfig, params: Dict[str, Any], x: jax.Array) -> jax.Array:
    """Oracle: route every token to its top-k experts with no capacity limit.

    Used by tests to bound the dispatch error introduced by capacity drops.
    """
    B, S, d = x.shape
    xt = x.reshape(-1, d).astype(jnp.float32)
    logits = xt @ params["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, cfg.num_experts_per_tok)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)
    w_in = params["w_in"].astype(jnp.float32)
    w_out = params["w_out"].astype(jnp.float32)
    w_gate = params.get("w_gate")
    out = jnp.zeros_like(xt)
    for k in range(cfg.num_experts_per_tok):
        idx = expert_idx[:, k]
        wi = w_in[idx]                                   # (T, d, f)
        h = jnp.einsum("td,tdf->tf", xt, wi)
        if w_gate is not None:
            g = jnp.einsum("td,tdf->tf", xt, w_gate.astype(jnp.float32)[idx])
            h = _act(cfg.act, g) * h
        else:
            h = _act(cfg.act, h)
        y = jnp.einsum("tf,tfd->td", h, w_out[idx])
        out = out + gate_vals[:, k:k + 1] * y
    return out.reshape(B, S, d).astype(x.dtype)
