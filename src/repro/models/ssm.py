"""Mamba-2 (SSD) block: in_proj → causal depthwise conv → SSD scan → gated
norm → out_proj, plus the single-step recurrent path for decoding.

Sharding: SSD heads are independent, so the block is head-TP over the
`model` axis (ssm heads always divide 16 for the assigned archs); the
recurrent state (B, H, P, N) shards the same way for decode.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.config import ModelConfig
from repro.core.module import P
from repro.kernels import ops
from repro.parallel.sharding import ShardingCtx


def _dims(cfg: ModelConfig):
    di = cfg.d_inner
    nh = cfg.ssm_nheads
    ng, ns = cfg.ssm_ngroups, cfg.ssm_state
    conv_dim = di + 2 * ng * ns
    in_dim = 2 * di + 2 * ng * ns + nh        # z, x, B, C, dt
    return di, nh, ng, ns, conv_dim, in_dim


def ssm_defs(cfg: ModelConfig) -> Dict[str, Any]:
    d = cfg.d_model
    di, nh, ng, ns, conv_dim, in_dim = _dims(cfg)

    def a_init(key, shape, dtype):
        # A in [-16, -1): standard mamba2 init, log-uniform
        u = jax.random.uniform(key, shape, jnp.float32, 1.0, 16.0)
        return (-u).astype(dtype)

    def dt_bias_init(key, shape, dtype):
        # softplus^-1 of dt in [1e-3, 1e-1]
        dt = jnp.exp(
            jax.random.uniform(key, shape, jnp.float32)
            * (jnp.log(0.1) - jnp.log(0.001))
            + jnp.log(0.001)
        )
        return (dt + jnp.log(-jnp.expm1(-dt))).astype(dtype)

    return {
        "w_in": P((d, in_dim), ("fsdp", "tp"), fan_in=d),
        "conv_w": P((cfg.ssm_conv, conv_dim), (None, "tp"), init="normal", scale=0.1),
        "conv_b": P((conv_dim,), ("tp",), init="zeros"),
        "A": P((nh,), ("tp",), init=a_init),
        "D": P((nh,), ("tp",), init="ones"),
        "dt_bias": P((nh,), ("tp",), init=dt_bias_init),
        "norm_scale": P((di,), ("tp",), init="ones"),
        "w_out": P((di, d), ("tp", "fsdp"), fan_in=di),
    }


def _split_in(cfg, h):
    di, nh, ng, ns, conv_dim, in_dim = _dims(cfg)
    z = h[..., :di]
    xbc = h[..., di:di + conv_dim]
    dt = h[..., di + conv_dim:]
    return z, xbc, dt


def _grouped_rmsnorm(x: jax.Array, scale: jax.Array, nheads: int, eps=1e-5):
    """RMSNorm per SSD head group (keeps the op local under head-TP)."""
    B, S, di = x.shape
    hd = di // nheads
    xg = x.reshape(B, S, nheads, hd).astype(jnp.float32)
    var = jnp.mean(xg * xg, axis=-1, keepdims=True)
    y = xg * jax.lax.rsqrt(var + eps)
    return (y.reshape(B, S, di) * scale.astype(jnp.float32)).astype(x.dtype)


def ssm_apply(
    cfg: ModelConfig,
    ctx: ShardingCtx,
    params: Dict[str, Any],
    x: jax.Array,                      # (B, S, d_model)
    *,
    mode: str = "train",
    cache: Optional[Dict[str, jax.Array]] = None,
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    B, S, d = x.shape
    cdt = x.dtype
    di, nh, ng, ns, conv_dim, in_dim = _dims(cfg)
    kw = cfg.ssm_conv

    h = x @ params["w_in"].astype(cdt)            # (B, S, in_dim)
    if ctx.context_parallel and mode != "decode":
        # Megatron-SP-style boundary: the residual stream arrives sequence-
        # sharded (CP); the SSD recurrence needs the full sequence per head,
        # so gather seq here and stay channel-sharded (head-TP) inside.
        h = ctx.cons(h, "batch", None, "tp")
    z, xbc, dt_raw = _split_in(cfg, h)

    if mode == "decode":
        assert cache is not None
        # roll conv buffer: (B, kw-1, conv_dim) holds previous inputs
        conv_buf = cache["conv"]
        window = jnp.concatenate([conv_buf, xbc.astype(conv_buf.dtype)], axis=1)  # (B,kw,conv)
        conv_out = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32),
                              params["conv_w"].astype(jnp.float32))
        conv_out = conv_out + params["conv_b"].astype(jnp.float32)
        conv_out = jax.nn.silu(conv_out)[:, None].astype(cdt)     # (B,1,conv)
        new_conv = window[:, 1:]
    else:
        # causal depthwise conv over the sequence
        pad = jnp.zeros((B, kw - 1, conv_dim), xbc.dtype)
        xp = jnp.concatenate([pad, xbc], axis=1)                   # (B, S+kw-1, conv)
        conv_out = sum(
            xp[:, i:i + S].astype(jnp.float32)
            * params["conv_w"][i].astype(jnp.float32)[None, None, :]
            for i in range(kw)
        )
        conv_out = jax.nn.silu(conv_out + params["conv_b"].astype(jnp.float32)).astype(cdt)
        new_conv = xp[:, S:, :] if False else xp[:, -(kw - 1):, :]  # last kw-1 inputs

    xs = conv_out[..., :di].reshape(B, -1, nh, di // nh)           # (B,S,H,P)
    Bm = conv_out[..., di:di + ng * ns].reshape(B, -1, ng, ns)
    Cm = conv_out[..., di + ng * ns:].reshape(B, -1, ng, ns)
    dt = jax.nn.softplus(
        dt_raw.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32)
    )                                                               # (B,S,H)

    if mode == "decode":
        y, new_state = ops.ssd_decode_step(
            xs, dt, params["A"], Bm, Cm, params["D"], cache["state"]
        )
        new_cache = {"conv": new_conv, "state": new_state}
    else:
        y, final_state = ops.ssd(
            xs, dt, params["A"], Bm, Cm, params["D"], chunk=cfg.ssm_chunk
        )
        new_cache = (
            {"conv": new_conv.astype(cdt), "state": final_state.astype(jnp.float32)}
            if mode == "prefill"
            else None
        )

    y = y.reshape(B, -1, di)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(cdt)         # gate
    y = _grouped_rmsnorm(y, params["norm_scale"], nh)
    out = y @ params["w_out"].astype(cdt)
    if ctx.context_parallel and mode != "decode":
        # back to the sequence-sharded residual layout (reduce-scatter)
        out = ctx.cons(out, "batch", "seq_cp", None)
    return out, new_cache


def init_ssm_cache(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    di, nh, ng, ns, conv_dim, _ = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype),
        "state": jnp.zeros((batch, nh, di // nh, ns), jnp.float32),
    }
