"""Transformer stacks: decoder (dense/MoE/SSM/hybrid), encoder, enc-dec.

Layers are grouped into scan *units* so heterogeneous interleaves stay
scannable: unit size = attn_layer_period for hybrids (jamba: 1 attn + 7
mamba), moe_layer_period for MoE (llama4-maverick: dense/MoE alternation),
1 for plain dense.  Unit params are stacked over units and the stack runs
as one ``lax.scan`` (keeps HLO size O(unit), essential for 126-layer
llama3-405b lowering), with per-unit remat.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.config import ModelConfig
from repro.core.module import P, stack_tree
from repro.models import layers as L
from repro.models.attention import attention_apply, attention_defs
from repro.models.moe import aux_shape, moe_apply, moe_defs
from repro.models.ssm import init_ssm_cache, ssm_apply, ssm_defs
from repro.parallel.sharding import ShardingCtx


# --------------------------------------------------------------------- #
# scan-unit structure
# --------------------------------------------------------------------- #
def unit_size(cfg: ModelConfig) -> int:
    if cfg.family == "hybrid":
        return cfg.attn_layer_period
    if cfg.num_experts and cfg.moe_layer_period > 1:
        return cfg.moe_layer_period
    return 1


def num_units(cfg: ModelConfig) -> int:
    u = unit_size(cfg)
    assert cfg.num_layers % u == 0, (cfg.num_layers, u)
    return cfg.num_layers // u


def num_moe_layers(cfg: ModelConfig) -> int:
    """Total MoE layers in the stack (normalizes summed aux statistics)."""
    if not cfg.num_experts:
        return 0
    u = unit_size(cfg)
    return sum(1 for i in range(u) if cfg.is_moe_layer(i)) * num_units(cfg)


def _sublayer_defs(cfg: ModelConfig, li: int, cross: bool) -> Dict[str, Any]:
    """Param defs for global layer index `li` (within a unit)."""
    d = cfg.d_model
    defs: Dict[str, Any] = {"norm1": L.norm_defs(cfg, d)}
    if cfg.is_attn_layer(li):
        defs["attn"] = attention_defs(cfg)
    else:
        defs["ssm"] = ssm_defs(cfg)
    if cross:
        defs["norm_x"] = L.norm_defs(cfg, d)
        defs["xattn"] = attention_defs(cfg, cross=True)
    if cfg.d_ff > 0:
        if not cfg.parallel_residual:
            defs["norm2"] = L.norm_defs(cfg, d)
        if cfg.is_moe_layer(li):
            defs["ffn"] = moe_defs(cfg)
        else:
            defs["ffn"] = L.mlp_defs(cfg, d, cfg.d_ff)
    return defs


def unit_defs(cfg: ModelConfig, cross: bool = False) -> Dict[str, Any]:
    u = unit_size(cfg)
    return {f"sub{i}": _sublayer_defs(cfg, i, cross) for i in range(u)}


def stack_defs(cfg: ModelConfig, cross: bool = False) -> Dict[str, Any]:
    return stack_tree(unit_defs(cfg, cross), num_units(cfg))


# --------------------------------------------------------------------- #
# sub-layer application
# --------------------------------------------------------------------- #
def _apply_sublayer(
    cfg: ModelConfig,
    ctx: ShardingCtx,
    li: int,
    params: Dict[str, Any],
    x: jax.Array,
    *,
    mode: str,
    positions,
    cache,
    cache_pos,
    cross_kv,
    causal: Optional[bool] = None,
    block_table=None,
    chunk_valid=None,
) -> Tuple[jax.Array, Any, jax.Array]:
    """Returns (x, new_cache, aux) — aux is the fixed-shape router stats
    vector for MoE models (``moe.aux_shape``), a scalar zero for dense."""
    aux = jnp.zeros(aux_shape(cfg), jnp.float32)
    new_cache: Dict[str, Any] = {}
    h = L.norm_apply(cfg, params["norm1"], x)
    is_attn = cfg.is_attn_layer(li)
    if is_attn:
        mix, c = attention_apply(
            cfg, ctx, params["attn"], h,
            positions=positions, mode=mode,
            cache=cache.get("attn") if cache else None,
            cache_pos=cache_pos, causal=causal, block_table=block_table,
            chunk_valid=chunk_valid,
        )
        if c is not None:
            new_cache["attn"] = c
    else:
        if mode == "chunk":
            raise ValueError(
                "chunked prefill requires an attention-only stack (SSM "
                "state cannot be advanced per-chunk with bucket padding)"
            )
        mix, c = ssm_apply(
            cfg, ctx, params["ssm"], h, mode=mode,
            cache=cache.get("ssm") if cache else None,
        )
        if c is not None:
            new_cache["ssm"] = c

    if cfg.parallel_residual and "ffn" in params:
        ff = (
            moe_apply(cfg, ctx, params["ffn"], h)
            if cfg.is_moe_layer(li)
            else (L.mlp_apply(cfg, ctx, params["ffn"], h), None)
        )
        if isinstance(ff, tuple) and ff[1] is not None:
            ff_out, aux = ff
        else:
            ff_out = ff[0] if isinstance(ff, tuple) else ff
        x = x + mix + ff_out
        return x, new_cache, aux

    x = x + mix

    if cross_kv is not None or (cache and "xattn" in cache):
        hx = L.norm_apply(cfg, params["norm_x"], x)
        xmix, _ = attention_apply(
            cfg, ctx, params["xattn"], hx,
            mode=mode, cross_kv=cross_kv,
            cache=cache.get("xattn") if cache else None,
        )
        x = x + xmix
        if mode == "prefill" and cross_kv is not None:
            # cross KV is static during decode: compute & store once
            from repro.models.attention import _project_qkv

            _, ck, cv = _project_qkv(cfg, params["xattn"], hx, kv_src=cross_kv)
            new_cache["xattn"] = {
                "k": ck, "v": cv,
                "len": jnp.full((x.shape[0],), cross_kv.shape[1], jnp.int32),
            }

    if "ffn" in params:
        h2 = L.norm_apply(cfg, params["norm2"], x)
        if cfg.is_moe_layer(li):
            ff_out, aux = moe_apply(cfg, ctx, params["ffn"], h2)
        else:
            ff_out = L.mlp_apply(cfg, ctx, params["ffn"], h2)
        x = x + ff_out
    return x, new_cache, aux


def _remat_wrap(fn, policy: str):
    if policy == "none":
        return fn
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    if policy == "full":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.everything_saveable)
    return jax.checkpoint(fn)  # "block": save only unit boundaries


# --------------------------------------------------------------------- #
# stacks
# --------------------------------------------------------------------- #
def decoder_stack(
    cfg: ModelConfig,
    ctx: ShardingCtx,
    stacked_params: Dict[str, Any],
    x: jax.Array,
    *,
    mode: str = "train",
    positions=None,
    caches=None,              # stacked cache pytree (prefill out / decode in-out)
    cache_pos=None,
    cross_kv=None,
    causal: Optional[bool] = None,
    block_table=None,         # (B, pages_per_seq): paged decode (all layers)
    chunk_valid=None,         # scalar: valid rows of a prefill chunk
) -> Tuple[jax.Array, Any, jax.Array]:
    """Runs the full layer stack.  Returns (x, new_caches, aux_loss_sum)."""
    u = unit_size(cfg)

    def unit_body(carry, xs):
        x, aux_sum = carry
        uparams, ucache = xs
        new_ucache = {}
        for i in range(u):
            sub = f"sub{i}"
            x, nc, aux = _apply_sublayer(
                cfg, ctx, i, uparams[sub], x,
                mode=mode, positions=positions,
                cache=ucache.get(sub) if ucache else None,
                cache_pos=cache_pos, cross_kv=cross_kv, causal=causal,
                block_table=block_table, chunk_valid=chunk_valid,
            )
            aux_sum = aux_sum + aux
            if nc:
                new_ucache[sub] = nc
        if ctx.context_parallel and mode not in ("decode", "chunk"):
            x = ctx.cons(x, "batch", "seq_cp", None)
        else:
            x = ctx.cons(x, "batch", None, None)
        return (x, aux_sum), new_ucache

    body = unit_body
    if mode == "train":
        body = _remat_wrap(unit_body, ctx.pc.remat_policy)

    aux0 = jnp.zeros(aux_shape(cfg), jnp.float32)

    if not ctx.pc.scan_layers:
        n = num_units(cfg)
        carry = (x, aux0)
        new_caches = []
        for j in range(n):
            up = jax.tree.map(lambda p: p[j], stacked_params)
            uc = jax.tree.map(lambda c: c[j], caches) if caches is not None else None
            carry, nc = body(carry, (up, uc))
            new_caches.append(nc)
        (x, aux_sum) = carry
        stacked_cache = (
            jax.tree.map(lambda *cs: jnp.stack(cs), *new_caches)
            if (mode != "train" and new_caches and new_caches[0])
            else None
        )
        return x, stacked_cache, aux_sum

    if caches is None:
        (x, aux_sum), new_caches = jax.lax.scan(
            lambda c, p: body(c, (p, None)), (x, aux0), stacked_params
        )
    else:
        (x, aux_sum), new_caches = jax.lax.scan(
            body, (x, aux0), (stacked_params, caches)
        )
    if mode == "train":
        new_caches = None
    return x, new_caches, aux_sum


def init_stack_cache(
    cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16,
    cross_len: int = 0, *, layout: str = "dense", page_size: int = 0,
    num_pages: int = 0,
):
    """Preallocated decode cache, stacked over scan units.

    ``layout="paged"`` replaces each attention layer's dense per-slot
    ``(B, T, Hkv, D)`` buffers with a shared ``(num_pages, page, Hkv, D)``
    pool; SSM and cross-attention state stay dense per-slot.
    """
    from repro.models.attention import init_cache as init_attn_cache
    from repro.models.attention import init_paged_cache

    u = unit_size(cfg)
    unit = {}
    for i in range(u):
        sub: Dict[str, Any] = {}
        if cfg.is_attn_layer(i):
            if layout == "paged":
                sub["attn"] = init_paged_cache(cfg, num_pages, page_size, dtype)
            else:
                sub["attn"] = init_attn_cache(cfg, batch, max_len, dtype)
        else:
            sub["ssm"] = init_ssm_cache(cfg, batch, dtype)
        if cfg.is_encoder_decoder and cross_len:
            hd = cfg.resolved_head_dim
            sub["xattn"] = {
                "k": jnp.zeros((batch, cross_len, cfg.num_kv_heads, hd), dtype),
                "v": jnp.zeros((batch, cross_len, cfg.num_kv_heads, hd), dtype),
                "len": jnp.full((batch,), cross_len, jnp.int32),
            }
        unit[f"sub{i}"] = sub
    n = num_units(cfg)
    return jax.tree.map(lambda x: jnp.broadcast_to(x, (n, *x.shape)), unit)
