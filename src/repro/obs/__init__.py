"""Unified telemetry: metrics registry, lifecycle tracing, profiling hooks.

See ``obs/README.md`` for the metric catalog, trace event schema, and
the launcher knobs (``--metrics-dir``, ``--trace``, ``--profile``)."""
from repro.obs.metrics import (
    LATENCY_BUCKETS,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
)
from repro.obs.profile import StepTimer, annotate, trace_ctx
from repro.obs.trace import EVENTS, TraceRecorder

__all__ = [
    "LATENCY_BUCKETS",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "StepTimer",
    "annotate",
    "trace_ctx",
    "EVENTS",
    "TraceRecorder",
]
