"""Metrics registry: named counters, gauges, and fixed-bucket histograms.

One low-overhead telemetry surface shared by the serving engine and the
training loop, so throughput/SLO claims are measured the same way
everywhere instead of each subsystem growing its own ad-hoc dict of
counters.  Design constraints, in order:

  * **Hot-path cost is a Python attribute add.**  ``Counter.inc`` /
    ``Gauge.set`` / ``Histogram.observe`` touch plain host floats — no
    locks (the engine and trainer are single-threaded per process), no
    allocation after the first ``labels()`` resolution, and never a
    device sync.  Instrumentation must stay inside the engine's
    one-bulk-transfer-per-step contract and the trainer's
    one-transfer-per-log-interval contract; everything here consumes
    values the host already holds.
  * **Labels resolve once.**  ``family.labels(v)`` returns a child
    series; callers cache the child (the engine resolves its lifecycle
    counters at construction), so steady state never re-hashes label
    tuples.
  * **Two export formats.**  ``to_prometheus()`` writes the standard
    text exposition (``# HELP`` / ``# TYPE`` / samples, cumulative
    histogram buckets with ``+Inf``); ``dump_json()`` appends one
    timestamped record to a ``{"runs": [...]}`` trajectory file — the
    same shape as the repo's ``BENCH_*.json`` perf trajectories — with
    the tmp-file + ``os.replace`` atomicity of ``checkpoint/ckpt.py``.

Histograms are fixed-bucket (Prometheus-style): quantiles come from
linear interpolation inside the bucket that crosses the target rank, so
``quantile(0.99)`` is an estimate bounded by bucket width, not an exact
order statistic — good enough for TTFT/ITL/step-time SLO reporting and
O(len(buckets)) memory forever.
"""
from __future__ import annotations

import bisect
import json
import math
import os
import re
import time
from typing import Dict, List, Optional, Sequence, Tuple

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")

# default latency buckets (seconds): log-spaced from 100us to 60s, the
# range TTFT / ITL / queue-wait / step-time land in on anything from a
# smoke CPU run to a loaded TPU pod
LATENCY_BUCKETS: Tuple[float, ...] = (
    1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3, 1e-2, 2.5e-2, 5e-2,
    0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0, 60.0,
)


def _fmt(v: float) -> str:
    """Prometheus sample formatting: integers render bare, floats full."""
    if v == math.inf:
        return "+Inf"
    if float(v).is_integer() and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


def _escape(v: str) -> str:
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


class Counter:
    """Monotonically increasing value (one label-resolved series)."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError(f"counter increment must be >= 0 (got {n})")
        self.value += n


class Gauge:
    """Point-in-time value (one label-resolved series)."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def inc(self, n: float = 1.0) -> None:
        self.value += n

    def dec(self, n: float = 1.0) -> None:
        self.value -= n


class Histogram:
    """Fixed-bucket histogram (one label-resolved series).

    ``bucket_counts[i]`` counts observations <= ``buckets[i]`` exclusive
    of earlier buckets (non-cumulative internally; the exposition writer
    emits the cumulative Prometheus form).  The implicit final bucket
    catches everything above the last boundary."""

    __slots__ = ("buckets", "bucket_counts", "count", "sum")

    def __init__(self, buckets: Sequence[float]) -> None:
        b = tuple(float(x) for x in buckets)
        if not b or any(b[i] >= b[i + 1] for i in range(len(b) - 1)):
            raise ValueError(f"buckets must be strictly increasing: {b}")
        self.buckets = b
        self.bucket_counts = [0] * (len(b) + 1)   # +1: the +Inf overflow
        self.count = 0
        self.sum = 0.0

    def observe(self, v: float) -> None:
        v = float(v)
        self.bucket_counts[bisect.bisect_left(self.buckets, v)] += 1
        self.count += 1
        self.sum += v

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def quantile(self, q: float) -> float:
        """Bucket-interpolated quantile estimate (Prometheus semantics).

        Returns 0.0 on an empty histogram.  Ranks landing in the +Inf
        overflow bucket clamp to the last finite boundary — the estimate
        is then a lower bound, which is the conservative direction for a
        latency SLO."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile must be in [0, 1] (got {q})")
        if self.count == 0:
            return 0.0
        rank = q * self.count
        seen = 0
        for i, n in enumerate(self.bucket_counts):
            if seen + n >= rank:
                if i >= len(self.buckets):
                    return self.buckets[-1]
                lo = self.buckets[i - 1] if i else 0.0
                hi = self.buckets[i]
                frac = (rank - seen) / n if n else 0.0
                return lo + (hi - lo) * frac
            seen += n
        return self.buckets[-1]


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class _Family:
    """One named metric plus its label-resolved children.

    With no declared labels the family owns a single anonymous child and
    forwards ``inc``/``set``/``observe``/``value`` to it, so unlabeled
    metrics read naturally: ``reg.counter("steps").inc()``."""

    __slots__ = ("name", "help", "kind", "label_names", "children", "_mk")

    def __init__(self, name: str, help: str, kind: str,
                 label_names: Tuple[str, ...], mk) -> None:
        self.name = name
        self.help = help
        self.kind = kind
        self.label_names = label_names
        self.children: Dict[Tuple[str, ...], object] = {}
        self._mk = mk
        if not label_names:
            self.children[()] = mk()

    def labels(self, *values: str):
        if len(values) != len(self.label_names):
            raise ValueError(
                f"{self.name}: expected labels {self.label_names}, "
                f"got {len(values)} values"
            )
        key = tuple(str(v) for v in values)
        child = self.children.get(key)
        if child is None:
            child = self.children[key] = self._mk()
        return child

    # unlabeled convenience forwarding
    def _solo(self):
        if self.label_names:
            raise ValueError(
                f"{self.name} declares labels {self.label_names}; "
                f"use .labels(...)"
            )
        return self.children[()]

    def inc(self, n: float = 1.0) -> None:
        self._solo().inc(n)

    def dec(self, n: float = 1.0) -> None:
        self._solo().dec(n)

    def set(self, v: float) -> None:
        self._solo().set(v)

    def observe(self, v: float) -> None:
        self._solo().observe(v)

    @property
    def value(self) -> float:
        return self._solo().value

    def quantile(self, q: float) -> float:
        return self._solo().quantile(q)

    @property
    def mean(self) -> float:
        return self._solo().mean

    @property
    def count(self) -> int:
        return self._solo().count

    @property
    def sum(self) -> float:
        return self._solo().sum


class MetricsRegistry:
    """Process-local registry of named metric families.

    Registration is idempotent: asking for an existing name returns the
    existing family (kind and labels must match), so two subsystems —
    or two Engine instances sharing one registry — aggregate into the
    same series instead of clobbering each other."""

    def __init__(self) -> None:
        self._families: Dict[str, _Family] = {}

    def _register(self, name: str, help: str, kind: str,
                  labels: Sequence[str], mk) -> _Family:
        if not _NAME_RE.match(name):
            raise ValueError(f"invalid metric name {name!r}")
        for ln in labels:
            if not _LABEL_RE.match(ln):
                raise ValueError(f"invalid label name {ln!r} on {name!r}")
        fam = self._families.get(name)
        if fam is not None:
            if fam.kind != kind or fam.label_names != tuple(labels):
                raise ValueError(
                    f"metric {name!r} already registered as {fam.kind} "
                    f"with labels {fam.label_names}"
                )
            return fam
        fam = _Family(name, help, kind, tuple(labels), mk)
        self._families[name] = fam
        return fam

    def counter(self, name: str, help: str = "",
                labels: Sequence[str] = ()) -> _Family:
        return self._register(name, help, "counter", labels, Counter)

    def gauge(self, name: str, help: str = "",
              labels: Sequence[str] = ()) -> _Family:
        return self._register(name, help, "gauge", labels, Gauge)

    def histogram(self, name: str, help: str = "",
                  buckets: Sequence[float] = LATENCY_BUCKETS,
                  labels: Sequence[str] = ()) -> _Family:
        return self._register(
            name, help, "histogram", labels, lambda: Histogram(buckets)
        )

    def get(self, name: str) -> Optional[_Family]:
        return self._families.get(name)

    # ------------------------------------------------------------- export
    def to_prometheus(self) -> str:
        """Standard Prometheus text exposition (version 0.0.4)."""
        out: List[str] = []
        for name in sorted(self._families):
            fam = self._families[name]
            if fam.help:
                out.append(f"# HELP {name} {_escape(fam.help)}")
            out.append(f"# TYPE {name} {fam.kind}")
            for key in sorted(fam.children):
                child = fam.children[key]
                pairs = list(zip(fam.label_names, key))
                lbl = ",".join(f'{k}="{_escape(v)}"' for k, v in pairs)
                if fam.kind == "histogram":
                    cum = 0
                    for i, b in enumerate(child.buckets):
                        cum += child.bucket_counts[i]
                        le = (lbl + "," if lbl else "") + f'le="{_fmt(b)}"'
                        out.append(f"{name}_bucket{{{le}}} {cum}")
                    le = (lbl + "," if lbl else "") + 'le="+Inf"'
                    out.append(f"{name}_bucket{{{le}}} {child.count}")
                    sfx = f"{{{lbl}}}" if lbl else ""
                    out.append(f"{name}_sum{sfx} {_fmt(child.sum)}")
                    out.append(f"{name}_count{sfx} {child.count}")
                else:
                    sfx = f"{{{lbl}}}" if lbl else ""
                    out.append(f"{name}{sfx} {_fmt(child.value)}")
        return "\n".join(out) + "\n"

    def snapshot(self) -> List[dict]:
        """One JSON-able row per series (histograms carry quantiles)."""
        rows: List[dict] = []
        for name in sorted(self._families):
            fam = self._families[name]
            for key in sorted(fam.children):
                child = fam.children[key]
                full = name
                if key:
                    lbl = ",".join(
                        f'{k}="{v}"' for k, v in zip(fam.label_names, key)
                    )
                    full = f"{name}{{{lbl}}}"
                if fam.kind == "histogram":
                    rows.append({
                        "name": full, "kind": "histogram",
                        "count": child.count, "sum": child.sum,
                        "mean": child.mean,
                        "p50": child.quantile(0.50),
                        "p95": child.quantile(0.95),
                        "p99": child.quantile(0.99),
                    })
                else:
                    rows.append({
                        "name": full, "kind": fam.kind, "value": child.value,
                    })
        return rows

    def dump_json(self, path: str, *, now: Optional[float] = None,
                  extra: Optional[dict] = None) -> None:
        """Append one snapshot record to a ``{"runs": [...]}`` trajectory.

        Same file shape and atomic-write discipline as the repo's
        ``BENCH_*.json`` perf trajectories (``benchmarks/run.py``): each
        record is ``{"timestamp", "rows", ...extra}``, the whole file is
        rewritten to a tmp path and ``os.replace``d, so a reader never
        sees a torn snapshot.  ``now`` is injectable (epoch seconds) for
        deterministic tests."""
        stamp = time.strftime(
            "%Y-%m-%dT%H:%M:%SZ",
            time.gmtime(time.time() if now is None else now),
        )
        try:
            with open(path) as f:
                runs = json.load(f)["runs"]
        except (OSError, ValueError, KeyError):
            runs = []
        rec = {"timestamp": stamp, "rows": self.snapshot()}
        if extra:
            rec.update(extra)
        runs.append(rec)
        tmp = f"{path}.tmp.{os.getpid()}"
        with open(tmp, "w") as f:
            json.dump({"runs": runs}, f, indent=1)
            f.write("\n")
        os.replace(tmp, path)

    def write_prometheus(self, path: str) -> None:
        """Atomic exposition dump (tmp + ``os.replace``, like dump_json)."""
        tmp = f"{path}.tmp.{os.getpid()}"
        with open(tmp, "w") as f:
            f.write(self.to_prometheus())
        os.replace(tmp, path)
