"""Profiling hooks: opt-in ``jax.profiler`` wrappers + host step timers.

Three tools, all default-off and all zero-cost when off:

  * :func:`trace_ctx` — a context manager around ``jax.profiler.trace``:
    the whole serving/training run inside it lands in a TensorBoard-
    readable XPlane trace under the given directory.  No-op when the
    directory is falsy or the profiler is unavailable (e.g. a stripped
    CPU wheel), so launchers can pass the flag through unconditionally.
  * :class:`annotate` — a named ``jax.profiler.TraceAnnotation`` scope
    marking host-side regions (the jitted decode dispatch, a train
    step) so they are attributable in the trace timeline.  Constructed
    with ``enabled=False`` it is a no-op context manager; the engine
    and trainer gate it on their ``profile`` knob so the default hot
    path pays nothing.
  * :class:`StepTimer` — a host-side per-phase timing accumulator
    (``perf_counter`` spans, plain floats).  It deliberately does NOT
    ``block_until_ready``: it measures *dispatch* wall time, which is
    what the host-side scheduling loop can actually stall on, and
    inserting syncs would break the engine's one-bulk-transfer-per-step
    contract the transfer-guard tests pin down.  Per-span cost is two
    clock reads and a dict update.
"""
from __future__ import annotations

import contextlib
import time
from typing import Dict, Iterator, Optional

try:  # profiler is optional at runtime; hooks degrade to no-ops
    from jax import profiler as _jax_profiler
except Exception:  # noqa: BLE001 — any import failure means "unavailable"
    _jax_profiler = None


@contextlib.contextmanager
def trace_ctx(log_dir: Optional[str]) -> Iterator[None]:
    """``with trace_ctx("/tmp/prof"):`` profiles the enclosed run.

    Falsy ``log_dir`` (or an unavailable/already-active profiler) makes
    this a plain no-op, so call sites need no conditional."""
    if not log_dir or _jax_profiler is None:
        yield
        return
    try:
        _jax_profiler.start_trace(log_dir)
    except Exception:  # noqa: BLE001 — e.g. a trace is already running
        yield
        return
    try:
        yield
    finally:
        try:
            _jax_profiler.stop_trace()
        except Exception:  # noqa: BLE001 — never let teardown kill the run
            pass


class annotate:
    """Named profiler annotation scope; a no-op unless ``enabled``.

    ``with annotate("engine/decode", enabled=profile): ...`` shows up as
    a named span on the host timeline of a ``trace_ctx`` capture."""

    __slots__ = ("_ctx",)

    def __init__(self, name: str, enabled: bool = True) -> None:
        self._ctx = (
            _jax_profiler.TraceAnnotation(name)
            if enabled and _jax_profiler is not None
            else None
        )

    def __enter__(self) -> "annotate":
        if self._ctx is not None:
            self._ctx.__enter__()
        return self

    def __exit__(self, *exc) -> bool:
        if self._ctx is not None:
            self._ctx.__exit__(*exc)
        return False


class StepTimer:
    """Accumulates wall time per named phase across many steps.

    ``totals[name] = (count, total_seconds)``; ``summary()`` renders
    mean/total per phase.  Host-side only — see module docstring for why
    it never syncs the device."""

    __slots__ = ("totals", "_clock")

    def __init__(self, clock=time.perf_counter) -> None:
        self.totals: Dict[str, list] = {}
        self._clock = clock

    @contextlib.contextmanager
    def span(self, name: str) -> Iterator[None]:
        t0 = self._clock()
        try:
            yield
        finally:
            dt = self._clock() - t0
            cell = self.totals.get(name)
            if cell is None:
                self.totals[name] = [1, dt]
            else:
                cell[0] += 1
                cell[1] += dt

    def mean(self, name: str) -> float:
        cell = self.totals.get(name)
        return cell[1] / cell[0] if cell else 0.0

    def summary(self) -> Dict[str, Dict[str, float]]:
        return {
            name: {"count": c, "total_s": t, "mean_s": t / c}
            for name, (c, t) in sorted(self.totals.items())
        }

    def report(self) -> str:
        return "\n".join(
            f"{name}: n={v['count']} mean={v['mean_s'] * 1e3:.3f}ms "
            f"total={v['total_s']:.3f}s"
            for name, v in self.summary().items()
        )
