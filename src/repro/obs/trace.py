"""Request-lifecycle tracing: a bounded ring-buffer span recorder.

The serving engine emits one structured event per lifecycle transition —
``submit -> queued -> prefill -> decode -> finish`` on the happy path,
plus ``preempt`` / ``resume``, ``quarantine``, ``timeout`` and
``overload_reject`` on the degraded paths — into a fixed-capacity ring
buffer.  Events are plain host tuples at emit time (no JSON, no I/O, no
device traffic on the hot path); serialization happens only when the
trace is exported.

Determinism contract: the recorder never reads a clock of its own — the
engine stamps every event with its *injectable* clock (``Engine(clock=)``,
the same source its deadline machinery uses).  A seeded ``FaultPlan`` run
driven by a fake clock therefore produces a byte-identical JSONL trace
across runs — asserted in ``tests/test_obs.py`` — which turns "what did
the engine do during the outage" from archaeology into a golden file.

JSONL schema (one object per line, keys sorted, compact separators):

    {"event": <str>, "step": <int>, "ts": <float>, "uid": <int>, ...}

``event`` is one of :data:`EVENTS`; ``step`` is the engine step counter
at emit time (1-based, 0 outside any step); ``uid`` is the request id
(-1 for engine-scoped events); extra keyword fields ride along verbatim
(slot, reason, queue_depth, cached_tokens, ...).
"""
from __future__ import annotations

import collections
import json
import os
from typing import Deque, Dict, Iterator, List, Tuple

# the full lifecycle vocabulary; emit() rejects anything else so a typo'd
# event name fails the producer, not every downstream consumer
EVENTS = frozenset({
    "submit",            # request passed validation and was accepted
    "queued",            # request appended to the admission queue
    "prefill",           # admitted to a slot; prefill begins (or resumes)
    "decode",            # first token emitted; slot flipped to lockstep decode
    "finish",            # terminal: finish_reason + token count ride along
    "preempt",           # evicted under page pressure, re-queued
    "resume",            # replayed prefill caught up; decoding continues
    "quarantine",        # non-finite logits; slot isolated
    "timeout",           # deadline expired (queued or in flight)
    "overload_reject",   # bounded queue full; typed rejection at submit
})


class TraceRecorder:
    """Fixed-capacity lifecycle event recorder (host-side, allocation-light).

    ``capacity`` bounds memory: older events fall off the front — the
    serving trace is a flight recorder, not an unbounded log.  ``emit``
    stores a ``(ts, step, uid, event, extra)`` tuple; exporting renders
    JSONL with sorted keys and compact separators so equal event streams
    produce equal bytes."""

    def __init__(self, capacity: int = 4096) -> None:
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1 (got {capacity})")
        self.capacity = capacity
        self._buf: Deque[Tuple[float, int, int, str, tuple]] = \
            collections.deque(maxlen=capacity)
        self.emitted = 0      # total ever emitted (dropped = emitted - len)

    def emit(self, event: str, *, ts: float, uid: int = -1, step: int = 0,
             **data) -> None:
        if event not in EVENTS:
            raise ValueError(
                f"unknown trace event {event!r} (known: {sorted(EVENTS)})"
            )
        # sort extras once at emit so export is a pure render
        self._buf.append(
            (float(ts), int(step), int(uid), event, tuple(sorted(data.items())))
        )
        self.emitted += 1

    def __len__(self) -> int:
        return len(self._buf)

    @property
    def dropped(self) -> int:
        return self.emitted - len(self._buf)

    def events(self) -> List[Dict]:
        """Decoded events, oldest first."""
        return [
            {"ts": ts, "step": step, "uid": uid, "event": ev, **dict(extra)}
            for ts, step, uid, ev, extra in self._buf
        ]

    def lines(self) -> Iterator[str]:
        for e in self.events():
            yield json.dumps(e, sort_keys=True, separators=(",", ":"))

    def to_jsonl(self) -> str:
        return "".join(line + "\n" for line in self.lines())

    def write(self, path: str) -> None:
        """Atomic JSONL dump (tmp + ``os.replace``)."""
        tmp = f"{path}.tmp.{os.getpid()}"
        with open(tmp, "w") as f:
            f.write(self.to_jsonl())
        os.replace(tmp, path)

    def clear(self) -> None:
        self._buf.clear()
        self.emitted = 0
