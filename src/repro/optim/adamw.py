"""AdamW with sharded states (no optax dependency).

States inherit the parameter PartitionSpecs (ZeRO-3-like: fully sharded
optimizer).  ``state_dtype`` selects fp32 (faithful Megatron) or bf16
moments (beyond-paper memory optimization used for llama3-405b — see
EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.config import TrainConfig


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


def init_state(params: Any, state_dtype=jnp.float32) -> AdamWState:
    z = lambda p: jnp.zeros(p.shape, state_dtype)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(z, params),
        nu=jax.tree.map(z, params),
    )


def state_specs(param_specs: Any) -> AdamWState:
    from jax.sharding import PartitionSpec

    return AdamWState(step=PartitionSpec(), mu=param_specs, nu=param_specs)


def apply_updates(
    params: Any,
    grads: Any,
    state: AdamWState,
    lr: jax.Array,
    tc: TrainConfig,
) -> Tuple[Any, AdamWState]:
    step = state.step + 1
    b1, b2 = tc.beta1, tc.beta2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, n):
        gf = g.astype(jnp.float32)
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        n_new = b2 * n.astype(jnp.float32) + (1 - b2) * gf * gf
        mhat = m_new / c1
        nhat = n_new / c2
        delta = mhat / (jnp.sqrt(nhat) + tc.eps)
        if tc.weight_decay > 0 and p.ndim >= 2:  # no decay on norms/bias
            delta = delta + tc.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return p_new.astype(p.dtype), m_new.astype(m.dtype), n_new.astype(n.dtype)

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    params_new = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    mu_new = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    nu_new = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return params_new, AdamWState(step=step, mu=mu_new, nu=nu_new)


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def clip_by_global_norm(grads: Any, max_norm: float) -> Tuple[Any, jax.Array]:
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), gn
