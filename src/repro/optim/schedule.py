"""LR schedules: WSD (warmup-stable-decay), cosine, Noam, constant."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.config import TrainConfig


def lr_at(tc: TrainConfig, step) -> jnp.ndarray:
    s = jnp.asarray(step, jnp.float32)
    peak = tc.learning_rate
    warm = jnp.float32(max(tc.warmup_steps, 1))
    if tc.schedule == "const":
        return jnp.where(s < warm, peak * s / warm, peak)
    if tc.schedule == "noam":
        # Vaswani et al.: d^-0.5 * min(s^-0.5, s * warm^-1.5), scaled by peak
        s1 = jnp.maximum(s, 1.0)
        return peak * jnp.minimum(s1 ** -0.5, s1 * warm ** -1.5) / (warm ** -0.5)
    total = jnp.float32(max(tc.total_steps, 1))
    if tc.schedule == "cosine":
        frac = jnp.clip((s - warm) / jnp.maximum(total - warm, 1.0), 0.0, 1.0)
        cos = tc.min_lr + 0.5 * (peak - tc.min_lr) * (1 + jnp.cos(jnp.pi * frac))
        return jnp.where(s < warm, peak * s / warm, cos)
    # WSD: warmup -> stable -> linear decay over the last decay_steps
    decay = jnp.float32(max(tc.decay_steps, 1))
    decay_start = total - decay
    lin = peak + (tc.min_lr - peak) * jnp.clip((s - decay_start) / decay, 0.0, 1.0)
    stable = jnp.where(s < decay_start, peak, lin)
    return jnp.where(s < warm, peak * s / warm, stable)
