"""Logical→physical sharding rules.

The mesh has axes (``data``, ``model``) on one pod and (``pod``, ``data``,
``model``) across pods.  Models annotate params/activations with *logical*
axis names; this module maps them onto mesh axes per :class:`ParallelConfig`.

Consumers of these rules span both halves of the system:

  * training — ``training/train_step.make_sharded_train_step`` turns
    ``train_state_specs(model)`` (built from ``spec_tree`` over these
    rules) into the jit in/out shardings of the distributed train step,
    and ``training/loop.Trainer`` places host batches on the ``data``
    axes via ``host_batch_sharding``; parity with the single-device run
    is asserted in tests/test_trainer_distributed.py (8-virtual-device
    CPU mesh) and tests/test_parallel_numerics.py.
  * serving / dry-run — ``launch/shapes.dryrun_bundle`` shards the
    prefill/decode entry points for the 256/512-chip compile-only sweep,
    and ``serving/engine.Engine`` runs tensor-parallel inference end to
    end: ``Model.cache_specs`` (built from these rules) pins the
    in/out shardings of every per-step jit so the paged K/V pools shard
    over the head (``model``) axis while the host-side page allocator
    stays global — parity with the single-device engine is asserted in
    tests/test_serving_sharded.py on (1,8) and (2,4) CPU meshes.

Weight storage convention (uniform across archs — see DESIGN.md §5):
  * every large 2-D weight is stored (fsdp-dim, tp-dim) — combined FSDP+TP,
    ZeRO-3-like.  GSPMD inserts the all-gathers at use sites.
  * expert weights carry a leading `experts` dim on the `model` axis.
  * activations: batch over (pod?, data); in context-parallel attention the
    sequence dim is constrained to `model`.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.core.config import ParallelConfig


def mesh_axis_sizes(mesh: Mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def axis_rules(pc: ParallelConfig, mesh: Mesh) -> Dict[str, Any]:
    """Logical-name -> mesh-axis (or tuple) mapping."""
    names = mesh.axis_names
    has_pod = "pod" in names
    batch_axes: Tuple[str, ...] = ("pod", "data") if has_pod else ("data",)
    fsdp = tuple(a for a in pc.fsdp_axes if a in names)
    rules: Dict[str, Any] = {
        "batch": batch_axes,
        "seq": None,            # sequence replicated by default
        "seq_cp": "model",      # context-parallel sequence shard
        "embed": None,          # residual stream dim: replicated
        "fsdp": fsdp or None,
        "tp": "model",
        "experts": pc.expert_axis,
        "layers": None,
        "cache_seq": "model" if pc.shard_cache_seq else None,
        "cache_batch": batch_axes,
        "vocab": "model",
        "kv_tp": "model",
        "stats": None,
        # flattened (batch*seq) token dim (loss computation)
        "tokens": (
            (*batch_axes, "model")
            if pc.attention_parallelism == "context"
            else batch_axes
        ),
    }
    if len(fsdp) == 1:
        rules["fsdp"] = fsdp[0]
    return rules


def spec(rules: Dict[str, Any], *logical: Optional[str]) -> PartitionSpec:
    phys = [rules.get(ax) if ax is not None else None for ax in logical]
    while phys and phys[-1] is None:
        phys.pop()
    return PartitionSpec(*phys)


def named(mesh: Mesh, pspec: PartitionSpec) -> NamedSharding:
    return NamedSharding(mesh, pspec)


def fit_spec(shape, mesh: Mesh, pspec: PartitionSpec) -> PartitionSpec:
    """Drop mesh axes that do not evenly divide their dimension.

    ``with_sharding_constraint`` tolerates uneven dims (XLA pads), but
    *placement* shardings — ``jax.device_put`` and jit ``in_shardings`` /
    ``out_shardings`` — require exact divisibility.  Callers building
    placement shardings for concrete buffers use this to degrade per-dim
    to replication instead of erroring (e.g. 3 serving slots on a data=2
    mesh axis keep the slot dim replicated while the KV heads of the same
    cache still shard over ``model``)."""
    sizes = mesh_axis_sizes(mesh)
    phys = []
    for dim, ax in zip(shape, tuple(pspec) + (None,) * len(shape)):
        if ax is None:
            phys.append(None)
            continue
        n = 1
        for a in (ax if isinstance(ax, tuple) else (ax,)):
            n *= sizes.get(a, 1)
        phys.append(ax if dim % n == 0 else None)
    while phys and phys[-1] is None:
        phys.pop()
    return PartitionSpec(*phys)


def constrain(x, mesh: Mesh, pspec: PartitionSpec):
    """with_sharding_constraint that is a no-op off-mesh (CPU unit tests)."""
    if mesh is None or mesh.empty or mesh.size == 1:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, pspec))


class ShardingCtx:
    """Bundles mesh + rules; threaded through model apply fns.

    When ``mesh`` is None (pure single-device CPU tests) every constraint is
    a no-op, so the same model code runs everywhere.
    """

    def __init__(self, mesh: Optional[Mesh], pc: ParallelConfig):
        self.mesh = mesh
        self.pc = pc
        self.rules = axis_rules(pc, mesh) if mesh is not None else {}

    @property
    def context_parallel(self) -> bool:
        return self.pc.attention_parallelism == "context"

    def cons(self, x, *logical: Optional[str]):
        if self.mesh is None:
            return x
        return constrain(x, self.mesh, spec(self.rules, *logical))

    def sp(self, *logical: Optional[str]) -> PartitionSpec:
        if self.mesh is None:
            return PartitionSpec()
        return spec(self.rules, *logical)

    # ------------------------------------------------------- expert axis
    def expert_axis_size(self) -> int:
        """Product of the mesh axes the logical ``experts`` dim maps to
        (1 off-mesh or when the rule is unmapped)."""
        if self.mesh is None:
            return 1
        ax = self.rules.get("experts")
        if ax is None:
            return 1
        sizes = mesh_axis_sizes(self.mesh)
        n = 1
        for a in (ax if isinstance(ax, tuple) else (ax,)):
            n *= sizes.get(a, 1)
        return n

    def expert_parallel(self, num_experts: int) -> bool:
        """True when the expert-parallel MoE path applies: a real mesh
        whose expert axis evenly divides the expert count.  Otherwise
        MoE degrades to the replicated ragged path (and ``fit_spec``
        degrades the expert-dim weight placement to replication)."""
        n = self.expert_axis_size()
        return n > 1 and num_experts % n == 0


def null_ctx() -> ShardingCtx:
    return ShardingCtx(None, ParallelConfig())
