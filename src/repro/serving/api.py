"""``LLM`` — the one-stop generation facade over the serving engine.

Generation API v2's public surface: construct an ``LLM`` once (it owns a
continuous-batching ``Engine`` with whatever cache layout / prefix-cache
/ chunked-prefill configuration serving needs), then

* ``LLM.generate(prompts, params)`` — batch completion: submits every
  prompt with its own ``SamplingParams`` (one shared instance or a
  per-prompt list), drives the engine to completion, and returns
  ``Completion`` records in input order;
* ``LLM.stream(prompts, params)`` — iteration-level streaming: a
  generator yielding one ``StreamChunk`` per generated token, in the
  order the lockstep engine produces them — tokens from different
  requests interleave exactly as they are decoded.

This subsumes the old ``launch/serve.py::generate`` static-batch loop
and raw ``Engine``/``Request`` wiring for decoder-only serving; both
remain as thin back-compat paths.

    llm = LLM(model, params, slots=8, max_len=512, cache_layout="paged")
    outs = llm.generate(prompts, SamplingParams(temperature=0.8, top_k=40))
    for chunk in llm.stream(prompts, SamplingParams(max_new=64)):
        print(chunk.index, chunk.token)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterator, List, Optional, Sequence, Union

import numpy as np

from repro.core.config import ServeConfig
from repro.serving.engine import Engine, EngineOverloaded, Request
from repro.serving.sampling import SamplingParams

ParamsArg = Union[None, SamplingParams, Sequence[Optional[SamplingParams]]]


@dataclasses.dataclass
class Completion:
    """One finished request, in the order its prompt was passed in.

    ``finish_reason`` extends beyond the happy path: ``"stop"`` /
    ``"length"`` (normal), ``"timeout"`` (deadline passed — ``tokens``
    holds whatever was produced, possibly nothing), ``"error"`` (the
    request's logits went non-finite and its slot was quarantined), and
    ``"overloaded"`` (rejected at submit by the engine's bounded queue —
    the request never ran; retriable).  Degraded outcomes are data, not
    exceptions: one saturated engine must not turn a whole batch call
    into a stack trace.

    Timings: ``ttft_s`` is ``None`` — not ``0.0`` — when no token was
    ever produced (queued timeout, overload rejection, a first-token
    quarantine): "instant first token" and "no first token" are
    different facts, and SLO math must not average them together.
    ``queue_wait_s`` (submit -> first slot admission) is reported
    alongside, and is also ``None`` for requests that never reached a
    slot."""

    index: int
    tokens: List[int]
    finish_reason: str
    logprobs: Optional[List[float]] = None
    ttft_s: Optional[float] = None        # submit -> first token; None if none
    queue_wait_s: Optional[float] = None  # submit -> admission; None if never
    latency_s: float = 0.0                # submit -> done


@dataclasses.dataclass
class StreamChunk:
    """One newly decoded token of one in-flight request."""

    index: int
    token: int
    logprob: Optional[float] = None
    done: bool = False
    finish_reason: str = ""


class LLM:
    """Unified generate/stream facade over the continuous-batching engine.

    Construction mirrors ``Engine`` (or use ``LLM.from_config`` with a
    ``ServeConfig``).  ``default_params`` applies to prompts submitted
    without explicit params; it defaults to greedy.
    """

    def __init__(self, model, params, *, slots: int = 4, max_len: int = 512,
                 cache_layout: str = "dense", page_size: int = 16,
                 num_pages: int = 0, bucket_prompts: Optional[bool] = None,
                 prefix_cache: bool = False, prefill_chunk: int = 0,
                 max_queue: int = 0, preempt: bool = False,
                 faults: Optional[Any] = None,
                 extra_batch: Optional[Dict[str, Any]] = None,
                 default_params: Optional[SamplingParams] = None,
                 metrics: Optional[Any] = None, trace: Optional[Any] = None,
                 profile: bool = False, on_step: Optional[Any] = None):
        self.engine = Engine(
            model, params, slots=slots, max_len=max_len,
            extra_batch=extra_batch, cache_layout=cache_layout,
            page_size=page_size, num_pages=num_pages,
            bucket_prompts=bucket_prompts, prefix_cache=prefix_cache,
            prefill_chunk=prefill_chunk, max_queue=max_queue,
            preempt=preempt, faults=faults,
            metrics=metrics, trace=trace, profile=profile, on_step=on_step,
        )
        self.default_params = default_params or SamplingParams()
        self._uid = 0

    @classmethod
    def from_config(cls, model, params, sc: ServeConfig, *,
                    slots: Optional[int] = None,
                    extra_batch: Optional[Dict[str, Any]] = None,
                    **kw) -> "LLM":
        """Build from a ``ServeConfig`` — its sampling knobs (temperature,
        top_k, top_p, seed) become the default ``SamplingParams``.  Extra
        keyword args (``metrics``, ``trace``, ``profile``, ``on_step``)
        pass through to the constructor."""
        return cls(
            model, params,
            slots=slots if slots is not None else sc.batch_size,
            max_len=sc.max_seq_len, cache_layout=sc.cache_layout,
            page_size=sc.page_size, prefix_cache=sc.prefix_cache,
            prefill_chunk=sc.prefill_chunk, max_queue=sc.max_queue,
            preempt=sc.preempt, extra_batch=extra_batch,
            default_params=SamplingParams(
                temperature=sc.temperature, top_k=sc.top_k, top_p=sc.top_p,
                seed=sc.seed, deadline_ms=sc.deadline_ms,
            ),
            **kw,
        )

    # ---------------------------------------------------------- internals
    def _submit(self, prompts, params: ParamsArg) -> List[Optional[Request]]:
        """Submit every prompt; returns one entry per prompt, ``None``
        where the engine's bounded queue rejected it (surfaced to the
        caller as an ``"overloaded"`` outcome — the accepted prompts in
        the same batch still run).  Validation errors, by contrast, abort
        the whole call: they can never succeed on retry, and partial
        silent submission would leave orphans decoding inside later
        calls."""
        if isinstance(params, SamplingParams) or params is None:
            plist: List[Optional[SamplingParams]] = [params] * len(prompts)
        else:
            plist = list(params)
            if len(plist) != len(prompts):
                raise ValueError(
                    f"got {len(plist)} SamplingParams for {len(prompts)} prompts"
                )
        reqs: List[Optional[Request]] = []
        try:
            for prompt, sp in zip(prompts, plist):
                req = Request(
                    uid=self._uid,
                    prompt=np.asarray(prompt, np.int32),
                    params=sp or self.default_params,
                )
                self._uid += 1
                try:
                    self.engine.submit(req)
                except EngineOverloaded:
                    reqs.append(None)
                    continue
                reqs.append(req)
        except Exception:
            # mid-batch validation failure: withdraw what was already
            # queued, or it would silently decode inside the next call
            for r in reqs:
                if r is not None:
                    self.engine.cancel(r)
            raise
        return reqs

    # ------------------------------------------------------------ public
    def generate(self, prompts, params: ParamsArg = None,
                 max_steps: int = 100_000) -> List[Completion]:
        """Run every prompt to completion; results in input order."""
        reqs = self._submit(prompts, params)
        self.engine.run(max_steps=max_steps)
        outs = []
        for i, req in enumerate(reqs):
            if req is None:
                # bounded-queue rejection at submit: a typed outcome, so
                # one saturated engine degrades per-request, not per-call
                outs.append(Completion(
                    index=i, tokens=[], finish_reason="overloaded",
                ))
                continue
            if not req.finish_reason:
                # same leak-prevention as stream(): an overrun must not
                # leave orphans decoding inside later calls
                for r in reqs:
                    if r is not None and not r.finish_reason:
                        self.engine.cancel(r)
                raise RuntimeError(
                    f"request {req.uid} unfinished after {max_steps} steps"
                )
            outs.append(Completion(
                index=i, tokens=list(req.output or []),
                finish_reason=req.finish_reason, logprobs=req.logprobs,
                # None, not 0.0, when no token / no admission ever
                # happened — see the Completion docstring
                ttft_s=(req.t_first - req.t_submit) if req.t_first else None,
                queue_wait_s=(
                    (req.t_admit - req.t_submit) if req.t_admit else None
                ),
                latency_s=req.t_done - req.t_submit,
            ))
        return outs

    def embed(self, prompts) -> np.ndarray:
        """Batched embedding extraction through the engine: token prompts
        -> ``(n, d_model)`` float32 masked-mean-pooled vectors, in input
        order.  Prompts dispatch in length-bucketed device batches and
        the result comes back in one bulk transfer; lifecycle counters
        and trace events flow through the engine's telemetry like any
        generate call.  See ``Engine.embed``."""
        return self.engine.embed(prompts)

    def stream(self, prompts, params: ParamsArg = None,
               max_steps: int = 100_000) -> Iterator[StreamChunk]:
        """Yield tokens as the engine decodes them, interleaved across
        requests at iteration granularity (the continuous-batching
        analogue of server-sent streaming).  Abandoning the iterator
        (break / close) cancels the remaining in-flight requests and
        frees their slots/pages.

        Submission (and its validation errors) happens HERE, not at the
        first ``next()`` — ``stream`` is not itself a generator, it
        returns one, so a too-long prompt raises at the call site and
        the TTFT clocks start at call time."""
        reqs = self._submit(prompts, params)
        return self._stream(reqs, max_steps)

    def _stream(self, reqs: List[Optional[Request]],
                max_steps: int) -> Iterator[StreamChunk]:
        emitted = [0] * len(reqs)
        closed = [False] * len(reqs)
        try:
            # overload rejections are known before any engine step: emit
            # their terminal chunks up front (token=-1, no tokens exist)
            for i, req in enumerate(reqs):
                if req is None:
                    closed[i] = True
                    yield StreamChunk(
                        index=i, token=-1, done=True,
                        finish_reason="overloaded",
                    )
            for _ in range(max_steps):
                self.engine.step()
                for i, req in enumerate(reqs):
                    if req is None:
                        continue
                    out = req.output or []
                    while emitted[i] < len(out):
                        j = emitted[i]
                        emitted[i] += 1
                        last = emitted[i] == len(out)
                        fin = req.finish_reason if last else ""
                        closed[i] = closed[i] or bool(fin)
                        yield StreamChunk(
                            index=i, token=out[j],
                            logprob=(req.logprobs[j] if req.logprobs else None),
                            done=bool(fin), finish_reason=fin,
                        )
                    if req.finish_reason and not closed[i]:
                        # finished without a fresh token (queued timeout,
                        # quarantined first token): the consumer still
                        # needs a terminal chunk to stop waiting on i
                        closed[i] = True
                        yield StreamChunk(
                            index=i, token=-1, done=True,
                            finish_reason=req.finish_reason,
                        )
                if all(closed):
                    return
            raise RuntimeError(
                f"stream unfinished after {max_steps} engine steps"
            )
        finally:
            # consumer broke out / closed the generator: cancel whatever
            # is still in flight so orphaned requests don't keep decoding
            # (and holding slots) inside later generate()/stream() calls
            for req in reqs:
                if req is not None and not req.finish_reason:
                    self.engine.cancel(req)
