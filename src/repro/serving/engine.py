"""Continuous-batching serving engine (slot-based, iteration-level).

BioNeMo's serving story (NIM) is request-level batching; this engine
implements the standard slot scheduler on top of the framework's
per-slot-position decode path:

  * a fixed pool of B slots shares one preallocated KV cache
    (``Model.init_cache`` with a (B,) position vector);
  * an admitted request is prefilled alone (batch-1) and its cache slice
    is written into its slot (tree-wide dynamic_update_slice on the batch
    axis) — decoding of other slots is never paused for padding;
  * every engine step decodes ALL active slots in lockstep hardware-wise
    but with independent positions; finished slots (eos / max tokens) are
    released and refilled from the queue immediately.

The per-slot cache write in attention is a masked O(B·T) update — the
production path is a paged cache + Pallas scatter; iteration-level
semantics here are identical.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray           # (S,) int32
    max_new: int = 32
    eos_id: int = -1             # -1: never stops early
    # filled by the engine:
    output: Optional[List[int]] = None
    t_submit: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0


class Engine:
    def __init__(self, model: Model, params, *, slots: int, max_len: int,
                 extra_batch: Optional[Dict[str, Any]] = None):
        self.model = model
        self.params = params
        self.B = slots
        self.max_len = max_len
        self.extra = extra_batch or {}
        cross = model.cfg.num_frontend_tokens if model.cfg.is_encoder_decoder else 0
        cache = model.init_cache(slots, max_len, cross_len=cross)
        cache["pos"] = jnp.zeros((slots,), jnp.int32)
        self.cache = cache
        self.slot_req: List[Optional[Request]] = [None] * slots
        self.slot_last: np.ndarray = np.zeros((slots,), np.int32)
        self.slot_left: np.ndarray = np.zeros((slots,), np.int32)
        self.queue: List[Request] = []
        self.done: List[Request] = []

        self._prefill = jax.jit(lambda p, b: model.prefill(p, b, max_len))
        self._decode = jax.jit(model.decode_step)

    # -------------------------------------------------------------- admin
    def submit(self, req: Request) -> None:
        req.t_submit = time.time()
        self.queue.append(req)

    def _write_slot(self, slot: int, one_cache, pos: int) -> None:
        """Insert a batch-1 prefilled cache into slot `slot`."""

        def put(dst, src):
            # stacked leaves: (units, B, ...) — batch axis 1; scalar 'pos'
            # handled separately.
            if dst.ndim == src.ndim and dst.ndim >= 2 and src.shape[1] == 1:
                idx = (0, slot) + (0,) * (dst.ndim - 2)
                return jax.lax.dynamic_update_slice(dst, src.astype(dst.dtype), idx)
            return dst

        self.cache["layers"] = jax.tree.map(
            put, self.cache["layers"], one_cache["layers"]
        )
        self.cache["pos"] = self.cache["pos"].at[slot].set(pos)

    def _admit(self) -> None:
        for slot in range(self.B):
            if self.slot_req[slot] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            batch = {"tokens": jnp.asarray(req.prompt[None, :], jnp.int32)}
            for k, v in self.extra.items():
                batch[k] = v
            logits, one_cache = self._prefill(self.params, batch)
            nxt = int(jnp.argmax(logits[0, -1]))
            self._write_slot(slot, one_cache, int(one_cache["pos"]))
            req.output = [nxt]
            req.t_first = time.time()
            self.slot_req[slot] = req
            self.slot_last[slot] = nxt
            self.slot_left[slot] = req.max_new - 1
            if nxt == req.eos_id or req.max_new <= 1:
                self._finish(slot)

    def _finish(self, slot: int) -> None:
        req = self.slot_req[slot]
        req.t_done = time.time()
        self.done.append(req)
        self.slot_req[slot] = None
        self.slot_left[slot] = 0

    # --------------------------------------------------------------- step
    def step(self) -> int:
        """Admit + one decode iteration over all active slots.
        Returns the number of active slots decoded."""
        self._admit()
        active = [s for s in range(self.B) if self.slot_req[s] is not None]
        if not active:
            return 0
        tokens = jnp.asarray(self.slot_last[:, None], jnp.int32)
        logits, self.cache = self._decode(self.params, self.cache, tokens)
        nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1), np.int32)
        for s in active:
            req = self.slot_req[s]
            req.output.append(int(nxt[s]))
            self.slot_last[s] = nxt[s]
            self.slot_left[s] -= 1
            if int(nxt[s]) == req.eos_id or self.slot_left[s] <= 0:
                self._finish(s)
        # inactive slots also stepped (lockstep hardware batch) — their
        # positions advanced harmlessly; reset them to 0 for cleanliness
        inactive = [s for s in range(self.B) if self.slot_req[s] is None]
        if inactive:
            pos = np.array(self.cache["pos"])  # copy (device arrays are RO)
            pos[inactive] = np.minimum(pos[inactive], self.max_len - 1)
            self.cache["pos"] = jnp.asarray(pos)
        return len(active)

    def run(self, max_steps: int = 10_000) -> List[Request]:
        steps = 0
        while (self.queue or any(r is not None for r in self.slot_req)) \
                and steps < max_steps:
            self.step()
            steps += 1
        return self.done
