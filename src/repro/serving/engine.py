"""Continuous-batching serving engine (slot-based, iteration-level).

BioNeMo's serving story (NIM) is request-level batching; this engine
implements the standard slot scheduler on top of the framework's
per-slot-position decode path:

  * a fixed pool of B slots shares one preallocated KV cache
    (``Model.init_cache`` with a (B,) position vector);
  * an admitted request is prefilled alone (batch-1) and its cache is
    inserted into its slot — decoding of other slots is never paused for
    padding;
  * every engine step decodes ALL active slots in lockstep hardware-wise
    but with independent positions; finished slots (eos / max tokens) are
    released and refilled from the queue immediately.

Two cache layouts:

``cache_layout="dense"``
    One (B, max_len) KV buffer per layer; the per-slot decode write is a
    masked O(B·max_len) select.  Simple, always available.

``cache_layout="paged"`` — the production path
    Fixed-size pages of a shared pool, mapped per slot by a block table
    (``paged_cache.PageAllocator``).  Admission reserves the request's
    full budget (prompt + max_new) — capacity-aware: a request that does
    not fit waits in the queue, one that can never fit is rejected at
    submit.  Release returns pages to the free list for immediate reuse.
    The decode write is an O(B·page) Pallas scatter and attention reads
    K/V through the block table (``kernels/paged_attention.py``).

Prefix caching + chunked prefill (paged layout only):

``prefix_cache=True``
    Admission hashes the prompt's full blocks against the allocator's
    content-addressed page index.  Hash-hit blocks are *shared* — their
    pages are mapped into the new slot (refcounted) and prefill skips
    them entirely, running only over the suffix.  After a prompt
    finishes prefilling, its full blocks are registered for future
    sharing; a shared page is never written (copy-on-write privatizes
    the final page when a fully-cached prompt recomputes its last token
    for logits).

``prefill_chunk=N``
    Prompts prefill in bounded chunks of at most N tokens, one chunk per
    engine step, interleaved with decode iterations — a long prompt can
    no longer stall in-flight decodes for its whole length.  ``N=0``
    with ``prefix_cache=True`` prefills the (possibly shortened) suffix
    in one chunk.  Mid-prefill slots are invisible to the lockstep
    decode: their block-table rows are masked to the null page in the
    device copy, so concurrent decode writes touch no live data.

Both features need right-paddable causal attention-only stacks (the same
condition as prompt bucketing) and are rejected otherwise.

Prompt bucketing: prompts are right-padded to power-of-2 buckets so the
jitted prefill compiles once per bucket instead of once per unique prompt
length.  Sound only for causal attention-only stacks (pad rows sit in the
future of every real row; SSM state would carry pad garbage), so it is
auto-disabled elsewhere.

Generation API v2 (per-request sampling, on-device selection):

Every request may carry a ``SamplingParams`` (``serving/sampling.py``) —
temperature / top-k / top-p / seed / stop tokens / stop sequences /
logprobs — and the numeric fields live on device as per-slot vectors.
Token *selection* happens inside the jitted decode step
(``ops.sample_tokens``: fused per-slot filter + categorical, greedy rows
degrade to argmax), so the steady-state decode loop is token-in /
token-out: the previous step's sampled tokens feed the next step without
ever visiting the host, and the only host traffic per step is ONE bulk
``jax.device_get`` of the sampled (tokens, logprobs, fault flags) triple
for bookkeeping and stop checks.  A request without params decodes
greedily with its legacy ``max_new``/``eos_id`` fields — old
``Engine(...)`` call sites keep working unchanged;
``serving/api.py::LLM`` is the v2 facade.

Fault tolerance (the request-lifecycle hardening pass):

  * **Bounded backpressure** — ``max_queue=N`` caps the admission queue;
    ``submit`` raises the typed, retriable :class:`EngineOverloaded`
    instead of growing the queue without bound (overload then costs the
    caller a rejection, not every caller an unbounded TTFT).
  * **Deadlines** — a request carrying ``deadline_ms`` (on its
    ``SamplingParams`` or directly on the ``Request``) times out as a
    wall-clock SLO from submit: expired *queued* requests finish with
    ``finish_reason="timeout"`` without running; expired *in-flight*
    requests are released at the next step boundary with whatever
    tokens they produced.  ``clock`` is injectable for deterministic
    tests.
  * **Preempt-and-requeue** (``preempt=True``, paged layout) — when the
    queue head is blocked on page pressure, the engine evicts the
    most-recently-admitted in-flight decode instead of head-of-line
    blocking: the victim's exclusive pages free (prefix-registered ones
    park in the evictable set), the request re-queues right behind the
    blocked head, and on re-admission it *replays* via prefill over
    prompt + generated-so-far.  Its generation index is the resume
    cursor — the counter-hash sampling PRNG (keyed on request seed +
    generation index, PR 4) makes the resumed request token-identical
    to an unpreempted run.  Each request is preempted at most once and
    only requests that were never preempted trigger or suffer
    preemption, so the cycle cannot livelock.
  * **Fault isolation** — a non-finite sentinel inside the jitted step
    (and the admission first-token path) quarantines only the offending
    slot with ``finish_reason="error"``; every other slot's sampled
    token is provably untouched (the sentinel also sanitizes the bad
    row before it reaches the fused sampler, so a NaN in one slot's
    logits can never poison a batch-wide reduction).
  * **Observability** — :meth:`Engine.health` snapshots queue depth,
    slot occupancy, free pages, a steps-since-progress watchdog counter
    and the lifecycle counters; ``serving/faults.py`` injects
    deterministic fault schedules (NaN logits, allocator outages,
    crash-and-rebuild) through the ``faults=FaultPlan(...)`` hook.

Unified telemetry (``repro.obs``): pass ``metrics=MetricsRegistry()``
and every lifecycle counter, the watchdog, queue/slot/page gauges and
the TTFT / ITL / queue-wait / e2e-latency histograms become
registry-backed (``health()`` counters and the registry agree by
construction — both go through :meth:`_bump`); pass
``trace=TraceRecorder()`` and every lifecycle transition emits one
structured event stamped by the engine's injectable clock, so a seeded
fault run yields a byte-identical JSONL trace.  Both hooks are
host-side appends on paths the engine already walks: the
one-bulk-transfer-per-step contract is unchanged (transfer-guard
asserted in ``tests/test_obs.py``) and the measured tok/s overhead is
bounded <2% in ``benchmarks/serving_bench.py``.  ``profile=True`` wraps
the jitted prefill/decode dispatches in ``jax.profiler`` annotations
and accumulates per-phase host timings in ``Engine.step_timer``;
``on_step`` is a per-step callback the launchers use for periodic
health/exposition emission.

Sharded serving (tensor-parallel inference on the mesh):

A model built with a multi-device mesh (``build_model(cfg, pc, mesh)``,
``serve.py --mesh DxM``) makes the whole engine mesh-aware with no API
change: the paged K/V pools (and dense K/V buffers) shard over the
head/``model`` axis per :meth:`Model.cache_specs` while the host-side
page allocator, refcounts and prefix-hash index stay global — one
logical cache, sharded storage, so a page id means the same thing on
every device and prefix sharing / COW semantics are mesh-invariant.
Every jit below pins ``in_shardings``/``out_shardings`` to the canonical
placement with donation intact, so the steady-state decode loop updates
the sharded pools in place and keeps the one-bulk-transfer-per-step
contract (re-asserted on the mesh in tests/test_serving_sharded.py).
The fused sampler and the NaN sentinel consume the *replicated* logits
row, so a request's token stream depends only on its seed + generation
index: greedy and seeded-sampled outputs are token-identical across
(1,), (1,8) and (2,4) meshes.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

from repro.models.model import Model
from repro.parallel.sharding import fit_spec
from repro.kernels import ops
from repro.serving.paged_cache import (
    NULL_PAGE,
    PageAllocator,
    copy_pages,
    pages_for,
    write_slot_paged,
)
from repro.obs.metrics import LATENCY_BUCKETS, MetricsRegistry
from repro.obs.profile import StepTimer, annotate
from repro.obs.trace import TraceRecorder
from repro.serving.sampling import SamplingParams, StopChecker, effective_params


class EngineOverloaded(RuntimeError):
    """Typed admission rejection: the bounded queue is full.

    Raised by :meth:`Engine.submit` when ``max_queue`` is reached.  It is
    *retriable* by contract — the request was not mutated or partially
    admitted, and the caller may resubmit once :meth:`Engine.health`
    shows the queue draining (the serving analogue of HTTP 429/503)."""

    retriable = True

    def __init__(self, uid: int, depth: int, max_queue: int):
        super().__init__(
            f"request {uid}: admission queue full ({depth}/{max_queue}); "
            f"retry after the queue drains"
        )
        self.queue_depth = depth
        self.max_queue = max_queue


@dataclasses.dataclass
class EngineHealth:
    """One consistent snapshot of engine liveness (``Engine.health()``).

    ``steps_since_progress`` is the watchdog: engine steps since any
    request was admitted, advanced a prefill chunk, emitted a token, or
    finished.  A serving loop that sees it grow while ``queue_depth > 0``
    is wedged (e.g. a permanent allocator outage) and should alert or
    recycle the engine."""

    queue_depth: int
    slots: int
    active_slots: int
    prefilling: int
    free_pages: Optional[int]       # None for the dense layout
    total_pages: Optional[int]
    steps: int
    steps_since_progress: int
    counters: Dict[str, int]


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray           # (S,) int32
    max_new: int = 32
    eos_id: int = -1             # -1: never stops early
    # v2 sampling intent; None = legacy greedy decode with max_new/eos_id.
    # When set, a non-None params.max_new takes precedence (normalized at
    # submit; params.max_new=None inherits the field above) and
    # eos_id >= 0 folds into the stop-token set.
    params: Optional[SamplingParams] = None
    # wall-clock SLO from submit, in ms (params.deadline_ms wins when
    # set; None = no deadline)
    deadline_ms: Optional[float] = None
    # filled by the engine:
    output: Optional[List[int]] = None
    logprobs: Optional[List[float]] = None   # per-token, if params.logprobs
    # "stop" | "length" | "timeout" | "error" | "cancelled" once done
    finish_reason: str = ""
    preempted: int = 0                       # times evicted-and-requeued
    t_submit: float = 0.0
    t_admit: float = 0.0         # first admission to a slot (0 = never ran)
    t_first: float = 0.0
    t_done: float = 0.0
    _seq: int = -1                           # submit order (engine-assigned)


@dataclasses.dataclass
class _Prefill:
    """A slot mid-way through an incremental (chunked/suffix) prefill."""

    req: Request
    prompt: np.ndarray           # original, unpadded prompt (+ replayed
                                 # generated tokens for a resumed request)
    done: int                    # tokens whose KV is already in the pages


class Engine:
    def __init__(self, model: Model, params, *, slots: int, max_len: int,
                 extra_batch: Optional[Dict[str, Any]] = None,
                 cache_layout: str = "dense", page_size: int = 16,
                 num_pages: int = 0, bucket_prompts: Optional[bool] = None,
                 prefix_cache: bool = False, prefill_chunk: int = 0,
                 max_queue: int = 0, preempt: bool = False,
                 faults: Optional[Any] = None,
                 clock: Callable[[], float] = time.time,
                 metrics: Optional[MetricsRegistry] = None,
                 trace: Optional[TraceRecorder] = None,
                 profile: bool = False,
                 on_step: Optional[Callable[["Engine"], None]] = None):
        self.model = model
        self.params = params
        self.B = slots
        self.max_len = max_len
        self.extra = extra_batch or {}
        cfg = model.cfg
        self.layout = cache_layout
        # frontend rows are prepended only when the batch actually carries
        # img_embeds (_decoder_input); a vision model served text-only has
        # no frontend rows in its prefill
        self.n_front = (
            cfg.num_frontend_tokens
            if cfg.frontend == "vision_stub" and "img_embeds" in self.extra
            else 0
        )
        cross = cfg.num_frontend_tokens if cfg.is_encoder_decoder else 0

        # right-padding (prompt buckets, chunk buckets, prefix skips) is
        # only sound when pad rows stay in every real row's future: causal
        # attention, no SSM state carry, no rolling (sliding-window) cache
        has_ssm = any(not cfg.is_attn_layer(i) for i in range(cfg.num_layers))
        paddable = cfg.causal and not has_ssm and not cfg.sliding_window

        self.prefix_cache = prefix_cache
        self.prefill_chunk = prefill_chunk
        self._incremental = prefix_cache or prefill_chunk > 0
        if self._incremental:
            if cache_layout != "paged":
                raise ValueError(
                    "prefix_cache / prefill_chunk require cache_layout='paged'"
                )
            if not paddable or cfg.is_encoder_decoder or self.n_front:
                raise ValueError(
                    "prefix_cache / prefill_chunk require a causal "
                    "attention-only decoder with no frontend rows"
                )
        self.max_queue = int(max_queue)
        self.preempt = bool(preempt)
        if self.preempt and cache_layout != "paged":
            raise ValueError(
                "preempt=True requires cache_layout='paged' — preemption "
                "frees page-pool pressure, which the dense layout has none of"
            )
        self.faults = faults
        self._clock = clock

        if cache_layout == "paged":
            # default pool: every slot can hold a full max_len sequence,
            # +1 for the reserved null page — admission then only queues
            # on slot pressure, like the dense layout.
            pages_per_seq = pages_for(max_len, page_size)
            num_pages = num_pages or 1 + slots * pages_per_seq
            self.alloc = PageAllocator(
                num_pages, page_size, slots, max_len,
                prefix_cache=prefix_cache,
            )
            cache = model.init_cache(
                slots, max_len, cross_len=cross,
                layout="paged", page_size=page_size, num_pages=num_pages,
            )
        elif cache_layout == "dense":
            self.alloc = None
            cache = model.init_cache(slots, max_len, cross_len=cross)
        else:
            raise ValueError(f"unknown cache_layout {cache_layout!r}")
        cache["pos"] = jnp.zeros((slots,), jnp.int32)

        # ---- tensor-parallel serving: canonical placement on the mesh.
        # When the model carries a real multi-device mesh (build_model with
        # --mesh), the K/V storage shards over the head/model axis per
        # Model.cache_specs — one logical cache, sharded storage; the page
        # allocator, refcounts and prefix-hash index below stay host-global
        # and never learn about the mesh.  Params shard per param_specs
        # (fitted: axes that don't divide a dim degrade to replication) and
        # every per-slot control vector is replicated.  Off-mesh, placement
        # stays implicit and the jits below compile exactly as before.
        mesh = model.ctx.mesh
        self.mesh = (
            mesh if mesh is not None and not mesh.empty and mesh.size > 1
            else None
        )
        if self.mesh is not None:
            self._rep = NamedSharding(self.mesh, PartitionSpec())
            self._sh_cache = model.cache_shardings(cache)
            self._sh_params = jax.tree.map(
                lambda p, s: NamedSharding(
                    self.mesh, fit_spec(p.shape, self.mesh, s)
                ),
                params, model.param_specs(),
            )
            cache = jax.device_put(cache, self._sh_cache)
            self.params = jax.device_put(params, self._sh_params)
        else:
            self._rep = self._sh_cache = self._sh_params = None
        self.cache = cache
        self.slot_req: List[Optional[Request]] = [None] * slots
        self.slot_left: np.ndarray = np.zeros((slots,), np.int32)
        self.slot_deadline: List[Optional[float]] = [None] * slots
        self.queue: List[Request] = []
        self.done: List[Request] = []
        # slots mid-prefill, in admission order (FIFO chunk scheduling)
        self._prefilling: List[int] = []
        self._prefill_state: Dict[int, _Prefill] = {}

        # lifecycle bookkeeping: submit order (preemption victims must be
        # younger than nobody they displace from the queue), admission
        # recency (the preemption victim is the NEWEST in-flight decode),
        # and the health counters + watchdog.
        self._next_seq = 0
        self._admit_counter = 0
        self._admit_order: List[int] = [-1] * slots
        self.steps = 0
        self._steps_since_progress = 0
        self._progress = False
        self.counters: Dict[str, int] = {
            "submitted": 0, "completed": 0, "rejected": 0, "timeouts": 0,
            "errors": 0, "cancelled": 0, "preempted": 0, "resumed": 0,
        }

        # unified telemetry (repro.obs): every counter bump goes through
        # _bump so the registry and health() can never disagree; the
        # latency histograms observe host floats the engine already
        # computes, and the lifecycle tracer is stamped by self._clock —
        # all host-side appends, nothing touches the device hot loop.
        self.metrics = metrics
        self.trace = trace
        self.profile = bool(profile)
        self.step_timer = StepTimer() if self.profile else None
        self.on_step = on_step
        if metrics is not None:
            fam = metrics.counter(
                "engine_requests_total",
                "request lifecycle transitions by event", labels=("event",),
            )
            self._mc = {k: fam.labels(k) for k in self.counters}
            self._g = {
                name: metrics.gauge(f"engine_{name}", help)
                for name, help in (
                    ("queue_depth", "requests waiting for admission"),
                    ("active_slots", "slots holding an in-flight request"),
                    ("prefilling", "slots mid incremental prefill"),
                    ("free_pages", "KV pool pages on the free list"),
                    ("steps_since_progress",
                     "watchdog: engine steps since any request advanced"),
                )
            }
            self._c_steps = metrics.counter(
                "engine_steps_total", "engine scheduler iterations"
            )
            self._c_toks = metrics.counter(
                "engine_tokens_total", "generated tokens across all requests"
            )
            self._h_ttft = metrics.histogram(
                "engine_ttft_seconds", "submit -> first token",
                buckets=LATENCY_BUCKETS,
            )
            self._h_itl = metrics.histogram(
                "engine_itl_seconds", "per-request mean inter-token latency",
                buckets=LATENCY_BUCKETS,
            )
            self._h_queue = metrics.histogram(
                "engine_queue_wait_seconds", "submit -> slot admission",
                buckets=LATENCY_BUCKETS,
            )
            self._h_e2e = metrics.histogram(
                "engine_e2e_latency_seconds", "submit -> finish",
                buckets=LATENCY_BUCKETS,
            )
        else:
            self._mc = None

        # per-slot sampling state.  The numeric params live on DEVICE
        # ((B,) vectors consumed by the fused sampler inside the jitted
        # decode step); the stop machinery is host-side per slot.
        # ``gen`` is each slot's generation index (tokens emitted so
        # far) — it keys the counter-based PRNG stream, so a fixed-seed
        # request reproduces its tokens in any batch composition.
        self.slot_sp: List[Optional[SamplingParams]] = [None] * slots
        self.slot_stop: List[Optional[StopChecker]] = [None] * slots
        self._samp: Dict[str, jax.Array] = {
            "temp": jnp.zeros((slots,), jnp.float32),
            "top_k": jnp.zeros((slots,), jnp.int32),
            "top_p": jnp.ones((slots,), jnp.float32),
            "seed": jnp.zeros((slots,), jnp.uint32),
            "gen": jnp.zeros((slots,), jnp.int32),
            "active": jnp.zeros((slots,), bool),
        }
        # token-in/token-out: the last sampled token per slot stays on
        # device and feeds the next decode step directly
        self._last_tok = jnp.zeros((slots,), jnp.int32)
        # steady-state fault-injection vector (all clear) kept on device:
        # passing it adds no host->device traffic to the decode step
        self._no_inject = jnp.zeros((slots,), bool)
        if self.mesh is not None:
            # commit the control vectors replicated so the pinned jits
            # below accept them without a placement mismatch
            self._samp = jax.device_put(self._samp, self._rep)
            self._last_tok = jax.device_put(self._last_tok, self._rep)
            self._no_inject = jax.device_put(self._no_inject, self._rep)

        if bucket_prompts is None:
            bucket_prompts = paddable
        self.bucket_prompts = bucket_prompts

        impl = cfg.kernel_impl

        def _fused_step(params, cache, tok, samp, inject):
            """One decode iteration with ON-DEVICE token selection.

            Everything the old loop did on the host — argmax, idle-slot
            pos reset, next-token feedback — happens inside this one
            jitted call: the engine only transfers the sampled (tok,
            logp, bad) triple back, once, per step.  ``inject`` is the
            fault layer's NaN vector (all-False in steady state); the
            non-finite sentinel quarantines a poisoned slot's row —
            whether injected or organic — BEFORE it reaches the fused
            sampler, so one slot's NaN can never leak into another
            slot's token."""
            logits, cache = model.decode_step(params, cache, tok[:, None])
            # idle / mid-prefill slots stepped in lockstep: reset their
            # positions (their writes touched no live data)
            cache["pos"] = jnp.where(samp["active"], cache["pos"], 0)
            row = logits[:, -1]
            # sampler + sentinel consume the REPLICATED row: the head
            # matmul may leave logits vocab-sharded on a mesh, and both
            # the counter-hash PRNG draw and the isfinite reduction must
            # see identical full rows on every device for a request's
            # token stream to be independent of the mesh shape (off-mesh
            # this constraint is a no-op)
            row = model.ctx.cons(row, None, None)
            row = jnp.where(inject[:, None], jnp.float32(jnp.nan), row)
            bad = samp["active"] & ~jnp.all(jnp.isfinite(row), axis=-1)
            row = jnp.where(bad[:, None], 0.0, row)
            # idle slots read as greedy (temp 0) no matter what request
            # last held them — otherwise one retired sampled request
            # would defeat the sampler's all-greedy fast path for every
            # later greedy-only step
            nxt, logp = ops.sample_tokens(
                row,
                jnp.where(samp["active"], samp["temp"], 0.0),
                samp["top_k"], samp["top_p"],
                samp["seed"], samp["gen"], impl=impl,
            )
            nxt = jnp.where(samp["active"], nxt, 0)
            samp = dict(samp, gen=samp["gen"] + samp["active"].astype(jnp.int32))
            return nxt, logp, bad, cache, samp

        def _admit_slot(samp, last_tok, logits, slot, temp, k, p, seed,
                        gen0, inject):
            """Sample a request's NEXT token from its prefill logits and
            bind every per-slot device field in one jitted call —
            admission costs one dispatch + one device_get instead of a
            string of eager .at[].set updates (which showed up directly
            in shared-prefix TTFT).  ``gen0`` is the generation index to
            sample at: 0 for a fresh prompt, the number of already-
            emitted tokens for a preempted request replaying its
            prompt+output (same counter-hash stream => same tokens as an
            unpreempted run).  The same non-finite sentinel as the
            decode step guards the prefill logits."""
            row = logits[:, -1]
            # same replication guarantee as the decode step: first-token
            # sampling must be mesh-shape-independent too
            row = model.ctx.cons(row, None, None)
            row = jnp.where(inject, jnp.float32(jnp.nan), row)
            bad = ~jnp.all(jnp.isfinite(row))
            row = jnp.where(bad, 0.0, row)
            tok, logp = ops.sample_tokens(
                row, temp[None], k[None], p[None], seed[None],
                gen0[None].astype(jnp.uint32), impl=impl,
            )
            samp = dict(
                samp,
                temp=samp["temp"].at[slot].set(temp),
                top_k=samp["top_k"].at[slot].set(k),
                top_p=samp["top_p"].at[slot].set(p),
                seed=samp["seed"].at[slot].set(seed),
                gen=samp["gen"].at[slot].set((gen0 + 1).astype(jnp.int32)),
                active=samp["active"].at[slot].set(True),
            )
            return tok, logp, bad, samp, last_tok.at[slot].set(tok[0])

        def _release_slot(samp, pos, slot):
            """Deactivate a finished slot and reset its pos (one call)."""
            return (
                dict(samp, active=samp["active"].at[slot].set(False)),
                pos.at[slot].set(0),
            )

        # the engine cache is serving steady state: donate it so XLA
        # updates pools/buffers in place instead of copying the whole
        # cache every decode step / prefill chunk / page insert (each
        # call consumes self.cache[...] and the engine reassigns it)
        if self.mesh is None:
            self._prefill = jax.jit(
                lambda p, b, L: model.prefill(p, b, max_len, length=L)
            )
            self._decode = jax.jit(_fused_step, donate_argnums=(1, 3))
            self._admit_slot = jax.jit(_admit_slot, donate_argnums=(0, 1))
            self._release_slot = jax.jit(
                _release_slot, donate_argnums=(0, 1)
            )
            self._insert_paged = jax.jit(
                write_slot_paged, donate_argnums=(0,)
            )
            self._chunk = jax.jit(model.prefill_chunk, donate_argnums=(1,))
            self._copy = jax.jit(copy_pages, donate_argnums=(0,))
            self._embed_fn = jax.jit(model.embed_pool)
        else:
            # mesh-aware jits: every dispatch pins its in/out shardings to
            # the canonical placement (params per param_specs, cache per
            # cache_specs, control state replicated).  jax rejects a
            # committed arg whose sharding mismatches an explicit pin, so
            # the pins PROVE the steady-state decode loop moves no data:
            # every input already lives where the pin says, every output
            # is produced there (donated sharded buffers update in
            # place), and the only host traffic stays the one bulk
            # device_get of the sampled (tok, logp, bad) triple.  The
            # batch-1 prefill tree is replicated: it is O(max_len) small,
            # and its slot insert then writes each pool shard locally.
            rep = self._rep
            csh, psh = self._sh_cache, self._sh_params
            lsh = csh["layers"]
            ssh = {k: rep for k in self._samp}
            self._prefill = jax.jit(
                lambda p, b, L: model.prefill(p, b, max_len, length=L),
                in_shardings=(psh, rep, rep), out_shardings=rep,
            )
            self._decode = jax.jit(
                _fused_step, donate_argnums=(1, 3),
                in_shardings=(psh, csh, rep, ssh, rep),
                out_shardings=(rep, rep, rep, csh, ssh),
            )
            self._admit_slot = jax.jit(
                _admit_slot, donate_argnums=(0, 1),
                in_shardings=(ssh,) + (rep,) * 9,
                out_shardings=(rep, rep, rep, ssh, rep),
            )
            self._release_slot = jax.jit(
                _release_slot, donate_argnums=(0, 1),
                in_shardings=(ssh, rep, rep), out_shardings=(ssh, rep),
            )
            self._insert_paged = jax.jit(
                write_slot_paged, donate_argnums=(0,),
                in_shardings=(lsh, rep, rep, rep), out_shardings=lsh,
            )
            self._chunk = jax.jit(
                model.prefill_chunk, donate_argnums=(1,),
                in_shardings=(psh, lsh) + (rep,) * 4,
                out_shardings=(rep, lsh),
            )
            self._copy = jax.jit(
                copy_pages, donate_argnums=(0,),
                in_shardings=(lsh, rep, rep), out_shardings=lsh,
            )
            # embedding extraction: batch replicated in (it is O(B·S)
            # small), params per param_specs, pooled (B, d) out replicated
            self._embed_fn = jax.jit(
                model.embed_pool,
                in_shardings=(psh, rep, rep), out_shardings=rep,
            )

    # ---------------------------------------------------------- telemetry
    def _bump(self, name: str, n: int = 1) -> None:
        """Advance a lifecycle counter in BOTH the health() dict and the
        metrics registry — one call site per transition, so the two views
        cannot drift (parity asserted across chaos plans in
        tests/test_obs.py)."""
        self.counters[name] += n
        if self._mc is not None:
            self._mc[name].inc(n)

    def _emit(self, event: str, req: Optional[Request] = None,
              ts: Optional[float] = None, **data) -> None:
        """Record one lifecycle trace event, stamped by the engine's
        injectable clock (deterministic under a fake clock)."""
        if self.trace is None:
            return
        self.trace.emit(
            event,
            ts=self._clock() if ts is None else ts,
            uid=req.uid if req is not None else -1,
            step=self.steps,
            **data,
        )

    def _observe_gauges(self) -> None:
        g = self._g
        g["queue_depth"].set(len(self.queue))
        g["active_slots"].set(sum(r is not None for r in self.slot_req))
        g["prefilling"].set(len(self._prefilling))
        if self.alloc is not None:
            g["free_pages"].set(self.alloc.free_pages)
        g["steps_since_progress"].set(self._steps_since_progress)

    # -------------------------------------------------------------- admin
    def submit(self, req: Request) -> None:
        if req.params is not None and req.params.max_new is not None:
            # v2 requests budget via params; normalize the legacy field so
            # every admission/capacity path sees one source of truth
            # (params.max_new=None inherits the request's own budget)
            req.max_new = req.params.max_new
        if req.params is not None and req.params.deadline_ms is not None:
            req.deadline_ms = req.params.deadline_ms
        if req.max_new < 1:
            raise ValueError(
                f"request {req.uid}: max_new must be >= 1 (got {req.max_new})"
            )
        if len(req.prompt) == 0 and self.n_front == 0:
            raise ValueError(
                f"request {req.uid}: empty prompt — a causal LM has no "
                f"token to condition the first logits on"
            )
        need = len(req.prompt) + self.n_front + req.max_new
        if need > self.max_len:
            raise ValueError(
                f"request {req.uid}: prompt+max_new = {need} tokens "
                f"overflows max_len {self.max_len}"
            )
        if self.alloc is not None and not self.alloc.fits_slot(need):
            raise ValueError(
                f"request {req.uid}: {need} tokens can never fit the page "
                f"pool ({self.alloc.num_pages - 1} usable pages of "
                f"{self.alloc.page_size})"
            )
        # bounded backpressure: reject instead of queueing without bound.
        # Validation errors above are NOT rejections (they can never
        # succeed on retry); this one is — the typed exception tells the
        # caller to back off and try again.  Internal re-queues (preempted
        # requests) bypass submit and may transiently exceed the bound.
        if self.max_queue and len(self.queue) >= self.max_queue:
            self._bump("rejected")
            self._emit("overload_reject", req, queue_depth=len(self.queue),
                       max_queue=self.max_queue)
            raise EngineOverloaded(req.uid, len(self.queue), self.max_queue)
        req.t_submit = self._clock()
        req._seq = self._next_seq
        self._next_seq += 1
        self._bump("submitted")
        self.queue.append(req)
        self._emit("submit", req, ts=req.t_submit,
                   prompt_tokens=len(req.prompt), max_new=req.max_new)
        self._emit("queued", req, ts=req.t_submit,
                   queue_depth=len(self.queue))

    # ---------------------------------------------------------- embedding
    def embed(self, prompts: List[List[int]]) -> np.ndarray:
        """Batched embedding extraction: token prompts -> (n, d_model)
        float32 masked-mean-pooled vectors, in input order.

        Prompts group by power-of-2 length bucket and dispatch in rows of
        up to ``slots`` per jitted call — at most O(log max_len) compiled
        shapes, reused across calls.  Every dispatch stays on device; the
        (n, d) result comes back in ONE bulk ``device_get`` at the end.
        Pooling is right-pad safe for every stack this engine serves
        (causal attention/SSM never let pads reach valid rows;
        bidirectional models see pads exactly as during training), so no
        paddable gate applies.  Lifecycle counters/trace use the standard
        vocabulary: each prompt counts submitted+completed, each dispatch
        emits a ``prefill`` event and the call one ``finish``.
        """
        cfg = self.model.cfg
        if cfg.is_encoder_decoder or self.n_front:
            raise ValueError(
                "embed() supports decoder-only text stacks — encoder-"
                "decoder and vision-frontend models have no single "
                "token-aligned hidden sequence to pool"
            )
        prompts = [np.asarray(p, np.int32) for p in prompts]
        n = len(prompts)
        if n == 0:
            return np.zeros((0, cfg.d_model), np.float32)
        for i, p in enumerate(prompts):
            if p.ndim != 1 or len(p) == 0:
                raise ValueError(f"prompt {i}: empty or non-1-D")
            if len(p) > self.max_len:
                raise ValueError(
                    f"prompt {i}: {len(p)} tokens overflows max_len "
                    f"{self.max_len}"
                )
        self._bump("submitted", n)
        groups: Dict[int, List[int]] = {}
        for i, p in enumerate(prompts):
            b = 8
            while b < len(p):
                b *= 2
            groups.setdefault(max(len(p), min(b, self.max_len)), []).append(i)
        parts = []      # (input positions, device (rows, d) slice)
        t0 = self._clock()
        for L in sorted(groups):
            idxs = groups[L]
            for s in range(0, len(idxs), self.B):
                chunk = idxs[s : s + self.B]
                # pad the row dimension to the full slot count so each
                # bucket compiles exactly one (B, L) shape
                toks = np.zeros((self.B, L), np.int32)
                lens = np.zeros((self.B,), np.int32)
                for r, gi in enumerate(chunk):
                    toks[r, : len(prompts[gi])] = prompts[gi]
                    lens[r] = len(prompts[gi])
                self._emit("prefill", None, embed=True, bucket=L,
                           rows=len(chunk))
                emb = self._embed_fn(
                    self.params,
                    {"tokens": jnp.asarray(toks)},
                    jnp.asarray(lens),
                )
                parts.append((chunk, emb[: len(chunk)]))
        host = jax.device_get([e for _, e in parts])  # ONE bulk transfer
        out = np.zeros((n, host[0].shape[-1]), np.float32)
        for (chunk, _), h in zip(parts, host):
            out[np.asarray(chunk, np.int64)] = h
        self._bump("completed", n)
        self._emit("finish", None, embed=True, embedded=n,
                   wall=self._clock() - t0)
        return out

    def _bucket(self, n: int) -> int:
        """Pad a prompt/chunk length to a power-of-2 bucket (min 8, capped
        at the longest prompt max_len admits) so prefill stops recompiling
        per unique length.  Never returns less than `n`: at the cap
        boundary (prompt exactly at max_len) the old min() could hand back
        a bucket SMALLER than the prompt and silently truncate it."""
        if not self.bucket_prompts:
            return n
        cap = max(self.max_len - self.n_front, 1)
        b = 8
        while b < n:
            b *= 2
        return max(n, min(b, cap))

    def _push_table(self) -> None:
        """Push the block table to the device cache, masking mid-prefill
        slots to the null page: the lockstep decode must neither read nor
        write their half-built pages (their writes land on page 0, which
        belongs to no sequence)."""
        tbl = self.alloc.table
        if self._prefilling:
            tbl = tbl.copy()
            tbl[self._prefilling, :] = NULL_PAGE
        self.cache["block_table"] = jnp.asarray(tbl)
        self._canon()

    def _canon(self) -> None:
        """Re-commit the cache to its canonical shardings after an eager
        (non-jitted) update — the mesh-pinned jits reject committed args
        whose placement drifted.  Identity for already-canonical leaves;
        only admission / release paths ever call it, never the
        steady-state decode loop."""
        if self.mesh is not None:
            self.cache = jax.device_put(self.cache, self._sh_cache)

    def _write_slot(self, slot: int, one_cache, pos: int) -> None:
        """Insert a batch-1 prefilled cache into slot `slot` (dense)."""

        def put(dst, src):
            # stacked leaves: (units, B, ...) — batch axis 1; scalar 'pos'
            # handled separately.
            if dst.ndim == src.ndim and dst.ndim >= 2 and src.shape[1] == 1:
                idx = (0, slot) + (0,) * (dst.ndim - 2)
                return jax.lax.dynamic_update_slice(dst, src.astype(dst.dtype), idx)
            return dst

        self.cache["layers"] = jax.tree.map(
            put, self.cache["layers"], one_cache["layers"]
        )
        self.cache["pos"] = self.cache["pos"].at[slot].set(pos)
        self._canon()

    def _write_slot_paged(self, slot: int, one_cache, pos: int,
                          pages: np.ndarray, n_tiles: int) -> None:
        """Scatter a batch-1 prefilled cache into `slot`'s pool pages."""
        ids = np.full((n_tiles,), NULL_PAGE, np.int32)
        ids[: min(n_tiles, len(pages))] = pages[:n_tiles]
        self.cache["layers"] = self._insert_paged(
            self.cache["layers"], one_cache["layers"], slot,
            jnp.asarray(ids),
        )
        self._push_table()
        self.cache["pos"] = self.cache["pos"].at[slot].set(pos)
        self._canon()

    # ------------------------------------------------- sampling plumbing
    def _set_slot_params(self, slot: int, req: Request) -> None:
        """Bind a request's sampling intent to its slot (host side: the
        stop machinery, deadline, admission recency).  The device-side
        per-slot vectors are written by ``_emit_first`` in one fused
        call — nothing reads them while the slot is inactive."""
        sp = effective_params(req)
        self.slot_sp[slot] = sp
        self.slot_stop[slot] = StopChecker(sp, req.eos_id)
        self.slot_deadline[slot] = self._abs_deadline(req)
        self._admit_order[slot] = self._admit_counter
        self._admit_counter += 1
        first_admission = req.t_admit == 0.0
        req.t_admit = self._clock()
        if self.metrics is not None and first_admission:
            # queue wait = time to FIRST admission; a preempted request's
            # re-admission is scheduler churn, not queueing delay
            self._h_queue.observe(req.t_admit - req.t_submit)

    def _abs_deadline(self, req: Request) -> Optional[float]:
        if req.deadline_ms is None:
            return None
        return req.t_submit + req.deadline_ms / 1e3

    def _nan_slots(self) -> List[int]:
        if self.faults is None:
            return []
        return [s for s in self.faults.nan_slots(self.steps)
                if 0 <= s < self.B]

    def _emit_first(self, slot: int, logits) -> None:
        """Sample the next generated token from prefill logits (on
        device, at the request's generation index — 0 for a fresh prompt,
        the replay cursor for a resumed one), bind the slot's device-side
        sampling state, record the token, and flip the slot to lockstep
        decoding (or finish immediately on stop/budget/poisoned
        logits)."""
        req = self.slot_req[slot]
        sp = self.slot_sp[slot]
        gen0 = len(req.output) if req.output else 0
        inject = slot in self._nan_slots()
        tok_d, logp_d, bad_d, self._samp, self._last_tok = self._admit_slot(
            self._samp, self._last_tok, logits, np.int32(slot),
            np.float32(sp.temperature), np.int32(sp.top_k),
            np.float32(sp.top_p), np.uint32(sp.seed & 0xFFFFFFFF),
            np.uint32(gen0), np.bool_(inject),
        )
        nxt, lp, bad = jax.device_get((tok_d, logp_d, bad_d))
        if bool(bad):
            # poisoned prefill logits: quarantine this slot only
            req.finish_reason = "error"
            self._emit("quarantine", req, slot=slot, where="prefill")
            self._finish(slot)
            return
        t0 = int(nxt[0])
        if gen0 == 0:
            req.output = [t0]
            req.logprobs = [float(lp[0])] if sp.logprobs else None
            req.t_first = self._clock()
            if self.metrics is not None:
                self._h_ttft.observe(req.t_first - req.t_submit)
            self._emit("decode", req, ts=req.t_first, slot=slot,
                       ttft_s=req.t_first - req.t_submit)
        else:
            # preempted request resuming: the replayed prefill re-derived
            # the logits its next token would have seen, and gen0 keys
            # the same PRNG draw — the token stream continues exactly
            self._bump("resumed")
            self._emit("resume", req, slot=slot, replayed_tokens=gen0)
            req.output.append(t0)
            if req.logprobs is not None:
                req.logprobs.append(float(lp[0]))
        if self.metrics is not None:
            self._c_toks.inc()
        self.slot_left[slot] = req.max_new - len(req.output)
        fin = self.slot_stop[slot].check(req.output, self.slot_left[slot])
        if fin:
            req.finish_reason = fin
            self._finish(slot)

    # ------------------------------------------------------- preemption
    def _replay_prompt(self, req: Request) -> np.ndarray:
        """The token sequence a (possibly preempted) request prefills:
        prompt + generated-so-far.  For a fresh request this is just the
        prompt; for a resumed one the generated tokens become prompt
        rows, so their KV is rebuilt and decoding continues from the
        exact position it was evicted at."""
        if req.output:
            return np.concatenate(
                [req.prompt, np.asarray(req.output, np.int32)]
            )
        return req.prompt

    def _requeue(self, req: Request) -> None:
        """Re-queue a preempted request in submit order among the entries
        BEHIND the blocked head (position 0): the head keeps the front —
        putting the older victim ahead of it would only re-admit the
        victim into the pages it just freed and spin forever."""
        i = len(self.queue)
        for j in range(1, len(self.queue)):
            if self.queue[j]._seq > req._seq:
                i = j
                break
        self.queue.insert(max(i, 1) if self.queue else 0, req)

    def _preempt_slot(self, slot: int) -> None:
        """Evict an in-flight decode: deactivate the slot, release its
        pages (exclusive ones free; prefix-registered ones park in the
        evictable set, still indexed — a resumed replay may hash-hit
        them), and re-queue the request.  No sampling state needs saving:
        the generation index IS the resume cursor, and the counter-hash
        PRNG replays the remaining tokens identically."""
        req = self.slot_req[slot]
        req.preempted += 1
        self._bump("preempted")
        self._emit("preempt", req, slot=slot,
                   generated_tokens=len(req.output or []))
        self.slot_req[slot] = None
        self.slot_left[slot] = 0
        self.slot_sp[slot] = None
        self.slot_stop[slot] = None
        self.slot_deadline[slot] = None
        self._samp, self.cache["pos"] = self._release_slot(
            self._samp, self.cache["pos"], np.int32(slot)
        )
        self.alloc.release(slot)
        self._push_table()
        self._requeue(req)

    def _preempt_for(self, head: Request, need: int, pp) -> bool:
        """Make room for the blocked queue head by evicting the newest
        in-flight decode(s); True iff the head fits afterwards.  Guards:

          * off unless ``preempt=True`` (head-of-line blocking stays the
            default behavior);
          * a once-preempted request neither triggers nor suffers
            preemption — every request is evicted at most once, so the
            preempt/requeue cycle terminates;
          * prechecked: victims' exclusively-held pages plus the free
            pool must cover the head's cost, so pages are never freed
            without an admission to consume them."""
        if not self.preempt or head.preempted:
            return False
        victims = [
            s for s in range(self.B)
            if self.slot_req[s] is not None
            and s not in self._prefill_state
            and self.slot_req[s].preempted == 0
        ]
        if not victims:
            return False
        plan = self.alloc.plan(need, pp)
        avail = self.alloc.free_pages + sum(
            self.alloc.releasable(s) for s in victims
        )
        if plan.cost > avail:
            return False
        victims.sort(key=lambda s: self._admit_order[s])
        while victims:
            if self.alloc.can_admit(need, self.alloc.plan(need, pp)):
                return True
            self._preempt_slot(victims.pop())   # newest-admitted first
        return self.alloc.can_admit(need, self.alloc.plan(need, pp))

    # ------------------------------------------------------------- admit
    def _admit(self) -> None:
        if self.faults is not None and self.faults.alloc_blocked(self.steps):
            return  # injected allocator outage: no admissions this step
        for slot in range(self.B):
            if self.slot_req[slot] is not None or not self.queue:
                continue
            req = self.queue[0]
            pp = self._replay_prompt(req)
            L = len(pp)
            # total budget is invariant under replay: prompt + max_new
            # (generated tokens move from budget to prompt rows)
            need = len(req.prompt) + self.n_front + req.max_new
            if self._incremental:
                plan = self.alloc.plan(need, pp)
                if not self.alloc.can_admit(need, plan):
                    if not self._preempt_for(req, need, pp):
                        break  # head-of-line blocking keeps FIFO order
                    plan = self.alloc.plan(need, pp)
                self.queue.pop(0)
                self.alloc.alloc(slot, need, plan)
                if self.alloc.last_cow is not None:
                    # the final page of a fully-cached prompt is shared:
                    # privatize it (copy-on-write) before the last-token
                    # recompute writes into it
                    src, dst = self.alloc.last_cow
                    self.cache["layers"] = self._copy(
                        self.cache["layers"],
                        jnp.asarray([src], jnp.int32),
                        jnp.asarray([dst], jnp.int32),
                    )
                self.slot_req[slot] = req
                self._set_slot_params(slot, req)
                self._emit("prefill", req, ts=req.t_admit, slot=slot,
                           prompt_tokens=L, cached_tokens=plan.cached_tokens)
                self._prefill_state[slot] = _Prefill(
                    req=req, prompt=pp, done=plan.cached_tokens
                )
                self._prefilling.append(slot)
                self._push_table()
                self._progress = True
                continue
            if self.alloc is not None and not self.alloc.can_admit(need):
                if not self._preempt_for(req, need, None):
                    # head-of-line blocking keeps FIFO order: wait for pages
                    break
            self.queue.pop(0)
            Sb = self._bucket(L)
            prompt = pp
            if Sb != L:
                prompt = np.zeros((Sb,), np.int32)
                prompt[:L] = pp
            batch = {"tokens": jnp.asarray(prompt[None, :], jnp.int32)}
            for k, v in self.extra.items():
                batch[k] = v
            Lx = L + self.n_front          # valid decoder-input tokens
            with annotate("engine/prefill", enabled=self.profile):
                logits, one_cache = self._prefill(self.params, batch, Lx)
            if self.alloc is not None:
                pages = self.alloc.alloc(slot, need)
                page = self.alloc.page_size
                n_tiles = pages_for(Sb + self.n_front, page)
                self._write_slot_paged(slot, one_cache, Lx, pages, n_tiles)
            else:
                self._write_slot(slot, one_cache, int(one_cache["pos"]))
            self.slot_req[slot] = req
            self._set_slot_params(slot, req)
            self._emit("prefill", req, ts=req.t_admit, slot=slot,
                       prompt_tokens=L, cached_tokens=0)
            self._progress = True
            self._emit_first(slot, logits)

    # ----------------------------------------------------- chunked prefill
    def _advance_prefill(self, slot: int) -> None:
        """Run ONE bounded prefill chunk for mid-prefill slot `slot`; on
        prompt completion emit the first token and flip the slot to
        decoding."""
        st = self._prefill_state[slot]
        L = len(st.prompt)
        remaining = L - st.done
        c = min(self.prefill_chunk or remaining, remaining)
        Cbuf = self._bucket(c)
        toks = np.zeros((1, Cbuf), np.int32)
        toks[0, :c] = st.prompt[st.done : st.done + c]
        logits, self.cache["layers"] = self._chunk(
            self.params, self.cache["layers"], jnp.asarray(toks),
            jnp.asarray(self.alloc.table[slot : slot + 1]),
            jnp.int32(st.done), jnp.int32(c),
        )
        st.done += c
        self._progress = True
        if st.done < L:
            return
        # prompt complete: register its full blocks for future sharing,
        # make the slot's pages visible to the lockstep decode, emit the
        # first generated token (sampled on device — no argmax roundtrip)
        self.alloc.register(slot, st.prompt)
        self._prefilling.remove(slot)
        del self._prefill_state[slot]
        self._push_table()
        self.cache["pos"] = self.cache["pos"].at[slot].set(L)
        self._canon()
        self._emit_first(slot, logits)

    def cancel(self, req: Request) -> None:
        """Abort a queued or in-flight request, releasing its slot/pages
        immediately (``finish_reason="cancelled"``; the request still
        lands in ``done`` with whatever tokens it produced).  Used by the
        LLM facade when a stream consumer abandons its iterator — an
        orphaned request must not keep decoding into other calls."""
        # identity, not ==: the dataclass __eq__ tuple-compares the numpy
        # prompt field, which raises on same-shape prompts
        for i, q in enumerate(self.queue):
            if q is req:
                del self.queue[i]
                req.finish_reason = "cancelled"
                req.t_done = self._clock()
                self._bump("cancelled")
                self._emit("finish", req, ts=req.t_done,
                           reason="cancelled", tokens=len(req.output or []))
                self.done.append(req)
                return
        for slot in range(self.B):
            if self.slot_req[slot] is req:
                if slot in self._prefill_state:
                    del self._prefill_state[slot]
                    self._prefilling.remove(slot)
                req.finish_reason = "cancelled"
                self._finish(slot)
                return

    def _finish(self, slot: int) -> None:
        req = self.slot_req[slot]
        if not req.finish_reason:
            req.finish_reason = "length"
        reason = req.finish_reason
        if reason == "timeout":
            self._bump("timeouts")
        elif reason == "error":
            self._bump("errors")
        elif reason == "cancelled":
            self._bump("cancelled")
        else:
            self._bump("completed")
        req.t_done = self._clock()
        n_out = len(req.output or [])
        if self.metrics is not None:
            self._h_e2e.observe(req.t_done - req.t_submit)
            if req.t_first and n_out >= 2:
                self._h_itl.observe(
                    (req.t_done - req.t_first) / (n_out - 1)
                )
        self._emit("finish", req, ts=req.t_done, slot=slot,
                   reason=reason, tokens=n_out)
        self.done.append(req)
        self.slot_req[slot] = None
        self.slot_left[slot] = 0
        self.slot_sp[slot] = None
        self.slot_stop[slot] = None
        self.slot_deadline[slot] = None
        # one fused call: deactivate + reset pos so the slot comes back
        # with clean semantics immediately (the in-jit reset only covers
        # slots idle during a decode step)
        self._samp, self.cache["pos"] = self._release_slot(
            self._samp, self.cache["pos"], np.int32(slot)
        )
        if self.alloc is not None:
            self.alloc.release(slot)
            self._push_table()

    # ---------------------------------------------------------- deadlines
    def _expire_queued(self) -> None:
        """Finish queued requests whose deadline passed before they ever
        ran (``finish_reason="timeout"``).  A preempted request waiting to
        resume keeps its partial output."""
        if not self.queue:
            return
        now = self._clock()
        kept: List[Request] = []
        for req in self.queue:
            dl = self._abs_deadline(req)
            if dl is not None and now >= dl:
                req.finish_reason = "timeout"
                req.t_done = now
                self._bump("timeouts")
                self._emit("timeout", req, ts=now, where="queue")
                self._emit("finish", req, ts=now, reason="timeout", tokens=0)
                self.done.append(req)
            else:
                kept.append(req)
        self.queue = kept

    def _expire_in_flight(self) -> None:
        """Release in-flight requests past deadline at the step boundary
        (they keep the tokens produced so far)."""
        if all(d is None for d in self.slot_deadline):
            return
        now = self._clock()
        for s in range(self.B):
            dl = self.slot_deadline[s]
            if dl is None or self.slot_req[s] is None or now < dl:
                continue
            if s in self._prefill_state:
                del self._prefill_state[s]
                self._prefilling.remove(s)
                if self.alloc is not None:
                    # _push_table in _finish re-derives the mask
                    pass
            self.slot_req[s].finish_reason = "timeout"
            self._emit("timeout", self.slot_req[s], ts=now, where="in_flight",
                       slot=s)
            self._finish(s)

    # --------------------------------------------------------------- step
    def step(self) -> int:
        """Admit + bounded prefill chunks + one decode iteration over all
        decoding slots.  Returns the number of slots decoded.

        With in-flight decodes, only the longest-waiting mid-prefill slot
        advances — by ONE chunk — per step, so a long prompt delays each
        decode iteration by at most `prefill_chunk` tokens of compute.
        With no decodes to protect, every mid-prefill slot advances a
        chunk (there is nothing to stall, and admission ramps faster).

        Lifecycle order: queued deadline expiry -> admission (possibly
        preempting) -> prefill chunks -> lockstep decode + quarantine ->
        in-flight deadline expiry (the "next step boundary" of the
        deadline contract) -> watchdog accounting."""
        self.steps += 1
        self._progress = False
        done0 = len(self.done)
        self._expire_queued()
        self._admit()
        if self._prefilling:
            decoding = any(
                self.slot_req[s] is not None and s not in self._prefill_state
                for s in range(self.B)
            )
            for slot in (self._prefilling[:1] if decoding
                         else list(self._prefilling)):
                self._advance_prefill(slot)
        active = [
            s for s in range(self.B)
            if self.slot_req[s] is not None and s not in self._prefill_state
        ]
        if active:
            # token-in/token-out: selection (and the idle-slot pos reset)
            # happens inside the jitted step; the sampled tokens feed the
            # next iteration straight from device memory, and the ONLY
            # host traffic is this one bulk device_get per step
            inject = self._no_inject
            bad_slots = self._nan_slots()
            if bad_slots:
                v = np.zeros((self.B,), bool)
                v[bad_slots] = True
                inject = jnp.asarray(v)
            if self.step_timer is not None:
                with self.step_timer.span("decode"), \
                        annotate("engine/decode", enabled=True):
                    tok_d, logp_d, bad_d, self.cache, self._samp = \
                        self._decode(self.params, self.cache,
                                     self._last_tok, self._samp, inject)
                with self.step_timer.span("host_sync"):
                    self._last_tok = tok_d
                    nxt, logps, bads = jax.device_get((tok_d, logp_d, bad_d))
            else:
                tok_d, logp_d, bad_d, self.cache, self._samp = self._decode(
                    self.params, self.cache, self._last_tok, self._samp,
                    inject
                )
                self._last_tok = tok_d
                nxt, logps, bads = jax.device_get((tok_d, logp_d, bad_d))
            emitted = 0
            for s in active:
                req = self.slot_req[s]
                if bads[s]:
                    # non-finite logits in THIS slot only: quarantine it
                    # (drop the garbage token) and leave every other
                    # slot's sampled token untouched
                    req.finish_reason = "error"
                    self._emit("quarantine", req, slot=s, where="decode")
                    self._finish(s)
                    continue
                t = int(nxt[s])
                req.output.append(t)
                emitted += 1
                if req.logprobs is not None:
                    req.logprobs.append(float(logps[s]))
                self.slot_left[s] -= 1
                fin = self.slot_stop[s].check(req.output, self.slot_left[s])
                if fin:
                    req.finish_reason = fin
                    self._finish(s)
            if self.metrics is not None and emitted:
                self._c_toks.inc(emitted)
        self._expire_in_flight()
        if active or self._progress or len(self.done) != done0:
            self._steps_since_progress = 0
        else:
            self._steps_since_progress += 1
        if self.metrics is not None:
            self._c_steps.inc()
            self._observe_gauges()
        if self.on_step is not None:
            self.on_step(self)
        return len(active)

    # -------------------------------------------------------------- health
    def health(self) -> EngineHealth:
        """Cheap host-side liveness snapshot (no device sync)."""
        return EngineHealth(
            queue_depth=len(self.queue),
            slots=self.B,
            active_slots=sum(r is not None for r in self.slot_req),
            prefilling=len(self._prefilling),
            free_pages=self.alloc.free_pages if self.alloc else None,
            total_pages=(self.alloc.num_pages - 1) if self.alloc else None,
            steps=self.steps,
            steps_since_progress=self._steps_since_progress,
            counters=dict(self.counters),
        )

    def run(self, max_steps: int = 10_000) -> List[Request]:
        steps = 0
        while (self.queue or any(r is not None for r in self.slot_req)) \
                and steps < max_steps:
            self.step()
            steps += 1
        return self.done
