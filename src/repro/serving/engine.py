"""Continuous-batching serving engine (slot-based, iteration-level).

BioNeMo's serving story (NIM) is request-level batching; this engine
implements the standard slot scheduler on top of the framework's
per-slot-position decode path:

  * a fixed pool of B slots shares one preallocated KV cache
    (``Model.init_cache`` with a (B,) position vector);
  * an admitted request is prefilled alone (batch-1) and its cache is
    inserted into its slot — decoding of other slots is never paused for
    padding;
  * every engine step decodes ALL active slots in lockstep hardware-wise
    but with independent positions; finished slots (eos / max tokens) are
    released and refilled from the queue immediately.

Two cache layouts:

``cache_layout="dense"``
    One (B, max_len) KV buffer per layer; the per-slot decode write is a
    masked O(B·max_len) select.  Simple, always available.

``cache_layout="paged"`` — the production path
    Fixed-size pages of a shared pool, mapped per slot by a block table
    (``paged_cache.PageAllocator``).  Admission reserves the request's
    full budget (prompt + max_new) — capacity-aware: a request that does
    not fit waits in the queue, one that can never fit is rejected at
    submit.  Release returns pages to the free list for immediate reuse.
    The decode write is an O(B·page) Pallas scatter and attention reads
    K/V through the block table (``kernels/paged_attention.py``).

Prefix caching + chunked prefill (paged layout only):

``prefix_cache=True``
    Admission hashes the prompt's full blocks against the allocator's
    content-addressed page index.  Hash-hit blocks are *shared* — their
    pages are mapped into the new slot (refcounted) and prefill skips
    them entirely, running only over the suffix.  After a prompt
    finishes prefilling, its full blocks are registered for future
    sharing; a shared page is never written (copy-on-write privatizes
    the final page when a fully-cached prompt recomputes its last token
    for logits).

``prefill_chunk=N``
    Prompts prefill in bounded chunks of at most N tokens, one chunk per
    engine step, interleaved with decode iterations — a long prompt can
    no longer stall in-flight decodes for its whole length.  ``N=0``
    with ``prefix_cache=True`` prefills the (possibly shortened) suffix
    in one chunk.  Mid-prefill slots are invisible to the lockstep
    decode: their block-table rows are masked to the null page in the
    device copy, so concurrent decode writes touch no live data.

Both features need right-paddable causal attention-only stacks (the same
condition as prompt bucketing) and are rejected otherwise.

Prompt bucketing: prompts are right-padded to power-of-2 buckets so the
jitted prefill compiles once per bucket instead of once per unique prompt
length.  Sound only for causal attention-only stacks (pad rows sit in the
future of every real row; SSM state would carry pad garbage), so it is
auto-disabled elsewhere.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model
from repro.serving.paged_cache import (
    NULL_PAGE,
    PageAllocator,
    copy_pages,
    pages_for,
    write_slot_paged,
)


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray           # (S,) int32
    max_new: int = 32
    eos_id: int = -1             # -1: never stops early
    # filled by the engine:
    output: Optional[List[int]] = None
    t_submit: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0


@dataclasses.dataclass
class _Prefill:
    """A slot mid-way through an incremental (chunked/suffix) prefill."""

    req: Request
    prompt: np.ndarray           # original, unpadded prompt
    done: int                    # tokens whose KV is already in the pages


class Engine:
    def __init__(self, model: Model, params, *, slots: int, max_len: int,
                 extra_batch: Optional[Dict[str, Any]] = None,
                 cache_layout: str = "dense", page_size: int = 16,
                 num_pages: int = 0, bucket_prompts: Optional[bool] = None,
                 prefix_cache: bool = False, prefill_chunk: int = 0):
        self.model = model
        self.params = params
        self.B = slots
        self.max_len = max_len
        self.extra = extra_batch or {}
        cfg = model.cfg
        self.layout = cache_layout
        # frontend rows are prepended only when the batch actually carries
        # img_embeds (_decoder_input); a vision model served text-only has
        # no frontend rows in its prefill
        self.n_front = (
            cfg.num_frontend_tokens
            if cfg.frontend == "vision_stub" and "img_embeds" in self.extra
            else 0
        )
        cross = cfg.num_frontend_tokens if cfg.is_encoder_decoder else 0

        # right-padding (prompt buckets, chunk buckets, prefix skips) is
        # only sound when pad rows stay in every real row's future: causal
        # attention, no SSM state carry, no rolling (sliding-window) cache
        has_ssm = any(not cfg.is_attn_layer(i) for i in range(cfg.num_layers))
        paddable = cfg.causal and not has_ssm and not cfg.sliding_window

        self.prefix_cache = prefix_cache
        self.prefill_chunk = prefill_chunk
        self._incremental = prefix_cache or prefill_chunk > 0
        if self._incremental:
            if cache_layout != "paged":
                raise ValueError(
                    "prefix_cache / prefill_chunk require cache_layout='paged'"
                )
            if not paddable or cfg.is_encoder_decoder or self.n_front:
                raise ValueError(
                    "prefix_cache / prefill_chunk require a causal "
                    "attention-only decoder with no frontend rows"
                )

        if cache_layout == "paged":
            # default pool: every slot can hold a full max_len sequence,
            # +1 for the reserved null page — admission then only queues
            # on slot pressure, like the dense layout.
            pages_per_seq = pages_for(max_len, page_size)
            num_pages = num_pages or 1 + slots * pages_per_seq
            self.alloc = PageAllocator(
                num_pages, page_size, slots, max_len,
                prefix_cache=prefix_cache,
            )
            cache = model.init_cache(
                slots, max_len, cross_len=cross,
                layout="paged", page_size=page_size, num_pages=num_pages,
            )
        elif cache_layout == "dense":
            self.alloc = None
            cache = model.init_cache(slots, max_len, cross_len=cross)
        else:
            raise ValueError(f"unknown cache_layout {cache_layout!r}")
        cache["pos"] = jnp.zeros((slots,), jnp.int32)
        self.cache = cache
        self.slot_req: List[Optional[Request]] = [None] * slots
        self.slot_last: np.ndarray = np.zeros((slots,), np.int32)
        self.slot_left: np.ndarray = np.zeros((slots,), np.int32)
        self.queue: List[Request] = []
        self.done: List[Request] = []
        # slots mid-prefill, in admission order (FIFO chunk scheduling)
        self._prefilling: List[int] = []
        self._prefill_state: Dict[int, _Prefill] = {}

        if bucket_prompts is None:
            bucket_prompts = paddable
        self.bucket_prompts = bucket_prompts

        self._prefill = jax.jit(
            lambda p, b, L: model.prefill(p, b, max_len, length=L)
        )
        # the engine cache is serving steady state: donate it so XLA
        # updates pools/buffers in place instead of copying the whole
        # cache every decode step / prefill chunk / page insert (each
        # call consumes self.cache[...] and the engine reassigns it)
        self._decode = jax.jit(model.decode_step, donate_argnums=(1,))
        self._insert_paged = jax.jit(write_slot_paged, donate_argnums=(0,))
        self._chunk = jax.jit(model.prefill_chunk, donate_argnums=(1,))
        self._copy = jax.jit(copy_pages, donate_argnums=(0,))

    # -------------------------------------------------------------- admin
    def submit(self, req: Request) -> None:
        if req.max_new < 1:
            raise ValueError(
                f"request {req.uid}: max_new must be >= 1 (got {req.max_new})"
            )
        if len(req.prompt) == 0 and self.n_front == 0:
            raise ValueError(
                f"request {req.uid}: empty prompt — a causal LM has no "
                f"token to condition the first logits on"
            )
        need = len(req.prompt) + self.n_front + req.max_new
        if need > self.max_len:
            raise ValueError(
                f"request {req.uid}: prompt+max_new = {need} tokens "
                f"overflows max_len {self.max_len}"
            )
        if self.alloc is not None and not self.alloc.fits_slot(need):
            raise ValueError(
                f"request {req.uid}: {need} tokens can never fit the page "
                f"pool ({self.alloc.num_pages - 1} usable pages of "
                f"{self.alloc.page_size})"
            )
        req.t_submit = time.time()
        self.queue.append(req)

    def _bucket(self, n: int) -> int:
        """Pad a prompt/chunk length to a power-of-2 bucket (min 8, capped
        at the longest prompt max_len admits) so prefill stops recompiling
        per unique length.  Never returns less than `n`: at the cap
        boundary (prompt exactly at max_len) the old min() could hand back
        a bucket SMALLER than the prompt and silently truncate it."""
        if not self.bucket_prompts:
            return n
        cap = max(self.max_len - self.n_front, 1)
        b = 8
        while b < n:
            b *= 2
        return max(n, min(b, cap))

    def _push_table(self) -> None:
        """Push the block table to the device cache, masking mid-prefill
        slots to the null page: the lockstep decode must neither read nor
        write their half-built pages (their writes land on page 0, which
        belongs to no sequence)."""
        tbl = self.alloc.table
        if self._prefilling:
            tbl = tbl.copy()
            tbl[self._prefilling, :] = NULL_PAGE
        self.cache["block_table"] = jnp.asarray(tbl)

    def _write_slot(self, slot: int, one_cache, pos: int) -> None:
        """Insert a batch-1 prefilled cache into slot `slot` (dense)."""

        def put(dst, src):
            # stacked leaves: (units, B, ...) — batch axis 1; scalar 'pos'
            # handled separately.
            if dst.ndim == src.ndim and dst.ndim >= 2 and src.shape[1] == 1:
                idx = (0, slot) + (0,) * (dst.ndim - 2)
                return jax.lax.dynamic_update_slice(dst, src.astype(dst.dtype), idx)
            return dst

        self.cache["layers"] = jax.tree.map(
            put, self.cache["layers"], one_cache["layers"]
        )
        self.cache["pos"] = self.cache["pos"].at[slot].set(pos)

    def _write_slot_paged(self, slot: int, one_cache, pos: int,
                          pages: np.ndarray, n_tiles: int) -> None:
        """Scatter a batch-1 prefilled cache into `slot`'s pool pages."""
        ids = np.full((n_tiles,), NULL_PAGE, np.int32)
        ids[: min(n_tiles, len(pages))] = pages[:n_tiles]
        self.cache["layers"] = self._insert_paged(
            self.cache["layers"], one_cache["layers"], slot,
            jnp.asarray(ids),
        )
        self._push_table()
        self.cache["pos"] = self.cache["pos"].at[slot].set(pos)

    def _admit(self) -> None:
        for slot in range(self.B):
            if self.slot_req[slot] is not None or not self.queue:
                continue
            req = self.queue[0]
            L = len(req.prompt)
            need = L + self.n_front + req.max_new
            if self._incremental:
                plan = self.alloc.plan(need, req.prompt)
                if not self.alloc.can_admit(need, plan):
                    break  # head-of-line blocking keeps FIFO order
                self.queue.pop(0)
                self.alloc.alloc(slot, need, plan)
                if self.alloc.last_cow is not None:
                    # the final page of a fully-cached prompt is shared:
                    # privatize it (copy-on-write) before the last-token
                    # recompute writes into it
                    src, dst = self.alloc.last_cow
                    self.cache["layers"] = self._copy(
                        self.cache["layers"],
                        jnp.asarray([src], jnp.int32),
                        jnp.asarray([dst], jnp.int32),
                    )
                self.slot_req[slot] = req
                self._prefill_state[slot] = _Prefill(
                    req=req, prompt=req.prompt, done=plan.cached_tokens
                )
                self._prefilling.append(slot)
                self._push_table()
                continue
            if self.alloc is not None and not self.alloc.can_admit(need):
                # head-of-line blocking keeps FIFO order: wait for pages
                break
            self.queue.pop(0)
            Sb = self._bucket(L)
            prompt = req.prompt
            if Sb != L:
                prompt = np.zeros((Sb,), np.int32)
                prompt[:L] = req.prompt
            batch = {"tokens": jnp.asarray(prompt[None, :], jnp.int32)}
            for k, v in self.extra.items():
                batch[k] = v
            Lx = L + self.n_front          # valid decoder-input tokens
            logits, one_cache = self._prefill(self.params, batch, Lx)
            nxt = int(jnp.argmax(logits[0, -1]))
            if self.alloc is not None:
                pages = self.alloc.alloc(slot, need)
                page = self.alloc.page_size
                n_tiles = pages_for(Sb + self.n_front, page)
                self._write_slot_paged(slot, one_cache, Lx, pages, n_tiles)
            else:
                self._write_slot(slot, one_cache, int(one_cache["pos"]))
            req.output = [nxt]
            req.t_first = time.time()
            self.slot_req[slot] = req
            self.slot_last[slot] = nxt
            self.slot_left[slot] = req.max_new - 1
            if nxt == req.eos_id or req.max_new <= 1:
                self._finish(slot)

    # ----------------------------------------------------- chunked prefill
    def _advance_prefill(self, slot: int) -> None:
        """Run ONE bounded prefill chunk for mid-prefill slot `slot`; on
        prompt completion emit the first token and flip the slot to
        decoding."""
        st = self._prefill_state[slot]
        L = len(st.prompt)
        remaining = L - st.done
        c = min(self.prefill_chunk or remaining, remaining)
        Cbuf = self._bucket(c)
        toks = np.zeros((1, Cbuf), np.int32)
        toks[0, :c] = st.prompt[st.done : st.done + c]
        logits, self.cache["layers"] = self._chunk(
            self.params, self.cache["layers"], jnp.asarray(toks),
            jnp.asarray(self.alloc.table[slot : slot + 1]),
            jnp.int32(st.done), jnp.int32(c),
        )
        st.done += c
        if st.done < L:
            return
        # prompt complete: register its full blocks for future sharing,
        # make the slot's pages visible to the lockstep decode, emit the
        # first generated token
        req = st.req
        self.alloc.register(slot, st.prompt)
        self._prefilling.remove(slot)
        del self._prefill_state[slot]
        self._push_table()
        self.cache["pos"] = self.cache["pos"].at[slot].set(L)
        nxt = int(jnp.argmax(logits[0, -1]))
        req.output = [nxt]
        req.t_first = time.time()
        self.slot_last[slot] = nxt
        self.slot_left[slot] = req.max_new - 1
        if nxt == req.eos_id or req.max_new <= 1:
            self._finish(slot)

    def _finish(self, slot: int) -> None:
        req = self.slot_req[slot]
        req.t_done = time.time()
        self.done.append(req)
        self.slot_req[slot] = None
        self.slot_left[slot] = 0
        if self.alloc is not None:
            self.alloc.release(slot)
            self._push_table()

    # --------------------------------------------------------------- step
    def step(self) -> int:
        """Admit + bounded prefill chunks + one decode iteration over all
        decoding slots.  Returns the number of slots decoded.

        With in-flight decodes, only the longest-waiting mid-prefill slot
        advances — by ONE chunk — per step, so a long prompt delays each
        decode iteration by at most `prefill_chunk` tokens of compute.
        With no decodes to protect, every mid-prefill slot advances a
        chunk (there is nothing to stall, and admission ramps faster)."""
        self._admit()
        if self._prefilling:
            decoding = any(
                self.slot_req[s] is not None and s not in self._prefill_state
                for s in range(self.B)
            )
            for slot in (self._prefilling[:1] if decoding
                         else list(self._prefilling)):
                self._advance_prefill(slot)
        active = [
            s for s in range(self.B)
            if self.slot_req[s] is not None and s not in self._prefill_state
        ]
        if active:
            tokens = jnp.asarray(self.slot_last[:, None], jnp.int32)
            logits, self.cache = self._decode(self.params, self.cache, tokens)
            nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1), np.int32)
            for s in active:
                req = self.slot_req[s]
                req.output.append(int(nxt[s]))
                self.slot_last[s] = nxt[s]
                self.slot_left[s] -= 1
                if int(nxt[s]) == req.eos_id or self.slot_left[s] <= 0:
                    self._finish(s)
        # slots without a decoding request also stepped (lockstep hardware
        # batch): their positions advanced harmlessly — reset them to 0 so
        # a stale slot is re-admitted with clean pos semantics (paged:
        # their writes all land on the null page; mid-prefill slots are
        # masked out of the device block table entirely)
        idle = [
            s for s in range(self.B)
            if self.slot_req[s] is None or s in self._prefill_state
        ]
        if idle and active:
            pos = np.array(self.cache["pos"])  # copy (device arrays are RO)
            pos[idle] = 0
            self.cache["pos"] = jnp.asarray(pos)
        return len(active)

    def run(self, max_steps: int = 10_000) -> List[Request]:
        steps = 0
        while (self.queue or any(r is not None for r in self.slot_req)) \
                and steps < max_steps:
            self.step()
            steps += 1
        return self.done
