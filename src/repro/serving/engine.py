"""Continuous-batching serving engine (slot-based, iteration-level).

BioNeMo's serving story (NIM) is request-level batching; this engine
implements the standard slot scheduler on top of the framework's
per-slot-position decode path:

  * a fixed pool of B slots shares one preallocated KV cache
    (``Model.init_cache`` with a (B,) position vector);
  * an admitted request is prefilled alone (batch-1) and its cache is
    inserted into its slot — decoding of other slots is never paused for
    padding;
  * every engine step decodes ALL active slots in lockstep hardware-wise
    but with independent positions; finished slots (eos / max tokens) are
    released and refilled from the queue immediately.

Two cache layouts:

``cache_layout="dense"``
    One (B, max_len) KV buffer per layer; the per-slot decode write is a
    masked O(B·max_len) select.  Simple, always available.

``cache_layout="paged"`` — the production path
    Fixed-size pages of a shared pool, mapped per slot by a block table
    (``paged_cache.PageAllocator``).  Admission reserves the request's
    full budget (prompt + max_new) — capacity-aware: a request that does
    not fit waits in the queue, one that can never fit is rejected at
    submit.  Release returns pages to the free list for immediate reuse.
    The decode write is an O(B·page) Pallas scatter and attention reads
    K/V through the block table (``kernels/paged_attention.py``).

Prefix caching + chunked prefill (paged layout only):

``prefix_cache=True``
    Admission hashes the prompt's full blocks against the allocator's
    content-addressed page index.  Hash-hit blocks are *shared* — their
    pages are mapped into the new slot (refcounted) and prefill skips
    them entirely, running only over the suffix.  After a prompt
    finishes prefilling, its full blocks are registered for future
    sharing; a shared page is never written (copy-on-write privatizes
    the final page when a fully-cached prompt recomputes its last token
    for logits).

``prefill_chunk=N``
    Prompts prefill in bounded chunks of at most N tokens, one chunk per
    engine step, interleaved with decode iterations — a long prompt can
    no longer stall in-flight decodes for its whole length.  ``N=0``
    with ``prefix_cache=True`` prefills the (possibly shortened) suffix
    in one chunk.  Mid-prefill slots are invisible to the lockstep
    decode: their block-table rows are masked to the null page in the
    device copy, so concurrent decode writes touch no live data.

Both features need right-paddable causal attention-only stacks (the same
condition as prompt bucketing) and are rejected otherwise.

Prompt bucketing: prompts are right-padded to power-of-2 buckets so the
jitted prefill compiles once per bucket instead of once per unique prompt
length.  Sound only for causal attention-only stacks (pad rows sit in the
future of every real row; SSM state would carry pad garbage), so it is
auto-disabled elsewhere.

Generation API v2 (per-request sampling, on-device selection):

Every request may carry a ``SamplingParams`` (``serving/sampling.py``) —
temperature / top-k / top-p / seed / stop tokens / stop sequences /
logprobs — and the numeric fields live on device as per-slot vectors.
Token *selection* happens inside the jitted decode step
(``ops.sample_tokens``: fused per-slot filter + categorical, greedy rows
degrade to argmax), so the steady-state decode loop is token-in /
token-out: the previous step's sampled tokens feed the next step without
ever visiting the host, and the only host traffic per step is ONE bulk
``jax.device_get`` of the sampled (tokens, logprobs) pair for
bookkeeping and stop checks.  A request without params decodes greedily
with its legacy ``max_new``/``eos_id`` fields — old ``Engine(...)`` call
sites keep working unchanged; ``serving/api.py::LLM`` is the v2 facade.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model
from repro.kernels import ops
from repro.serving.paged_cache import (
    NULL_PAGE,
    PageAllocator,
    copy_pages,
    pages_for,
    write_slot_paged,
)
from repro.serving.sampling import SamplingParams, StopChecker, effective_params


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray           # (S,) int32
    max_new: int = 32
    eos_id: int = -1             # -1: never stops early
    # v2 sampling intent; None = legacy greedy decode with max_new/eos_id.
    # When set, a non-None params.max_new takes precedence (normalized at
    # submit; params.max_new=None inherits the field above) and
    # eos_id >= 0 folds into the stop-token set.
    params: Optional[SamplingParams] = None
    # filled by the engine:
    output: Optional[List[int]] = None
    logprobs: Optional[List[float]] = None   # per-token, if params.logprobs
    finish_reason: str = ""                  # "stop" | "length" once done
    t_submit: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0


@dataclasses.dataclass
class _Prefill:
    """A slot mid-way through an incremental (chunked/suffix) prefill."""

    req: Request
    prompt: np.ndarray           # original, unpadded prompt
    done: int                    # tokens whose KV is already in the pages


class Engine:
    def __init__(self, model: Model, params, *, slots: int, max_len: int,
                 extra_batch: Optional[Dict[str, Any]] = None,
                 cache_layout: str = "dense", page_size: int = 16,
                 num_pages: int = 0, bucket_prompts: Optional[bool] = None,
                 prefix_cache: bool = False, prefill_chunk: int = 0):
        self.model = model
        self.params = params
        self.B = slots
        self.max_len = max_len
        self.extra = extra_batch or {}
        cfg = model.cfg
        self.layout = cache_layout
        # frontend rows are prepended only when the batch actually carries
        # img_embeds (_decoder_input); a vision model served text-only has
        # no frontend rows in its prefill
        self.n_front = (
            cfg.num_frontend_tokens
            if cfg.frontend == "vision_stub" and "img_embeds" in self.extra
            else 0
        )
        cross = cfg.num_frontend_tokens if cfg.is_encoder_decoder else 0

        # right-padding (prompt buckets, chunk buckets, prefix skips) is
        # only sound when pad rows stay in every real row's future: causal
        # attention, no SSM state carry, no rolling (sliding-window) cache
        has_ssm = any(not cfg.is_attn_layer(i) for i in range(cfg.num_layers))
        paddable = cfg.causal and not has_ssm and not cfg.sliding_window

        self.prefix_cache = prefix_cache
        self.prefill_chunk = prefill_chunk
        self._incremental = prefix_cache or prefill_chunk > 0
        if self._incremental:
            if cache_layout != "paged":
                raise ValueError(
                    "prefix_cache / prefill_chunk require cache_layout='paged'"
                )
            if not paddable or cfg.is_encoder_decoder or self.n_front:
                raise ValueError(
                    "prefix_cache / prefill_chunk require a causal "
                    "attention-only decoder with no frontend rows"
                )

        if cache_layout == "paged":
            # default pool: every slot can hold a full max_len sequence,
            # +1 for the reserved null page — admission then only queues
            # on slot pressure, like the dense layout.
            pages_per_seq = pages_for(max_len, page_size)
            num_pages = num_pages or 1 + slots * pages_per_seq
            self.alloc = PageAllocator(
                num_pages, page_size, slots, max_len,
                prefix_cache=prefix_cache,
            )
            cache = model.init_cache(
                slots, max_len, cross_len=cross,
                layout="paged", page_size=page_size, num_pages=num_pages,
            )
        elif cache_layout == "dense":
            self.alloc = None
            cache = model.init_cache(slots, max_len, cross_len=cross)
        else:
            raise ValueError(f"unknown cache_layout {cache_layout!r}")
        cache["pos"] = jnp.zeros((slots,), jnp.int32)
        self.cache = cache
        self.slot_req: List[Optional[Request]] = [None] * slots
        self.slot_left: np.ndarray = np.zeros((slots,), np.int32)
        self.queue: List[Request] = []
        self.done: List[Request] = []
        # slots mid-prefill, in admission order (FIFO chunk scheduling)
        self._prefilling: List[int] = []
        self._prefill_state: Dict[int, _Prefill] = {}

        # per-slot sampling state.  The numeric params live on DEVICE
        # ((B,) vectors consumed by the fused sampler inside the jitted
        # decode step); the stop machinery is host-side per slot.
        # ``gen`` is each slot's generation index (tokens emitted so
        # far) — it keys the counter-based PRNG stream, so a fixed-seed
        # request reproduces its tokens in any batch composition.
        self.slot_sp: List[Optional[SamplingParams]] = [None] * slots
        self.slot_stop: List[Optional[StopChecker]] = [None] * slots
        self._samp: Dict[str, jax.Array] = {
            "temp": jnp.zeros((slots,), jnp.float32),
            "top_k": jnp.zeros((slots,), jnp.int32),
            "top_p": jnp.ones((slots,), jnp.float32),
            "seed": jnp.zeros((slots,), jnp.uint32),
            "gen": jnp.zeros((slots,), jnp.int32),
            "active": jnp.zeros((slots,), bool),
        }
        # token-in/token-out: the last sampled token per slot stays on
        # device and feeds the next decode step directly
        self._last_tok = jnp.zeros((slots,), jnp.int32)

        if bucket_prompts is None:
            bucket_prompts = paddable
        self.bucket_prompts = bucket_prompts

        impl = cfg.kernel_impl

        def _fused_step(params, cache, tok, samp):
            """One decode iteration with ON-DEVICE token selection.

            Everything the old loop did on the host — argmax, idle-slot
            pos reset, next-token feedback — happens inside this one
            jitted call: the engine only transfers the sampled (tok,
            logp) pair back, once, per step."""
            logits, cache = model.decode_step(params, cache, tok[:, None])
            # idle / mid-prefill slots stepped in lockstep: reset their
            # positions (their writes touched no live data)
            cache["pos"] = jnp.where(samp["active"], cache["pos"], 0)
            # idle slots read as greedy (temp 0) no matter what request
            # last held them — otherwise one retired sampled request
            # would defeat the sampler's all-greedy fast path for every
            # later greedy-only step
            nxt, logp = ops.sample_tokens(
                logits[:, -1],
                jnp.where(samp["active"], samp["temp"], 0.0),
                samp["top_k"], samp["top_p"],
                samp["seed"], samp["gen"], impl=impl,
            )
            nxt = jnp.where(samp["active"], nxt, 0)
            samp = dict(samp, gen=samp["gen"] + samp["active"].astype(jnp.int32))
            return nxt, logp, cache, samp

        def _admit_slot(samp, last_tok, logits, slot, temp, k, p, seed):
            """Sample a request's FIRST token from its prefill logits and
            bind every per-slot device field in one jitted call —
            admission costs one dispatch + one device_get instead of a
            string of eager .at[].set updates (which showed up directly
            in shared-prefix TTFT)."""
            tok, logp = ops.sample_tokens(
                logits[:, -1], temp[None], k[None], p[None], seed[None],
                jnp.zeros((1,), jnp.uint32), impl=impl,
            )
            samp = dict(
                samp,
                temp=samp["temp"].at[slot].set(temp),
                top_k=samp["top_k"].at[slot].set(k),
                top_p=samp["top_p"].at[slot].set(p),
                seed=samp["seed"].at[slot].set(seed),
                gen=samp["gen"].at[slot].set(1),
                active=samp["active"].at[slot].set(True),
            )
            return tok, logp, samp, last_tok.at[slot].set(tok[0])

        def _release_slot(samp, pos, slot):
            """Deactivate a finished slot and reset its pos (one call)."""
            return (
                dict(samp, active=samp["active"].at[slot].set(False)),
                pos.at[slot].set(0),
            )

        self._prefill = jax.jit(
            lambda p, b, L: model.prefill(p, b, max_len, length=L)
        )
        # the engine cache is serving steady state: donate it so XLA
        # updates pools/buffers in place instead of copying the whole
        # cache every decode step / prefill chunk / page insert (each
        # call consumes self.cache[...] and the engine reassigns it)
        self._decode = jax.jit(_fused_step, donate_argnums=(1, 3))
        self._admit_slot = jax.jit(_admit_slot, donate_argnums=(0, 1))
        self._release_slot = jax.jit(_release_slot, donate_argnums=(0, 1))
        self._insert_paged = jax.jit(write_slot_paged, donate_argnums=(0,))
        self._chunk = jax.jit(model.prefill_chunk, donate_argnums=(1,))
        self._copy = jax.jit(copy_pages, donate_argnums=(0,))

    # -------------------------------------------------------------- admin
    def submit(self, req: Request) -> None:
        if req.params is not None and req.params.max_new is not None:
            # v2 requests budget via params; normalize the legacy field so
            # every admission/capacity path sees one source of truth
            # (params.max_new=None inherits the request's own budget)
            req.max_new = req.params.max_new
        if req.max_new < 1:
            raise ValueError(
                f"request {req.uid}: max_new must be >= 1 (got {req.max_new})"
            )
        if len(req.prompt) == 0 and self.n_front == 0:
            raise ValueError(
                f"request {req.uid}: empty prompt — a causal LM has no "
                f"token to condition the first logits on"
            )
        need = len(req.prompt) + self.n_front + req.max_new
        if need > self.max_len:
            raise ValueError(
                f"request {req.uid}: prompt+max_new = {need} tokens "
                f"overflows max_len {self.max_len}"
            )
        if self.alloc is not None and not self.alloc.fits_slot(need):
            raise ValueError(
                f"request {req.uid}: {need} tokens can never fit the page "
                f"pool ({self.alloc.num_pages - 1} usable pages of "
                f"{self.alloc.page_size})"
            )
        req.t_submit = time.time()
        self.queue.append(req)

    def _bucket(self, n: int) -> int:
        """Pad a prompt/chunk length to a power-of-2 bucket (min 8, capped
        at the longest prompt max_len admits) so prefill stops recompiling
        per unique length.  Never returns less than `n`: at the cap
        boundary (prompt exactly at max_len) the old min() could hand back
        a bucket SMALLER than the prompt and silently truncate it."""
        if not self.bucket_prompts:
            return n
        cap = max(self.max_len - self.n_front, 1)
        b = 8
        while b < n:
            b *= 2
        return max(n, min(b, cap))

    def _push_table(self) -> None:
        """Push the block table to the device cache, masking mid-prefill
        slots to the null page: the lockstep decode must neither read nor
        write their half-built pages (their writes land on page 0, which
        belongs to no sequence)."""
        tbl = self.alloc.table
        if self._prefilling:
            tbl = tbl.copy()
            tbl[self._prefilling, :] = NULL_PAGE
        self.cache["block_table"] = jnp.asarray(tbl)

    def _write_slot(self, slot: int, one_cache, pos: int) -> None:
        """Insert a batch-1 prefilled cache into slot `slot` (dense)."""

        def put(dst, src):
            # stacked leaves: (units, B, ...) — batch axis 1; scalar 'pos'
            # handled separately.
            if dst.ndim == src.ndim and dst.ndim >= 2 and src.shape[1] == 1:
                idx = (0, slot) + (0,) * (dst.ndim - 2)
                return jax.lax.dynamic_update_slice(dst, src.astype(dst.dtype), idx)
            return dst

        self.cache["layers"] = jax.tree.map(
            put, self.cache["layers"], one_cache["layers"]
        )
        self.cache["pos"] = self.cache["pos"].at[slot].set(pos)

    def _write_slot_paged(self, slot: int, one_cache, pos: int,
                          pages: np.ndarray, n_tiles: int) -> None:
        """Scatter a batch-1 prefilled cache into `slot`'s pool pages."""
        ids = np.full((n_tiles,), NULL_PAGE, np.int32)
        ids[: min(n_tiles, len(pages))] = pages[:n_tiles]
        self.cache["layers"] = self._insert_paged(
            self.cache["layers"], one_cache["layers"], slot,
            jnp.asarray(ids),
        )
        self._push_table()
        self.cache["pos"] = self.cache["pos"].at[slot].set(pos)

    # ------------------------------------------------- sampling plumbing
    def _set_slot_params(self, slot: int, req: Request) -> None:
        """Bind a request's sampling intent to its slot (host side: the
        stop machinery).  The device-side per-slot vectors are written by
        ``_emit_first`` in one fused call — nothing reads them while the
        slot is inactive."""
        sp = effective_params(req)
        self.slot_sp[slot] = sp
        self.slot_stop[slot] = StopChecker(sp, req.eos_id)

    def _emit_first(self, slot: int, logits) -> None:
        """Sample the first generated token from prefill logits (on
        device, generation index 0), bind the slot's device-side sampling
        state, record the token, and flip the slot to lockstep decoding
        (or finish immediately on stop/budget)."""
        req = self.slot_req[slot]
        sp = self.slot_sp[slot]
        tok_d, logp_d, self._samp, self._last_tok = self._admit_slot(
            self._samp, self._last_tok, logits, np.int32(slot),
            np.float32(sp.temperature), np.int32(sp.top_k),
            np.float32(sp.top_p), np.uint32(sp.seed & 0xFFFFFFFF),
        )
        nxt, lp = jax.device_get((tok_d, logp_d))
        t0 = int(nxt[0])
        req.output = [t0]
        req.logprobs = [float(lp[0])] if sp.logprobs else None
        req.t_first = time.time()
        self.slot_left[slot] = req.max_new - 1
        fin = self.slot_stop[slot].check(req.output, self.slot_left[slot])
        if fin:
            req.finish_reason = fin
            self._finish(slot)

    def _admit(self) -> None:
        for slot in range(self.B):
            if self.slot_req[slot] is not None or not self.queue:
                continue
            req = self.queue[0]
            L = len(req.prompt)
            need = L + self.n_front + req.max_new
            if self._incremental:
                plan = self.alloc.plan(need, req.prompt)
                if not self.alloc.can_admit(need, plan):
                    break  # head-of-line blocking keeps FIFO order
                self.queue.pop(0)
                self.alloc.alloc(slot, need, plan)
                if self.alloc.last_cow is not None:
                    # the final page of a fully-cached prompt is shared:
                    # privatize it (copy-on-write) before the last-token
                    # recompute writes into it
                    src, dst = self.alloc.last_cow
                    self.cache["layers"] = self._copy(
                        self.cache["layers"],
                        jnp.asarray([src], jnp.int32),
                        jnp.asarray([dst], jnp.int32),
                    )
                self.slot_req[slot] = req
                self._set_slot_params(slot, req)
                self._prefill_state[slot] = _Prefill(
                    req=req, prompt=req.prompt, done=plan.cached_tokens
                )
                self._prefilling.append(slot)
                self._push_table()
                continue
            if self.alloc is not None and not self.alloc.can_admit(need):
                # head-of-line blocking keeps FIFO order: wait for pages
                break
            self.queue.pop(0)
            Sb = self._bucket(L)
            prompt = req.prompt
            if Sb != L:
                prompt = np.zeros((Sb,), np.int32)
                prompt[:L] = req.prompt
            batch = {"tokens": jnp.asarray(prompt[None, :], jnp.int32)}
            for k, v in self.extra.items():
                batch[k] = v
            Lx = L + self.n_front          # valid decoder-input tokens
            logits, one_cache = self._prefill(self.params, batch, Lx)
            if self.alloc is not None:
                pages = self.alloc.alloc(slot, need)
                page = self.alloc.page_size
                n_tiles = pages_for(Sb + self.n_front, page)
                self._write_slot_paged(slot, one_cache, Lx, pages, n_tiles)
            else:
                self._write_slot(slot, one_cache, int(one_cache["pos"]))
            self.slot_req[slot] = req
            self._set_slot_params(slot, req)
            self._emit_first(slot, logits)

    # ----------------------------------------------------- chunked prefill
    def _advance_prefill(self, slot: int) -> None:
        """Run ONE bounded prefill chunk for mid-prefill slot `slot`; on
        prompt completion emit the first token and flip the slot to
        decoding."""
        st = self._prefill_state[slot]
        L = len(st.prompt)
        remaining = L - st.done
        c = min(self.prefill_chunk or remaining, remaining)
        Cbuf = self._bucket(c)
        toks = np.zeros((1, Cbuf), np.int32)
        toks[0, :c] = st.prompt[st.done : st.done + c]
        logits, self.cache["layers"] = self._chunk(
            self.params, self.cache["layers"], jnp.asarray(toks),
            jnp.asarray(self.alloc.table[slot : slot + 1]),
            jnp.int32(st.done), jnp.int32(c),
        )
        st.done += c
        if st.done < L:
            return
        # prompt complete: register its full blocks for future sharing,
        # make the slot's pages visible to the lockstep decode, emit the
        # first generated token (sampled on device — no argmax roundtrip)
        self.alloc.register(slot, st.prompt)
        self._prefilling.remove(slot)
        del self._prefill_state[slot]
        self._push_table()
        self.cache["pos"] = self.cache["pos"].at[slot].set(L)
        self._emit_first(slot, logits)

    def cancel(self, req: Request) -> None:
        """Abort a queued or in-flight request, releasing its slot/pages
        immediately (``finish_reason="cancelled"``; the request still
        lands in ``done`` with whatever tokens it produced).  Used by the
        LLM facade when a stream consumer abandons its iterator — an
        orphaned request must not keep decoding into other calls."""
        # identity, not ==: the dataclass __eq__ tuple-compares the numpy
        # prompt field, which raises on same-shape prompts
        for i, q in enumerate(self.queue):
            if q is req:
                del self.queue[i]
                req.finish_reason = "cancelled"
                req.t_done = time.time()
                self.done.append(req)
                return
        for slot in range(self.B):
            if self.slot_req[slot] is req:
                if slot in self._prefill_state:
                    del self._prefill_state[slot]
                    self._prefilling.remove(slot)
                req.finish_reason = "cancelled"
                self._finish(slot)
                return

    def _finish(self, slot: int) -> None:
        req = self.slot_req[slot]
        if not req.finish_reason:
            req.finish_reason = "length"
        req.t_done = time.time()
        self.done.append(req)
        self.slot_req[slot] = None
        self.slot_left[slot] = 0
        self.slot_sp[slot] = None
        self.slot_stop[slot] = None
        # one fused call: deactivate + reset pos so the slot comes back
        # with clean semantics immediately (the in-jit reset only covers
        # slots idle during a decode step)
        self._samp, self.cache["pos"] = self._release_slot(
            self._samp, self.cache["pos"], np.int32(slot)
        )
        if self.alloc is not None:
            self.alloc.release(slot)
            self._push_table()

    # --------------------------------------------------------------- step
    def step(self) -> int:
        """Admit + bounded prefill chunks + one decode iteration over all
        decoding slots.  Returns the number of slots decoded.

        With in-flight decodes, only the longest-waiting mid-prefill slot
        advances — by ONE chunk — per step, so a long prompt delays each
        decode iteration by at most `prefill_chunk` tokens of compute.
        With no decodes to protect, every mid-prefill slot advances a
        chunk (there is nothing to stall, and admission ramps faster)."""
        self._admit()
        if self._prefilling:
            decoding = any(
                self.slot_req[s] is not None and s not in self._prefill_state
                for s in range(self.B)
            )
            for slot in (self._prefilling[:1] if decoding
                         else list(self._prefilling)):
                self._advance_prefill(slot)
        active = [
            s for s in range(self.B)
            if self.slot_req[s] is not None and s not in self._prefill_state
        ]
        if active:
            # token-in/token-out: selection (and the idle-slot pos reset)
            # happens inside the jitted step; the sampled tokens feed the
            # next iteration straight from device memory, and the ONLY
            # host traffic is this one bulk device_get per step
            tok_d, logp_d, self.cache, self._samp = self._decode(
                self.params, self.cache, self._last_tok, self._samp
            )
            self._last_tok = tok_d
            nxt, logps = jax.device_get((tok_d, logp_d))
            for s in active:
                req = self.slot_req[s]
                t = int(nxt[s])
                req.output.append(t)
                if req.logprobs is not None:
                    req.logprobs.append(float(logps[s]))
                self.slot_left[s] -= 1
                fin = self.slot_stop[s].check(req.output, self.slot_left[s])
                if fin:
                    req.finish_reason = fin
                    self._finish(s)
        return len(active)

    def run(self, max_steps: int = 10_000) -> List[Request]:
        steps = 0
        while (self.queue or any(r is not None for r in self.slot_req)) \
                and steps < max_steps:
            self.step()
            steps += 1
        return self.done
