"""Deterministic fault injection for the serving engine.

Faults at serving scale are the steady state, not the exception — but a
fault you cannot reproduce is a fault you cannot test.  This module
makes every failure mode the engine defends against *injectable on a
schedule*:

  * **NaN logits** — at step N, slot S's decode (or admission
    first-token) logits are poisoned with NaN on device, exercising the
    engine's non-finite sentinel: the slot must finish with
    ``finish_reason="error"`` and every other slot's token stream must
    be bit-identical to a fault-free run.
  * **Allocator outages** — for a window of steps the engine admits
    nothing (a stand-in for transient page-pool exhaustion or a wedged
    allocator); queued requests wait (or time out on their deadlines)
    and the ``steps_since_progress`` watchdog climbs.
  * **Crash-and-rebuild** — :func:`crash_and_rebuild` hard-kills the
    engine at step N (all in-flight state lost) and rebuilds a fresh one
    from the unfinished requests, the recovery the ROADMAP's
    "millions of users" serving tier needs.  A crash is NOT a
    preemption: pre-crash tokens are discarded and survivors re-run
    from their prompts — counter-hash sampling still makes their final
    outputs token-identical to a crash-free run.
  * **Deadline storms** — :func:`deadline_storm` stamps a seeded random
    subset of requests with tight deadlines, driving the timeout path
    under load.

Schedules are keyed on the engine's own step counter (``Engine.steps``,
1-based: the first ``step()`` call is step 1), so a plan replays
identically run-to-run — the chaos suite in
``tests/test_engine_faults.py`` asserts engine invariants under
:meth:`FaultPlan.seeded` plans across many seeds, and
``benchmarks/serving_bench.py`` drives a degraded-mode workload with
the same machinery.

Usage::

    plan = FaultPlan(nan={5: (1,)}, alloc_outages=((8, 3),))
    eng = Engine(model, params, ..., faults=plan)
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class FaultPlan:
    """A deterministic fault schedule consumed by ``Engine``.

    ``nan`` maps engine step -> slot ids whose logits are poisoned at
    that step.  ``alloc_outages`` is a tuple of ``(start_step,
    duration)`` windows during which admission is blocked.  ``crash_at``
    names the step at which :func:`crash_and_rebuild` kills the engine
    (the engine itself never reads it — a crash is external by nature).
    """

    nan: Dict[int, Tuple[int, ...]] = dataclasses.field(default_factory=dict)
    alloc_outages: Tuple[Tuple[int, int], ...] = ()
    crash_at: Optional[int] = None

    def nan_slots(self, step: int) -> Tuple[int, ...]:
        """Slot ids whose logits are NaN-poisoned at engine step `step`."""
        return self.nan.get(step, ())

    def alloc_blocked(self, step: int) -> bool:
        """True while an injected allocator outage covers `step`."""
        return any(s <= step < s + d for s, d in self.alloc_outages)

    def should_crash(self, step: int) -> bool:
        return self.crash_at is not None and step >= self.crash_at

    @classmethod
    def seeded(cls, seed: int, *, horizon: int = 48, slots: int = 4,
               nan_events: int = 1, outages: int = 1, max_outage: int = 4,
               crash: bool = False) -> "FaultPlan":
        """A reproducible random plan: same seed, same faults, forever.

        ``horizon`` bounds the steps at which events may fire; size it to
        the workload (an event past the last engine step never fires —
        harmless, but tests asserting "the fault fired" should keep the
        horizon inside their step budget)."""
        rng = np.random.default_rng(seed)
        nan: Dict[int, set] = {}
        for _ in range(nan_events):
            step = int(rng.integers(2, max(horizon, 3)))
            nan.setdefault(step, set()).add(int(rng.integers(0, slots)))
        outs = tuple(
            (int(rng.integers(1, max(horizon, 2))),
             int(rng.integers(1, max_outage + 1)))
            for _ in range(outages)
        )
        crash_at = int(rng.integers(3, max(horizon, 4))) if crash else None
        return cls(
            nan={s: tuple(sorted(v)) for s, v in nan.items()},
            alloc_outages=outs,
            crash_at=crash_at,
        )


def deadline_storm(requests: Sequence, *, seed: int, fraction: float = 0.5,
                   deadline_ms: Tuple[float, float] = (1.0, 50.0)) -> List[int]:
    """Stamp a seeded random subset of `requests` with tight deadlines
    (in place, before submit).  Returns the stormed uids — the chaos
    suite checks each either finished normally before its deadline or
    carries ``finish_reason="timeout"``, never a hung slot."""
    rng = np.random.default_rng(seed)
    hit: List[int] = []
    for r in requests:
        if rng.random() < fraction:
            r.deadline_ms = float(rng.uniform(*deadline_ms))
            hit.append(r.uid)
    return hit


def crash_and_rebuild(make_engine: Callable[[], "object"],
                      requests: Sequence, *,
                      max_steps: int = 10_000) -> Tuple[List, bool]:
    """Drive `requests` to completion across a hard engine crash.

    ``make_engine()`` builds a fresh engine (its ``faults`` plan decides
    ``crash_at``).  All requests are submitted; when the engine's step
    counter reaches the plan's crash step, the engine object is dropped
    on the floor — in-flight KV, queue and device state all lost — and a
    rebuilt engine (faults cleared: the same plan would just re-crash)
    takes over every request that had not finished.  Survivors are reset
    to their pre-submit state (generated tokens are NOT carried over —
    unlike preemption, a crash loses the cache pages that made the
    partial output resumable) and re-run from their prompts.

    Returns ``(done_requests, crashed)`` where `done_requests` holds
    every input request that reached a finish reason, in completion
    order."""
    eng = make_engine()
    plan = getattr(eng, "faults", None)
    for r in requests:
        eng.submit(r)
    done: List = []
    crashed = False
    steps = 0
    while (eng.queue or any(s is not None for s in eng.slot_req)) \
            and steps < max_steps:
        eng.step()
        steps += 1
        if (not crashed and plan is not None
                and plan.should_crash(eng.steps)):
            crashed = True
            done.extend(eng.done)
            survivors = [r for r in requests if not r.finish_reason]
            eng = make_engine()
            eng.faults = None
            for r in survivors:
                r.output = None
                r.logprobs = None
                r.preempted = 0
                r.t_first = 0.0
                r.t_done = 0.0
                eng.submit(r)
    # plain concat, no ==-dedup (Request.__eq__ tuple-compares numpy
    # prompts and raises): pre-crash finishers live only in the first
    # engine's done list, post-crash ones only in the second's
    done.extend(eng.done)
    return done, crashed
