"""Block-table page allocator for the paged KV-cache serving subsystem,
with content-addressed prefix caching and copy-on-write page sharing.

The paged layout stores every sequence's KV tokens in fixed-size *pages*
of a pool shared by all slots (``(num_pages, page, Hkv, D)`` per
attention layer).  A host-side :class:`PageAllocator` owns the mapping:

  * a free list of physical page ids — released pages are reused
    immediately (LIFO keeps recently-touched pages warm);
  * a (slots, pages_per_seq) block table of physical page ids, the device
    copy of which the Pallas paged-attention kernels index through
    scalar prefetch (``kernels/paged_attention.py``);
  * capacity-aware admission: :meth:`can_admit` answers whether a request
    (prompt + generation budget) fits in the free pool *and* in one
    slot's table — a long request is refused up front instead of
    silently overflowing a slot.

Page 0 is reserved as the **null page**: unallocated block-table entries
point at it, so inactive slots read/write only garbage that belongs to no
sequence.  The allocator never hands out page 0.

Prefix caching (vLLM-style, block granularity)
----------------------------------------------
Every *full* prompt block can be registered in a hash→page index keyed on
the block's token content **chained with its prefix hash** (so identical
blocks at different depths never collide).  Admission calls
:meth:`plan` / :meth:`alloc` with the prompt tokens:

  * hash-hit blocks are **shared** — the cached physical page is mapped
    into the new slot's table and its refcount bumped; no prefill compute
    or KV write happens for those tokens;
  * a page is only writable by a slot that owns it exclusively.  When the
    engine must write into a shared page (the whole prompt hash-hit and
    the last token is recomputed for logits), :meth:`cow_write` gives the
    slot a private copy (**copy-on-write**) — the shared page itself is
    never mutated;
  * releasing a slot decrements refcounts.  A registered page whose
    refcount drops to 0 is not freed: it parks in an LRU *evictable* set,
    still indexed, and is revived on the next hash hit.  Under pressure
    the allocator evicts the oldest unreferenced cached page (dropping
    its index entry) before refusing an admission — the hash index never
    points at a page on the free list.

The engine's admission policy reserves a sequence's full budget
(``prompt + max_new`` tokens) at admission, so decode can never run out
of pages mid-request; :meth:`append` exists for callers that prefer lazy
per-token growth and is exercised by the property tests.
"""
from __future__ import annotations

import dataclasses
import zlib
from collections import OrderedDict
from typing import Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.kernels import tiling

NULL_PAGE = 0


def pages_for(tokens: int, page_size: int) -> int:
    return -(-tokens // page_size)


def block_hashes(token_ids: np.ndarray, page_size: int) -> List[int]:
    """Chained content hashes of the *full* blocks of a token sequence.

    ``h_i = crc32(h_{i-1} || tokens[i*page : (i+1)*page])`` — chaining
    makes the hash position-dependent, so block content is only shared
    between sequences whose entire prefix up to that block matches.
    The trailing partial block (if any) is never hashed.
    """
    toks = np.asarray(token_ids, np.int64)
    out: List[int] = []
    h = 0
    for i in range(len(toks) // page_size):
        blk = toks[i * page_size : (i + 1) * page_size]
        h = zlib.crc32(blk.tobytes(), h)
        out.append(h)
    return out


@dataclasses.dataclass
class PrefixPlan:
    """Admission plan: which cached pages to share and what remains."""

    shared: List[int]          # physical pages to share, in block order
    cow_last: bool             # whole prompt hit: privatize the last page
    n_new: int                 # fresh pages to pop (incl. the COW copy)
    cached_tokens: int         # tokens whose KV is reused (skip prefill)
    cost: int                  # pages consumed from free ∪ evictable
    looked_up: bool = False    # a prompt was hashed against the index


class PageAllocator:
    def __init__(self, num_pages: int, page_size: int, slots: int, max_len: int,
                 prefix_cache: bool = False):
        if num_pages < 2:
            raise ValueError("need at least 2 pages (page 0 is the null page)")
        self.num_pages = num_pages
        self.page_size = page_size
        self.slots = slots
        self.pages_per_seq = pages_for(max_len, page_size)
        self.capacity = self.pages_per_seq * page_size
        self.prefix_cache = prefix_cache
        # LIFO free list over pages 1..num_pages-1 (0 = null page)
        self._free: List[int] = list(range(num_pages - 1, 0, -1))
        self._owned: List[List[int]] = [[] for _ in range(slots)]
        self._tokens: List[int] = [0] * slots
        self._ref = np.zeros((num_pages,), np.int64)
        # hash index: bijection _page_of[h] == p  <=>  _hash_of[p] == h.
        # _block_of holds the registered page's actual block tokens — a
        # hit is only honored when the content matches, so a crc32
        # collision degrades to a miss instead of serving wrong KV.
        self._page_of: Dict[int, int] = {}
        self._hash_of: Dict[int, int] = {}
        self._block_of: Dict[int, Tuple[int, ...]] = {}
        # ref==0 pages still in the index, oldest-released first (LRU)
        self._evictable: "OrderedDict[int, None]" = OrderedDict()
        self.table = np.full((slots, self.pages_per_seq), NULL_PAGE, np.int32)
        self.stats = {"lookups": 0, "hit_tokens": 0, "evictions": 0,
                      "cow_copies": 0}

    # ------------------------------------------------------------- query
    @property
    def free_pages(self) -> int:
        """Pages an admission may consume: truly free + evictable cached."""
        return len(self._free) + len(self._evictable)

    def owned(self, slot: int) -> List[int]:
        return list(self._owned[slot])

    def ref(self, page: int) -> int:
        return int(self._ref[page])

    def is_registered(self, page: int) -> bool:
        return page in self._hash_of

    def can_admit(self, tokens: int, plan: Optional[PrefixPlan] = None) -> bool:
        """True iff `tokens` fit in one slot's table and the free pool.

        With a :class:`PrefixPlan`, shared pages with live references cost
        nothing and only ``plan.cost`` fresh/evictable pages are needed.
        """
        need = pages_for(tokens, self.page_size)
        if need > self.pages_per_seq:
            return False
        cost = plan.cost if plan is not None else need
        return cost <= self.free_pages

    def releasable(self, slot: int) -> int:
        """Pages admission would get back if `slot` released right now:
        every owned page whose only live reference is this slot (it would
        land on the free list, or park registered in the evictable set —
        either way it counts toward :attr:`free_pages`).  Shared pages
        with other live referents stay mapped and free nothing.  The
        engine's preempt-and-requeue policy prechecks this before
        evicting a victim, so it never frees pages it cannot use."""
        return sum(1 for p in self._owned[slot] if self._ref[p] == 1)

    def fits_slot(self, tokens: int) -> bool:
        """True iff `tokens` can EVER fit (ignores current free pool)."""
        need = pages_for(tokens, self.page_size)
        return need <= self.pages_per_seq and need <= self.num_pages - 1

    # ------------------------------------------------------ prefix cache
    def match_prefix(self, prompt: np.ndarray) -> List[int]:
        """Longest chain of cached pages covering full blocks of `prompt`."""
        pages: List[int] = []
        if not self.prefix_cache:
            return pages
        for i, h in enumerate(block_hashes(prompt, self.page_size)):
            p = self._page_of.get(h)
            if p is None:
                break
            blk = tuple(
                int(t) for t in
                prompt[i * self.page_size : (i + 1) * self.page_size]
            )
            if self._block_of.get(p) != blk:   # crc32 collision: miss
                break
            pages.append(p)
        return pages

    def plan(self, tokens: int, prompt: Optional[np.ndarray]) -> PrefixPlan:
        """Admission plan for a request of `tokens` total budget whose
        prompt is `prompt` (hash lookup source).  ``cached_tokens`` counts
        the prompt prefix whose KV can be reused; when the *entire* prompt
        is cached, the last page is planned as a copy-on-write private
        copy so the engine can recompute the final token for its logits
        without mutating the shared page."""
        need = pages_for(tokens, self.page_size)
        if prompt is None or not self.prefix_cache:
            return PrefixPlan([], False, need, 0, need)
        shared = self.match_prefix(prompt)[:need]
        cached = len(shared) * self.page_size
        cow_last = False
        if shared and cached >= len(prompt):
            # full hit: keep the last token for recompute (logits) — its
            # page becomes a private COW copy at alloc time
            cow_last = True
            cached = len(prompt) - 1
        # pages popped from free∪evictable: fresh tail pages + the COW
        # copy; reviving an evictable shared page also consumes from the
        # evictable side of the pool
        n_new = need - len(shared) + (1 if cow_last else 0)
        revive = sum(1 for p in set(shared) if p in self._evictable)
        return PrefixPlan(shared, cow_last, n_new, cached, n_new + revive,
                          looked_up=True)

    def register(self, slot: int, prompt: np.ndarray) -> int:
        """Index `slot`'s pages holding full blocks of `prompt` for future
        sharing.  Already-indexed hashes are left pointing at their
        existing page (first writer wins).  Returns #pages registered."""
        if not self.prefix_cache:
            return 0
        n = 0
        for i, h in enumerate(block_hashes(prompt, self.page_size)):
            if i >= len(self._owned[slot]):
                break
            page = self._owned[slot][i]
            if h in self._page_of or page in self._hash_of:
                continue
            self._page_of[h] = page
            self._hash_of[page] = h
            self._block_of[page] = tuple(
                int(t) for t in
                prompt[i * self.page_size : (i + 1) * self.page_size]
            )
            n += 1
        return n

    # ------------------------------------------------------------- mutate
    def _pop_page(self) -> int:
        """Pop a writable page: free list first, then evict the oldest
        unreferenced cached page (dropping its hash entry)."""
        if self._free:
            return self._free.pop()
        if not self._evictable:
            raise RuntimeError("out of pages")
        page, _ = self._evictable.popitem(last=False)
        h = self._hash_of.pop(page)
        del self._page_of[h]
        del self._block_of[page]
        self.stats["evictions"] += 1
        return page

    def _take_shared(self, page: int) -> None:
        """Add one reference to a cached page (reviving it if parked)."""
        if self._ref[page] == 0:
            # must be parked in the evictable set; revive it
            del self._evictable[page]
        self._ref[page] += 1

    def alloc(self, slot: int, tokens: int,
              plan: Optional[PrefixPlan] = None) -> np.ndarray:
        """Reserve pages for `tokens` tokens in `slot`; returns page ids.

        With a `plan`, cached pages are shared (refcount bumped) and only
        the remainder is popped fresh.  ``plan.cow_last`` replaces the
        final shared page with a private copy — the engine must copy the
        page content on device (see :attr:`last_cow`)."""
        if self._owned[slot]:
            raise RuntimeError(f"slot {slot} already holds pages")
        need = pages_for(tokens, self.page_size)
        if need > self.pages_per_seq:
            raise ValueError(
                f"{tokens} tokens need {need} pages > pages_per_seq "
                f"{self.pages_per_seq} — request overflows the slot"
            )
        if plan is None:
            plan = PrefixPlan([], False, need, 0, need)
        if not self.can_admit(tokens, plan):
            raise RuntimeError(
                f"out of pages: need {plan.cost}, free {self.free_pages}"
            )
        # stats live here, not in plan(): a blocked queue head re-plans
        # every engine step and would inflate the reuse numbers
        if plan.looked_up:
            self.stats["lookups"] += 1
            self.stats["hit_tokens"] += plan.cached_tokens
        pages: List[int] = []
        self.last_cow: Optional[Tuple[int, int]] = None
        # share the hash-hit prefix first so reviving cannot race with
        # eviction in _pop_page
        for i, p in enumerate(plan.shared):
            if plan.cow_last and i == len(plan.shared) - 1:
                break
            self._take_shared(p)
            pages.append(p)
        if plan.cow_last:
            src = plan.shared[-1]
            dst = self._pop_page()
            self._ref[dst] = 1
            pages.append(dst)
            self.last_cow = (src, dst)
            self.stats["cow_copies"] += 1
        while len(pages) < need:
            p = self._pop_page()
            self._ref[p] = 1
            pages.append(p)
        self._owned[slot] = pages
        self._tokens[slot] = tokens
        self.table[slot, :need] = pages
        self.table[slot, need:] = NULL_PAGE
        return np.asarray(pages, np.int32)

    def append(self, slot: int, n: int = 1) -> None:
        """Extend `slot`'s reservation by `n` tokens (lazy growth)."""
        if not self._owned[slot]:
            raise RuntimeError(f"slot {slot} holds no pages")
        tokens = self._tokens[slot] + n
        need = pages_for(tokens, self.page_size)
        have = len(self._owned[slot])
        if need > self.pages_per_seq:
            raise ValueError(f"append overflows slot {slot} ({tokens} tokens)")
        if need - have > self.free_pages:
            raise RuntimeError("out of pages on append")
        for j in range(have, need):
            page = self._pop_page()
            self._ref[page] = 1
            self._owned[slot].append(page)
            self.table[slot, j] = page
        self._tokens[slot] = tokens

    def cow_write(self, slot: int, idx: int) -> Optional[Tuple[int, int]]:
        """Make `slot`'s idx-th page privately writable.

        * shared page (ref > 1): pop a fresh page, remap the slot to it and
          drop one reference from the original — returns ``(src, dst)`` so
          the caller can copy the page content on device.  The shared page
          itself is NEVER written.
        * exclusively-owned but hash-registered page: writing would corrupt
          the cached content for future sharers, so the page is unregistered
          in place (no copy needed) — returns ``None``.
        * private unregistered page: no-op, returns ``None``.
        """
        page = self._owned[slot][idx]
        if self._ref[page] > 1:
            dst = self._pop_page()
            self._ref[dst] = 1
            self._ref[page] -= 1
            self._owned[slot][idx] = dst
            self.table[slot, idx] = dst
            self.stats["cow_copies"] += 1
            return (page, dst)
        if page in self._hash_of:
            h = self._hash_of.pop(page)
            del self._page_of[h]
            del self._block_of[page]
        return None

    def release(self, slot: int) -> int:
        """Drop `slot`'s references; returns how many pages it held.

        A page whose refcount reaches 0 returns to the free list — unless
        it is hash-registered, in which case it parks in the evictable LRU
        set, still indexed for future prefix hits."""
        pages = self._owned[slot]
        for p in pages:
            if self._ref[p] <= 0:  # pragma: no cover - guard
                raise RuntimeError("double free detected")
            self._ref[p] -= 1
            if self._ref[p] == 0:
                if p in self._hash_of:
                    self._evictable[p] = None  # most-recently released last
                else:
                    self._free.append(p)
        n = len(pages)
        self._owned[slot] = []
        self._tokens[slot] = 0
        self.table[slot, :] = NULL_PAGE
        return n

    def drop_cache(self) -> int:
        """Evict every unreferenced cached page (flush); returns count."""
        n = len(self._evictable)
        while self._evictable:
            page, _ = self._evictable.popitem(last=False)
            h = self._hash_of.pop(page)
            del self._page_of[h]
            del self._block_of[page]
            self._free.append(page)
        return n

    # ------------------------------------------------------------- checks
    def check_invariants(self) -> None:
        """Refcounts equal live references; no page both free and mapped;
        the hash index never points at a freed page; no page leaks."""
        free = set(self._free)
        assert len(free) == len(self._free), "duplicate pages in free list"
        assert NULL_PAGE not in free, "null page entered the free list"
        evictable = set(self._evictable)
        assert not free & evictable, "page both free and evictable"
        # refcount == number of slot references holding the page
        counts = np.zeros((self.num_pages,), np.int64)
        for slot, pages in enumerate(self._owned):
            need = pages_for(self._tokens[slot], self.page_size)
            assert len(pages) == need, (slot, len(pages), need)
            for p in pages:
                counts[p] += 1
        assert np.array_equal(counts, self._ref), "refcount drift"
        owned = {p for pages in self._owned for p in pages}
        assert not free & owned, "page both free and owned"
        assert not evictable & owned, "page both evictable and owned"
        # hash index bijection, and never into the free list
        assert len(self._page_of) == len(self._hash_of)
        assert set(self._block_of) == set(self._hash_of), \
            "registered block content out of sync with the index"
        for h, p in self._page_of.items():
            assert self._hash_of.get(p) == h, "hash index not a bijection"
            assert p not in free, "hash index points at a freed page"
            assert p != NULL_PAGE
            if self._ref[p] == 0:
                assert p in evictable, "unreferenced cached page not parked"
        for p in evictable:
            assert p in self._hash_of, "evictable page missing from index"
            assert self._ref[p] == 0, "evictable page still referenced"
        # conservation: every non-null page is free, evictable, or owned
        assert len(free) + len(evictable) + len(owned) == self.num_pages - 1, \
            "page leak"
        # block-table rows mirror ownership
        for slot, pages in enumerate(self._owned):
            assert list(self.table[slot, : len(pages)]) == pages
            assert all(
                p == NULL_PAGE for p in self.table[slot, len(pages):]
            )


# --------------------------------------------------------------------- #
# prefill insertion: dense batch-1 cache -> pool pages + dense leaves
# --------------------------------------------------------------------- #
def write_slot_paged(
    cache_layers: Dict,
    one_layers: Dict,
    slot,
    page_ids: jax.Array,    # (n_pages,) physical pages for the prompt tiles
):
    """Insert a batch-1 prefilled cache into a paged engine cache.

    Attention ``k``/``v`` leaves (dense ``(units, 1, W, Hkv, D)``) are cut
    into page tiles and scattered to ``k_pool``/``v_pool`` at `page_ids`;
    every other leaf (SSM state, cross-attn KV, lengths) is written into
    the slot's batch row like the dense layout.  `page_ids` may be padded
    with the null page — those tiles land on page 0 and are never read.

    Jit-friendly: `slot` and `page_ids` can be traced (shapes static).
    """
    n_pages = page_ids.shape[0]

    def put_dense(dst, src):
        if dst.ndim == src.ndim and dst.ndim >= 2 and src.shape[1] == 1:
            idx = (0, slot) + (0,) * (dst.ndim - 2)
            return jax.lax.dynamic_update_slice(dst, src.astype(dst.dtype), idx)
        return dst

    def walk(dst, src):
        if isinstance(dst, dict):
            if "k_pool" in dst:
                page = dst["k_pool"].shape[2]   # (units, P, page, Hkv, D)
                out = dict(dst)
                for pool_name, leaf_name in (("k_pool", "k"), ("v_pool", "v")):
                    leaf = src[leaf_name]       # (units, 1, W, Hkv, D)
                    u, _, W = leaf.shape[:3]
                    rows = n_pages * page
                    tiles = tiling.pad_dim(leaf[:, 0], 1, max(rows, W))[:, :rows]
                    tiles = tiles.reshape(u, n_pages, page, *leaf.shape[3:])
                    out[pool_name] = dst[pool_name].at[:, page_ids].set(
                        tiles.astype(dst[pool_name].dtype)
                    )
                return out
            return {
                k: walk(v, src[k]) if k in src else v for k, v in dst.items()
            }
        return put_dense(dst, src)

    return walk(cache_layers, one_layers)


def copy_pages(cache_layers: Dict, src: jax.Array, dst: jax.Array) -> Dict:
    """Copy pool pages ``src`` -> ``dst`` in every layer (COW support).

    `src`/`dst` are (n,) int32 physical page ids; non-pool leaves pass
    through.  Jit-friendly (ids may be traced)."""

    def walk(tree):
        if isinstance(tree, dict):
            if "k_pool" in tree:
                out = dict(tree)
                for name in ("k_pool", "v_pool"):
                    pool = tree[name]           # (units, P, page, Hkv, D)
                    out[name] = pool.at[:, dst].set(pool[:, src])
                return out
            return {k: walk(v) for k, v in tree.items()}
        return tree

    return walk(cache_layers)
