"""Block-table page allocator for the paged KV-cache serving subsystem.

The paged layout stores every sequence's KV tokens in fixed-size *pages*
of a pool shared by all slots (``(num_pages, page, Hkv, D)`` per
attention layer).  A host-side :class:`PageAllocator` owns the mapping:

  * a free list of physical page ids — released pages are reused
    immediately (LIFO keeps recently-touched pages warm);
  * a (slots, pages_per_seq) block table of physical page ids, the device
    copy of which the Pallas paged-attention kernel indexes through
    scalar prefetch (``kernels/paged_attention.py``);
  * capacity-aware admission: :meth:`can_admit` answers whether a request
    (prompt + generation budget) fits in the free pool *and* in one
    slot's table — a long request is refused up front instead of
    silently overflowing a slot.

Page 0 is reserved as the **null page**: unallocated block-table entries
point at it, so inactive slots read/write only garbage that belongs to no
sequence.  The allocator never hands out page 0.

The engine's admission policy reserves a sequence's full budget
(``prompt + max_new`` tokens) at admission, so decode can never run out
of pages mid-request; :meth:`append` exists for callers that prefer lazy
per-token growth and is exercised by the property tests.
"""
from __future__ import annotations

from typing import Dict, List

import jax
import numpy as np

from repro.kernels import tiling

NULL_PAGE = 0


def pages_for(tokens: int, page_size: int) -> int:
    return -(-tokens // page_size)


class PageAllocator:
    def __init__(self, num_pages: int, page_size: int, slots: int, max_len: int):
        if num_pages < 2:
            raise ValueError("need at least 2 pages (page 0 is the null page)")
        self.num_pages = num_pages
        self.page_size = page_size
        self.slots = slots
        self.pages_per_seq = pages_for(max_len, page_size)
        self.capacity = self.pages_per_seq * page_size
        # LIFO free list over pages 1..num_pages-1 (0 = null page)
        self._free: List[int] = list(range(num_pages - 1, 0, -1))
        self._owned: List[List[int]] = [[] for _ in range(slots)]
        self._tokens: List[int] = [0] * slots
        self.table = np.full((slots, self.pages_per_seq), NULL_PAGE, np.int32)

    # ------------------------------------------------------------- query
    @property
    def free_pages(self) -> int:
        return len(self._free)

    def owned(self, slot: int) -> List[int]:
        return list(self._owned[slot])

    def can_admit(self, tokens: int) -> bool:
        """True iff `tokens` fit in one slot's table and the free pool."""
        need = pages_for(tokens, self.page_size)
        return need <= self.pages_per_seq and need <= len(self._free)

    def fits_slot(self, tokens: int) -> bool:
        """True iff `tokens` can EVER fit (ignores current free pool)."""
        need = pages_for(tokens, self.page_size)
        return need <= self.pages_per_seq and need <= self.num_pages - 1

    # ------------------------------------------------------------- mutate
    def alloc(self, slot: int, tokens: int) -> np.ndarray:
        """Reserve pages for `tokens` tokens in `slot`; returns page ids."""
        if self._owned[slot]:
            raise RuntimeError(f"slot {slot} already holds pages")
        need = pages_for(tokens, self.page_size)
        if need > self.pages_per_seq:
            raise ValueError(
                f"{tokens} tokens need {need} pages > pages_per_seq "
                f"{self.pages_per_seq} — request overflows the slot"
            )
        if need > len(self._free):
            raise RuntimeError(f"out of pages: need {need}, free {len(self._free)}")
        pages = [self._free.pop() for _ in range(need)]
        self._owned[slot] = pages
        self._tokens[slot] = tokens
        self.table[slot, :need] = pages
        self.table[slot, need:] = NULL_PAGE
        return np.asarray(pages, np.int32)

    def append(self, slot: int, n: int = 1) -> None:
        """Extend `slot`'s reservation by `n` tokens (lazy growth)."""
        if not self._owned[slot]:
            raise RuntimeError(f"slot {slot} holds no pages")
        tokens = self._tokens[slot] + n
        need = pages_for(tokens, self.page_size)
        have = len(self._owned[slot])
        if need > self.pages_per_seq:
            raise ValueError(f"append overflows slot {slot} ({tokens} tokens)")
        if need - have > len(self._free):
            raise RuntimeError("out of pages on append")
        for j in range(have, need):
            page = self._free.pop()
            self._owned[slot].append(page)
            self.table[slot, j] = page
        self._tokens[slot] = tokens

    def release(self, slot: int) -> int:
        """Return `slot`'s pages to the free list; returns how many."""
        pages = self._owned[slot]
        if any(p in self._free for p in pages):  # pragma: no cover - guard
            raise RuntimeError("double free detected")
        self._free.extend(reversed(pages))
        n = len(pages)
        self._owned[slot] = []
        self._tokens[slot] = 0
        self.table[slot, :] = NULL_PAGE
        return n

    # ------------------------------------------------------------- checks
    def check_invariants(self) -> None:
        """No page leaked, none shared, none both free and owned."""
        free = set(self._free)
        assert len(free) == len(self._free), "duplicate pages in free list"
        assert NULL_PAGE not in free, "null page entered the free list"
        owned_all: List[int] = []
        for slot, pages in enumerate(self._owned):
            owned_all.extend(pages)
            assert not free & set(pages), f"slot {slot} owns freed pages"
            need = pages_for(self._tokens[slot], self.page_size)
            assert len(pages) == need, (slot, len(pages), need)
        assert len(set(owned_all)) == len(owned_all), "page owned twice"
        assert len(free) + len(owned_all) == self.num_pages - 1, "page leak"


# --------------------------------------------------------------------- #
# prefill insertion: dense batch-1 cache -> pool pages + dense leaves
# --------------------------------------------------------------------- #
def write_slot_paged(
    cache_layers: Dict,
    one_layers: Dict,
    slot,
    page_ids: jax.Array,    # (n_pages,) physical pages for the prompt tiles
):
    """Insert a batch-1 prefilled cache into a paged engine cache.

    Attention ``k``/``v`` leaves (dense ``(units, 1, W, Hkv, D)``) are cut
    into page tiles and scattered to ``k_pool``/``v_pool`` at `page_ids`;
    every other leaf (SSM state, cross-attn KV, lengths) is written into
    the slot's batch row like the dense layout.  `page_ids` may be padded
    with the null page — those tiles land on page 0 and are never read.

    Jit-friendly: `slot` and `page_ids` can be traced (shapes static).
    """
    n_pages = page_ids.shape[0]

    def put_dense(dst, src):
        if dst.ndim == src.ndim and dst.ndim >= 2 and src.shape[1] == 1:
            idx = (0, slot) + (0,) * (dst.ndim - 2)
            return jax.lax.dynamic_update_slice(dst, src.astype(dst.dtype), idx)
        return dst

    def walk(dst, src):
        if isinstance(dst, dict):
            if "k_pool" in dst:
                page = dst["k_pool"].shape[2]   # (units, P, page, Hkv, D)
                out = dict(dst)
                for pool_name, leaf_name in (("k_pool", "k"), ("v_pool", "v")):
                    leaf = src[leaf_name]       # (units, 1, W, Hkv, D)
                    u, _, W = leaf.shape[:3]
                    rows = n_pages * page
                    tiles = tiling.pad_dim(leaf[:, 0], 1, max(rows, W))[:, :rows]
                    tiles = tiles.reshape(u, n_pages, page, *leaf.shape[3:])
                    out[pool_name] = dst[pool_name].at[:, page_ids].set(
                        tiles.astype(dst[pool_name].dtype)
                    )
                return out
            return {
                k: walk(v, src[k]) if k in src else v for k, v in dst.items()
            }
        return put_dense(dst, src)

    return walk(cache_layers, one_layers)
