"""Per-request sampling intent: ``SamplingParams`` + stop machinery.

Generation API v2 attaches a ``SamplingParams`` to every request instead
of one global ``temperature`` float: a serving batch can mix greedy
pLM-embedding traffic with high-temperature molecule sampling (the
MolMIM workload) in the same lockstep decode step.  The numeric fields
(temperature, top_k, top_p, seed) are vectorized per slot and consumed
on device by the fused sampler (``kernels/ops.py::sample_tokens``); the
stop fields are host-side bookkeeping applied to the step's bulk token
transfer.

Determinism: ``seed`` keys a counter-based PRNG stream indexed by the
request's own generation step, so a fixed-seed request reproduces the
same tokens no matter which slots/batch it shares a decode step with.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """How one request wants its tokens chosen and when to stop.

    ``temperature <= 0`` is greedy argmax (the default — token-identical
    to the pre-v2 engine).  ``top_k=0`` and ``top_p=1.0`` disable the
    respective filters.  ``max_new=None`` inherits the carrying
    ``Request``'s budget (so a legacy call site can attach sampling
    intent without its explicit ``max_new`` being silently replaced);
    facade requests default to 32.  ``stop_token_ids`` stop on a single
    generated token; ``stop_sequences`` stop when the generated suffix
    matches a multi-token pattern (matched tokens stay in the output,
    like eos).  ``logprobs`` records the chosen token's log-probability
    per step.  ``deadline_ms`` is a wall-clock SLO measured from submit:
    a request still queued past its deadline finishes with
    ``finish_reason="timeout"`` without ever running, and an in-flight
    request past it is released at the next engine step boundary with
    whatever tokens it produced (``None`` = no deadline).
    """

    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int = 0
    max_new: Optional[int] = None
    stop_token_ids: Tuple[int, ...] = ()
    stop_sequences: Tuple[Tuple[int, ...], ...] = ()
    logprobs: bool = False
    deadline_ms: Optional[float] = None

    def __post_init__(self):
        if self.temperature < 0:
            raise ValueError(f"temperature must be >= 0 (got {self.temperature})")
        if self.deadline_ms is not None and self.deadline_ms <= 0:
            raise ValueError(
                f"deadline_ms must be > 0 (got {self.deadline_ms})"
            )
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0 (got {self.top_k})")
        if not 0.0 < self.top_p <= 1.0 + 1e-9:
            raise ValueError(f"top_p must be in (0, 1] (got {self.top_p})")
        if self.max_new is not None and self.max_new < 1:
            raise ValueError(f"max_new must be >= 1 (got {self.max_new})")
        # normalize stop containers to hashable tuples
        object.__setattr__(
            self, "stop_token_ids", tuple(int(t) for t in self.stop_token_ids)
        )
        seqs = tuple(tuple(int(t) for t in s) for s in self.stop_sequences)
        if any(len(s) == 0 for s in seqs):
            raise ValueError("stop_sequences entries must be non-empty")
        object.__setattr__(self, "stop_sequences", seqs)

    @property
    def greedy(self) -> bool:
        return self.temperature <= 0.0


class StopChecker:
    """Host-side stop evaluation for one request.

    Built once at admission from the request's effective params (legacy
    ``Request.eos_id >= 0`` folds into the stop-token set; ``eos_id=-1``
    keeps the never-stop semantics).  ``check`` is called after every
    emitted token with the full generated output and the remaining
    budget; it returns a finish reason (``"stop"`` / ``"length"``) or
    ``""`` to keep decoding.  Matched stop tokens/sequences remain in
    the output (same contract as the legacy eos path).
    """

    def __init__(self, params: SamplingParams, eos_id: int = -1):
        ids = set(params.stop_token_ids)
        if eos_id >= 0:
            ids.add(int(eos_id))
        self.stop_ids = frozenset(ids)
        self.stop_seqs: Tuple[List[int], ...] = tuple(
            list(s) for s in params.stop_sequences
        )

    def check(self, output: Sequence[int], left: int) -> str:
        if output and output[-1] in self.stop_ids:
            return "stop"
        for s in self.stop_seqs:
            if len(output) >= len(s) and list(output[-len(s):]) == s:
                return "stop"
        if left <= 0:
            return "length"
        return ""


def effective_params(req) -> SamplingParams:
    """The params a request decodes under.

    ``Request.params`` wins when present; a legacy request (no params)
    maps to greedy with its ``max_new`` budget — the exact pre-v2
    behavior, which keeps old ``Engine(...)`` call sites working.
    """
    if getattr(req, "params", None) is not None:
        return req.params
    return SamplingParams(max_new=req.max_new)
