"""Training loop with logging + checkpoint hooks (BioNeMo trainer analogue)."""
from __future__ import annotations

import os
import time
from typing import Any, Callable, Dict, Iterator, List, Optional

import jax
import numpy as np

from repro.checkpoint import ckpt
from repro.core.config import TrainConfig
from repro.models.model import Model
from repro.training.train_step import TrainState, init_train_state, make_train_step


def run_training(
    model: Model,
    tc: TrainConfig,
    batches: Iterator[Dict[str, np.ndarray]],
    *,
    state: Optional[TrainState] = None,
    hooks: Optional[List[Callable[[int, Dict[str, float]], None]]] = None,
    verbose: bool = True,
) -> tuple[TrainState, List[Dict[str, float]]]:
    key = jax.random.PRNGKey(tc.seed)
    if state is None:
        state = init_train_state(model, key, tc)
    step_fn = jax.jit(make_train_step(model, tc), donate_argnums=(0,))

    history: List[Dict[str, float]] = []
    t0 = time.time()
    tokens_seen = 0
    it = iter(batches)
    for step in range(tc.total_steps):
        batch = next(it)
        state, metrics = step_fn(state, batch)
        if (step % max(tc.log_every, 1)) == 0 or step == tc.total_steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            dt = time.time() - t0
            tokens_seen += float(m.get("tokens", 0)) * max(tc.log_every, 1)
            m.update(step=step, wall=dt)
            history.append(m)
            if verbose:
                print(
                    f"step {step:5d}  loss {m['loss']:.4f}  ce {m['ce_loss']:.4f}  "
                    f"gnorm {m['grad_norm']:.2f}  lr {m['lr']:.2e}  {dt:.1f}s"
                )
            for h in hooks or []:
                h(step, m)
        if tc.ckpt_every and tc.ckpt_dir and step and step % tc.ckpt_every == 0:
            ckpt.save(os.path.join(tc.ckpt_dir, f"step_{step}"), state.params, step)
    if tc.ckpt_every and tc.ckpt_dir:
        ckpt.save(
            os.path.join(tc.ckpt_dir, f"step_{tc.total_steps}"),
            state.params,
            tc.total_steps,
        )
    return state, history
