"""Distributed training engine (BioNeMo/Megatron trainer analogue).

``Trainer`` owns the training vertical end-to-end:

  * sharded step — ``make_sharded_train_step`` (jit with state/batch
    in_shardings, state out_shardings, donated state), compiled ONCE ahead
    of time; the compiled HLO feeds the tokens/s + MFU report through
    ``launch/hlo_cost.analyze``
  * batch placement — host pipeline batches land on the mesh's ``data``
    axes (``jax.make_array_from_process_local_data`` when running
    multi-process, a sharded ``device_put`` on one host)
  * double-buffered device prefetch — batch N+1 transfers to device while
    step N runs
  * async metrics — per-step metrics stay on device; ONE bulk
    ``jax.device_get`` per log interval and no implicit transfers in the
    steady state (transfer-guard tested like the serving engine)
  * unified telemetry — pass ``metrics=MetricsRegistry()`` (``repro.obs``)
    and the log-interval flush also feeds the shared registry
    (tokens/s, step-time histogram, grad-norm, loss, skipped-step
    counters): the serving engine and the trainer then report through
    one exposition surface.  Registry writes consume only the values
    the flush already fetched, so the transfer contract is untouched.
    ``profile=True`` wraps the jitted step dispatch in a
    ``jax.profiler`` annotation and accumulates host-side per-phase
    timings in ``Trainer.step_timer``
  * resumable checkpoints — the FULL TrainState (params + AdamW moments +
    optimizer step) plus the data-iterator cursor; ``resume_from``
    reproduces the uninterrupted run bit-exactly
    (tests/test_trainer_distributed.py)

``run_training`` remains as the functional wrapper older call sites use.
"""
from __future__ import annotations

import collections
import os
import time
from typing import Any, Callable, Dict, Iterator, List, Optional

import jax
import numpy as np

from repro.checkpoint import ckpt
from repro.core.config import TrainConfig
from repro.models.model import Model
from repro.obs.metrics import MetricsRegistry
from repro.obs.profile import StepTimer, annotate
from repro.training import train_step as TS
from repro.training.train_step import TrainState


class NonFiniteLossError(RuntimeError):
    """Raised by ``Trainer`` after ``TrainConfig.max_nonfinite_skips``
    CONSECUTIVE optimizer steps were skipped for non-finite loss/grads —
    at that point the run is diverged (or the data is poisoned), not
    transiently unlucky, and silently skipping forever would burn the
    cluster while the loss curve flatlines.  Carries ``step`` (the last
    offending optimizer step) and ``skips``."""

    def __init__(self, step: int, skips: int):
        super().__init__(
            f"non-finite loss/grad-norm on {skips} consecutive steps "
            f"(last: optimizer step {step}); update was skipped each time "
            f"— aborting instead of training on garbage"
        )
        self.step = step
        self.skips = skips


class _DevicePrefetch:
    """Double-buffered host->device pipeline feeding the train step.

    Each buffered batch carries the pipeline's post-draw cursor
    (``state_dict()``, when the pipeline has one), so a checkpoint taken
    after consuming batch N records "next draw is N+1" even though the
    prefetcher has already pulled batches N+1, N+2 off the host iterator.
    """

    def __init__(self, pipeline, place, depth: int = 2):
        self.pipeline = pipeline
        self.src = iter(pipeline)
        self.place = place
        self.depth = max(int(depth), 1)
        self.buf: collections.deque = collections.deque()
        self.cursor = self._snapshot()  # state before any draw
        self.exhausted = False

    def _snapshot(self):
        sd = getattr(self.pipeline, "state_dict", None)
        return sd() if callable(sd) else None

    def _pull(self) -> None:
        try:
            b = next(self.src)
        except StopIteration:
            self.exhausted = True
            return
        self.buf.append((self.place(b), self._snapshot()))

    def __iter__(self):
        return self

    def __next__(self):
        while len(self.buf) < self.depth and not self.exhausted:
            self._pull()
        if not self.buf:
            raise StopIteration
        batch, cur = self.buf.popleft()
        if cur is not None:
            self.cursor = cur
        return batch


class Trainer:
    """Mesh-aware training engine; see module docstring.

    Drive it with ``run(batches)`` for a whole schedule, or
    ``prepare(batches)`` + repeated ``step()`` for finer control (the
    transfer-guard tests step it manually around the warmup/compile)."""

    def __init__(
        self,
        model: Model,
        tc: TrainConfig,
        *,
        hooks: Optional[List[Callable[[int, Dict[str, float]], None]]] = None,
        verbose: bool = True,
        peak_flops: Optional[float] = None,
        prefetch: int = 2,
        metrics: Optional[MetricsRegistry] = None,
        profile: bool = False,
    ):
        self.model, self.tc = model, tc
        mesh = model.ctx.mesh
        self.mesh = None if (mesh is None or mesh.empty or mesh.size == 1) else mesh
        self.hooks = list(hooks or [])
        self.verbose = verbose
        self.peak_flops = peak_flops or float(
            os.environ.get("REPRO_PEAK_FLOPS", "0")
        ) or None
        self.prefetch = max(int(prefetch), 1)
        self._jit_step = TS.make_sharded_train_step(model, tc)
        # per-shape compile cache: size-aware batching yields a bounded
        # set of (rows, len) shapes (one per length bucket); each shape
        # AOT-compiles once and is reused, never recompiled per step
        self._compiled: Dict[Any, Dict[str, Any]] = {}
        self.hlo_cost: Optional[Dict[str, Any]] = None  # per-device, one step
        self._model_flops = 0.0                         # global, one step
        self.state: Optional[TrainState] = None
        self.step_idx = 0            # optimizer steps completed
        self.history: List[Dict[str, float]] = []
        self._pending: List[Dict] = []  # device metrics since last log
        self._tokens_seen = 0.0
        # non-finite-step guard (see train_step.py): totals and the
        # current consecutive-skip streak, advanced at each log flush
        self.skipped_total = 0
        self._skip_streak = 0
        self._it: Optional[_DevicePrefetch] = None
        self._t0 = self._t_log = 0.0

        # unified telemetry (repro.obs): registry series are fed at the
        # log-interval flush from values the ONE bulk device_get already
        # fetched — no extra transfers, no per-step host work
        self.metrics = metrics
        self.profile = bool(profile)
        self.step_timer = StepTimer() if self.profile else None
        if metrics is not None:
            self._c_steps = metrics.counter(
                "train_steps_total", "optimizer steps completed"
            )
            self._c_tokens = metrics.counter(
                "train_tokens_total", "non-pad tokens consumed"
            )
            self._c_skipped = metrics.counter(
                "train_skipped_steps_total",
                "updates withheld for non-finite loss/grads",
            )
            self._h_step = metrics.histogram(
                "train_step_time_seconds", "mean step wall per log interval"
            )
            self._tg = {
                name: metrics.gauge(f"train_{name}", help)
                for name, help in (
                    ("loss", "last flushed total loss"),
                    ("grad_norm", "last flushed global gradient norm"),
                    ("tokens_per_sec", "interval throughput"),
                    ("lr", "current learning rate"),
                    ("aux_loss", "router load-balance loss (MoE)"),
                    ("router_entropy", "mean router entropy (MoE)"),
                    ("router_drop_frac", "capacity-dropped slot fraction"),
                )
            }
            self._g_load = metrics.gauge(
                "train_router_load",
                "per-expert fraction of kept routed slots",
                labels=("expert",),
            )

    # ------------------------------------------------------------ placement
    def _place(self, batch):
        """Put a host batch onto the mesh's data axes (per-host placement
        on multi-process runs), or the default device off-mesh."""
        if self.mesh is None:
            return jax.device_put(batch)
        sh = TS.host_batch_sharding(self.model)
        if jax.process_count() > 1:
            return jax.tree.map(
                lambda x: jax.make_array_from_process_local_data(
                    sh, np.asarray(x)
                ),
                batch,
            )
        return jax.device_put(batch, sh)

    def _place_state(self, state: TrainState) -> TrainState:
        if self.mesh is None:
            return jax.device_put(state)
        return jax.device_put(state, TS.state_shardings(self.model))

    # ------------------------------------------------------------ lifecycle
    def prepare(
        self,
        batches,
        *,
        state: Optional[TrainState] = None,
        resume_from: Optional[str] = None,
    ) -> "Trainer":
        if resume_from:
            self.load(resume_from, batches)
        elif state is not None:
            self.state = self._place_state(state)
        if self.state is None:
            self.state = TS.init_sharded_train_state(
                self.model, jax.random.PRNGKey(self.tc.seed), self.tc
            )
        self._it = _DevicePrefetch(batches, self._place, self.prefetch)
        self._t0 = self._t_log = time.perf_counter()
        return self

    @staticmethod
    def _batch_sig(batch) -> Any:
        """Hashable shape signature of a device batch — the compile-cache
        key.  Bucketed pipelines emit a bounded set of these."""
        if not isinstance(batch, dict):
            return None
        return tuple(
            sorted(
                (k, tuple(v.shape), str(v.dtype)) for k, v in batch.items()
            )
        )

    def _build_compiled(self, batch, sig) -> Dict[str, Any]:
        """AOT-compile the sharded step for this batch shape (avoids the
        double compile of lower-after-first-call) and extract the HLO
        roofline terms the tokens/s / MFU report uses."""
        entry: Dict[str, Any] = {"fn": self._jit_step, "hlo": None,
                                 "flops": 0.0}
        try:
            compiled = self._jit_step.lower(self.state, batch).compile()
            try:
                from repro.launch.hlo_cost import analyze

                entry["hlo"] = analyze(compiled.as_text())
            except Exception:  # noqa: BLE001 — reporting only
                pass
            entry["fn"] = compiled
        except Exception:  # noqa: BLE001 — fall back to on-dispatch compile
            pass
        tok = batch.get("tokens") if isinstance(batch, dict) else None
        if tok is not None and getattr(tok, "ndim", 0) >= 2:
            # model-FLOPs convention: 6 · active params · processed tokens
            entry["flops"] = (
                6.0
                * self.model.cfg.active_param_count()
                * tok.shape[0]
                * tok.shape[1]
            )
        self._compiled[sig] = entry
        return entry

    # ------------------------------------------------------------ stepping
    def step(self) -> int:
        """One optimizer step: pull a prefetched device batch, run the
        sharded step, stash device metrics; log/checkpoint on schedule."""
        batch = next(self._it)
        sig = self._batch_sig(batch)
        entry = self._compiled.get(sig)
        if entry is None:
            entry = self._build_compiled(batch, sig)
        # MFU/roofline terms follow the shape actually stepped
        self._model_flops = entry["flops"]
        self.hlo_cost = entry["hlo"]
        fn = entry["fn"]
        if self.step_timer is not None:
            with self.step_timer.span("train_step"), \
                    annotate("train/step", enabled=True):
                self.state, metrics = fn(self.state, batch)
        else:
            self.state, metrics = fn(self.state, batch)
        s = self.step_idx
        self.step_idx = s + 1
        self._pending.append(metrics)
        if (s % max(self.tc.log_every, 1)) == 0 or s == self.tc.total_steps - 1:
            self._flush_log(s)
        if (
            self.tc.ckpt_every
            and self.tc.ckpt_dir
            and self.step_idx % self.tc.ckpt_every == 0
        ):
            self.save(
                os.path.join(self.tc.ckpt_dir, f"step_{self.step_idx}")
            )
        return self.step_idx

    def _flush_log(self, s: int) -> None:
        fetched = jax.device_get(self._pending)  # the ONE bulk transfer
        self._pending = []
        now = time.perf_counter()
        dt = now - self._t_log
        self._t_log = now
        n = len(fetched)
        tokens = float(sum(m["tokens"] for m in fetched))
        self._tokens_seen += tokens
        # non-finite guard bookkeeping: the jitted step already withheld
        # the update on skipped steps; here we count them (in order, so
        # the consecutive streak is exact) and abort a diverged run
        for i, fm in enumerate(fetched):
            if float(fm.get("skipped", 0.0)) > 0.0:
                self.skipped_total += 1
                self._skip_streak += 1
                if self.metrics is not None:
                    self._c_skipped.inc()
                if self._skip_streak >= max(self.tc.max_nonfinite_skips, 1):
                    raise NonFiniteLossError(
                        s - n + 1 + i, self._skip_streak
                    )
            else:
                self._skip_streak = 0
        last = fetched[-1]
        # vector-valued metrics (per-expert router load) stay out of the
        # scalar history dict and feed the labeled gauge instead
        m = {k: float(v) for k, v in last.items() if np.ndim(v) == 0}
        step_time = dt / max(n, 1)
        m.update(
            step=s,
            wall=now - self._t0,
            step_time=step_time,
            tokens_per_sec=tokens / dt if dt > 0 else 0.0,
            tokens_seen=self._tokens_seen,
            skipped_total=self.skipped_total,
        )
        if self._model_flops:
            m["model_flops_per_sec"] = self._model_flops / step_time
            if self.hlo_cost and self.hlo_cost.get("flops"):
                ndev = self.mesh.size if self.mesh is not None else 1
                m["useful_flop_ratio"] = (
                    self._model_flops / ndev
                ) / self.hlo_cost["flops"]
            if self.peak_flops:
                m["mfu"] = self._model_flops / step_time / self.peak_flops
        if self.metrics is not None:
            # registry feed: everything below is already host-side (the
            # single bulk fetch above) — zero extra device traffic
            self._c_steps.inc(n)
            self._c_tokens.inc(tokens)
            self._h_step.observe(step_time)
            for name in self._tg:
                if name in m:
                    self._tg[name].set(m[name])
            load = last.get("router_load")
            if load is not None and np.ndim(load) == 1:
                for e, frac in enumerate(np.asarray(load)):
                    self._g_load.labels(str(e)).set(float(frac))
        self.history.append(m)
        if self.verbose:
            skips = f"  SKIPPED {self.skipped_total}" if self.skipped_total else ""
            print(
                f"step {s:5d}  loss {m['loss']:.4f}  ce {m['ce_loss']:.4f}  "
                f"gnorm {m['grad_norm']:.2f}  lr {m['lr']:.2e}  "
                f"{m['tokens_per_sec']:.0f} tok/s  {m['wall']:.1f}s{skips}"
            )
        for h in self.hooks:
            h(s, m)

    def run(
        self,
        batches,
        *,
        state: Optional[TrainState] = None,
        resume_from: Optional[str] = None,
    ):
        """Train to ``tc.total_steps``; returns ``(state, history)``."""
        self.prepare(batches, state=state, resume_from=resume_from)
        while self.step_idx < self.tc.total_steps:
            self.step()
        if self.tc.ckpt_every and self.tc.ckpt_dir:
            final = os.path.join(
                self.tc.ckpt_dir, f"step_{self.tc.total_steps}"
            )
            if not os.path.isdir(final):
                self.save(final)
        return self.state, self.history

    # -------------------------------------------------------- checkpointing
    def save(self, ckpt_dir: str) -> None:
        """Full-state checkpoint: TrainState + data cursor + counters.

        ``tokens_seen`` must cover every completed step, including the
        ones whose metrics are still pending the next log flush (a
        checkpoint need not align with a log boundary) — fetching their
        token counts here is fine, checkpointing is a host sync anyway.
        The in-memory counter is untouched; those steps still add to it
        at their regular flush."""
        pending_tokens = float(
            sum(jax.device_get([m["tokens"] for m in self._pending]))
        ) if self._pending else 0.0
        extra = {
            "step_idx": self.step_idx,
            "tokens_seen": self._tokens_seen + pending_tokens,
            "data": self._it.cursor if self._it is not None else None,
        }
        ckpt.save_train_state(ckpt_dir, self.state, self.step_idx, extra=extra)

    def load(self, ckpt_dir: str, batches=None) -> "Trainer":
        """Sharding-aware restore of the full TrainState; rewinds the data
        pipeline to the saved cursor when it supports ``load_state_dict``."""
        shardings = (
            TS.state_shardings(self.model) if self.mesh is not None else None
        )
        state, step, extra = ckpt.restore_train_state(
            ckpt_dir, TS.abstract_train_state(self.model), shardings
        )
        self.state = state if self.mesh is not None else self._place_state(state)
        self.step_idx = int(extra.get("step_idx", step))
        self._tokens_seen = float(extra.get("tokens_seen", 0.0))
        cur = extra.get("data")
        if cur is not None and hasattr(batches, "load_state_dict"):
            batches.load_state_dict(cur)
        return self


def run_training(
    model: Model,
    tc: TrainConfig,
    batches: Iterator[Dict[str, np.ndarray]],
    *,
    state: Optional[TrainState] = None,
    hooks: Optional[List[Callable[[int, Dict[str, float]], None]]] = None,
    verbose: bool = True,
) -> tuple[TrainState, List[Dict[str, float]]]:
    """Back-compat functional wrapper over :class:`Trainer`."""
    return Trainer(model, tc, hooks=hooks, verbose=verbose).run(
        batches, state=state
    )
