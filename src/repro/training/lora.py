"""LoRA fine-tuning (BioNeMo ships PEFT/LoRA recipes as first-class
features for adapting ESM-2/Geneformer to downstream drug-discovery tasks).

Implementation: adapters live in a *separate* pytree from the frozen base
params — the base stays sharded/donated untouched, the optimizer holds
states only for the adapters (tiny), and merging is an explicit export
step.  Adapters target the attention projections (wq/wk/wv/wo) and/or MLP
in/out, selected by name.

    adapters   = lora.init_adapters(model, rank=8, key=key)
    apply_fn   = lora.merged_params(model, base_params, adapters)  # lazily
    loss       = model.loss_fn(apply_fn, batch)
"""
from __future__ import annotations

import math
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.models.model import Model

DEFAULT_TARGETS = ("wq", "wv")


def _walk(tree: Any, path=()):
    if isinstance(tree, dict):
        for k, v in tree.items():
            yield from _walk(v, path + (k,))
    else:
        yield path, tree


def target_paths(
    params: Any, targets: Tuple[str, ...] = DEFAULT_TARGETS
) -> List[Tuple[str, ...]]:
    """Paths of 2-D (or scan-stacked 3-D) weights whose leaf name matches."""
    out = []
    for path, leaf in _walk(params):
        if path[-1] in targets and getattr(leaf, "ndim", 0) in (2, 3):
            out.append(path)
    return sorted(out)


def init_adapters(
    base_params: Any,
    rank: int = 8,
    alpha: float = 16.0,
    targets: Tuple[str, ...] = DEFAULT_TARGETS,
    *,
    key: jax.Array,
) -> Dict[str, Any]:
    """A/B pairs per target weight; A ~ N(0, 1/r), B = 0 (standard init)."""
    adapters: Dict[str, Any] = {"alpha": jnp.float32(alpha), "weights": {}}
    for i, path in enumerate(target_paths(base_params, targets)):
        leaf = base_params
        for k in path:
            leaf = leaf[k]
        stacked = leaf.ndim == 3  # (layers, din, dout)
        din, dout = leaf.shape[-2], leaf.shape[-1]
        lead = (leaf.shape[0],) if stacked else ()
        ka = jax.random.fold_in(key, i)
        A = jax.random.normal(ka, (*lead, din, rank), jnp.float32) / math.sqrt(rank)
        B = jnp.zeros((*lead, rank, dout), jnp.float32)
        adapters["weights"]["/".join(path)] = {"A": A, "B": B}
    return adapters


def merged_params(base_params: Any, adapters: Dict[str, Any]) -> Any:
    """Functional merge: W' = W + (alpha/r)·A·B (no in-place mutation)."""
    alpha = adapters["alpha"]
    wmap = adapters["weights"]

    def merge(tree, path=()):
        if isinstance(tree, dict):
            return {k: merge(v, path + (k,)) for k, v in tree.items()}
        key = "/".join(path)
        if key in wmap:
            A, B = wmap[key]["A"], wmap[key]["B"]
            r = A.shape[-1]
            delta = jnp.einsum("...ir,...ro->...io", A, B) * (alpha / r)
            return (tree.astype(jnp.float32) + delta).astype(tree.dtype)
        return tree

    return merge(base_params)


def make_lora_loss(model: Model, base_params: Any):
    """loss(adapters, batch) — differentiates ONLY the adapters."""

    def loss_fn(adapters, batch):
        params = merged_params(base_params, adapters)
        return model.loss_fn(params, batch)

    return loss_fn


def count_trainable(adapters: Dict[str, Any]) -> int:
    return sum(
        x.size for x in jax.tree.leaves(adapters["weights"])
    )
