"""Train/serve step builders: loss + grad + clip + AdamW, with shardings.

``make_train_step`` builds the raw ``step_fn(state, batch)`` — including
microbatch gradient accumulation (``TrainConfig.accum_steps``) and the
mixed-precision policy (bf16 compute params cast once per step from the
fp32 master copy held in ``TrainState``; see ``core/precision.compute_view``).

``make_sharded_train_step`` is the distributed entry point: it consumes
``train_state_specs(model)`` / the model's ``ShardingCtx`` and returns
``jit(step_fn, in_shardings=…, out_shardings=…, donate_argnums=…)`` — the
same builder serves CPU unit tests (mesh=None), the 8-virtual-device CPU
mesh (``--xla_force_host_platform_device_count=8``) and the 256/512-chip
production mesh.  ``training/loop.Trainer`` drives it end-to-end.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

from repro.core.config import ModelConfig, ParallelConfig, TrainConfig
from repro.core.precision import compute_view, dtype_of
from repro.models.model import Model, build_model
from repro.optim import adamw
from repro.optim.schedule import lr_at


class TrainState:
    """Plain pytree: params + optimizer state."""

    def __init__(self, params, opt: adamw.AdamWState):
        self.params = params
        self.opt = opt

    def tree_flatten(self):
        return (self.params, self.opt), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


jax.tree_util.register_pytree_node(
    TrainState, TrainState.tree_flatten, TrainState.tree_unflatten
)


def init_train_state(model: Model, key, tc: TrainConfig) -> TrainState:
    params = model.init(key)
    sdt = dtype_of(model.ctx.pc.optimizer_state_dtype)
    return TrainState(params, adamw.init_state(params, sdt))


def abstract_train_state(model: Model) -> TrainState:
    params = model.abstract_params()
    sdt = dtype_of(model.ctx.pc.optimizer_state_dtype)
    z = lambda p: jax.ShapeDtypeStruct(p.shape, sdt)
    opt = adamw.AdamWState(
        step=jax.ShapeDtypeStruct((), jnp.int32),
        mu=jax.tree.map(z, params),
        nu=jax.tree.map(z, params),
    )
    return TrainState(params, opt)


def train_state_specs(model: Model) -> TrainState:
    pspecs = model.param_specs()
    return TrainState(pspecs, adamw.state_specs(pspecs))


def state_shardings(model: Model) -> TrainState:
    """``train_state_specs`` mapped onto the model's mesh as NamedShardings
    (the checkpoint-restore / device_put / jit in_shardings currency)."""
    mesh = model.ctx.mesh
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), train_state_specs(model)
    )


def host_batch_sharding(model: Model) -> NamedSharding:
    """Pytree-prefix sharding for any host batch dict: the leading (batch)
    dim of every leaf lands on the mesh's data axes, the rest replicated."""
    return NamedSharding(
        model.ctx.mesh, PartitionSpec(model.ctx.rules.get("batch"))
    )


def _split_micro(batch: Dict[str, jax.Array], accum: int):
    """(B, …) -> (accum, B/accum, …) microbatch stack for lax.scan."""

    def sp(x):
        if x.shape[0] % accum:
            raise ValueError(
                f"global batch {x.shape[0]} not divisible by "
                f"accum_steps {accum}"
            )
        return x.reshape(accum, x.shape[0] // accum, *x.shape[1:])

    return jax.tree.map(sp, batch)


def make_train_step(model: Model, tc: TrainConfig):
    """Returns step_fn(state, batch) -> (state, metrics).

    * Mixed precision: the forward/backward runs on a compute-dtype view of
      the master params (``compute_view``); gradients land back in the
      master dtype and AdamW updates the fp32 copy.
    * Gradient accumulation: ``tc.accum_steps > 1`` scans microbatches with
      fp32 grad accumulators, weighting each microbatch gradient by its
      token count, so ``accum=N`` matches one N×-larger batch exactly for
      the masked-mean CE loss (MLM microbatches mask different token
      counts); the MoE aux term is token-weighted too, which coincides with
      the large-batch value when microbatch token counts are equal.
    """
    accum = max(int(tc.accum_steps), 1)
    policy = model.policy

    def loss_and_grads(params, mb):
        def loss_of(p):
            return model.loss_fn(compute_view(policy, p), mb)

        return jax.value_and_grad(loss_of, has_aux=True)(params)

    def step_fn(state: TrainState, batch: Dict[str, jax.Array]):
        params = state.params
        if accum == 1:
            (loss, metrics), grads = loss_and_grads(params, batch)
            metrics = dict(metrics)
            metrics["loss"] = loss
        else:
            micro = _split_micro(batch, accum)
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            # token-weighted: loss/ce average over tokens; microbatch-mean:
            # aux/router stats are already per-layer-summed means per
            # microbatch, so they average over the accum steps
            moe = bool(model.cfg.num_experts)
            acc0 = {"loss": 0.0, "ce_loss": 0.0, "tokens": 0.0,
                    "aux_loss": 0.0}
            if moe:
                acc0.update(
                    router_entropy=0.0, router_drop_frac=0.0,
                    router_load=jnp.zeros((model.cfg.num_experts,)),
                )
            acc0 = jax.tree.map(lambda v: jnp.asarray(v, jnp.float32), acc0)
            init = (zeros, acc0)

            def one(carry, mb):
                g_acc, acc = carry
                (loss, m), grads = loss_and_grads(params, mb)
                d = m["tokens"].astype(jnp.float32)
                g_acc = jax.tree.map(
                    lambda a, g: a + d * g.astype(jnp.float32), g_acc, grads
                )
                upd = {
                    "loss": acc["loss"] + d * loss,
                    "ce_loss": acc["ce_loss"] + d * m["ce_loss"],
                    "tokens": acc["tokens"] + d,
                    "aux_loss": acc["aux_loss"] + m["aux_loss"] / accum,
                }
                if moe:
                    for k in ("router_entropy", "router_drop_frac",
                              "router_load"):
                        upd[k] = acc[k] + m[k] / accum
                return (g_acc, upd), None

            (g_acc, acc), _ = jax.lax.scan(one, init, micro)
            d_acc = acc["tokens"]
            grads = jax.tree.map(
                lambda g, p: (g / d_acc).astype(p.dtype), g_acc, params
            )
            metrics = dict(
                acc, loss=acc["loss"] / d_acc, ce_loss=acc["ce_loss"] / d_acc
            )
        grads, gnorm = adamw.clip_by_global_norm(grads, tc.grad_clip)
        lr = lr_at(tc, state.opt.step + 1)  # first update uses step 1 (warmup>0)
        new_params, new_opt = adamw.apply_updates(
            params, grads, state.opt, lr, tc
        )
        # non-finite guard: a diverged/poisoned step (NaN/inf loss or
        # grad norm — the clip already rescaled by gnorm, so one bad
        # grad taints EVERY param) applies NO update.  Params and AdamW
        # moments keep their old values and opt.step does not advance,
        # so the lr schedule is unaffected; the host-side Trainer counts
        # consecutive skips and aborts past TrainConfig.max_nonfinite_skips.
        ok = jnp.isfinite(metrics["loss"]) & jnp.isfinite(gnorm)
        sel = lambda new, old: jax.tree.map(
            lambda n, o: jnp.where(ok, n, o), new, old
        )
        params, opt = sel(new_params, params), sel(new_opt, state.opt)
        metrics.update(
            grad_norm=gnorm, lr=lr,
            skipped=(~ok).astype(jnp.float32),
        )
        return TrainState(params, opt), metrics

    return step_fn


def make_sharded_train_step(model: Model, tc: TrainConfig):
    """The distributed train step: ``make_train_step`` jitted against the
    model's mesh with state/batch in_shardings, state out_shardings and a
    donated input state.  Off-mesh (mesh=None or a 1-device mesh) it
    degrades to a plain donated jit, so the same builder runs everywhere.
    """
    step_fn = make_train_step(model, tc)
    donate = (0,) if model.ctx.pc.donate_params else ()
    mesh = model.ctx.mesh
    if mesh is None or mesh.empty or mesh.size == 1:
        return jax.jit(step_fn, donate_argnums=donate)
    state_sh = state_shardings(model)
    batch_sh = host_batch_sharding(model)
    metrics_sh = NamedSharding(mesh, PartitionSpec())
    return jax.jit(
        step_fn,
        in_shardings=(state_sh, batch_sh),
        out_shardings=(state_sh, metrics_sh),
        donate_argnums=donate,
    )


def init_sharded_train_state(model: Model, key, tc: TrainConfig) -> TrainState:
    """Initialize the TrainState, then place it onto its mesh shardings.

    Init runs un-sharded on the default device so the draws are identical
    to the single-device reference regardless of mesh shape (legacy
    non-partitionable threefry changes values when the RNG computation is
    partitioned); ``device_put`` then scatters the leaves.  At true
    3B-on-256-chips scale, enable ``jax_threefry_partitionable`` and jit
    the init with ``out_shardings=state_shardings(model)`` instead so
    params materialize pre-sharded.
    """
    state = init_train_state(model, key, tc)
    mesh = model.ctx.mesh
    if mesh is None or mesh.empty or mesh.size == 1:
        return state
    return jax.device_put(state, state_shardings(model))


def make_eval_step(model: Model):
    def eval_fn(params, batch):
        loss, metrics = model.loss_fn(params, batch)
        return metrics

    return eval_fn


# ------------------------------------------------------------------ serving
def make_prefill_step(model: Model, max_len: int):
    def prefill_fn(params, batch):
        return model.prefill(params, batch, max_len)

    return prefill_fn


def make_decode_step(model: Model):
    def decode_fn(params, cache, tokens):
        return model.decode_step(params, cache, tokens)

    return decode_fn
