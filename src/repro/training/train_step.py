"""Train/serve step builders: loss + grad + clip + AdamW, with shardings.

``make_train_step`` returns (step_fn, in_shardings, out_shardings) ready for
``jax.jit`` — the same builder serves CPU unit tests (mesh=None) and the
256/512-chip dry-run (mesh=production).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.config import ModelConfig, ParallelConfig, TrainConfig
from repro.core.precision import dtype_of
from repro.models.model import Model, build_model
from repro.optim import adamw
from repro.optim.schedule import lr_at


class TrainState:
    """Plain pytree: params + optimizer state."""

    def __init__(self, params, opt: adamw.AdamWState):
        self.params = params
        self.opt = opt

    def tree_flatten(self):
        return (self.params, self.opt), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


jax.tree_util.register_pytree_node(
    TrainState, TrainState.tree_flatten, TrainState.tree_unflatten
)


def init_train_state(model: Model, key, tc: TrainConfig) -> TrainState:
    params = model.init(key)
    sdt = dtype_of(model.ctx.pc.optimizer_state_dtype)
    return TrainState(params, adamw.init_state(params, sdt))


def abstract_train_state(model: Model) -> TrainState:
    params = model.abstract_params()
    sdt = dtype_of(model.ctx.pc.optimizer_state_dtype)
    z = lambda p: jax.ShapeDtypeStruct(p.shape, sdt)
    opt = adamw.AdamWState(
        step=jax.ShapeDtypeStruct((), jnp.int32),
        mu=jax.tree.map(z, params),
        nu=jax.tree.map(z, params),
    )
    return TrainState(params, opt)


def train_state_specs(model: Model) -> TrainState:
    pspecs = model.param_specs()
    return TrainState(pspecs, adamw.state_specs(pspecs))


def make_train_step(model: Model, tc: TrainConfig):
    """Returns step_fn(state, batch) -> (state, metrics)."""

    def step_fn(state: TrainState, batch: Dict[str, jax.Array]):
        def loss_of(params):
            return model.loss_fn(params, batch)

        (loss, metrics), grads = jax.value_and_grad(loss_of, has_aux=True)(state.params)
        grads, gnorm = adamw.clip_by_global_norm(grads, tc.grad_clip)
        lr = lr_at(tc, state.opt.step + 1)  # first update uses step 1 (warmup>0)
        params, opt = adamw.apply_updates(state.params, grads, state.opt, lr, tc)
        metrics = dict(metrics)
        metrics.update(loss=loss, grad_norm=gnorm, lr=lr)
        return TrainState(params, opt), metrics

    return step_fn


def make_eval_step(model: Model):
    def eval_fn(params, batch):
        loss, metrics = model.loss_fn(params, batch)
        return metrics

    return eval_fn


# ------------------------------------------------------------------ serving
def make_prefill_step(model: Model, max_len: int):
    def prefill_fn(params, batch):
        return model.prefill(params, batch, max_len)

    return prefill_fn


def make_decode_step(model: Model):
    def decode_fn(params, cache, tokens):
        return model.decode_step(params, cache, tokens)

    return decode_fn
