"""Shared test configuration.

Registers the "ci" Hypothesis profile at collection time so
``pytest --hypothesis-profile=ci`` (the CI serving/property job) can
select it: derandomized (fixed seed) for reproducible runs, no deadline
(CI boxes are noisy).  Individual property tests may override
``max_examples`` with their own ``@settings``.
"""
try:
    from hypothesis import settings

    settings.register_profile("ci", max_examples=20, deadline=None,
                              derandomize=True)
except ImportError:  # hypothesis is optional outside the CI serving job
    pass
