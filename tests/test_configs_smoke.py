"""Per-architecture smoke tests: a REDUCED variant of each assigned config
(<=2 layers, d_model<=256, <=4 experts — same family wiring) runs one
forward/train step and, where applicable, one prefill+decode step on CPU.
Asserts output shapes and absence of NaNs."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, get_smoke_config, list_archs
from repro.core.config import TrainConfig
from repro.models.model import build_model
from repro.training.train_step import init_train_state, make_train_step

ASSIGNED = [
    "command-r-35b", "mamba2-2.7b", "qwen1.5-32b", "llama4-scout-17b-a16e",
    "whisper-medium", "internvl2-26b", "qwen2-7b", "llama3-405b",
    "llama4-maverick-400b-a17b", "jamba-1.5-large-398b",
]
BIO = ["esm2-650m", "esm2-3b", "geneformer-106m", "molmim-65m"]


def make_batch(cfg, B=2, S=32, key=jax.random.PRNGKey(0)):
    batch = {}
    if cfg.frontend == "vision_stub":
        nf = cfg.num_frontend_tokens
        batch["tokens"] = jax.random.randint(key, (B, S), 5, cfg.vocab_size)
        batch["img_embeds"] = jax.random.normal(key, (B, nf, cfg.d_model))
    elif cfg.frontend == "audio_stub":
        batch["tokens"] = jax.random.randint(key, (B, S), 5, cfg.vocab_size)
        batch["enc_embeds"] = jax.random.normal(
            key, (B, cfg.num_frontend_tokens, cfg.d_model)
        )
    elif cfg.is_encoder_decoder:
        batch["tokens"] = jax.random.randint(key, (B, S), 5, cfg.vocab_size)
        batch["src_tokens"] = batch["tokens"]
    elif cfg.objective == "mlm":
        batch["tokens"] = jax.random.randint(key, (B, S), 5, cfg.vocab_size)
        batch["targets"] = batch["tokens"]
        batch["loss_mask"] = jnp.ones((B, S), jnp.float32)
    else:
        batch["tokens"] = jax.random.randint(key, (B, S), 5, cfg.vocab_size)
    return batch


@pytest.mark.parametrize("arch", ASSIGNED + BIO)
def test_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    assert cfg.num_layers <= max(2, cfg.attn_layer_period)
    assert cfg.d_model <= 512 and (cfg.num_experts or 0) <= 4
    model = build_model(cfg)
    tc = TrainConfig(total_steps=1, warmup_steps=1)
    state = init_train_state(model, jax.random.PRNGKey(0), tc)
    batch = make_batch(cfg)
    step = jax.jit(make_train_step(model, tc))
    new_state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"])), (arch, metrics)
    # params updated (at least one leaf changed)
    changed = any(
        not np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(
            jax.tree.leaves(state.params), jax.tree.leaves(new_state.params)
        )
    )
    assert changed, arch


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_prefill_decode(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, B=2, S=16)
    batch.pop("targets", None)
    batch.pop("loss_mask", None)
    logits, cache = jax.jit(lambda p, b: model.prefill(p, b, 32))(params, batch)
    V = cfg.padded_vocab
    assert logits.shape == (2, 1, V)
    assert np.isfinite(np.asarray(logits)).all(), arch
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    logits2, cache = jax.jit(model.decode_step)(params, cache, tok)
    assert logits2.shape == (2, 1, V)
    assert np.isfinite(np.asarray(logits2)).all(), arch
    n_front = cfg.num_frontend_tokens if cfg.frontend == "vision_stub" else 0
    assert int(cache["pos"]) == 16 + n_front + 1


@pytest.mark.parametrize("arch", ASSIGNED)
def test_full_config_matches_assignment(arch):
    """The FULL configs carry the exact assigned dimensions."""
    spec = {
        "command-r-35b": (40, 8192, 64, 8, 22528, 256000),
        "mamba2-2.7b": (64, 2560, None, None, 0, 50280),
        "qwen1.5-32b": (64, 5120, 40, 40, 27392, 152064),
        "llama4-scout-17b-a16e": (48, 5120, 40, 8, 8192, 202048),
        "whisper-medium": (24, 1024, 16, 16, 4096, 51865),
        "internvl2-26b": (48, 6144, 48, 8, 16384, 92553),
        "qwen2-7b": (28, 3584, 28, 4, 18944, 152064),
        "llama3-405b": (126, 16384, 128, 8, 53248, 128256),
        "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048),
        "jamba-1.5-large-398b": (72, 8192, 64, 8, 24576, 65536),
    }[arch]
    cfg = get_config(arch)
    L, d, h, kv, ff, V = spec
    assert cfg.num_layers == L and cfg.d_model == d
    if h is not None:
        assert cfg.num_heads == h and cfg.num_kv_heads == kv
    assert cfg.d_ff == ff and cfg.vocab_size == V
    assert cfg.citation


def test_moe_configs_expert_counts():
    assert get_config("llama4-scout-17b-a16e").num_experts == 16
    assert get_config("llama4-maverick-400b-a17b").num_experts == 128
    j = get_config("jamba-1.5-large-398b")
    assert j.num_experts == 16 and j.num_experts_per_tok == 2
    assert j.attn_layer_period == 8


def test_param_counts_in_expected_range():
    expect = {
        "llama3-405b": (380e9, 430e9),
        "jamba-1.5-large-398b": (370e9, 420e9),
        "llama4-maverick-400b-a17b": (370e9, 420e9),
        "mamba2-2.7b": (2.4e9, 3.0e9),
        "qwen2-7b": (7.0e9, 8.2e9),
        "esm2-650m": (0.6e9, 0.72e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, (arch, n)
