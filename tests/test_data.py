"""Data substrate: memmap datasets, cluster sampling statistics, MLM
corruption statistics, CLM packing."""
import numpy as np
import pytest

from repro.data.dataset import (
    MemmapTokenDataset,
    build_synthetic_protein_memmap,
    synthetic_protein_sequences,
)
from repro.data.pipeline import CLMBatches, MLMBatches, mlm_corrupt
from repro.data.sampler import ClusterSampler, greedy_length_clusters
from repro.data.tokenizer import ProteinTokenizer, SmilesTokenizer


def test_memmap_roundtrip(tmp_path):
    seqs = [np.arange(i + 3, dtype=np.int32) for i in range(17)]
    ds = MemmapTokenDataset.write(str(tmp_path / "d"), seqs)
    assert len(ds) == 17
    for i in (0, 5, 16):
        np.testing.assert_array_equal(ds[i], seqs[i])
    ds2 = MemmapTokenDataset(str(tmp_path / "d"))
    np.testing.assert_array_equal(ds2[7], seqs[7])


def test_protein_tokenizer_roundtrip():
    tok = ProteinTokenizer()
    s = "MKVLAAGERT"
    ids = tok.encode(s)
    assert ids[0] == tok.cls_id and ids[-1] == tok.eos_id
    assert tok.decode(ids) == s
    assert tok.vocab_size == 30  # 5 specials + 25 AA codes


def test_cluster_sampler_uniform_over_clusters():
    """A 100x-bigger cluster must NOT be sampled 100x more often (UniRef50
    down-weighting semantics)."""
    members = [list(range(0, 1000)), [1000], [1001, 1002]]
    s = ClusterSampler(members, seed=0)
    draws = s.sample(9000)
    counts = [
        np.isin(draws, m).sum() for m in members
    ]
    frac = np.array(counts) / 9000
    np.testing.assert_allclose(frac, [1 / 3] * 3, atol=0.03)


def test_mlm_corruption_statistics():
    tok = ProteinTokenizer()
    rng = np.random.default_rng(0)
    toks = rng.integers(5, tok.vocab_size, size=(64, 128)).astype(np.int32)
    out = mlm_corrupt(toks, tok, rng, mask_prob=0.15)
    mask = out["loss_mask"].astype(bool)
    rate = mask.mean()
    assert 0.10 < rate < 0.20
    # ~80% of selected positions became <mask>
    masked = (out["tokens"] == tok.mask_id) & mask
    assert 0.7 < masked.sum() / mask.sum() < 0.9
    # unselected positions unchanged
    np.testing.assert_array_equal(out["tokens"][~mask], toks[~mask])
    np.testing.assert_array_equal(out["targets"], toks)
    # every row has at least one target
    assert mask.any(axis=1).all()


def test_clm_packing_stream(tmp_path):
    ds, tok = build_synthetic_protein_memmap(str(tmp_path / "p"), n=50)
    it = iter(CLMBatches(ds, batch=4, seq_len=64))
    b1, b2 = next(it), next(it)
    assert b1["tokens"].shape == (4, 64)
    assert b1["tokens"].dtype == np.int32
    assert not np.array_equal(b1["tokens"], b2["tokens"])


def test_mlm_batches_with_cluster_sampler(tmp_path):
    ds, tok = build_synthetic_protein_memmap(str(tmp_path / "p"), n=100)
    lengths = [len(ds[i]) for i in range(len(ds))]
    sampler = ClusterSampler(greedy_length_clusters(lengths, 10))
    it = iter(MLMBatches(ds, tok, sampler, batch=4, seq_len=48))
    b = next(it)
    assert set(b) == {"tokens", "targets", "loss_mask"}
    assert b["tokens"].shape == (4, 48)
    assert (b["loss_mask"].sum(1) >= 1).all()


def test_synthetic_sequences_have_shared_motifs():
    seqs = synthetic_protein_sequences(50, seed=1)
    # learnability proxy: 4-mers repeat far above chance
    from collections import Counter

    c = Counter()
    for s in seqs:
        for i in range(len(s) - 4):
            c[s[i:i + 4]] += 1
    top = c.most_common(5)
    assert top[0][1] > 20
