"""Data-plane subsystem: sharded memmap store, token-budget batching,
background producer — and the full-stack bit-exact resume contract
(sharded store + size-aware sampler + producer through the Trainer).
"""
import os

import numpy as np
import pytest

from repro.data.dataset import (
    build_synthetic_protein_memmap,
    build_synthetic_protein_store,
)
from repro.data.pipeline import CLMBatches, MLMBatches
from repro.data.producer import BackgroundProducer
from repro.data.sampler import ClusterSampler, greedy_length_clusters
from repro.data.size_aware import SizeAwareSampler, length_buckets
from repro.data.store import (
    MANIFEST,
    ShardedStoreWriter,
    ShardedTokenStore,
)

try:
    from hypothesis import given, settings, strategies as st

    HAS_HYPOTHESIS = True
except ImportError:  # pragma: no cover - CI installs hypothesis
    HAS_HYPOTHESIS = False


def _corpus(tmp_path, n=300, seed=1, shard_tokens=4096):
    return build_synthetic_protein_store(
        str(tmp_path / "store"), n=n, seed=seed, shard_tokens=shard_tokens
    )


# --------------------------------------------------------------------- #
# sharded store
# --------------------------------------------------------------------- #
def test_store_roundtrip_matches_single_file(tmp_path):
    store, _ = _corpus(tmp_path)
    mm, _ = build_synthetic_protein_memmap(
        str(tmp_path / "mm" / "p"), n=300, seed=1
    )
    assert store.num_shards > 1  # the threshold actually sharded
    assert len(store) == len(mm)
    for i in (0, 1, 149, 298, 299):
        assert np.array_equal(store[i], mm[i])
    assert np.array_equal(store.lengths(), mm.lengths())
    assert store.total_tokens == int(mm.lengths().sum())


def test_store_locate_and_bounds(tmp_path):
    store, _ = _corpus(tmp_path)
    # every global index maps back through (shard, local) consistently
    for i in range(0, len(store), 13):
        k, j = store.locate(i)
        assert int(store.cum_seqs[k]) + j == i
        assert 0 <= j < store.shards[k]["sequences"]
    with pytest.raises(IndexError):
        store.locate(len(store))
    with pytest.raises(IndexError):
        store.locate(-1)


def test_store_manifest_committed_last(tmp_path):
    """A writer that never finalizes leaves shard files but NO manifest —
    the store is invisible, not truncated (atomic-commit discipline)."""
    root = str(tmp_path / "crash")
    w = ShardedStoreWriter(root, shard_tokens=64)
    for _ in range(20):
        w.add(np.arange(10, dtype=np.int32))
    # crash before finalize: shards staged, manifest absent
    assert any(f.endswith(".bin") for f in os.listdir(root))
    assert MANIFEST not in os.listdir(root)
    with pytest.raises(FileNotFoundError):
        ShardedTokenStore(root)
    w.finalize()
    assert len(ShardedTokenStore(root)) == 20


def test_store_writer_validation(tmp_path):
    w = ShardedStoreWriter(str(tmp_path / "v"))
    with pytest.raises(ValueError):
        w.add(np.empty((0,), np.int32))
    with pytest.raises(ValueError):
        w.finalize()  # empty store
    w2 = ShardedStoreWriter(str(tmp_path / "v2"))
    w2.add([1, 2, 3])
    w2.finalize()
    with pytest.raises(RuntimeError):
        w2.finalize()


def test_store_version_rejected(tmp_path):
    store, _ = _corpus(tmp_path)
    import json

    path = os.path.join(store.root, MANIFEST)
    with open(path) as f:
        m = json.load(f)
    m["version"] = 99
    with open(path, "w") as f:
        json.dump(m, f)
    with pytest.raises(ValueError, match="version"):
        ShardedTokenStore(store.root)


def test_worker_shards_disjoint_and_complete(tmp_path):
    store, _ = _corpus(tmp_path)
    W = 3
    assigned = [store.shard_assignment(w, W) for w in range(W)]
    flat = sorted(s for a in assigned for s in a)
    assert flat == list(range(store.num_shards))  # disjoint + complete
    seen = []
    for w in range(W):
        seen += [s.tobytes() for s in store.reader(worker=w, num_workers=W)]
    assert len(seen) == len(store)
    assert sorted(seen) == sorted(store[i].tobytes() for i in range(len(store)))
    with pytest.raises(ValueError):
        store.shard_assignment(3, 3)


def test_reader_resume_bit_exact(tmp_path):
    store, _ = _corpus(tmp_path)
    r = store.reader(worker=1, num_workers=2)
    consumed = [next(r) for _ in range(25)]
    cur = r.state_dict()
    rest = [s.tobytes() for s in r]

    r2 = store.reader(worker=1, num_workers=2)
    r2.load_state_dict(cur)
    rest2 = [s.tobytes() for s in r2]
    assert rest == rest2
    assert len(consumed) + len(rest) == len(store.reader(worker=1, num_workers=2))


# --------------------------------------------------------------------- #
# size-aware (token-budget) batching
# --------------------------------------------------------------------- #
def _check_budget(sas, lengths, budget, round_to=1, n=40):
    for _ in range(n):
        idx, L = sas.sample_batch()
        assert len(idx) * L <= budget, (len(idx), L)
        assert (lengths[idx] <= L).all()
        assert len(idx) % round_to == 0
        assert len(idx) >= 1


def test_size_aware_budget_and_round_to(tmp_path):
    store, _ = _corpus(tmp_path)
    lengths = store.lengths()
    for round_to in (1, 2, 4):
        sas = SizeAwareSampler(lengths, 2048, seed=0, round_to=round_to)
        _check_budget(sas, lengths, 2048, round_to)


def test_size_aware_composes_with_cluster_sampler(tmp_path):
    store, _ = _corpus(tmp_path)
    lengths = store.lengths()
    base = ClusterSampler(greedy_length_clusters(lengths, 8), seed=3)
    sas = SizeAwareSampler(lengths, 2048, base=base, seed=0)
    _check_budget(sas, lengths, 2048)


def test_size_aware_rejects_impossible_budget():
    with pytest.raises(ValueError, match="cannot fit"):
        SizeAwareSampler([10, 200], 100, round_to=1)
    with pytest.raises(ValueError, match="exceeds the top bucket"):
        SizeAwareSampler([10, 300], 4096, boundaries=[64, 128])


def test_length_buckets_geometric():
    b = length_buckets(200, min_len=16, growth=1.3)
    assert b[0] == 16 and b[-1] == 200
    assert (np.diff(b) > 0).all()
    # waste inside a bucket is bounded by the growth factor (+1 for the
    # integer ceil in each boundary)
    assert (b[1:] <= np.ceil(b[:-1] * 1.3)).all()


def test_size_aware_padding_waste_below_bound(tmp_path):
    """Mean padded-token waste of emitted batches stays under the
    geometric-bucket bound (1 - 1/growth plus slack), far below the
    ~50% of fixed-shape padding on this corpus."""
    store, _ = _corpus(tmp_path, n=500)
    lengths = np.minimum(store.lengths(), 256)
    sas = SizeAwareSampler(lengths, 8192, seed=0, growth=1.3)
    padded = real = 0
    for _ in range(60):
        idx, L = sas.sample_batch()
        padded += len(idx) * L
        real += int(lengths[idx].sum())
    waste = (padded - real) / padded
    assert waste < (1 - 1 / 1.3) + 0.05, waste


def _resume_matches(make):
    """Cursor contract: state_dict mid-stream -> identical batch future."""
    a = make()
    for _ in range(7):
        a.sample_batch()
    cur = a.state_dict()
    want = [a.sample_batch() for _ in range(10)]
    b = make()
    b.load_state_dict(cur)
    got = [b.sample_batch() for _ in range(10)]
    for (i1, l1), (i2, l2) in zip(want, got):
        assert l1 == l2 and np.array_equal(i1, i2)


def test_size_aware_resume_bit_exact_uniform(tmp_path):
    store, _ = _corpus(tmp_path)
    lengths = store.lengths()
    _resume_matches(lambda: SizeAwareSampler(lengths, 2048, seed=9))


def test_size_aware_resume_bit_exact_composed(tmp_path):
    store, _ = _corpus(tmp_path)
    lengths = store.lengths()
    _resume_matches(
        lambda: SizeAwareSampler(
            lengths, 2048, seed=9,
            base=ClusterSampler(greedy_length_clusters(lengths, 8), seed=4),
        )
    )


def test_size_aware_cursor_rejects_bucket_mismatch(tmp_path):
    store, _ = _corpus(tmp_path)
    lengths = store.lengths()
    cur = SizeAwareSampler(lengths, 2048, seed=0).state_dict()
    other = SizeAwareSampler(lengths, 2048, seed=0, boundaries=[64, 256])
    with pytest.raises(ValueError, match="bucket"):
        other.load_state_dict(cur)


if HAS_HYPOTHESIS:

    @settings(max_examples=25, deadline=None)
    @given(
        lens=st.lists(st.integers(1, 200), min_size=5, max_size=60),
        budget=st.integers(256, 4096),
        warm=st.integers(0, 12),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_size_aware_property_budget_and_resume(lens, budget, warm, seed):
        lengths = np.asarray(lens, np.int64)
        sas = SizeAwareSampler(lengths, budget, seed=seed)
        for _ in range(warm):
            idx, L = sas.sample_batch()
            assert len(idx) * L <= budget and (lengths[idx] <= L).all()
        cur = sas.state_dict()
        want = [sas.sample_batch() for _ in range(5)]
        sas2 = SizeAwareSampler(lengths, budget, seed=seed)
        sas2.load_state_dict(cur)
        got = [sas2.sample_batch() for _ in range(5)]
        for (i1, l1), (i2, l2) in zip(want, got):
            assert l1 == l2 and np.array_equal(i1, i2)

else:  # pragma: no cover - seeded fallback where hypothesis is absent

    def test_size_aware_property_budget_and_resume():
        rng = np.random.default_rng(0)
        for _ in range(25):
            lengths = rng.integers(1, 200, size=int(rng.integers(5, 60)))
            budget = int(rng.integers(256, 4096))
            sas = SizeAwareSampler(lengths, budget, seed=int(rng.integers(2**31)))
            for _ in range(int(rng.integers(0, 12))):
                idx, L = sas.sample_batch()
                assert len(idx) * L <= budget and (lengths[idx] <= L).all()
            cur = sas.state_dict()
            want = [sas.sample_batch() for _ in range(5)]
            # ctor seed is irrelevant after restore: the cursor carries
            # the full rng state
            sas2 = SizeAwareSampler(lengths, budget, seed=0)
            sas2.load_state_dict(cur)
            got = [sas2.sample_batch() for _ in range(5)]
            for (i1, l1), (i2, l2) in zip(want, got):
                assert l1 == l2 and np.array_equal(i1, i2)


# --------------------------------------------------------------------- #
# background producer
# --------------------------------------------------------------------- #
def _mlm(tmp_path, seed=9):
    mm, tok = build_synthetic_protein_memmap(
        str(tmp_path / "mm" / "p"), n=200, seed=2
    )
    return MLMBatches(mm, tok, None, batch=4, seq_len=64, seed=seed)


def test_producer_preserves_order(tmp_path):
    bare = iter(_mlm(tmp_path))
    with BackgroundProducer(_mlm(tmp_path), depth=3) as prod:
        for _ in range(12):
            a, b = next(bare), next(prod)
            assert all(np.array_equal(a[k], b[k]) for k in a)


def test_producer_resume_bit_exact(tmp_path):
    with BackgroundProducer(_mlm(tmp_path), depth=3) as prod:
        for _ in range(9):
            next(prod)
        cur = prod.state_dict()
        assert cur["consumed"] == 9
        want = [next(prod)["tokens"].copy() for _ in range(6)]
    p2 = BackgroundProducer(_mlm(tmp_path), depth=3)
    p2.load_state_dict(cur)
    with p2:
        got = [next(p2)["tokens"].copy() for _ in range(6)]
    assert all(np.array_equal(a, b) for a, b in zip(want, got))


def test_producer_cursor_excludes_prefetched(tmp_path):
    """The checkpoint cursor reflects CONSUMED batches only — prefetch
    depth never leaks into what a resume replays."""
    import time

    prod = BackgroundProducer(_mlm(tmp_path), depth=4)
    with prod:
        next(prod)
        time.sleep(0.3)  # let the worker fill the queue well past us
        cur = prod.state_dict()
    assert cur["consumed"] == 1
    p2 = BackgroundProducer(_mlm(tmp_path), depth=4)
    p2.load_state_dict(cur)
    bare = iter(_mlm(tmp_path))
    next(bare)  # skip batch 0
    with p2:
        assert np.array_equal(next(p2)["tokens"], next(bare)["tokens"])


def test_producer_finite_stream_and_close(tmp_path):
    store, _ = _corpus(tmp_path, n=40, shard_tokens=512)
    reader = store.reader()
    prod = BackgroundProducer(reader, depth=2)
    with prod:
        out = list(prod)
    assert len(out) == 40  # StopIteration propagated after the epoch
    with pytest.raises(StopIteration):  # stays exhausted, protocol-correct
        next(prod)
    prod.close()  # idempotent

    # a CLOSED (not exhausted) producer refuses to restart its worker
    p2 = BackgroundProducer(store.reader(), depth=2)
    with p2:
        next(p2)
    with pytest.raises(RuntimeError, match="closed"):
        next(p2)


def test_producer_propagates_worker_error():
    class Boom:
        def __iter__(self):
            yield {"x": 1}
            raise RuntimeError("poisoned shard")

    prod = BackgroundProducer(Boom(), depth=2)
    with prod:
        assert next(prod) == {"x": 1}
        with pytest.raises(RuntimeError, match="poisoned shard"):
            next(prod)


def test_producer_close_unblocks_full_queue():
    """close() must not deadlock against a worker blocked on put()."""

    def forever():
        while True:
            yield np.zeros((256,), np.int32)

    prod = BackgroundProducer(forever(), depth=1)
    next(prod)
    import time

    time.sleep(0.2)  # worker now blocked on the full queue
    t0 = time.perf_counter()
    prod.close()
    assert time.perf_counter() - t0 < 5.0
    assert prod._thread is None


def test_producer_rejects_late_restore(tmp_path):
    prod = BackgroundProducer(_mlm(tmp_path), depth=2)
    with prod:
        cur = prod.state_dict()
        next(prod)
        with pytest.raises(RuntimeError, match="after iteration"):
            prod.load_state_dict(cur)


# --------------------------------------------------------------------- #
# CLM EOS separators + bucketed pipelines
# --------------------------------------------------------------------- #
def test_clm_inserts_eos_between_documents(tmp_path):
    mm, tok = build_synthetic_protein_memmap(
        str(tmp_path / "mm" / "p"), n=100, seed=2
    )
    c = CLMBatches(mm, batch=2, seq_len=128, seed=0, eos_id=tok.eos_id)
    flat = np.concatenate(
        [next(iter(c))["tokens"].reshape(-1) for _ in range(4)]
    )
    n_eos = int((flat == tok.eos_id).sum())
    # every packed document ends in exactly one separator; with ~100-200
    # token docs a 1024-token window must contain several
    assert n_eos >= 3
    # document boundary integrity: replay the same rng and check each
    # sampled document appears contiguously, followed by the EOS
    rng = np.random.default_rng(0)
    pos = 0
    while pos < len(flat) - 300:
        doc = mm[int(rng.integers(len(mm)))]
        assert np.array_equal(flat[pos : pos + len(doc)], doc)
        assert flat[pos + len(doc)] == tok.eos_id
        pos += len(doc) + 1


def test_clm_eos_cursor_bit_exact(tmp_path):
    mm, tok = build_synthetic_protein_memmap(
        str(tmp_path / "mm" / "p"), n=100, seed=2
    )

    def make():
        return CLMBatches(mm, batch=2, seq_len=96, seed=5, eos_id=tok.eos_id)

    a = make()
    ia = iter(a)
    for _ in range(6):
        next(ia)
    cur = a.state_dict()
    want = [next(ia)["tokens"].copy() for _ in range(6)]
    b = make()
    b.load_state_dict(cur)
    ib = iter(b)
    got = [next(ib)["tokens"].copy() for _ in range(6)]
    assert all(np.array_equal(x, y) for x, y in zip(want, got))


def test_mlm_bucketed_respects_budget(tmp_path):
    store, tok = _corpus(tmp_path)
    lengths = np.minimum(store.lengths(), 128)
    sas = SizeAwareSampler(lengths, 1024, seed=5)
    it = iter(MLMBatches(store, tok, sas, batch=8, seq_len=128))
    shapes = set()
    for _ in range(30):
        b = it.__next__()
        r, L = b["tokens"].shape
        assert r * L <= 1024
        assert b["targets"].shape == (r, L)
        shapes.add((r, L))
    assert len(shapes) <= len(sas.boundaries)


def test_clm_bucketed_masks_padding(tmp_path):
    store, tok = _corpus(tmp_path)
    lengths = np.minimum(store.lengths(), 128)
    sas = SizeAwareSampler(lengths, 1024, seed=6)
    b = next(iter(CLMBatches(store, batch=8, seq_len=128, sampler=sas)))
    assert b["tokens"].shape == b["loss_mask"].shape
    # mask covers exactly the real tokens (pad id 0 beyond each length)
    real = b["loss_mask"].astype(bool)
    assert (b["tokens"][~real] == 0).all()
    assert (b["loss_mask"].sum(axis=1) >= 1).all()


# --------------------------------------------------------------------- #
# ClusterSampler vectorization regression
# --------------------------------------------------------------------- #
def test_cluster_sampler_vectorized_draws_preserved():
    """The vectorized sample() must consume the Generator's bit stream
    exactly as the former per-item loop did: identical indices for any
    fixed seed (resume cursors saved before the change stay valid)."""
    rng0 = np.random.default_rng(7)
    members = [
        rng0.integers(0, 10_000, size=int(rng0.integers(1, 50))).tolist()
        for _ in range(23)
    ]
    for seed in (0, 11, 99):
        got = ClusterSampler(members, seed=seed).sample(777)
        # inline oracle: the pre-vectorization implementation
        rng = np.random.default_rng(seed)
        m = [np.asarray(x, np.int64) for x in members]
        cl = rng.integers(0, len(m), size=777)
        want = np.array(
            [m[c][rng.integers(len(m[c]))] for c in cl], np.int64
        )
        assert np.array_equal(got, want)


def test_cluster_sampler_interleaved_draws_preserved():
    """Same equivalence across MULTIPLE sample() calls (the stream, not
    just one call, must match — cursors resume mid-stream)."""
    members = [[1, 2, 3], [4], [5, 6], [7, 8, 9, 10]]
    s = ClusterSampler(members, seed=3)
    got = np.concatenate([s.sample(n) for n in (5, 1, 17, 4)])
    rng = np.random.default_rng(3)
    m = [np.asarray(x, np.int64) for x in members]
    want = []
    for n in (5, 1, 17, 4):
        cl = rng.integers(0, len(m), size=n)
        want += [m[c][rng.integers(len(m[c]))] for c in cl]
    assert np.array_equal(got, np.asarray(want, np.int64))


# --------------------------------------------------------------------- #
# full stack: the acceptance resume test
# --------------------------------------------------------------------- #
def _tiny_mlm_cfg():
    from repro.core.config import ModelConfig

    return ModelConfig(
        name="dp-test", family="dense", num_layers=2, d_model=32,
        num_heads=2, num_kv_heads=2, d_ff=64, vocab_size=64,
        dtype="float32", objective="mlm",
    )


def test_trainer_resume_bit_exact_full_data_plane(tmp_path):
    """THE acceptance contract: sharded store + size-aware sampler +
    background producer, interrupted at a checkpoint — the resumed run's
    final params match the uninterrupted run bit-for-bit (which requires
    the exact same batch sequence through every prefetch layer)."""
    import jax

    from repro.core.config import TrainConfig
    from repro.launch.train import make_batches
    from repro.models.model import build_model
    from repro.training.loop import Trainer

    cfg = _tiny_mlm_cfg()
    tc = TrainConfig(
        global_batch=4, seq_len=64, learning_rate=1e-3, total_steps=8,
        warmup_steps=2, decay_steps=2, log_every=2,
        ckpt_dir=str(tmp_path / "ck"), ckpt_every=3,
    )
    model = build_model(cfg)

    def mk():
        return make_batches(
            cfg, tc, str(tmp_path / "data"),
            sharded=True, max_tokens=512, producer_depth=2,
        )

    b1 = mk()
    try:
        s1, _ = Trainer(model, tc, verbose=False).run(b1)
    finally:
        b1.close()

    b2 = mk()
    try:
        s2, _ = Trainer(model, tc, verbose=False).run(
            b2, resume_from=str(tmp_path / "ck" / "step_3")
        )
    finally:
        b2.close()

    for a, b in zip(
        jax.tree.leaves(jax.device_get(s1.params)),
        jax.tree.leaves(jax.device_get(s2.params)),
    ):
        assert np.array_equal(a, b)
    for a, b in zip(
        jax.tree.leaves(jax.device_get(s1.opt)),
        jax.tree.leaves(jax.device_get(s2.opt)),
    ):
        assert np.array_equal(a, b)


def test_trainer_compiles_once_per_shape(tmp_path):
    """Bucketed batches produce a bounded shape set; the trainer compiles
    each ONCE and reuses it (no per-step recompile)."""
    from repro.core.config import TrainConfig
    from repro.models.model import build_model
    from repro.training.loop import Trainer

    cfg = _tiny_mlm_cfg()
    tc = TrainConfig(
        global_batch=4, seq_len=64, learning_rate=1e-3, total_steps=10,
        warmup_steps=2, decay_steps=2, log_every=100,
    )
    store, tok = _corpus(tmp_path)
    # two buckets with very different capacities force >= 2 shapes fast
    lengths = np.minimum(store.lengths(), 64)
    sas = SizeAwareSampler(lengths, 256, seed=0, boundaries=[48, 64])
    pipe = MLMBatches(store, tok, sas, batch=4, seq_len=64)
    tr = Trainer(build_model(cfg), tc, verbose=False)
    tr.prepare(pipe)
    builds = []
    orig = tr._build_compiled

    def spy(batch, sig):
        builds.append(sig)
        return orig(batch, sig)

    tr._build_compiled = spy
    while tr.step_idx < tc.total_steps:
        tr.step()
    assert len(builds) == len(set(builds))  # never rebuilt a seen shape
    assert len(tr._compiled) == len(builds) >= 1
