"""Dry-run machinery on a small placeholder-device mesh (subprocess: the
XLA device-count flag must be set before jax initializes — we keep the main
pytest process at 1 device per the project rules).

Also validates the scan-aware HLO cost analyzer against XLA's own
cost_analysis on unrolled modules.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_py(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, env=env,
        timeout=600,
    )
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_hlo_analyzer_matches_xla_on_unrolled():
    from repro.launch.hlo_cost import analyze
    import jax, jax.numpy as jnp

    def f(w, x):
        for _ in range(6):
            x = jnp.tanh(x @ w)
        return x.sum()

    comp = jax.jit(f).lower(
        jax.ShapeDtypeStruct((128, 128), jnp.float32),
        jax.ShapeDtypeStruct((64, 128), jnp.float32),
    ).compile()
    a = analyze(comp.as_text())
    ca = comp.cost_analysis()
    if isinstance(ca, list):  # older jax wrapped the dict in a 1-elem list
        ca = ca[0]
    assert abs(a["flops"] - ca["flops"]) / ca["flops"] < 0.05
    assert abs(a["hbm_bytes"] - ca["bytes accessed"]) / ca["bytes accessed"] < 0.25


def test_hlo_analyzer_scan_equals_unroll():
    from repro.launch.hlo_cost import analyze
    import jax, jax.numpy as jnp

    def f_scan(w, x):
        y, _ = jax.lax.scan(lambda c, _: (jnp.tanh(c @ w), None), x, None, length=6)
        return y.sum()

    def f_unroll(w, x):
        for _ in range(6):
            x = jnp.tanh(x @ w)
        return x.sum()

    shapes = (
        jax.ShapeDtypeStruct((128, 128), jnp.float32),
        jax.ShapeDtypeStruct((64, 128), jnp.float32),
    )
    a_s = analyze(jax.jit(f_scan).lower(*shapes).compile().as_text())
    a_u = analyze(jax.jit(f_unroll).lower(*shapes).compile().as_text())
    assert a_s["flops"] == a_u["flops"]
    assert abs(a_s["hbm_bytes"] - a_u["hbm_bytes"]) / a_u["hbm_bytes"] < 0.2


@pytest.mark.parametrize("arch", ["qwen2-7b", "mamba2-2.7b", "jamba-1.5-large-398b"])
def test_dryrun_bundle_small_mesh(arch):
    code = textwrap.dedent(f"""
        import jax, json
        from repro.core.config import ParallelConfig
        from repro.configs import get_smoke_config
        from repro.launch.shapes import InputShape, dryrun_bundle
        from repro.launch.hlo_cost import analyze
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = get_smoke_config("{arch}")
        for shp in [InputShape("t", 64, 8, "train"), InputShape("d", 64, 8, "decode")]:
            fn, args, in_sh, meta = dryrun_bundle(cfg, shp, mesh, ParallelConfig())
            with mesh:
                comp = jax.jit(fn, in_shardings=in_sh).lower(*args).compile()
            a = analyze(comp.as_text())
            assert a["flops"] > 0
            print(json.dumps({{"kind": shp.kind, "flops": a["flops"],
                               "colls": sorted(a["collectives"]) }}))
    """)
    out = run_py(code)
    lines = [json.loads(l) for l in out.splitlines() if l.startswith("{")]
    assert len(lines) == 2
    assert all(l["flops"] > 0 for l in lines)


def test_multipod_mini_mesh():
    """(pod, data, model) 3-axis mesh lowers and shards the pod axis."""
    code = textwrap.dedent("""
        import jax, json
        from repro.core.config import ParallelConfig
        from repro.configs import get_smoke_config
        from repro.launch.shapes import InputShape, dryrun_bundle
        from repro.launch.hlo_cost import analyze
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        cfg = get_smoke_config("qwen2-7b")
        shp = InputShape("t", 64, 8, "train")
        pc = ParallelConfig(fsdp_axes=("pod", "data"))
        fn, args, in_sh, meta = dryrun_bundle(cfg, shp, mesh, pc)
        with mesh:
            comp = jax.jit(fn, in_shardings=in_sh).lower(*args).compile()
        a = analyze(comp.as_text())
        print(json.dumps({"flops": a["flops"], "ncolls": len(a["collectives"])}))
    """)
    out = run_py(code)
    rec = json.loads([l for l in out.splitlines() if l.startswith("{")][0])
    assert rec["flops"] > 0 and rec["ncolls"] >= 1


def test_production_mesh_shapes():
    code = textwrap.dedent("""
        from repro.launch.mesh import make_production_mesh
        m1 = make_production_mesh()
        m2 = make_production_mesh(multi_pod=True)
        print(m1.devices.shape, m1.axis_names)
        print(m2.devices.shape, m2.axis_names)
    """)
    out = run_py(code, devices=512)
    assert "(16, 16) ('data', 'model')" in out
    assert "(2, 16, 16) ('pod', 'data', 'model')" in out


def test_hlo_analyzer_nested_scans_multiply():
    """scan-inside-scan (layer scan × attention kv scan): flops must equal
    the fully unrolled program — multipliers compose across while nesting."""
    from repro.launch.hlo_cost import analyze
    import jax, jax.numpy as jnp

    def inner(x, w):  # kv-block-style scan
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=4)
        return y

    def f_nested(w, x):
        def layer(c, _):
            return inner(c, w), None
        y, _ = jax.lax.scan(layer, x, None, length=3)
        return y.sum()

    def f_unrolled(w, x):
        for _ in range(3):
            for _ in range(4):
                x = jnp.tanh(x @ w)
        return x.sum()

    shapes = (
        jax.ShapeDtypeStruct((64, 64), jnp.float32),
        jax.ShapeDtypeStruct((32, 64), jnp.float32),
    )
    a_n = analyze(jax.jit(f_nested).lower(*shapes).compile().as_text())
    a_u = analyze(jax.jit(f_unrolled).lower(*shapes).compile().as_text())
    assert a_n["flops"] == a_u["flops"] == 2 * 32 * 64 * 64 * 12
