"""``LLM.embed`` / ``Engine.embed``: batched embedding extraction through
the serving engine — pooled-vector correctness against a direct forward
oracle, input ordering, determinism, telemetry parity, and validation.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.config import ModelConfig
from repro.models.model import build_model
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import TraceRecorder
from repro.serving.api import LLM


def build(family="dense", **over):
    kw = dict(
        name="t", family=family, num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=2, d_ff=128, vocab_size=64, dtype="float32",
    )
    kw.update(over)
    cfg = ModelConfig(**kw)
    model = build_model(cfg)
    return model, model.init(jax.random.PRNGKey(0))


def oracle(model, params, prompt):
    """Direct masked-mean pooling of the train-mode hidden states for one
    unpadded prompt — what embed() must reproduce batched and padded."""
    t = jnp.asarray(np.asarray(prompt, np.int32)[None])
    x, _ = model._decoder_input(params, {"tokens": t}, "train")
    x, _, _ = model._backbone(params, x, mode="train")
    return np.asarray(x[0].astype(jnp.float32).mean(axis=0))


def _prompts(n=7, lo=3, hi=40, seed=0):
    rng = np.random.default_rng(seed)
    return [
        rng.integers(5, 64, size=int(L)).tolist()
        for L in rng.integers(lo, hi, size=n)
    ]


def test_embed_matches_direct_pooling_oracle():
    model, params = build()
    llm = LLM(model, params, slots=3, max_len=64)
    prompts = _prompts()
    out = llm.embed(prompts)
    assert out.shape == (len(prompts), 64) and out.dtype == np.float32
    for i, p in enumerate(prompts):
        want = oracle(model, params, p)
        np.testing.assert_allclose(out[i], want, atol=1e-4)


def test_embed_bidirectional_mlm_model():
    """Geneformer-style bidirectional stacks embed through the same path;
    padding is visible to attention exactly as during MLM training, and
    only valid positions enter the mean."""
    model, params = build(causal=False, objective="mlm")
    llm = LLM(model, params, slots=4, max_len=64)
    prompts = _prompts(5)
    out = llm.embed(prompts)
    for i, p in enumerate(prompts):
        # the oracle is padding-free; rows whose length hits their bucket
        # exactly see no pads, so compare one such prompt directly
        if 2 ** int(np.ceil(np.log2(len(p)))) == len(p) or len(p) <= 8:
            np.testing.assert_allclose(
                out[i], oracle(model, params, p), atol=1e-4
            )
    assert out.shape == (5, 64)


def test_embed_input_order_and_determinism():
    model, params = build()
    llm = LLM(model, params, slots=2, max_len=64)
    prompts = _prompts(9)
    a = llm.embed(prompts)
    assert np.array_equal(a, llm.embed(prompts))  # deterministic
    perm = [4, 0, 8, 2, 6, 1, 7, 3, 5]
    b = llm.embed([prompts[i] for i in perm])
    np.testing.assert_allclose(b, a[perm], atol=1e-5)


def test_embed_independent_of_batch_composition():
    """A prompt's vector must not depend on which prompts share its
    dispatch (masked pooling + row padding leak nothing across rows)."""
    model, params = build()
    llm = LLM(model, params, slots=4, max_len=64)
    prompts = _prompts(6, lo=10, hi=14)  # same bucket, shared dispatches
    together = llm.embed(prompts)
    alone = np.stack([llm.embed([p])[0] for p in prompts])
    np.testing.assert_allclose(together, alone, atol=1e-5)


def test_embed_telemetry_counters_and_trace():
    model, params = build()
    reg, tr = MetricsRegistry(), TraceRecorder()
    llm = LLM(model, params, slots=3, max_len=64, metrics=reg, trace=tr)
    prompts = _prompts(5)
    llm.embed(prompts)
    c = llm.engine.counters
    assert c["submitted"] == c["completed"] == 5
    evs = [e["event"] for e in tr.events()]
    assert "prefill" in evs and "finish" in evs
    # registry/counter parity (the _bump contract)
    vals = {r["name"]: r.get("value") for r in reg.snapshot()}
    assert vals['engine_requests_total{event="submitted"}'] == 5
    assert vals['engine_requests_total{event="completed"}'] == 5


def test_embed_validation():
    model, params = build()
    llm = LLM(model, params, slots=2, max_len=32)
    with pytest.raises(ValueError, match="overflows"):
        llm.embed([[1] * 33])
    with pytest.raises(ValueError, match="empty"):
        llm.embed([[1, 2], []])
    assert llm.embed([]).shape == (0, 64)


def test_embed_rejects_encoder_decoder():
    model, params = build(
        is_encoder_decoder=True, encoder_layers=2, frontend="audio_stub",
        num_frontend_tokens=8, use_rope=False, max_pos=64,
    )
    llm = LLM(model, params, slots=2, max_len=32)
    with pytest.raises(ValueError, match="decoder-only"):
        llm.embed([[1, 2, 3]])


def test_embed_one_bulk_transfer():
    """The device->host hop is ONE bulk device_get for the whole call,
    regardless of how many bucketed dispatches ran."""
    model, params = build()
    llm = LLM(model, params, slots=2, max_len=64)
    prompts = _prompts(9)  # multiple buckets AND multiple row-chunks
    llm.embed(prompts)  # compile all buckets first
    calls = []
    real_get = jax.device_get
    jax.device_get = lambda x: calls.append(1) or real_get(x)
    try:
        out = llm.embed(prompts)
    finally:
        jax.device_get = real_get
    assert len(calls) == 1
    assert out.shape == (9, 64)
