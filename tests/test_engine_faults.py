"""Chaos suite for the fault-tolerant serving engine.

Covers the full degraded-request lifecycle: bounded-queue rejection,
deadline expiry in queue and in flight (deterministic via an injected
fake clock), preempt-and-requeue token parity (xla and pallas_interpret
sampler impls), NaN-quarantine isolation, seeded FaultPlan schedules
across dense/paged/prefix layouts, crash-and-rebuild recovery, deadline
storms, and the health/watchdog snapshot.

The sharded section at the bottom re-runs the fault lifecycle on (1,8)
and (2,4) CPU meshes (subprocess: the XLA device-count flag must be set
before jax initializes) and asserts parity against a single-device
engine in the same process — faults must degrade identically no matter
how the cache is sharded.
"""
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.core.config import ModelConfig
from repro.models.model import build_model
from repro.serving.engine import Engine, EngineOverloaded, Request
from repro.serving.faults import FaultPlan, crash_and_rebuild, deadline_storm
from repro.serving.sampling import SamplingParams

VOCAB = 64


class FakeClock:
    """Deterministic time source for deadline tests: deadlines fire when
    the test says so, never when CI is slow."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, s):
        self.t += s


_CACHE = {}


def build(kernel_impl="auto"):
    if kernel_impl not in _CACHE:
        cfg = ModelConfig(
            name="t", family="dense", num_layers=2, d_model=64, num_heads=4,
            num_kv_heads=2, d_ff=128, vocab_size=VOCAB, dtype="float32",
            kernel_impl=kernel_impl,
        )
        model = build_model(cfg)
        _CACHE[kernel_impl] = (model, model.init(jax.random.PRNGKey(0)))
    return _CACHE[kernel_impl]


def prompts_for(n, seed=0, lo=4, hi=10):
    rng = np.random.default_rng(seed)
    return [
        rng.integers(0, VOCAB, size=int(rng.integers(lo, hi + 1))).astype(np.int32)
        for _ in range(n)
    ]


def by_uid(reqs):
    return sorted(reqs, key=lambda r: r.uid)


# ------------------------------------------------------------ backpressure
def test_overload_rejection_is_typed_and_retriable():
    model, params = build()
    ps = prompts_for(5)
    eng = Engine(model, params, slots=1, max_len=64, max_queue=2)
    eng.submit(Request(uid=0, prompt=ps[0], max_new=3))
    eng.submit(Request(uid=1, prompt=ps[1], max_new=3))
    with pytest.raises(EngineOverloaded) as ei:
        eng.submit(Request(uid=2, prompt=ps[2], max_new=3))
    assert ei.value.retriable and ei.value.max_queue == 2
    assert eng.counters["rejected"] == 1
    # the rejected request was not partially admitted anywhere
    assert len(eng.queue) == 2 and all(r is None for r in eng.slot_req)
    eng.run()
    # retriable by contract: after the queue drains the same submit works
    late = Request(uid=2, prompt=ps[2], max_new=3)
    eng.submit(late)
    eng.run()
    assert late.finish_reason == "length" and len(late.output) == 3
    assert eng.counters["completed"] == 3
    assert eng.counters["submitted"] == 3  # rejections never counted as submitted


def test_unbounded_queue_never_rejects():
    model, params = build()
    eng = Engine(model, params, slots=1, max_len=64)  # max_queue=0
    for i, p in enumerate(prompts_for(8)):
        eng.submit(Request(uid=i, prompt=p, max_new=2))
    assert len(eng.queue) == 8
    eng.run()
    assert len(eng.done) == 8


# --------------------------------------------------------------- deadlines
def test_deadline_expires_in_queue():
    model, params = build()
    clk = FakeClock()
    ps = prompts_for(3)
    eng = Engine(model, params, slots=1, max_len=64, clock=clk)
    slow = Request(uid=0, prompt=ps[0], max_new=6)
    tight = Request(uid=1, prompt=ps[1], max_new=6, deadline_ms=50.0)
    # params.deadline_ms takes precedence over the Request field
    loose = Request(uid=2, prompt=ps[2], max_new=6, deadline_ms=1.0,
                    params=SamplingParams(deadline_ms=60_000.0))
    for r in (slow, tight, loose):
        eng.submit(r)
    clk.advance(0.2)  # 200ms: past tight's deadline before anything ran
    eng.run()
    assert tight.finish_reason == "timeout" and tight.output is None
    assert tight.t_first == 0.0
    assert slow.finish_reason == "length" and len(slow.output) == 6
    assert loose.finish_reason == "length" and len(loose.output) == 6
    assert eng.counters["timeouts"] == 1


def test_deadline_expires_in_flight_keeps_partial_output():
    model, params = build()
    clk = FakeClock()
    p = prompts_for(1)[0]
    eng = Engine(model, params, slots=1, max_len=64, clock=clk)
    req = Request(uid=0, prompt=p, max_new=20, deadline_ms=1_000.0)
    eng.submit(req)
    for _ in range(4):  # admit + a few decode steps, all inside deadline
        eng.step()
    produced = len(req.output)
    assert req.finish_reason == "" and produced >= 2
    clk.advance(5.0)  # blow the deadline; release at next step boundary
    eng.step()
    assert req.finish_reason == "timeout"
    assert len(req.output) >= produced  # partial tokens survive
    assert req.t_done == clk.t
    # slot is actually free again: a new request admits and completes
    nxt = Request(uid=1, prompt=p, max_new=3)
    eng.submit(nxt)
    eng.run()
    assert nxt.finish_reason == "length"


def test_deadline_storm_drains_deterministically():
    model, params = build()
    clk = FakeClock()
    ps = prompts_for(8, seed=3)
    reqs = [Request(uid=i, prompt=p, max_new=6) for i, p in enumerate(ps)]
    stormed = deadline_storm(reqs, seed=7, fraction=0.6,
                             deadline_ms=(5.0, 40.0))
    assert stormed  # seed 7 storms at least one request
    eng = Engine(model, params, slots=2, max_len=64, cache_layout="paged",
                 page_size=8, clock=clk)
    for r in reqs:
        eng.submit(r)
    steps = 0
    while (eng.queue or any(s is not None for s in eng.slot_req)) and steps < 500:
        eng.step()
        clk.advance(0.004)  # 4ms per step: some storm deadlines fire mid-run
        steps += 1
    assert all(r.finish_reason for r in reqs)
    for r in reqs:
        assert r.finish_reason in ("length", "timeout"), r.finish_reason
        if r.uid not in stormed:
            assert r.finish_reason == "length"
    assert eng.counters["timeouts"] == sum(
        r.finish_reason == "timeout" for r in reqs
    )
    eng.alloc.check_invariants()


# -------------------------------------------------------------- preemption
@pytest.mark.parametrize("impl", ["xla", "pallas_interpret"])
def test_preempt_resume_token_parity(impl):
    """The acceptance bar: a preempted-and-resumed request is
    token-for-token identical to the same request run without preemption,
    under real (non-greedy) sampling — the counter-hash PRNG keyed on
    (seed, gen index) is what makes the replay exact."""
    model, params = build(impl)
    ps = prompts_for(3, seed=1, lo=5, hi=6)

    def serve(preempt, num_pages):
        eng = Engine(model, params, slots=3, max_len=32, cache_layout="paged",
                     page_size=8, num_pages=num_pages, preempt=preempt,
                     prefix_cache=True)
        reqs = [
            Request(uid=i, prompt=ps[i], max_new=12,
                    params=SamplingParams(temperature=0.8, top_k=12,
                                          seed=40 + i))
            for i in range(3)
        ]
        for r in reqs:
            eng.submit(r)
        eng.run()
        return eng, reqs

    # generous pool: all three run concurrently, nobody preempted
    base_eng, base = serve(preempt=False, num_pages=0)
    assert base_eng.counters["preempted"] == 0
    # tight pool: 7 usable pages, 3 per request -> the third admission
    # must evict the newest in-flight decode and resume it later
    eng, reqs = serve(preempt=True, num_pages=8)
    assert eng.counters["preempted"] >= 1
    assert eng.counters["resumed"] >= 1
    assert any(r.preempted == 1 for r in reqs)
    for got, ref in zip(by_uid(reqs), by_uid(base)):
        assert got.finish_reason == ref.finish_reason
        assert list(got.output) == list(ref.output), (
            f"uid {got.uid} diverged after preemption"
        )
    eng.alloc.check_invariants()


def test_preempt_disabled_head_of_line_blocks():
    """Same tight pool without preempt=True: nobody is evicted; the
    blocked request waits for a slot's pages (FIFO preserved)."""
    model, params = build()
    ps = prompts_for(3, seed=1, lo=5, hi=6)
    eng = Engine(model, params, slots=3, max_len=32, cache_layout="paged",
                 page_size=8, num_pages=8)
    reqs = [Request(uid=i, prompt=ps[i], max_new=12) for i in range(3)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert eng.counters["preempted"] == 0
    assert all(r.preempted == 0 for r in reqs)
    assert all(r.finish_reason == "length" for r in reqs)


def test_preempt_requires_paged_layout():
    model, params = build()
    with pytest.raises(ValueError, match="preempt"):
        Engine(model, params, slots=2, max_len=32, preempt=True)


# ------------------------------------------------------------- quarantine
@pytest.mark.parametrize("layout", ["dense", "paged"])
def test_nan_quarantine_isolates_one_slot(layout):
    model, params = build()
    ps = prompts_for(2, seed=2)

    def serve(faults):
        eng = Engine(model, params, slots=2, max_len=64,
                     cache_layout=layout, page_size=8, faults=faults)
        reqs = [Request(uid=i, prompt=ps[i], max_new=8) for i in range(2)]
        for r in reqs:
            eng.submit(r)
        eng.run()
        return eng, reqs

    _, clean = serve(None)
    eng, faulted = serve(FaultPlan(nan={4: (1,)}))
    victim, survivor = faulted[1], faulted[0]
    assert victim.finish_reason == "error"
    assert len(victim.output) < 8  # cut short at the injected step
    assert eng.counters["errors"] == 1
    # the whole point: the other slot's tokens are bit-identical to the
    # fault-free run — one slot's NaN never leaks into the batch
    assert survivor.finish_reason == clean[0].finish_reason
    assert list(survivor.output) == list(clean[0].output)


def test_nan_on_admission_first_token():
    model, params = build()
    p = prompts_for(1)[0]
    # step 1 is the admission step for the first request: the injected
    # NaN hits the prefill first-token path, not the decode loop
    eng = Engine(model, params, slots=1, max_len=64,
                 faults=FaultPlan(nan={1: (0,)}))
    bad = Request(uid=0, prompt=p, max_new=8)
    ok = Request(uid=1, prompt=p, max_new=4)
    eng.submit(bad)
    eng.submit(ok)
    eng.run()
    assert bad.finish_reason == "error" and not bad.output
    assert ok.finish_reason == "length" and len(ok.output) == 4


# ------------------------------------------------------------ chaos sweep
CHAOS_LAYOUTS = (
    dict(cache_layout="dense"),
    dict(cache_layout="paged", page_size=8),
    dict(cache_layout="paged", page_size=8, prefix_cache=True,
         prefill_chunk=4),
)


@pytest.mark.parametrize("seed", range(5))
def test_chaos_seeded_fault_plans(seed):
    """Acceptance bar: >=5 seeded FaultPlan schedules, rotating through
    dense / paged / paged+prefix layouts.  Every request must reach a
    terminal state, allocator invariants must hold, and every request
    that finished NORMALLY must be token-identical to a fault-free run
    (faults may kill requests; they may never corrupt survivors)."""
    model, params = build()
    ps = prompts_for(6, seed=100 + seed)
    layout = CHAOS_LAYOUTS[seed % len(CHAOS_LAYOUTS)]

    def serve(faults):
        eng = Engine(model, params, slots=2, max_len=64, faults=faults,
                     **layout)
        reqs = [Request(uid=i, prompt=p, max_new=6)
                for i, p in enumerate(ps)]
        for r in reqs:
            eng.submit(r)
        eng.run(max_steps=2_000)
        return eng, reqs

    _, clean = serve(None)
    assert all(r.finish_reason == "length" for r in clean)
    plan = FaultPlan.seeded(seed, horizon=24, slots=2, nan_events=2,
                            outages=1, max_outage=4)
    eng, reqs = serve(plan)
    assert all(r.finish_reason for r in reqs), "chaos run did not drain"
    for got, ref in zip(by_uid(reqs), by_uid(clean)):
        assert got.finish_reason in ("length", "error")
        if got.finish_reason == "length":
            assert list(got.output) == list(ref.output), (
                f"seed {seed}: survivor uid {got.uid} corrupted"
            )
    assert eng.counters["errors"] == sum(
        r.finish_reason == "error" for r in reqs
    )
    if eng.alloc is not None:
        eng.alloc.check_invariants()
        assert eng.alloc.free_pages == eng.alloc.num_pages - 1


def test_crash_and_rebuild_token_parity():
    model, params = build()
    ps = prompts_for(4, seed=5)

    def mk():
        return Engine(model, params, slots=2, max_len=64,
                      cache_layout="paged", page_size=8,
                      faults=FaultPlan(crash_at=4))

    ref_eng = Engine(model, params, slots=2, max_len=64,
                     cache_layout="paged", page_size=8)
    ref = [Request(uid=i, prompt=p, max_new=6) for i, p in enumerate(ps)]
    for r in ref:
        ref_eng.submit(r)
    ref_eng.run()

    reqs = [Request(uid=i, prompt=p, max_new=6) for i, p in enumerate(ps)]
    done, crashed = crash_and_rebuild(mk, reqs)
    assert crashed
    assert len(done) == len(reqs)
    for got, want in zip(by_uid(reqs), by_uid(ref)):
        assert got.finish_reason == want.finish_reason
        assert list(got.output) == list(want.output)


# ----------------------------------------------------------------- health
def test_health_watchdog_climbs_during_outage():
    model, params = build()
    p = prompts_for(1)[0]
    # a 6-step allocator outage from step 1: the queued request cannot
    # admit, nothing progresses, the watchdog counts every stalled step
    eng = Engine(model, params, slots=1, max_len=64,
                 faults=FaultPlan(alloc_outages=((1, 6),)))
    eng.submit(Request(uid=0, prompt=p, max_new=3))
    for _ in range(6):
        eng.step()
    h = eng.health()
    assert h.steps == 6
    assert h.steps_since_progress == 6
    assert h.queue_depth == 1 and h.active_slots == 0
    eng.run()
    h = eng.health()
    assert h.steps_since_progress == 0
    assert h.counters["completed"] == 1
    assert h.queue_depth == 0 and h.active_slots == 0


def test_health_reports_pages_and_counters():
    model, params = build()
    ps = prompts_for(2)
    eng = Engine(model, params, slots=2, max_len=32, cache_layout="paged",
                 page_size=8)
    h0 = eng.health()
    assert h0.free_pages == h0.total_pages
    for i, p in enumerate(ps):
        eng.submit(Request(uid=i, prompt=p, max_new=4))
    eng.step()
    assert eng.health().free_pages < h0.total_pages
    eng.run()
    h = eng.health()
    assert h.free_pages == h0.total_pages
    assert h.counters["submitted"] == 2 and h.counters["completed"] == 2


# -------------------------------------------------------------- API facade
def test_llm_surfaces_overload_and_timeout_as_outcomes():
    from repro.serving.api import LLM

    model, params = build()
    ps = prompts_for(5, seed=4)
    llm = LLM(model, params, slots=1, max_len=64, max_queue=2)
    outs = llm.generate(ps, SamplingParams(max_new=3))
    assert len(outs) == 5
    reasons = [c.finish_reason for c in outs]
    # submission happens before any engine step, so the queue cap of 2
    # admits exactly 2 of the 5 prompts; the other 3 come back as typed
    # outcomes, not raises, and the accepted ones still run
    assert reasons.count("overloaded") == 3
    assert reasons.count("length") == 2
    for c in outs:
        if c.finish_reason == "overloaded":
            # never produced a token / never reached a slot: timings are
            # explicitly None, not a fake 0.0
            assert c.tokens == [] and c.ttft_s is None
            assert c.queue_wait_s is None
        else:
            assert len(c.tokens) == 3
    # the engine is still healthy for the next call
    outs2 = llm.generate(ps[:2], SamplingParams(max_new=2))
    assert [c.finish_reason for c in outs2] == ["length", "length"]


def test_llm_stream_emits_terminal_chunk_for_rejected_request():
    from repro.serving.api import LLM

    model, params = build()
    ps = prompts_for(4, seed=4)
    llm = LLM(model, params, slots=1, max_len=64, max_queue=2)
    chunks = list(llm.stream(ps, SamplingParams(max_new=2)))
    done = {c.index: c.finish_reason for c in chunks if c.done}
    assert set(done) == {0, 1, 2, 3}  # every request gets a terminal chunk
    assert sorted(done.values()) == ["length", "length", "overloaded",
                                     "overloaded"]
    rejected = [c for c in chunks if c.finish_reason == "overloaded"]
    assert all(c.token == -1 for c in rejected)


# -------------------------------------------------- faults on the mesh
SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_py(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, env=env,
        timeout=900,
    )
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


# 8 kv heads (not the in-process suite's 2) so the paged pools genuinely
# shard over every tested model-axis size instead of degrading to
# replication via sharding.fit_spec.
_MESH_COMMON = textwrap.dedent("""
    import jax, numpy as np
    from repro.core.config import ModelConfig, ParallelConfig
    from repro.models.model import build_model
    from repro.obs.trace import TraceRecorder
    from repro.serving.engine import Engine, Request
    from repro.serving.faults import FaultPlan
    from repro.serving.sampling import SamplingParams

    class FakeClock:
        def __init__(self):
            self.t = 0.0
        def __call__(self):
            return self.t
        def advance(self, s):
            self.t += s

    CFG = ModelConfig(name="t", family="dense", num_layers=2, d_model=64,
                      num_heads=8, num_kv_heads=8, d_ff=128, vocab_size=64,
                      dtype="float32")
    PARAMS = build_model(CFG).init(jax.random.PRNGKey(0))
    MESH = jax.make_mesh(__MESH__, ("data", "model"))

    def model_for(mesh):
        return build_model(CFG, ParallelConfig(), mesh)

    def prompts_for(n, seed=0, lo=4, hi=10):
        rng = np.random.default_rng(seed)
        return [rng.integers(0, 64, size=int(rng.integers(lo, hi + 1)))
                .astype(np.int32) for _ in range(n)]

    def by_uid(reqs):
        return sorted(reqs, key=lambda r: r.uid)
""")

_MESH_LIFECYCLE = _MESH_COMMON + textwrap.dedent("""
    # --- preempt-resume parity: tight page pool forces an eviction on
    # the mesh; tokens must match the un-preempted single-device run
    ps = prompts_for(3, seed=1, lo=5, hi=6)

    def serve(mesh, preempt, num_pages):
        eng = Engine(model_for(mesh), PARAMS, slots=3, max_len=32,
                     cache_layout="paged", page_size=8, num_pages=num_pages,
                     preempt=preempt, prefix_cache=True)
        reqs = [Request(uid=i, prompt=ps[i], max_new=12,
                        params=SamplingParams(temperature=0.8, top_k=12,
                                              seed=40 + i))
                for i in range(3)]
        for r in reqs:
            eng.submit(r)
        eng.run()
        return eng, reqs

    _, base = serve(None, False, 0)
    eng, reqs = serve(MESH, True, 8)
    assert eng.counters["preempted"] >= 1 and eng.counters["resumed"] >= 1
    for got, ref in zip(by_uid(reqs), by_uid(base)):
        assert got.finish_reason == ref.finish_reason
        assert list(got.output) == list(ref.output), got.uid
    eng.alloc.check_invariants()
    print("OK preempt")

    # --- NaN quarantine: logits are computed sharded; the injected NaN
    # must still quarantine exactly one slot, and the neighbour's tokens
    # stay bit-identical to the fault-free single-device run
    qs = prompts_for(2, seed=2)

    def serve_q(mesh, faults):
        eng = Engine(model_for(mesh), PARAMS, slots=2, max_len=64,
                     cache_layout="paged", page_size=8, faults=faults)
        reqs = [Request(uid=i, prompt=qs[i], max_new=8) for i in range(2)]
        for r in reqs:
            eng.submit(r)
        eng.run()
        return eng, reqs

    _, clean = serve_q(None, None)
    eng, faulted = serve_q(MESH, FaultPlan(nan={4: (1,)}))
    victim, survivor = faulted[1], faulted[0]
    assert victim.finish_reason == "error" and len(victim.output) < 8
    assert eng.counters["errors"] == 1
    assert survivor.finish_reason == clean[0].finish_reason
    assert list(survivor.output) == list(clean[0].output)
    print("OK quarantine")

    # --- trace byte-parity: the lifecycle JSONL of a seeded chaos run
    # (fake clock) is byte-identical on and off the mesh
    ts = prompts_for(4, seed=9)

    def serve_t(mesh):
        clk, rec = FakeClock(), TraceRecorder()
        eng = Engine(model_for(mesh), PARAMS, slots=2, max_len=64,
                     cache_layout="paged", page_size=8, clock=clk, trace=rec,
                     faults=FaultPlan.seeded(3, horizon=24, slots=2,
                                             nan_events=1, outages=1,
                                             max_outage=3))
        for i, p in enumerate(ts):
            eng.submit(Request(uid=i, prompt=p, max_new=6))
        while eng.queue or any(s is not None for s in eng.slot_req):
            eng.step()
            clk.advance(0.01)
        return rec.to_jsonl()

    assert serve_t(MESH) == serve_t(None)
    print("OK trace")
""")


@pytest.mark.parametrize("mesh_shape", [(1, 8), (2, 4)])
def test_mesh_fault_lifecycle_parity(mesh_shape):
    """Preempt-resume parity, NaN-quarantine isolation, and byte-identical
    lifecycle traces, re-pinned on the mesh."""
    out = run_py(_MESH_LIFECYCLE.replace("__MESH__", repr(mesh_shape)))
    assert out.count("OK") == 3, out


_MESH_CHAOS = _MESH_COMMON + textwrap.dedent("""
    for seed in range(5):
        ps = prompts_for(6, seed=100 + seed)

        def serve(mesh, faults):
            eng = Engine(model_for(mesh), PARAMS, slots=2, max_len=64,
                         cache_layout="paged", page_size=8, faults=faults)
            reqs = [Request(uid=i, prompt=p, max_new=6)
                    for i, p in enumerate(ps)]
            for r in reqs:
                eng.submit(r)
            eng.run(max_steps=2_000)
            return eng, reqs

        _, clean = serve(None, None)
        plan = FaultPlan.seeded(seed, horizon=24, slots=2, nan_events=2,
                                outages=1, max_outage=4)
        eng, reqs = serve(MESH, plan)
        assert all(r.finish_reason for r in reqs), f"seed {seed} did not drain"
        for got, ref in zip(by_uid(reqs), by_uid(clean)):
            assert got.finish_reason in ("length", "error")
            if got.finish_reason == "length":
                assert list(got.output) == list(ref.output), (seed, got.uid)
        eng.alloc.check_invariants()
        assert eng.alloc.free_pages == eng.alloc.num_pages - 1
        print("OK seed", seed)
""")


@pytest.mark.parametrize("mesh_shape", [(1, 8), (2, 4)])
def test_mesh_chaos_seeded_drain(mesh_shape):
    """Five seeded FaultPlan schedules drain on the mesh; survivors stay
    token-identical to the fault-free single-device run."""
    out = run_py(_MESH_CHAOS.replace("__MESH__", repr(mesh_shape)))
    assert out.count("OK seed") == 5, out
