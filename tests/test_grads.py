"""VJP allclose sweeps: the trainable kernel paths vs naive autodiff.

``impl="pallas"`` with ``interpret=True`` runs the Pallas forward AND the
Pallas backward kernels (custom VJP) through the interpreter — the same
code that compiles on TPU — so the fused training path is verifiable on
CPU.  ``impl="xla"`` checks the blockwise fallback's autodiff.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(11)

GRAD_TOL = dict(atol=2e-4, rtol=2e-3)


def _attn_inputs(B, S, T, H, Hkv, D):
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, T, Hkv, D))
    v = jax.random.normal(ks[2], (B, T, Hkv, D))
    do = jax.random.normal(ks[3], (B, S, H, D))
    return q, k, v, do


# causal / bidirectional (the ESM-2/BERT MLM case) / window / softcap combos
ATTN_VARIANTS = [
    (True, 0, 0.0),
    (False, 0, 0.0),
    (True, 32, 0.0),
    (True, 0, 20.0),
    (False, 24, 15.0),
]
# MHA, GQA, MQA; square and offset (T > S, decode-style); odd lengths;
# prime lengths exercise the pallas pad+mask tiling path
ATTN_SHAPES = [
    (2, 64, 64, 4, 4, 32),
    (1, 64, 64, 4, 2, 16),
    (1, 48, 80, 4, 1, 16),
    (1, 40, 40, 2, 2, 16),
    (1, 37, 53, 2, 1, 16),
]


@pytest.mark.parametrize("impl", ["pallas", "xla"])
@pytest.mark.parametrize("causal,window,softcap", ATTN_VARIANTS)
@pytest.mark.parametrize("B,S,T,H,Hkv,D", ATTN_SHAPES)
def test_attention_vjp_sweep(impl, causal, window, softcap, B, S, T, H, Hkv, D):
    q, k, v, do = _attn_inputs(B, S, T, H, Hkv, D)
    off = T - S

    def loss(which):
        def f(q, k, v):
            out = ops.attention(
                q, k, v, causal=causal, window=window, softcap=softcap,
                q_offset=off, impl=which, interpret=True,
            )
            return (out * do).sum()
        return f

    got = jax.grad(loss(impl), argnums=(0, 1, 2))(q, k, v)
    want = jax.grad(loss("naive"), argnums=(0, 1, 2))(q, k, v)
    for name, g, w in zip(("dq", "dk", "dv"), got, want):
        np.testing.assert_allclose(
            np.asarray(g), np.asarray(w), err_msg=f"{impl}:{name}", **GRAD_TOL
        )


@pytest.mark.parametrize("impl", ["pallas", "xla"])
def test_attention_vjp_bf16(impl):
    q, k, v, do = _attn_inputs(1, 64, 64, 4, 2, 32)
    q, k, v = (x.astype(jnp.bfloat16) for x in (q, k, v))

    def loss(which):
        def f(q, k, v):
            out = ops.attention(q, k, v, causal=True, impl=which, interpret=True)
            return (out.astype(jnp.float32) * do).sum()
        return f

    got = jax.grad(loss(impl), argnums=(0, 1, 2))(q, k, v)
    want = jax.grad(loss("naive"), argnums=(0, 1, 2))(q, k, v)
    for g, w in zip(got, want):
        assert g.dtype == jnp.bfloat16
        np.testing.assert_allclose(
            np.asarray(g, np.float32), np.asarray(w, np.float32),
            atol=5e-2, rtol=5e-2,
        )


def _ce_inputs(T, D, V, Vp):
    ks = jax.random.split(KEY, 4)
    h = jax.random.normal(ks[0], (T, D))
    W = jax.random.normal(ks[1], (D, Vp)) * 0.1
    tgt = jax.random.randint(ks[2], (T,), 0, V)
    gl = jax.random.normal(ks[3], (T,))
    return h, W, tgt, gl


@pytest.mark.parametrize("impl", ["pallas", "xla"])
@pytest.mark.parametrize("T,D,V,Vp", [
    (64, 32, 500, 512),
    (128, 64, 1000, 1024),
    (48, 24, 300, 384),    # odd token count, tail vocab block
    (37, 16, 600, 700),    # prime T, non-multiple Vp -> pad+mask tiling
])
def test_cross_entropy_vjp_sweep(impl, T, D, V, Vp):
    h, W, tgt, gl = _ce_inputs(T, D, V, Vp)

    def loss(which):
        def f(h, W):
            losses, lse = ops.cross_entropy(
                h, W, tgt, vocab=V, impl=which, interpret=True
            )
            # weighted loss + an lse term so both output cotangents are live
            return (losses * gl).sum() + 0.3 * lse.sum()
        return f

    got = jax.grad(loss(impl), argnums=(0, 1))(h, W)
    want = jax.grad(loss("naive"), argnums=(0, 1))(h, W)
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(want[0]), **GRAD_TOL)
    np.testing.assert_allclose(np.asarray(got[1]), np.asarray(want[1]), **GRAD_TOL)
    # vocab padding never receives gradient
    if Vp > V:
        assert np.abs(np.asarray(got[1][:, V:])).max() == 0.0


def test_kernel_padded_tiling_fwd_bwd():
    """Explicit small blocks over prime dims force the zero-pad + mask
    tiling path (grid covers padded rows/cols) in fwd AND bwd kernels."""
    from repro.kernels import flash_attention as fa
    from repro.kernels import cross_entropy as ce

    B, S, T, H, Hkv, D = 1, 37, 53, 2, 1, 16
    q, k, v, do = _attn_inputs(B, S, T, H, Hkv, D)
    kw = dict(causal=True, window=16, softcap=10.0, q_offset=T - S,
              block_q=16, block_k=16, interpret=True)
    out, lse = fa.flash_attention_fwd(q, k, v, **kw)
    want = ref.attention_ref(q, k, v, causal=True, window=16, softcap=10.0,
                             q_offset=T - S)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=3e-5, rtol=1e-4)
    dq, dk, dv = fa.flash_attention_bwd(q, k, v, out, lse, do, **kw)
    f = lambda q, k, v: (ref.attention_ref(
        q, k, v, causal=True, window=16, softcap=10.0, q_offset=T - S) * do).sum()
    for g, w in zip((dq, dk, dv), jax.grad(f, argnums=(0, 1, 2))(q, k, v)):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), **GRAD_TOL)

    Tt, Dd, V, Vp = 37, 16, 600, 700
    h, W, tgt, gl = _ce_inputs(Tt, Dd, V, Vp)
    loss, lse = ce.fused_cross_entropy(
        h, W, tgt, vocab=V, block_t=16, block_v=128, interpret=True
    )
    wl, wlse = ref.cross_entropy_ref(h, W[:, :V], tgt)
    np.testing.assert_allclose(np.asarray(loss), np.asarray(wl),
                               atol=3e-5, rtol=1e-4)
    dh, dw = ce.fused_cross_entropy_bwd(
        h, W, tgt, lse, gl, jnp.zeros_like(gl), vocab=V,
        block_t=16, block_v=128, interpret=True,
    )
    fce = lambda h, W: (ref.cross_entropy_ref(h, W[:, :V], tgt)[0] * gl).sum()
    wh, ww = jax.grad(fce, argnums=(0, 1))(h, W)
    np.testing.assert_allclose(np.asarray(dh), np.asarray(wh), **GRAD_TOL)
    np.testing.assert_allclose(np.asarray(dw), np.asarray(ww), **GRAD_TOL)


def test_cross_entropy_vjp_under_jit():
    h, W, tgt, gl = _ce_inputs(64, 32, 500, 512)

    @jax.jit
    def g(h, W):
        return jax.grad(
            lambda h, W: (
                ops.cross_entropy(h, W, tgt, vocab=500, impl="pallas",
                                  interpret=True)[0] * gl
            ).sum(),
            argnums=(0, 1),
        )(h, W)

    got = g(h, W)
    want = jax.grad(
        lambda h, W: (ref.cross_entropy_ref(h, W[:, :500], tgt)[0] * gl).sum(),
        argnums=(0, 1),
    )(h, W)
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(want[0]), **GRAD_TOL)
    np.testing.assert_allclose(np.asarray(got[1][:, :500]),
                               np.asarray(want[1][:, :500]), **GRAD_TOL)


def test_train_step_gradients_pallas_vs_xla():
    """End-to-end: Model.loss_fn grads with kernel_impl="pallas_interpret"
    (fused Pallas fwd+bwd kernels) match the xla blockwise path — the MLM
    training configuration the paper's ESM-2 recipe uses."""
    import dataclasses

    from repro.core.config import ModelConfig
    from repro.models.model import build_model

    base = ModelConfig(
        name="t", family="bio_bert", num_layers=2, d_model=32, num_heads=2,
        num_kv_heads=1, d_ff=64, vocab_size=60, causal=False,
        objective="mlm", norm_type="layernorm", dtype="float32",
        param_dtype="float32",
    )
    B, S = 2, 16
    ks = jax.random.split(KEY, 3)
    tokens = jax.random.randint(ks[0], (B, S), 0, 60)
    targets = jax.random.randint(ks[1], (B, S), 0, 60)
    mask = (jax.random.uniform(ks[2], (B, S)) < 0.3).astype(jnp.float32)
    batch = {"tokens": tokens, "targets": targets, "loss_mask": mask}

    grads = {}
    losses = {}
    for impl in ("pallas_interpret", "xla"):
        cfg = dataclasses.replace(base, kernel_impl=impl)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        (loss, _), g = jax.value_and_grad(
            lambda p: model.loss_fn(p, batch), has_aux=True
        )(params)
        grads[impl], losses[impl] = g, loss

    np.testing.assert_allclose(
        float(losses["pallas_interpret"]), float(losses["xla"]), rtol=1e-5
    )
    flat_p = jax.tree_util.tree_leaves_with_path(grads["pallas_interpret"])
    flat_x = jax.tree_util.tree_leaves_with_path(grads["xla"])
    for (path, gp), (_, gx) in zip(flat_p, flat_x):
        np.testing.assert_allclose(
            np.asarray(gp), np.asarray(gx), atol=5e-4, rtol=5e-3,
            err_msg=jax.tree_util.keystr(path),
        )
