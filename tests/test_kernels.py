"""Per-kernel allclose sweeps vs the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.cross_entropy import fused_cross_entropy
from repro.kernels.flash_attention import flash_attention
from repro.kernels.rmsnorm import layernorm, rmsnorm
from repro.kernels.ssd_scan import ssd_scan

KEY = jax.random.PRNGKey(42)


def tol(dtype):
    return dict(atol=5e-2, rtol=5e-2) if dtype == jnp.bfloat16 else dict(atol=3e-5, rtol=1e-4)


@pytest.mark.parametrize("B,S,T,H,Hkv,D", [
    (2, 128, 128, 4, 2, 64),
    (1, 256, 256, 8, 8, 128),
    (2, 64, 192, 6, 1, 64),
    (1, 128, 128, 4, 4, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window,softcap", [
    (True, 0, 0.0), (False, 0, 0.0), (True, 64, 0.0), (True, 0, 30.0),
])
def test_flash_attention_sweep(B, S, T, H, Hkv, D, dtype, causal, window, softcap):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, D), dtype)
    k = jax.random.normal(ks[1], (B, T, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (B, T, Hkv, D), dtype)
    off = T - S
    out = flash_attention(
        q, k, v, causal=causal, window=window, softcap=softcap,
        q_offset=off, block_q=64, block_k=64, interpret=True,
    )
    want = ref.attention_ref(
        q, k, v, causal=causal, window=window, softcap=softcap, q_offset=off
    )
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32), **tol(dtype)
    )


@pytest.mark.parametrize("rows,d", [(32, 128), (64, 256), (128, 512), (8, 1024)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_sweep(rows, d, dtype):
    x = jax.random.normal(KEY, (rows, d), dtype) * 3
    w = jax.random.normal(jax.random.fold_in(KEY, 1), (d,), dtype) * 0.2 + 1
    out = rmsnorm(x, w, interpret=True)
    np.testing.assert_allclose(
        np.asarray(out, np.float32),
        np.asarray(ref.rmsnorm_ref(x, w), np.float32),
        **tol(dtype),
    )


@pytest.mark.parametrize("rows,d,bias", [(32, 128, True), (64, 256, False), (16, 768, True)])
def test_layernorm_sweep(rows, d, bias):
    x = jax.random.normal(KEY, (rows, d)) * 2 + 1
    w = jax.random.normal(jax.random.fold_in(KEY, 1), (d,)) * 0.1 + 1
    b = jax.random.normal(jax.random.fold_in(KEY, 2), (d,)) * 0.1 if bias else None
    out = layernorm(x, w, b, interpret=True)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref.layernorm_ref(x, w, b)), atol=3e-5, rtol=1e-4
    )


@pytest.mark.parametrize("T,D,V,Vp,bv", [
    (64, 32, 500, 512, 128),
    (128, 64, 1000, 1024, 256),
    (256, 128, 2048, 2048, 512),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_cross_entropy_sweep(T, D, V, Vp, bv, dtype):
    h = jax.random.normal(KEY, (T, D), dtype)
    W = (jax.random.normal(jax.random.fold_in(KEY, 1), (D, Vp)) * 0.05).astype(dtype)
    tgt = jax.random.randint(jax.random.fold_in(KEY, 2), (T,), 0, V)
    loss, lse = fused_cross_entropy(h, W, tgt, vocab=V, block_v=bv, interpret=True)
    want_loss, want_lse = ref.cross_entropy_ref(
        h.astype(jnp.float32), W.astype(jnp.float32)[:, :V], tgt
    )
    np.testing.assert_allclose(np.asarray(loss), np.asarray(want_loss), **tol(dtype))
    np.testing.assert_allclose(np.asarray(lse), np.asarray(want_lse), **tol(dtype))


@pytest.mark.parametrize("B,S,H,P,G,N,chunk", [
    (2, 64, 4, 16, 2, 8, 16),
    (1, 128, 8, 32, 1, 16, 32),
    (2, 96, 2, 64, 2, 32, 8),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan_sweep(B, S, H, P, G, N, chunk, dtype):
    ks = jax.random.split(KEY, 6)
    x = jax.random.normal(ks[0], (B, S, H, P), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H))).astype(jnp.float32)
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (B, S, G, N), dtype)
    Cm = jax.random.normal(ks[4], (B, S, G, N), dtype)
    Dv = jax.random.normal(ks[5], (H,))
    y, hT = ssd_scan(x, dt, A, Bm, Cm, Dv, chunk=chunk, interpret=True)
    want_y, want_h = ref.ssd_ref(x, dt, A, Bm, Cm, Dv)
    t = dict(atol=2e-1, rtol=1e-1) if dtype == jnp.bfloat16 else dict(atol=2e-4, rtol=1e-3)
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(want_y, np.float32), **t
    )
    np.testing.assert_allclose(np.asarray(hT), np.asarray(want_h), **t)


def test_flash_attention_decode_shape():
    """S=1 decode-style call with large cache offset."""
    q = jax.random.normal(KEY, (2, 1, 8, 64))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (2, 256, 2, 64))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (2, 256, 2, 64))
    out = flash_attention(q, k, v, causal=True, q_offset=255, block_k=64, interpret=True)
    want = ref.attention_ref(q, k, v, causal=True, q_offset=255)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=3e-5, rtol=1e-4)


@pytest.mark.parametrize("B,T,H,Hkv,D,bt", [
    (2, 256, 8, 2, 64, 64),
    (3, 512, 4, 4, 128, 128),
    (1, 1024, 16, 2, 64, 256),
])
def test_flash_decode_sweep(B, T, H, Hkv, D, bt):
    from repro.kernels.flash_decode import flash_decode

    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, 1, H, D))
    k = jax.random.normal(ks[1], (B, T, Hkv, D))
    v = jax.random.normal(ks[2], (B, T, Hkv, D))
    lens = (jnp.arange(B) * 37 % (T - 40) + 33).astype(jnp.int32)
    out = flash_decode(q, k, v, lens, block_t=bt, interpret=True)
    for b in range(B):
        L = int(lens[b])
        want = ref.attention_ref(q[b:b+1], k[b:b+1, :L], v[b:b+1, :L], causal=False)
        np.testing.assert_allclose(
            np.asarray(out[b]), np.asarray(want[0]), atol=3e-5, rtol=1e-4
        )
