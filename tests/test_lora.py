"""LoRA fine-tuning: adapters train while the base stays frozen; merge is
exact; trainable count is tiny vs base."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.config import ModelConfig, TrainConfig
from repro.models.model import build_model
from repro.optim import adamw
from repro.training import lora


def setup():
    cfg = ModelConfig(
        name="t", family="dense", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=2, d_ff=128, vocab_size=64, dtype="float32",
    )
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, 64)}
    return cfg, model, params, batch


def test_zero_init_is_identity():
    cfg, model, params, batch = setup()
    adapters = lora.init_adapters(params, rank=4, key=jax.random.PRNGKey(2))
    merged = lora.merged_params(params, adapters)
    l0, _ = model.loss_fn(params, batch)
    l1, _ = model.loss_fn(merged, batch)
    assert float(l0) == float(l1)  # B=0 -> exact identity


def test_adapter_training_reduces_loss_base_frozen():
    cfg, model, params, batch = setup()
    adapters = lora.init_adapters(params, rank=4, key=jax.random.PRNGKey(2))
    loss_fn = lora.make_lora_loss(model, params)
    tc = TrainConfig(learning_rate=5e-3, weight_decay=0.0)
    state = adamw.init_state(adapters)

    @jax.jit
    def step(adapters, state):
        (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(adapters, batch)
        adapters, state = adamw.apply_updates(
            adapters, g, state, jnp.float32(5e-3), tc
        )
        return adapters, state, loss

    losses = []
    for _ in range(25):
        adapters, state, loss = step(adapters, state)
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.05, losses[::6]
    # alpha is part of the pytree but gradient-free in effect; weights moved
    moved = any(
        float(jnp.abs(x).max()) > 0
        for x in jax.tree.leaves(adapters["weights"])
    )
    assert moved


def test_trainable_fraction_small():
    cfg, model, params, batch = setup()
    adapters = lora.init_adapters(params, rank=4, key=jax.random.PRNGKey(2))
    n_base = sum(x.size for x in jax.tree.leaves(params))
    n_lora = lora.count_trainable(adapters)
    assert n_lora < 0.1 * n_base, (n_lora, n_base)
    # targets resolved on the stacked layer tree
    paths = lora.target_paths(params)
    assert any(p[-1] == "wq" for p in paths)
