"""Model-level invariants: decode==prefill consistency across families,
sliding-window cache rotation, MLM masking semantics, param-spec sanity."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.config import ModelConfig, ParallelConfig
from repro.core.module import P, spec_tree
from repro.models.model import build_model
from repro.parallel.sharding import axis_rules


def cfg_for(family, **kw):
    base = dict(
        name=f"t-{family}", family=family, num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=128, dtype="float32",
    )
    if family == "ssm":
        base.update(d_ff=0, num_kv_heads=4, ssm_state=16, ssm_headdim=32, ssm_chunk=8)
    if family == "hybrid":
        # capacity_factor high so prefill-vs-decode routing is drop-free
        # (capacity-based MoE is batch-dependent by design — GShard semantics)
        base.update(num_layers=4, attn_layer_period=4, ssm_state=16,
                    ssm_headdim=32, ssm_chunk=8, capacity_factor=8.0,
                    num_experts=4, num_experts_per_tok=2, moe_layer_period=2)
    if family == "moe":
        base.update(num_experts=4, num_experts_per_tok=1, n_shared_experts=1,
                    capacity_factor=8.0)
    base.update(kw)
    return ModelConfig(**base)


@pytest.mark.parametrize("family", ["dense", "moe", "ssm", "hybrid"])
def test_decode_matches_prefill(family):
    cfg = cfg_for(family)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab_size)
    lg_full, _ = model.prefill(params, {"tokens": toks}, 24)
    _, cache = model.prefill(params, {"tokens": toks[:, :-1]}, 24)
    lg_dec, _ = model.decode_step(params, cache, toks[:, -1:])
    np.testing.assert_allclose(
        np.asarray(lg_full), np.asarray(lg_dec), atol=3e-4, rtol=1e-3
    )


def test_sliding_window_rolling_cache_long_decode():
    """Decode far past the window: rolling cache must equal windowed ref."""
    cfg = cfg_for("dense", sliding_window=8)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 30), 0, cfg.vocab_size)
    # ground truth: teacher-forced full forward (window applies inside attn)
    lg_full, _ = model.prefill(params, {"tokens": toks}, 40)
    _, cache = model.prefill(params, {"tokens": toks[:, :20]}, 40)
    lg = None
    for t in range(20, 30):
        lg, cache = model.decode_step(params, cache, toks[:, t:t + 1])
    lg_want, _ = model.prefill(
        params, {"tokens": jnp.concatenate([toks, jnp.zeros((1, 0), jnp.int32)], 1)}, 40
    )
    np.testing.assert_allclose(
        np.asarray(lg)[:, -1], np.asarray(lg_want)[:, -1], atol=3e-4, rtol=1e-3
    )
    # cache buffer is window-sized
    k = jax.tree.leaves(cache["layers"])[0]
    assert cfg.sliding_window in k.shape


def test_mlm_loss_only_on_masked_positions():
    cfg = cfg_for("dense", objective="mlm", causal=False)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 5, cfg.vocab_size)
    tgt = toks
    mask = jnp.zeros((B, S)).at[:, :4].set(1.0)
    batch = {"tokens": toks, "targets": tgt, "loss_mask": mask}
    loss1, _ = model.loss_fn(params, batch)
    # changing UNMASKED targets must not change the loss
    tgt2 = tgt.at[:, 8:].set((tgt[:, 8:] + 7) % cfg.vocab_size)
    loss2, _ = model.loss_fn(params, {**batch, "targets": tgt2})
    assert float(loss1) == pytest.approx(float(loss2), rel=1e-6)


def test_vlm_image_tokens_excluded_from_loss():
    cfg = cfg_for("vlm", frontend="vision_stub", num_frontend_tokens=4)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab_size)
    img = jax.random.normal(jax.random.PRNGKey(2), (2, 4, cfg.d_model))
    loss, m = model.loss_fn(params, {"tokens": toks, "img_embeds": img})
    # token count in metrics == text next-token positions only
    assert float(m["tokens"]) == 2 * 11


def test_encdec_uses_encoder_output():
    cfg = cfg_for(
        "audio", is_encoder_decoder=True, encoder_layers=2,
        frontend="audio_stub", num_frontend_tokens=8,
        use_rope=False, max_pos=64, norm_type="layernorm", act="gelu",
    )
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 10), 0, cfg.vocab_size)
    emb1 = jax.random.normal(jax.random.PRNGKey(2), (1, 8, cfg.d_model))
    # NB: a constant shift would be annihilated by LayerNorm (shift
    # invariance) — perturb with noise instead
    emb2 = emb1 + jax.random.normal(jax.random.PRNGKey(3), emb1.shape)
    l1, _ = model.loss_fn(params, {"tokens": toks, "enc_embeds": emb1})
    l2, _ = model.loss_fn(params, {"tokens": toks, "enc_embeds": emb2})
    assert float(l1) != pytest.approx(float(l2))


def test_parallel_residual_structure():
    """command-r style block has a single pre-norm (no norm2 params)."""
    from repro.models.transformer import stack_defs
    cfg = cfg_for("dense", parallel_residual=True)
    defs = stack_defs(cfg)
    assert "norm2" not in defs["sub0"]
    assert "ffn" in defs["sub0"]


def test_param_specs_cover_all_leaves_and_axes_exist():
    import jax.sharding as shd

    for family in ("dense", "moe", "ssm", "hybrid"):
        cfg = cfg_for(family)
        pc = ParallelConfig()
        model = build_model(cfg)
        defs = model.param_defs()
        rules = axis_rules(pc, jax.make_mesh((1, 1), ("data", "model")))
        specs = spec_tree(defs, rules)
        names = {a for s in jax.tree.leaves(
            specs, is_leaf=lambda x: isinstance(x, shd.PartitionSpec))
            for a in s if a is not None
            for a in (a if isinstance(a, tuple) else (a,))}
        assert names <= {"data", "model"}, names


def test_hybrid_interleave_structure():
    cfg = cfg_for("hybrid")
    # unit of 4: attn at index 2 (period//2), ssm elsewhere; moe on odd layers
    from repro.models.transformer import unit_defs
    defs = unit_defs(cfg)
    assert "attn" in defs["sub2"]
    assert "ssm" in defs["sub0"] and "ssm" in defs["sub1"] and "ssm" in defs["sub3"]
    assert "router" in defs["sub1"]["ffn"]      # MoE layer
    assert "router" not in defs["sub0"]["ffn"]  # dense layer


def test_logit_softcap_bounds_logits():
    cfg = cfg_for("dense", logit_softcap=5.0)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0, cfg.vocab_size)
    lg, _ = model.prefill(params, {"tokens": toks}, 16)
    # padded-vocab ids are masked to -inf at serve time; check real vocab
    assert float(jnp.abs(lg[..., : cfg.vocab_size]).max()) <= 5.0 + 1e-3
