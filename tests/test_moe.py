"""MoE acceptance suite for the ragged (megablocks-style) dispatch path.

  * grouped-matmul parity: every impl (xla ragged_dot, xla capacity-batched,
    pallas interpret) against the (M, K, N) gather oracle — forward AND VJP —
    across expert counts and ragged edge cases (empty experts, all rows in
    one expert, dropped tail, non-tile-multiple M)
  * moe_apply vs the dense no-capacity oracle across capacity factors and
    top-1/top-2 routing
  * fp32 routing regression: a bf16 softmax/top-k would flip the routing
    decision on near-tied logits; the fp32 router must not
  * router stats vector (aux) semantics: drop fraction, per-expert load
  * Trainer integration: router metrics reach history + the obs registry
  * 8-virtual-device expert-parallel parity vs single device (subprocess)
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.config import ModelConfig, TrainConfig
from repro.core.module import materialize
from repro.kernels import ops, ref
from repro.models.moe import (
    AUX_BASE, aux_shape, capacity, moe_apply, moe_defs, moe_ref_dense,
)
from repro.models.model import build_model
from repro.parallel.sharding import null_ctx
from repro.training import train_step as TS

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
KEY = jax.random.PRNGKey(0)


def moe_cfg(**kw):
    base = dict(
        name="m", family="moe", num_layers=1, d_model=32, num_heads=2,
        num_kv_heads=2, d_ff=64, vocab_size=64, num_experts=4,
        num_experts_per_tok=2, capacity_factor=4.0, dtype="float32",
    )
    base.update(kw)
    return ModelConfig(**base)


# --------------------------------------------------------------------- #
# ragged grouped-matmul kernel parity (fwd + VJP)
# --------------------------------------------------------------------- #
def _size_cases(E, M):
    """Ragged edge cases for E groups over at most M rows."""
    rng = np.random.default_rng(E)
    even = [M // E] * E
    uneven = rng.multinomial(M, rng.dirichlet(np.ones(E))).tolist()
    cases = [
        even,
        uneven,
        [0] * E,                          # all experts empty
        [M] + [0] * (E - 1),              # everything in one expert
        [M // 2] + [0] * (E - 1),         # dropped tail (sum < M)
    ]
    if E >= 3:
        # interior empties + dropped tail (sum stays <= M, the contract)
        cases.append([0, M // 4, 0] + [(M // 2) // (E - 3)] * (E - 3))
    return cases


def _impl_calls(max_group_size):
    return [
        ("xla_ragged", dict(impl="xla")),
        ("xla_bounded", dict(impl="xla", max_group_size=max_group_size)),
        ("pallas_interpret", dict(impl="pallas", interpret=True)),
    ]


@pytest.mark.parametrize("E", [2, 8, 16])
def test_grouped_matmul_parity_fwd_and_vjp(E):
    M, K, N = 64, 16, 24
    x = jax.random.normal(KEY, (M, K), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(KEY, 1), (E, K, N)) * 0.3
    for sizes in _size_cases(E, M):
        gs = jnp.asarray(sizes, jnp.int32)
        want = ref.grouped_matmul_ref(x, w, gs)
        cot = jax.random.normal(jax.random.fold_in(KEY, 2), want.shape)

        def loss_ref(x, w):
            return (ref.grouped_matmul_ref(x, w, gs) * cot).sum()

        gx_ref, gw_ref = jax.grad(loss_ref, argnums=(0, 1))(x, w)
        for name, kw in _impl_calls(max(sizes) or 1):
            y = ops.grouped_matmul(x, w, gs, **kw)
            np.testing.assert_allclose(
                np.asarray(y), np.asarray(want), atol=1e-4, rtol=1e-4,
                err_msg=f"{name} fwd sizes={sizes}",
            )

            def loss(x, w, kw=kw):
                return (
                    ops.grouped_matmul(x, w, gs, **kw).astype(jnp.float32)
                    * cot
                ).sum()

            gx, gw = jax.grad(loss, argnums=(0, 1))(x, w)
            np.testing.assert_allclose(
                np.asarray(gx), np.asarray(gx_ref), atol=1e-3, rtol=1e-3,
                err_msg=f"{name} dX sizes={sizes}",
            )
            np.testing.assert_allclose(
                np.asarray(gw), np.asarray(gw_ref), atol=1e-3, rtol=1e-3,
                err_msg=f"{name} dW sizes={sizes}",
            )


def test_grouped_matmul_non_tile_multiple_rows():
    """M that is not a multiple of any tile size exercises the padded-tail
    masking in the pallas kernel and the bounded fallback."""
    M, K, N, E = 50, 16, 24, 3
    x = jax.random.normal(KEY, (M, K))
    w = jax.random.normal(jax.random.fold_in(KEY, 3), (E, K, N)) * 0.3
    gs = jnp.asarray([17, 0, 26], jnp.int32)      # sum=43 < 50: zero tail
    want = ref.grouped_matmul_ref(x, w, gs)
    for name, kw in _impl_calls(26):
        y = ops.grouped_matmul(x, w, gs, **kw)
        np.testing.assert_allclose(
            np.asarray(y), np.asarray(want), atol=1e-4, rtol=1e-4,
            err_msg=name,
        )
        assert np.abs(np.asarray(y[43:])).max() == 0.0, name


# --------------------------------------------------------------------- #
# moe_apply vs dense oracle
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("E,topk", [(4, 1), (4, 2), (8, 2)])
def test_moe_apply_matches_dense_oracle_generous_capacity(E, topk):
    cfg = moe_cfg(num_experts=E, num_experts_per_tok=topk,
                  capacity_factor=float(2 * E))
    params = materialize(moe_defs(cfg), KEY, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(KEY, 4), (2, 24, cfg.d_model))
    out, aux = moe_apply(cfg, null_ctx(), params, x)
    want = moe_ref_dense(cfg, params, x)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(want), atol=1e-4, rtol=1e-3
    )
    assert aux.shape == aux_shape(cfg)
    assert float(aux[2]) == 0.0                       # nothing dropped
    np.testing.assert_allclose(float(aux[AUX_BASE:].sum()), 1.0, atol=1e-5)


@pytest.mark.parametrize("cf", [0.25, 0.5, 1.0])
def test_moe_apply_tight_capacity_drops_and_reports(cf):
    cfg = moe_cfg(num_experts=4, num_experts_per_tok=1, capacity_factor=cf)
    params = materialize(moe_defs(cfg), KEY, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(KEY, 5), (1, 64, cfg.d_model))
    out, aux = moe_apply(cfg, null_ctx(), params, x)
    T = 64
    C = capacity(cfg, T)
    dropped, total = float(aux[2]), float(aux[3])
    assert total == T * cfg.num_experts_per_tok
    assert 0.0 <= dropped <= total
    # per-expert kept counts are capacity-clipped: load * kept_total <= C
    kept_total = total - dropped
    load = np.asarray(aux[AUX_BASE:])
    assert (load * kept_total <= C + 1e-3).all()
    if dropped:
        # dropped tokens contribute nothing: with top-1 routing their
        # output row is exactly zero (before the shared expert, absent here)
        norms = np.linalg.norm(np.asarray(out[0]), axis=-1)
        assert (norms < 1e-6).sum() >= 1


def test_moe_apply_consistent_across_impls():
    """The xla ragged path and the pallas interpret path produce the same
    moe output end-to-end (same routing, same combine)."""
    x = jax.random.normal(jax.random.fold_in(KEY, 6), (2, 16, 32))
    outs = {}
    for impl in ("xla", "pallas_interpret"):
        cfg = moe_cfg(capacity_factor=1.0, kernel_impl=impl)
        params = materialize(moe_defs(cfg), KEY, jnp.float32)
        outs[impl], _ = moe_apply(cfg, null_ctx(), params, x)
    np.testing.assert_allclose(
        np.asarray(outs["xla"]), np.asarray(outs["pallas_interpret"]),
        atol=1e-4, rtol=1e-3,
    )


# --------------------------------------------------------------------- #
# fp32 routing regression (bf16 softmax/top-k would flip the decision)
# --------------------------------------------------------------------- #
def test_router_routes_in_fp32_under_bf16_compute():
    """Construct logits e0=1.0, e1=1.0+2^-12 from exactly-bf16-representable
    weights.  fp32 routing picks expert 1; a bf16 softmax/top-k collapses
    the pair to a tie and top_k's index order picks expert 0 instead."""
    cfg = moe_cfg(num_experts=2, num_experts_per_tok=1, d_model=2,
                  capacity_factor=8.0, dtype="bfloat16")
    params = materialize(moe_defs(cfg), KEY, jnp.bfloat16)
    router = jnp.asarray([[1.0, 1.0], [0.0, 2.0 ** -12]], jnp.float32)
    assert (router.astype(jnp.bfloat16).astype(jnp.float32) == router).all()
    params = dict(params, router=router)
    x = jnp.asarray([[[1.0, 1.0]]], jnp.bfloat16)    # (B=1, S=1, d=2)

    # the buggy path this guards against: bf16 logits tie at 1.0
    logits_bf16 = (x.reshape(1, 2) @ router.astype(jnp.bfloat16))
    bad_choice = int(jnp.argmax(logits_bf16, -1)[0])
    assert bad_choice == 0  # tie -> lower index

    _, aux = moe_apply(cfg, null_ctx(), params, x)
    load = np.asarray(aux[AUX_BASE:])
    assert load[1] == 1.0 and load[0] == 0.0, load  # fp32 picked expert 1


# --------------------------------------------------------------------- #
# Trainer integration: router metrics reach history + the obs registry
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("accum", [1, 2])
def test_train_step_emits_router_metrics(accum):
    cfg = moe_cfg(num_experts=4, capacity_factor=1.0)
    model = build_model(cfg)
    tc = TrainConfig(total_steps=1, warmup_steps=1, accum_steps=accum)
    state = TS.init_train_state(model, KEY, tc)
    batch = {
        "tokens": np.random.default_rng(0)
        .integers(0, 64, size=(4, 32))
        .astype(np.int32)
    }
    _, m = jax.jit(TS.make_train_step(model, tc))(state, batch)
    assert np.isfinite(float(m["loss"]))
    for k in ("aux_loss", "router_entropy", "router_drop_frac"):
        v = float(m[k])
        assert np.isfinite(v) and v >= 0.0, (k, v)
    load = np.asarray(m["router_load"])
    assert load.shape == (4,)
    np.testing.assert_allclose(load.sum(), 1.0, atol=1e-4)
    assert float(m["router_entropy"]) <= np.log(4) + 1e-5


def test_trainer_feeds_router_gauges(tmp_path):
    from repro.data.dataset import build_synthetic_protein_memmap
    from repro.data.pipeline import CLMBatches
    from repro.obs.metrics import MetricsRegistry
    from repro.training.loop import Trainer

    cfg = moe_cfg(num_experts=4, vocab_size=64, capacity_factor=1.0)
    tc = TrainConfig(global_batch=4, seq_len=32, total_steps=2, log_every=1,
                     warmup_steps=1, decay_steps=1)
    ds, _ = build_synthetic_protein_memmap(str(tmp_path / "p"), n=64, seed=0)
    reg = MetricsRegistry()
    tr = Trainer(build_model(cfg), tc, verbose=False, metrics=reg)
    _, hist = tr.run(CLMBatches(ds, 4, 32, seed=0))
    # scalar history rows carry the router scalars, never the load vector
    assert "router_drop_frac" in hist[-1] and "router_load" not in hist[-1]
    for name in ("train_router_drop_frac", "train_aux_loss",
                 "train_router_entropy"):
        fam = reg.get(name)
        assert fam is not None and np.isfinite(fam.value), name
    loads = reg.get("train_router_load")
    assert loads is not None
    assert set(loads.children) == {("0",), ("1",), ("2",), ("3",)}
    total = sum(c.value for c in loads.children.values())
    np.testing.assert_allclose(total, 1.0, atol=1e-4)


# --------------------------------------------------------------------- #
# 8-virtual-device expert parallelism (subprocess)
# --------------------------------------------------------------------- #
EP_CODE = textwrap.dedent("""
    import jax, numpy as np
    import jax.numpy as jnp
    from repro.core.config import ModelConfig, ParallelConfig
    from repro.models.model import build_model

    assert jax.device_count() == 8, jax.device_count()
    cfg = ModelConfig(
        name="m", family="moe", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=2, d_ff=128, vocab_size=128, num_experts=8,
        num_experts_per_tok=2, capacity_factor=2.0, dtype="float32",
    )
    ref_model = build_model(cfg)
    params = ref_model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {"tokens": rng.integers(0, 128, size=(4, 32)).astype(np.int32)}

    loss_ref, m_ref = jax.jit(ref_model.loss_fn)(params, batch)
    logits_ref, cache = jax.jit(
        lambda p, b: ref_model.prefill(p, b, 48))(params, batch)
    toks_ref = [int(t) for t in jnp.argmax(logits_ref[:, -1], -1)]

    for shape in ((1, 8), (2, 4)):
        mesh = jax.make_mesh(shape, ("data", "model"))
        m_sh = build_model(cfg, ParallelConfig(), mesh)
        assert m_sh.ctx.expert_parallel(cfg.num_experts) == (shape[1] in (4, 8))
        sh_params = jax.device_put(
            params, jax.tree.map(
                lambda s: jax.sharding.NamedSharding(mesh, s),
                m_sh.param_specs()))
        loss_sh, m_sh_metrics = jax.jit(m_sh.loss_fn)(sh_params, batch)
        assert abs(float(loss_sh) - float(loss_ref)) < 1e-4, (
            shape, float(loss_sh), float(loss_ref))
        np.testing.assert_allclose(
            np.asarray(m_sh_metrics["router_load"]),
            np.asarray(m_ref["router_load"]), atol=1e-5)
        lg, _ = jax.jit(lambda p, b: m_sh.prefill(p, b, 48))(sh_params, batch)
        toks = [int(t) for t in jnp.argmax(lg[:, -1], -1)]
        assert toks == toks_ref, (shape, toks, toks_ref)
        print("mesh", shape, "ok")
    print("EP_OK")
""")


def test_expert_parallel_matches_single_device_8dev_subprocess():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    out = subprocess.run(
        [sys.executable, "-c", EP_CODE], capture_output=True, text=True,
        env=env, timeout=900,
    )
    assert out.returncode == 0, out.stderr[-4000:]
    assert "EP_OK" in out.stdout
