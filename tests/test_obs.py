"""Unified-telemetry acceptance suite (``repro.obs``).

Registry mechanics (counters/gauges/fixed-bucket histograms, labels,
Prometheus exposition, trajectory-format JSON dumps), ring-buffer trace
semantics, and the three cross-cutting contracts the observability layer
must honour:

  * **Determinism** — a seeded ``FaultPlan`` run driven by a fake clock
    produces a byte-identical, schema-valid JSONL lifecycle trace across
    runs (the trace is evidence, so it must be reproducible evidence).
  * **Parity** — the registry's lifecycle counters and ``Engine.health()``
    agree exactly across seeded chaos plans: both views are fed through
    the same ``_bump``, so they can never drift.
  * **Zero added transfers** — with the full instrumentation stack ON
    (registry + tracer + profile timers) the engine still performs
    exactly ONE bulk device->host transfer per steady-state step and the
    trainer ONE per log interval, under ``jax.transfer_guard``.
"""
import json

import jax
import numpy as np
import pytest

from repro.core.config import ModelConfig, TrainConfig
from repro.data.dataset import build_synthetic_protein_memmap
from repro.data.pipeline import CLMBatches
from repro.models.model import build_model
from repro.obs import (
    EVENTS,
    LATENCY_BUCKETS,
    MetricsRegistry,
    StepTimer,
    TraceRecorder,
    annotate,
    trace_ctx,
)
from repro.serving.engine import Engine, Request
from repro.serving.faults import FaultPlan
from repro.serving.sampling import SamplingParams
from repro.training.loop import Trainer

VOCAB = 64


class FakeClock:
    """Deterministic time source (starts away from 0.0 so "never stamped"
    sentinels can never collide with a real timestamp)."""

    def __init__(self, t=1000.0):
        self.t = t

    def __call__(self):
        return self.t

    def advance(self, s):
        self.t += s


class AutoClock(FakeClock):
    """Advances by a fixed dt on every read — lets ``Engine.run()`` hit
    deadlines without the test driving the step loop manually."""

    def __init__(self, t=1000.0, dt=0.05):
        super().__init__(t)
        self.dt = dt

    def __call__(self):
        self.t += self.dt
        return self.t


_CACHE = {}


def build():
    if "m" not in _CACHE:
        cfg = ModelConfig(
            name="t", family="dense", num_layers=2, d_model=64, num_heads=4,
            num_kv_heads=2, d_ff=128, vocab_size=VOCAB, dtype="float32",
        )
        model = build_model(cfg)
        _CACHE["m"] = (model, model.init(jax.random.PRNGKey(0)))
    return _CACHE["m"]


def prompts_for(n, seed=0, lo=4, hi=10):
    rng = np.random.default_rng(seed)
    return [
        rng.integers(0, VOCAB, size=int(rng.integers(lo, hi + 1)))
        .astype(np.int32)
        for _ in range(n)
    ]


# ------------------------------------------------------------ registry
def test_counter_gauge_and_labels():
    reg = MetricsRegistry()
    c = reg.counter("reqs_total", "requests", labels=("event",))
    c.labels("submitted").inc()
    c.labels("submitted").inc(2)
    c.labels("rejected").inc()
    assert c.labels("submitted").value == 3
    assert c.labels("rejected").value == 1
    with pytest.raises(ValueError):
        c.labels("submitted").inc(-1)   # counters are monotonic
    with pytest.raises(ValueError):
        c.inc()                         # labeled family: must resolve first
    g = reg.gauge("depth", "queue depth")
    g.set(7)
    g.dec(2)
    assert g.value == 5                 # unlabeled family forwards to solo


def test_registry_idempotent_and_conflicting():
    reg = MetricsRegistry()
    a = reg.counter("x_total", "x", labels=("k",))
    b = reg.counter("x_total", "x", labels=("k",))
    assert a is b                       # two subsystems share one series
    with pytest.raises(ValueError):
        reg.gauge("x_total")            # same name, different kind
    with pytest.raises(ValueError):
        reg.counter("x_total", labels=())  # same kind, different labels
    with pytest.raises(ValueError):
        reg.counter("bad name")
    with pytest.raises(ValueError):
        reg.counter("ok_total", labels=("bad-label",))


def test_histogram_buckets_and_quantiles():
    reg = MetricsRegistry()
    h = reg.histogram("lat", "latency", buckets=(1.0, 2.0, 4.0))
    assert h.quantile(0.5) == 0.0       # empty: defined, not a crash
    for v in (0.5, 1.5, 1.5, 3.0, 100.0):
        h.observe(v)
    assert h.count == 5
    assert h.sum == pytest.approx(106.5)
    # p50 rank lands in the (1, 2] bucket; interpolated inside it
    assert 1.0 <= h.quantile(0.5) <= 2.0
    # overflow ranks clamp to the last finite boundary (lower bound)
    assert h.quantile(0.99) == 4.0
    with pytest.raises(ValueError):
        h.quantile(1.5)
    with pytest.raises(ValueError):
        reg.histogram("bad", buckets=(2.0, 1.0))
    assert LATENCY_BUCKETS == tuple(sorted(LATENCY_BUCKETS))


def test_prometheus_exposition_format():
    reg = MetricsRegistry()
    reg.counter("reqs_total", "total requests", labels=("event",)) \
        .labels("submitted").inc(3)
    reg.gauge("depth", "queue depth").set(2)
    h = reg.histogram("lat_seconds", "latency", buckets=(0.1, 1.0))
    h.observe(0.05)
    h.observe(0.5)
    h.observe(5.0)
    text = reg.to_prometheus()
    assert "# HELP reqs_total total requests" in text
    assert "# TYPE reqs_total counter" in text
    assert 'reqs_total{event="submitted"} 3' in text
    assert "depth 2" in text
    # histogram buckets are CUMULATIVE and end at +Inf == _count
    assert 'lat_seconds_bucket{le="0.1"} 1' in text
    assert 'lat_seconds_bucket{le="1"} 2' in text
    assert 'lat_seconds_bucket{le="+Inf"} 3' in text
    assert "lat_seconds_count 3" in text
    assert "lat_seconds_sum 5.55" in text
    assert text.endswith("\n")


def test_dump_json_matches_trajectory_shape(tmp_path):
    reg = MetricsRegistry()
    reg.counter("steps_total").inc(4)
    reg.histogram("ttft_seconds", buckets=(0.1, 1.0)).observe(0.2)
    path = str(tmp_path / "metrics.json")
    reg.dump_json(path, now=0.0, extra={"git": "abc1234"})
    reg.counter("steps_total").inc()
    reg.dump_json(path, now=60.0)
    with open(path) as f:
        doc = json.load(f)
    assert set(doc) == {"runs"}         # BENCH_*.json trajectory shape
    assert len(doc["runs"]) == 2        # appended, not clobbered
    first, second = doc["runs"]
    assert first["timestamp"] == "1970-01-01T00:00:00Z"
    assert first["git"] == "abc1234"
    rows = {r["name"]: r for r in second["rows"]}
    assert rows["steps_total"]["value"] == 5
    hist = rows["ttft_seconds"]
    assert hist["count"] == 1 and "p95" in hist and "p99" in hist
    assert not list(tmp_path.glob("*.tmp.*"))  # atomic write left no turds


# --------------------------------------------------------------- trace
def test_trace_ring_buffer_bounds_and_validation():
    tr = TraceRecorder(capacity=4)
    for i in range(10):
        tr.emit("decode", ts=float(i), uid=i, step=i)
    assert len(tr) == 4 and tr.emitted == 10 and tr.dropped == 6
    assert [e["uid"] for e in tr.events()] == [6, 7, 8, 9]  # oldest fell off
    with pytest.raises(ValueError):
        tr.emit("reticulate", ts=0.0)   # typo'd events fail the producer
    with pytest.raises(ValueError):
        TraceRecorder(capacity=0)
    tr.clear()
    assert len(tr) == 0 and tr.emitted == 0


def test_trace_jsonl_render_and_write(tmp_path):
    tr = TraceRecorder()
    tr.emit("submit", ts=1.5, uid=3, step=0, prompt_tokens=7)
    tr.emit("finish", ts=2.5, uid=3, step=4, reason="length", tokens=8)
    lines = tr.to_jsonl().splitlines()
    assert len(lines) == 2
    first = json.loads(lines[0])
    assert first == {"event": "submit", "prompt_tokens": 7, "step": 0,
                     "ts": 1.5, "uid": 3}
    # keys sorted + compact separators => equal streams give equal bytes
    assert lines[0] == json.dumps(first, sort_keys=True,
                                  separators=(",", ":"))
    path = tmp_path / "trace.jsonl"
    tr.write(str(path))
    assert path.read_text() == tr.to_jsonl()
    assert not list(tmp_path.glob("*.tmp.*"))


# ----------------------------------------------- deterministic fault trace
def _traced_fault_run(seed):
    model, params = build()
    clk = FakeClock()
    tracer = TraceRecorder()
    reg = MetricsRegistry()
    plan = FaultPlan.seeded(seed, horizon=24, slots=4, nan_events=2,
                            outages=1)
    eng = Engine(model, params, slots=4, max_len=64, cache_layout="paged",
                 page_size=16, faults=plan, clock=clk, trace=tracer,
                 metrics=reg)
    ps = prompts_for(8, seed=1)
    for i, p in enumerate(ps):
        eng.submit(Request(uid=i, prompt=p, max_new=6))
    for _ in range(200):
        clk.advance(0.125)
        eng.step()
        if len(eng.done) == len(ps):
            break
    assert len(eng.done) == len(ps), "fault run failed to drain"
    return eng, reg, tracer


def test_fault_run_trace_is_byte_identical_and_schema_valid():
    eng, _, tr1 = _traced_fault_run(2)
    _, _, tr2 = _traced_fault_run(2)
    j1, j2 = tr1.to_jsonl(), tr2.to_jsonl()
    assert j1.encode() == j2.encode(), \
        "same seed + same clock must give the same bytes"
    events = []
    for line in j1.splitlines():
        e = json.loads(line)
        # schema: the three envelope fields always present and typed,
        # the event drawn from the closed vocabulary, keys sorted
        assert e["event"] in EVENTS
        assert isinstance(e["step"], int) and isinstance(e["uid"], int)
        assert isinstance(e["ts"], float) and e["ts"] >= 1000.0
        assert line == json.dumps(e, sort_keys=True, separators=(",", ":"))
        events.append(e)
    kinds = [e["event"] for e in events]
    assert kinds.count("submit") == 8 and kinds.count("finish") == 8
    # the seeded plan provably exercised a degraded path
    assert "quarantine" in kinds
    # per-request lifecycle ordering: submit precedes finish for every uid
    for uid in range(8):
        seq = [e["event"] for e in events if e["uid"] == uid]
        assert seq[0] == "submit" and seq[-1] == "finish"
    # timestamps are the engine clock: non-decreasing in buffer order
    ts = [e["ts"] for e in events]
    assert ts == sorted(ts)


@pytest.mark.parametrize("seed", range(5))
def test_chaos_counter_parity_with_health(seed):
    eng, reg, tracer = _traced_fault_run(seed)
    h = eng.health()
    fam = reg.get("engine_requests_total")
    for k, v in h.counters.items():
        assert fam.labels(k).value == v, \
            f"registry drifted from health() on {k!r} (seed {seed})"
    assert reg.get("engine_steps_total").value == eng.steps
    # tokens counter counts APPENDED tokens only — quarantined emissions
    # are dropped before they reach any request
    assert reg.get("engine_tokens_total").value == \
        sum(len(r.output or []) for r in eng.done)
    # every terminal outcome in the counters has a finish event on tape
    kinds = [e["event"] for e in tracer.events()]
    assert kinds.count("finish") == len(eng.done)


# -------------------------------------------------- transfer-guard parity
def test_instrumented_engine_still_one_bulk_transfer_per_step(monkeypatch):
    """The full stack ON (registry + tracer + profile timers + on_step
    health probe) must not add a single device sync to the steady-state
    decode step."""
    model, params = build()
    reg = MetricsRegistry()
    tracer = TraceRecorder()
    probes = []
    eng = Engine(model, params, slots=2, max_len=64, cache_layout="paged",
                 page_size=8, metrics=reg, trace=tracer, profile=True,
                 on_step=lambda e: probes.append(e.health().counters))
    rng = np.random.default_rng(9)
    for i in range(2):   # fill every slot; queue empty => no admissions
        eng.submit(Request(uid=i, prompt=rng.integers(0, VOCAB, size=6)
                           .astype(np.int32), max_new=40))
    eng.step()           # admissions + first decode (compiles)
    eng.step()           # warm steady state
    calls = []
    real_get = jax.device_get
    monkeypatch.setattr(jax, "device_get",
                        lambda x: calls.append(1) or real_get(x))
    with jax.transfer_guard("disallow"):
        n = eng.step()
    assert n == 2
    assert len(calls) == 1, f"expected 1 bulk transfer, saw {len(calls)}"
    assert probes and eng.step_timer.totals["decode"][0] == eng.steps


def _tiny_trainer(tmp_path, reg):
    cfg = ModelConfig(
        name="tiny", family="dense", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=2, d_ff=128, vocab_size=64, dtype="float32",
    )
    tc = TrainConfig(
        global_batch=8, seq_len=32, total_steps=9, log_every=3,
        warmup_steps=2, decay_steps=2, learning_rate=1e-3,
    )
    ds, _ = build_synthetic_protein_memmap(str(tmp_path / "prot"), n=200,
                                           seed=0)
    tr = Trainer(build_model(cfg), tc, verbose=False, metrics=reg,
                 profile=True)
    tr.prepare(CLMBatches(ds, 8, 32, seed=0))
    return tr, tc


def test_instrumented_trainer_still_one_transfer_per_interval(
        tmp_path, monkeypatch):
    reg = MetricsRegistry()
    tr, tc = _tiny_trainer(tmp_path, reg)
    tr.step()  # s=0: compile + first log flush, outside the guard
    calls = []
    real_get = jax.device_get
    monkeypatch.setattr(
        jax, "device_get", lambda x: calls.append(1) or real_get(x)
    )
    with jax.transfer_guard("disallow"):
        while tr.step_idx < tc.total_steps:
            tr.step()
    # steps 1..8 under the guard flush at s=3, s=6, s=8 — identical to
    # the uninstrumented contract in test_trainer_distributed.py
    assert len(calls) == 3, f"expected 3 bulk transfers, saw {len(calls)}"
    assert reg.get("train_steps_total").value == 9
    # one observe per flush: s=0 (pre-guard), s=3, s=6, s=8
    assert reg.get("train_step_time_seconds").count == 4
    assert reg.get("train_tokens_total").value == 9 * 8 * 31
    assert reg.get("train_loss").value > 0
    assert tr.step_timer.totals["train_step"][0] == 9


# ------------------------------------------------- Completion timing facts
def test_completion_ttft_none_on_queued_timeout():
    """"No first token" must surface as ttft_s=None (and queue_wait_s=None
    for a request that never reached a slot) — not as a fake 0.0 that an
    SLO average would happily swallow."""
    from repro.serving.api import LLM

    model, params = build()
    llm = LLM(model, params, slots=1, max_len=64)
    # AutoClock: every read advances 50ms, so the queued request's 200ms
    # deadline expires deterministically while slot 0 grinds through 30
    # tokens — no wall-clock dependence
    llm.engine._clock = AutoClock(dt=0.05)
    outs = llm.generate(
        prompts_for(2, seed=4),
        [SamplingParams(max_new=30), SamplingParams(max_new=4,
                                                    deadline_ms=200)],
    )
    served, expired = outs
    assert served.finish_reason == "length"
    assert served.ttft_s is not None and served.ttft_s > 0
    assert served.queue_wait_s is not None and served.queue_wait_s >= 0
    assert expired.finish_reason == "timeout" and expired.tokens == []
    assert expired.ttft_s is None
    assert expired.queue_wait_s is None


# ----------------------------------------------------------- profiling
def test_step_timer_accumulates_per_phase():
    t = [0.0]
    timer = StepTimer(clock=lambda: t[0])
    for dt in (1.0, 3.0):
        with timer.span("decode"):
            t[0] += dt
    with timer.span("host_sync"):
        t[0] += 0.5
    assert timer.totals["decode"] == [2, 4.0]
    assert timer.mean("decode") == 2.0
    assert timer.mean("missing") == 0.0
    s = timer.summary()
    assert s["host_sync"]["count"] == 1
    assert "decode: n=2 mean=2000.000ms" in timer.report()


def test_profile_hooks_are_noops_when_disabled(tmp_path):
    with trace_ctx(""):          # falsy dir: plain passthrough
        pass
    with trace_ctx(None):
        pass
    with annotate("x", enabled=False):
        pass
    # enabled path must also survive on a CPU-only wheel (real annotation
    # or graceful no-op, never a raise)
    with annotate("engine/decode", enabled=True):
        pass
    with trace_ctx(str(tmp_path / "prof")):
        jax.block_until_ready(jax.numpy.ones(4) * 2)
