"""The memory-bounded jnp (xla) paths vs oracles + differentiability,
and the MoE dispatch vs its dense oracle."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.config import ModelConfig
from repro.kernels import ops, ref
from repro.models.moe import moe_apply, moe_defs, moe_ref_dense
from repro.core.module import materialize
from repro.parallel.sharding import null_ctx

KEY = jax.random.PRNGKey(7)


def test_blockwise_attention_matches_ref():
    q = jax.random.normal(KEY, (2, 96, 4, 32))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (2, 96, 2, 32))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (2, 96, 2, 32))
    for causal, window in [(True, 0), (False, 0), (True, 32)]:
        out = ops.attention(q, k, v, causal=causal, window=window, impl="xla")
        want = ref.attention_ref(q, k, v, causal=causal, window=window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5, rtol=1e-4)


def test_blockwise_attention_grads_match_naive():
    q = jax.random.normal(KEY, (1, 64, 2, 16))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (1, 64, 2, 16))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (1, 64, 2, 16))

    def loss(impl):
        return lambda q: (ops.attention(q, k, v, impl=impl) ** 2).sum()

    g_x = jax.grad(loss("xla"))(q)
    g_n = jax.grad(loss("naive"))(q)
    np.testing.assert_allclose(np.asarray(g_x), np.asarray(g_n), atol=2e-4, rtol=1e-3)


def test_decode_attention_variable_lengths():
    q = jax.random.normal(KEY, (3, 1, 4, 32))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (3, 128, 2, 32))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (3, 128, 2, 32))
    lens = jnp.array([16, 77, 128])
    out = ops.decode_attention(q, k, v, lens)
    for b in range(3):
        L = int(lens[b])
        want = ref.attention_ref(
            q[b:b + 1], k[b:b + 1, :L], v[b:b + 1, :L], causal=False
        )
        np.testing.assert_allclose(np.asarray(out[b]), np.asarray(want[0]),
                                   atol=2e-5, rtol=1e-4)


def test_blockwise_ce_matches_ref_and_grads():
    T, D, V, Vp = 96, 48, 900, 1024
    h = jax.random.normal(KEY, (T, D))
    W = jax.random.normal(jax.random.fold_in(KEY, 1), (D, Vp)) * 0.1
    tgt = jax.random.randint(jax.random.fold_in(KEY, 2), (T,), 0, V)
    loss, lse = ops.cross_entropy(h, W, tgt, vocab=V, impl="xla")
    want, wlse = ref.cross_entropy_ref(h, W[:, :V], tgt)
    np.testing.assert_allclose(np.asarray(loss), np.asarray(want), atol=2e-5, rtol=1e-5)

    g_x = jax.grad(lambda h: ops.cross_entropy(h, W, tgt, vocab=V, impl="xla")[0].mean())(h)
    g_n = jax.grad(lambda h: ref.cross_entropy_ref(h, W[:, :V], tgt)[0].mean())(h)
    np.testing.assert_allclose(np.asarray(g_x), np.asarray(g_n), atol=2e-5, rtol=1e-4)


def test_ssd_chunked_matches_ref_multiple_chunkings():
    B, S, H, P, G, N = 2, 60, 4, 8, 2, 8
    ks = jax.random.split(KEY, 6)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (B, S, G, N))
    Cm = jax.random.normal(ks[4], (B, S, G, N))
    Dv = jax.random.normal(ks[5], (H,))
    want_y, want_h = ref.ssd_ref(x, dt, A, Bm, Cm, Dv)
    for chunk in (10, 20, 60):
        y, hT = ops.ssd(x, dt, A, Bm, Cm, Dv, chunk=chunk, impl="xla")
        np.testing.assert_allclose(np.asarray(y), np.asarray(want_y), atol=2e-4, rtol=1e-3)
        np.testing.assert_allclose(np.asarray(hT), np.asarray(want_h), atol=2e-4, rtol=1e-3)


def test_ssd_decode_chain_matches_scan():
    """Stepping the recurrent form token-by-token == full ssd over the seq."""
    B, S, H, P, G, N = 1, 12, 2, 4, 1, 4
    ks = jax.random.split(KEY, 6)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (B, S, G, N))
    Cm = jax.random.normal(ks[4], (B, S, G, N))
    Dv = jax.random.normal(ks[5], (H,))
    want_y, _ = ref.ssd_ref(x, dt, A, Bm, Cm, Dv)
    state = jnp.zeros((B, H, P, N))
    for t in range(S):
        y, state = ops.ssd_decode_step(
            x[:, t:t+1], dt[:, t:t+1], A, Bm[:, t:t+1], Cm[:, t:t+1], Dv, state
        )
        np.testing.assert_allclose(
            np.asarray(y[:, 0]), np.asarray(want_y[:, t]), atol=2e-4, rtol=1e-3
        )


def test_moe_capacity_dispatch_approaches_dense_oracle():
    """With generous capacity, GShard dispatch == dense top-k routing."""
    cfg = ModelConfig(
        name="m", family="moe", num_layers=1, d_model=32, num_heads=2,
        num_kv_heads=2, d_ff=64, vocab_size=64, num_experts=4,
        num_experts_per_tok=2, capacity_factor=4.0, dtype="float32",
    )
    params = materialize(moe_defs(cfg), KEY, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(KEY, 3), (2, 16, 32))
    out, aux = moe_apply(cfg, null_ctx(), params, x)
    want = moe_ref_dense(cfg, params, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-4, rtol=1e-3)
    assert float(aux[0]) >= 1.0 - 1e-3  # lb >= 1 by Cauchy-Schwarz at any routing


def test_moe_capacity_drops_tokens_when_tight():
    cfg = ModelConfig(
        name="m", family="moe", num_layers=1, d_model=32, num_heads=2,
        num_kv_heads=2, d_ff=64, vocab_size=64, num_experts=4,
        num_experts_per_tok=1, capacity_factor=0.25, dtype="float32",
    )
    params = materialize(moe_defs(cfg), KEY, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(KEY, 4), (1, 64, 32))
    out, _ = moe_apply(cfg, null_ctx(), params, x)
    # some token rows must be zero (dropped)
    norms = np.linalg.norm(np.asarray(out[0]), axis=-1)
    assert (norms < 1e-6).any()


def test_moe_grouping_invariance_with_generous_capacity():
    """With capacity_factor high enough that nothing drops, the grouped
    (per-device-capacity) dispatch must equal the ungrouped computation —
    grouping is a systems transformation, not a semantic one."""
    import jax.numpy as jnp
    from repro.core.config import ParallelConfig
    from repro.parallel.sharding import ShardingCtx

    cfg = ModelConfig(
        name="m", family="moe", num_layers=1, d_model=32, num_heads=2,
        num_kv_heads=2, d_ff=64, vocab_size=64, num_experts=4,
        num_experts_per_tok=2, capacity_factor=16.0, dtype="float32",
    )
    params = materialize(moe_defs(cfg), KEY, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(KEY, 9), (4, 16, 32))
    out_ungrouped, aux1 = moe_apply(cfg, null_ctx(), params, x)  # G=1 (no mesh)
    want = moe_ref_dense(cfg, params, x)
    np.testing.assert_allclose(np.asarray(out_ungrouped), np.asarray(want),
                               atol=1e-4, rtol=1e-3)
