"""Optimizer math, LR schedules, checkpoint resharding restore."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.config import TrainConfig
from repro.optim import adamw
from repro.optim.schedule import lr_at


def test_adamw_matches_reference_formula():
    """One AdamW step vs hand-computed update (fp32, no decay)."""
    tc = TrainConfig(beta1=0.9, beta2=0.999, eps=1e-8, weight_decay=0.0)
    p = {"w": jnp.array([1.0, -2.0, 3.0])}
    g = {"w": jnp.array([0.1, 0.2, -0.3])}
    state = adamw.init_state(p)
    lr = jnp.float32(0.01)
    new_p, new_s = adamw.apply_updates(p, g, state, lr, tc)
    m = 0.1 * np.asarray(g["w"])
    v = 0.001 * np.asarray(g["w"]) ** 2
    mhat = m / (1 - 0.9)
    vhat = v / (1 - 0.999)
    want = np.asarray(p["w"]) - 0.01 * mhat / (np.sqrt(vhat) + 1e-8)
    np.testing.assert_allclose(np.asarray(new_p["w"]), want, rtol=1e-6)
    assert int(new_s.step) == 1


def test_weight_decay_only_on_matrices():
    tc = TrainConfig(weight_decay=0.1, learning_rate=0.0)
    p = {"w": jnp.ones((2, 2)), "b": jnp.ones((2,))}
    g = {"w": jnp.zeros((2, 2)), "b": jnp.zeros((2,))}
    state = adamw.init_state(p)
    # lr=0.05 explicit
    new_p, _ = adamw.apply_updates(p, g, state, jnp.float32(0.05), tc)
    assert float(new_p["w"][0, 0]) < 1.0       # decayed
    assert float(new_p["b"][0]) == 1.0          # not decayed


def test_grad_clip():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, gn = adamw.clip_by_global_norm(g, 1.0)
    assert abs(float(gn) - 20.0) < 1e-4
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(clipped["a"])), 1.0, rtol=1e-5
    )


def test_bf16_optimizer_states():
    p = {"w": jnp.ones((4,), jnp.float32)}
    s = adamw.init_state(p, jnp.bfloat16)
    assert s.mu["w"].dtype == jnp.bfloat16
    tc = TrainConfig()
    g = {"w": jnp.full((4,), 0.5)}
    new_p, new_s = adamw.apply_updates(p, g, s, jnp.float32(0.1), tc)
    assert new_s.mu["w"].dtype == jnp.bfloat16
    assert np.isfinite(np.asarray(new_p["w"], np.float32)).all()


@pytest.mark.parametrize("sched", ["wsd", "cosine", "noam", "const"])
def test_schedules_warmup_and_finite(sched):
    tc = TrainConfig(learning_rate=1e-3, warmup_steps=10, total_steps=100,
                     decay_steps=20, schedule=sched)
    lrs = [float(lr_at(tc, s)) for s in range(0, 101, 5)]
    assert all(np.isfinite(lrs))
    assert lrs[0] <= lrs[1]            # warming up
    assert max(lrs) <= tc.learning_rate * 1.001


def test_wsd_decays_at_end():
    tc = TrainConfig(learning_rate=1e-3, min_lr=1e-5, warmup_steps=10,
                     total_steps=100, decay_steps=20, schedule="wsd")
    assert float(lr_at(tc, 50)) == pytest.approx(1e-3)
    assert float(lr_at(tc, 100)) == pytest.approx(1e-5, rel=1e-2)


def test_checkpoint_restore_with_shardings(tmp_path):
    from repro.checkpoint import ckpt

    tree = {"a": jnp.arange(8.0), "nest": {"b": jnp.ones((2, 3))}}
    ckpt.save(str(tmp_path / "c"), tree, step=3)
    shardings = jax.tree.map(
        lambda x: jax.sharding.SingleDeviceSharding(jax.devices()[0]), tree
    )
    restored = ckpt.restore(str(tmp_path / "c"), tree, shardings)
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.arange(8.0))
    assert restored["nest"]["b"].sharding == shardings["nest"]["b"]


def test_checkpoint_bfloat16_bit_pattern(tmp_path):
    """ml_dtypes leaves (kind 'V') are stored as raw bit patterns and
    restored to the logical dtype bit-exactly (np.save can't round-trip
    them natively)."""
    from repro.checkpoint import ckpt

    tree = {"w": jnp.array([1.5, -2.25, 3.0], jnp.bfloat16)}
    ckpt.save(str(tmp_path / "c"), tree, step=1)
    restored = ckpt.restore(str(tmp_path / "c"), tree)
    assert restored["w"].dtype == np.dtype(jnp.bfloat16)
    np.testing.assert_array_equal(
        np.asarray(restored["w"]).view(np.uint16),
        np.asarray(tree["w"]).view(np.uint16),
    )


def test_checkpoint_latest_step(tmp_path):
    from repro.checkpoint import ckpt

    for s in (10, 5, 20):
        ckpt.save(str(tmp_path / f"step_{s}"), {"x": jnp.zeros(1)}, s)
    latest = ckpt.latest_step(str(tmp_path))
    assert latest.endswith("step_20")


def test_checkpoint_save_is_atomic(tmp_path):
    """A crash mid-save must leave either the previous complete
    checkpoint or nothing resumable — never a half-written step dir."""
    from repro.checkpoint import ckpt

    target = tmp_path / "step_5"
    ckpt.save(str(target), {"x": jnp.arange(4.0)}, 5)
    # simulate a crash mid-write of a REPLACEMENT save: leaves present,
    # manifest (written last) missing — exactly the pre-replace state
    stale = tmp_path / f".step_5.tmp.{12345}"
    stale.mkdir()
    np.save(stale / "x.npy", np.zeros(4))
    # hidden tmp dirs are invisible to resume discovery
    assert ckpt.latest_step(str(tmp_path)).endswith("step_5")
    # and a fresh save over the same name replaces the old dir atomically
    ckpt.save(str(target), {"x": jnp.full((4,), 7.0)}, 5)
    restored = ckpt.restore(str(target), {"x": jnp.zeros(4)})
    np.testing.assert_array_equal(np.asarray(restored["x"]), np.full(4, 7.0))
    # no temp droppings remain from the completed save (the simulated
    # crash orphan is still there, which is fine: it is hidden)
    assert sorted(d for d in os.listdir(tmp_path) if not d.startswith(".")) \
        == ["step_5"]


def test_latest_step_skips_incomplete_dirs(tmp_path):
    """A step dir without the manifest sentinel (crashed pre-atomic
    writer, partial rsync, ...) is skipped, not picked or crashed on."""
    from repro.checkpoint import ckpt

    ckpt.save(str(tmp_path / "step_10"), {"x": jnp.zeros(2)}, 10)
    partial = tmp_path / "step_20"
    partial.mkdir()
    np.save(partial / "x.npy", np.zeros(2))  # leaves but no manifest.json
    assert ckpt.latest_step(str(tmp_path)).endswith("step_10")
    (tmp_path / "step_10" / "manifest.json").unlink()
    assert ckpt.latest_step(str(tmp_path)) is None


def test_save_train_state_extra_inside_atomic_unit(tmp_path):
    """extra.json rides inside the same atomic rename as the tensors."""
    from repro.checkpoint import ckpt
    from repro.training.train_step import TrainState

    params = {"w": jnp.ones((2, 2))}
    state = TrainState(params, adamw.init_state(params, jnp.float32))
    ckpt.save_train_state(
        str(tmp_path / "step_3"), state, 3, extra={"cursor": 17}
    )
    abstract = TrainState(
        {"w": jax.ShapeDtypeStruct((2, 2), jnp.float32)},
        adamw.AdamWState(
            step=jax.ShapeDtypeStruct((), jnp.int32),
            mu={"w": jax.ShapeDtypeStruct((2, 2), jnp.float32)},
            nu={"w": jax.ShapeDtypeStruct((2, 2), jnp.float32)},
        ),
    )
    restored, step, extra = ckpt.restore_train_state(
        str(tmp_path / "step_3"), abstract
    )
    assert step == 3 and extra == {"cursor": 17}
    np.testing.assert_array_equal(np.asarray(restored.params["w"]), np.ones((2, 2)))
