"""Paged KV-cache serving subsystem: allocator invariants, paged kernel
parity against the dense decode path, and engine-level layout parity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.config import ModelConfig
from repro.kernels import ops
from repro.models.model import build_model
from repro.serving.engine import Engine, Request
from repro.serving.paged_cache import NULL_PAGE, PageAllocator, pages_for


# --------------------------------------------------------------------- #
# allocator properties
# --------------------------------------------------------------------- #
def test_allocator_basic():
    al = PageAllocator(num_pages=9, page_size=4, slots=2, max_len=16)
    assert al.pages_per_seq == 4 and al.free_pages == 8
    pages = al.alloc(0, 10)                     # 3 pages
    assert len(pages) == 3 and NULL_PAGE not in pages
    assert al.free_pages == 5
    assert list(al.table[0, :3]) == list(pages)
    assert all(p == NULL_PAGE for p in al.table[0, 3:])
    al.check_invariants()
    assert al.release(0) == 3
    assert al.free_pages == 8
    al.check_invariants()


def test_allocator_capacity_refusal():
    al = PageAllocator(num_pages=5, page_size=4, slots=2, max_len=16)
    assert not al.can_admit(17)                 # > pages_per_seq * page
    assert not al.fits_slot(17)
    assert al.can_admit(16)
    al.alloc(0, 12)                             # 3 of 4 usable pages
    assert not al.can_admit(8)                  # only 1 page free
    assert al.can_admit(4)
    with pytest.raises(RuntimeError):
        al.alloc(1, 8)                          # out of pages
    with pytest.raises(RuntimeError):
        al.alloc(0, 4)                          # slot already holds pages
    al.check_invariants()


def test_allocator_append_page_boundary():
    al = PageAllocator(num_pages=9, page_size=4, slots=1, max_len=32)
    al.alloc(0, 3)
    assert len(al.owned(0)) == 1
    al.append(0)                                # 4 tokens: still 1 page
    assert len(al.owned(0)) == 1
    al.append(0)                                # 5 tokens: new page
    assert len(al.owned(0)) == 2
    al.check_invariants()
    with pytest.raises(ValueError):
        al.append(0, n=64)                      # overflows the slot


def test_allocator_churn_no_leak_no_double_alloc():
    """Randomized admit/append/release churn keeps every invariant: pages
    are never shared, never both free and owned, and never leak."""
    rng = np.random.default_rng(0)
    al = PageAllocator(num_pages=17, page_size=4, slots=4, max_len=24)
    active = {}
    for _ in range(500):
        op = rng.integers(0, 3)
        slot = int(rng.integers(0, 4))
        if op == 0 and slot not in active:
            tokens = int(rng.integers(1, 25))
            if al.can_admit(tokens):
                pages = al.alloc(slot, tokens)
                assert len(set(pages)) == len(pages)
                active[slot] = tokens
        elif op == 1 and slot in active:
            grown = active[slot] + 1
            if (pages_for(grown, 4) <= al.pages_per_seq
                    and pages_for(grown, 4) - len(al.owned(slot))
                    <= al.free_pages):
                al.append(slot)
                active[slot] = grown
        elif op == 2 and slot in active:
            al.release(slot)
            del active[slot]
        al.check_invariants()
        # cross-slot disjointness of the block table's live entries
        live = [p for s in active for p in al.owned(s)]
        assert len(set(live)) == len(live)
    for slot in list(active):
        al.release(slot)
    al.check_invariants()
    assert al.free_pages == al.num_pages - 1


# --------------------------------------------------------------------- #
# kernel parity: paged vs dense decode
# --------------------------------------------------------------------- #
def _random_paged(rng, B, Hkv, D, page, pps):
    P = 1 + B * pps
    k_pool = jnp.asarray(rng.normal(size=(P, page, Hkv, D)), jnp.float32)
    v_pool = jnp.asarray(rng.normal(size=(P, page, Hkv, D)), jnp.float32)
    # each slot owns a disjoint shuffled set of pages
    perm = rng.permutation(np.arange(1, P))
    bt = jnp.asarray(perm.reshape(B, pps).astype(np.int32))
    return k_pool, v_pool, bt


@pytest.mark.parametrize("impl", ["xla", "pallas_interpret"])
@pytest.mark.parametrize("softcap", [0.0, 20.0])
def test_paged_decode_matches_dense(impl, softcap):
    rng = np.random.default_rng(1)
    B, H, Hkv, D, page, pps = 3, 4, 2, 16, 8, 4
    q = jnp.asarray(rng.normal(size=(B, 1, H, D)), jnp.float32)
    k_pool, v_pool, bt = _random_paged(rng, B, Hkv, D, page, pps)
    lengths = jnp.asarray([20, 0, 32], jnp.int32)   # incl. empty slot

    got = ops.paged_decode_attention(
        q, k_pool, v_pool, bt, lengths, softcap=softcap, impl=impl
    )
    # dense reference: gather pages into a contiguous cache
    k = jnp.take(k_pool, bt.reshape(-1), 0).reshape(B, pps * page, Hkv, D)
    v = jnp.take(v_pool, bt.reshape(-1), 0).reshape(B, pps * page, Hkv, D)
    want = ops.decode_attention(q, k, v, lengths, softcap=softcap, impl="xla")
    np.testing.assert_allclose(got, want, atol=2e-5)


@pytest.mark.parametrize("impl", ["xla", "pallas_interpret"])
def test_paged_kv_update_scatter(impl):
    rng = np.random.default_rng(2)
    B, Hkv, D, page, pps = 3, 2, 16, 8, 4
    k_pool, v_pool, bt = _random_paged(rng, B, Hkv, D, page, pps)
    k_new = jnp.asarray(rng.normal(size=(B, 1, Hkv, D)), jnp.float32)
    v_new = jnp.asarray(rng.normal(size=(B, 1, Hkv, D)), jnp.float32)
    pos = np.asarray([5, 8, 31])
    page_idx = jnp.asarray(
        [int(bt[b, p // page]) for b, p in enumerate(pos)], jnp.int32
    )
    row = jnp.asarray(pos % page, jnp.int32)
    nk, nv = ops.paged_kv_update(
        k_pool, v_pool, k_new, v_new, page_idx, row, impl=impl
    )
    ek = k_pool.at[page_idx, row].set(k_new[:, 0])
    ev = v_pool.at[page_idx, row].set(v_new[:, 0])
    np.testing.assert_allclose(nk, ek, atol=0)
    np.testing.assert_allclose(nv, ev, atol=0)


def test_flash_decode_non_multiple_tail():
    """flash_decode pads+masks cache lengths that don't divide block_t
    (the PR-1 tail fix, extended to the decode kernel)."""
    rng = np.random.default_rng(3)
    B, H, Hkv, D, T = 2, 4, 2, 16, 100
    q = jnp.asarray(rng.normal(size=(B, 1, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, T, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, T, Hkv, D)), jnp.float32)
    lengths = jnp.asarray([77, 100], jnp.int32)
    from repro.kernels.flash_decode import flash_decode

    got = flash_decode(q, k, v, lengths, block_t=64, interpret=True)
    want = ops.decode_attention(q, k, v, lengths, impl="xla")
    np.testing.assert_allclose(got, want, atol=2e-5)


# --------------------------------------------------------------------- #
# engine-level layout parity
# --------------------------------------------------------------------- #
def _build(kernel_impl="auto"):
    cfg = ModelConfig(
        name="t", family="dense", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=2, d_ff=128, vocab_size=64, dtype="float32",
        kernel_impl=kernel_impl,
    )
    model = build_model(cfg)
    return model, model.init(jax.random.PRNGKey(0))


def _serve(model, params, prompts, layout, max_new=5, **kw):
    eng = Engine(
        model, params, slots=2, max_len=64, cache_layout=layout,
        page_size=8, **kw,
    )
    for i, p in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=p, max_new=max_new))
    done = eng.run()
    assert len(done) == len(prompts)
    return eng, {r.uid: r.output for r in done}


def test_engine_paged_matches_dense_xla():
    model, params = _build()
    rng = np.random.default_rng(4)
    prompts = [rng.integers(0, 64, size=L).astype(np.int32)
               for L in (5, 9, 7, 12, 6)]
    _, dense = _serve(model, params, prompts, "dense")
    eng, paged = _serve(model, params, prompts, "paged")
    assert paged == dense
    eng.alloc.check_invariants()
    assert eng.alloc.free_pages == eng.alloc.num_pages - 1, "pages leaked"
    # satellite fix: released slots come back with pos reset to 0
    assert np.all(np.asarray(eng.cache["pos"]) == 0)


def test_engine_paged_matches_dense_pallas_interpret():
    model, params = _build("pallas_interpret")
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, 64, size=L).astype(np.int32) for L in (5, 9, 3)]
    _, dense = _serve(model, params, prompts, "dense", max_new=4)
    _, paged = _serve(model, params, prompts, "paged", max_new=4)
    assert paged == dense


def test_engine_paged_under_page_pressure():
    """A pool far smaller than total demand forces queueing on pages;
    every request still completes with identical outputs."""
    model, params = _build()
    rng = np.random.default_rng(6)
    prompts = [rng.integers(0, 64, size=L).astype(np.int32)
               for L in (5, 9, 7, 12, 6)]
    _, dense = _serve(model, params, prompts, "dense")
    # 3 usable pages of 8 = 24 tokens: one request at a time
    eng, paged = _serve(model, params, prompts, "paged", num_pages=4)
    assert paged == dense
    assert eng.alloc.free_pages == eng.alloc.num_pages - 1


def test_engine_rejects_impossible_requests():
    model, params = _build()
    eng = Engine(model, params, slots=1, max_len=32, cache_layout="paged",
                 page_size=8)
    with pytest.raises(ValueError):
        eng.submit(Request(uid=0, prompt=np.zeros(30, np.int32), max_new=8))
    eng2 = Engine(model, params, slots=1, max_len=32)
    with pytest.raises(ValueError):
        eng2.submit(Request(uid=0, prompt=np.zeros(30, np.int32), max_new=8))


@pytest.mark.parametrize("layout", ["dense", "paged"])
def test_engine_vision_frontend(layout):
    """A vision_stub model counts frontend rows only when the batch really
    carries img_embeds — text-only serving must match isolated decoding."""
    cfg = ModelConfig(
        name="t", family="vlm", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=2, d_ff=128, vocab_size=64, dtype="float32",
        frontend="vision_stub", num_frontend_tokens=4,
    )
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(8)
    img = jnp.asarray(rng.normal(size=(1, 4, 64)), jnp.float32)

    for extra in ({}, {"img_embeds": img}):
        prompts = [rng.integers(0, 64, size=L).astype(np.int32)
                   for L in (5, 9, 7)]
        eng = Engine(model, params, slots=2, max_len=64,
                     cache_layout=layout, page_size=8, extra_batch=extra)
        for i, p in enumerate(prompts):
            eng.submit(Request(uid=i, prompt=p, max_new=5))
        done = eng.run()
        assert len(done) == len(prompts)
        for req in done:
            batch = {"tokens": jnp.asarray(prompts[req.uid][None], jnp.int32),
                     **extra}
            lg, cache = model.prefill(params, batch, 64)
            want = [int(jnp.argmax(lg[0, -1]))]
            for _ in range(4):
                lg, cache = model.decode_step(
                    params, cache, jnp.asarray([[want[-1]]], jnp.int32)
                )
                want.append(int(jnp.argmax(lg[0, -1])))
            assert req.output == want, (extra.keys(), req.uid)


def test_engine_bucketing_matches_unbucketed():
    """Prompt bucketing (right-pad to pow-2) must not change any token."""
    model, params = _build()
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, 64, size=L).astype(np.int32)
               for L in (3, 11, 17, 6)]
    _, on = _serve(model, params, prompts, "paged", bucket_prompts=True)
    _, off = _serve(model, params, prompts, "paged", bucket_prompts=False)
    assert on == off
