"""Sharded execution must be numerically equivalent to single-device:
head-TP and context-parallel losses/grad-norms match the mesh-free run.
(Subprocess: needs 8 placeholder devices.)"""
import os
import subprocess
import sys
import textwrap

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

CODE = textwrap.dedent("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding
    from repro.core.config import ModelConfig, ParallelConfig, TrainConfig
    from repro.models.model import build_model
    from repro.training.train_step import init_train_state, make_train_step

    cfg = ModelConfig(
        name="t", family="dense", num_layers=2, d_model=64, num_heads=8,
        num_kv_heads=2, d_ff=128, vocab_size=128, dtype="float32",
    )
    tc = TrainConfig(total_steps=1)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, 128)
    batch = {"tokens": tokens}

    def loss_with(mesh, pc):
        model = build_model(cfg, pc, mesh)
        state = init_train_state(model, jax.random.PRNGKey(0), tc)
        step = make_train_step(model, tc)
        if mesh is not None:
            with mesh:
                _, m = jax.jit(step)(state, batch)
        else:
            _, m = jax.jit(step)(state, batch)
        return float(m["loss"]), float(m["grad_norm"])

    ref = loss_with(None, ParallelConfig())
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    tp = loss_with(mesh, ParallelConfig(attention_parallelism="head_tp"))
    cp = loss_with(mesh, ParallelConfig(attention_parallelism="context"))
    print("ref", ref); print("tp", tp); print("cp", cp)
    for name, got in (("tp", tp), ("cp", cp)):
        assert abs(got[0] - ref[0]) < 1e-4, (name, got, ref)
        assert abs(got[1] - ref[1]) / max(ref[1], 1) < 1e-3, (name, got, ref)
    # SSM family under CP (SP boundaries inside the mamba block)
    scfg = ModelConfig(
        name="s", family="ssm", num_layers=2, d_model=64, num_heads=8,
        num_kv_heads=8, d_ff=0, vocab_size=128, ssm_state=16, ssm_headdim=16,
        ssm_chunk=8, dtype="float32",
    )
    def loss_ssm(mesh, pc):
        model = build_model(scfg, pc, mesh)
        state = init_train_state(model, jax.random.PRNGKey(0), tc)
        step = make_train_step(model, tc)
        ctx = mesh if mesh is not None else None
        if ctx is not None:
            with ctx:
                _, m = jax.jit(step)(state, batch)
        else:
            _, m = jax.jit(step)(state, batch)
        return float(m["loss"])
    r = loss_ssm(None, ParallelConfig())
    c = loss_ssm(mesh, ParallelConfig(attention_parallelism="context"))
    assert abs(r - c) < 1e-4, (r, c)
    print("ssm ok", r, c)
    print("ALL_OK")
""")


def test_sharded_equals_single_device():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    out = subprocess.run(
        [sys.executable, "-c", CODE], capture_output=True, text=True, env=env,
        timeout=900,
    )
    assert out.returncode == 0, out.stderr[-4000:]
    assert "ALL_OK" in out.stdout
