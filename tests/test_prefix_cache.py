"""Prefix caching + chunked prefill: allocator property churn and
engine/model parity.

The property suite drives random admit/decode/release/evict/COW churn
with shared prompt prefixes against :class:`PageAllocator` plus a shadow
content model (what the KV pages *would* hold), checking after every op:

  * refcounts equal live references (and the rest of
    ``check_invariants``: no page both free and mapped, hash index never
    points at a freed page, no leaks);
  * a hash hit always returns pages whose recorded content matches the
    prompt's blocks (content addressing is sound);
  * COW never mutates a shared page — any write target is exclusively
    owned, and the source page's content survives a copy-on-write.

Runs under Hypothesis when available (``@settings(derandomize=True)``
keeps CI deterministic); a seeded fallback driver runs the same churn
with 250 fixed examples where Hypothesis is not installed, so the
invariants are enforced in every environment.

Parity: greedy engine outputs with prefix caching ON are token-for-token
identical to cold-start prefill (dense engine and paged baseline),
across ``impl`` xla / pallas_interpret; chunked prefill logits match
one-shot prefill for chunk = 16 / 64 / max.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.config import ModelConfig
from repro.models.model import build_model
from repro.serving.engine import Engine, Request
from repro.serving.paged_cache import (
    PageAllocator,
    block_hashes,
    pages_for,
)

try:
    from hypothesis import given, settings, strategies as st

    HAS_HYPOTHESIS = True
except ImportError:  # pragma: no cover - CI installs hypothesis
    HAS_HYPOTHESIS = False

PAGE = 4


# --------------------------------------------------------------------- #
# allocator churn with a shadow content model
# --------------------------------------------------------------------- #
class Churn:
    """Drives one op stream; mirrors the engine's write discipline."""

    N_LINEAGES = 3

    def __init__(self, num_pages=21, slots=4, max_len=24):
        self.al = PageAllocator(num_pages, PAGE, slots, max_len,
                                prefix_cache=True)
        # each lineage is a long base sequence; prompts take a prefix of
        # a lineage plus a unique tail — natural shared-prefix traffic
        rng = np.random.default_rng(12345)
        self.lineages = [
            rng.integers(0, 7, size=max_len).astype(np.int32)
            for _ in range(self.N_LINEAGES)
        ]
        self.uniq = 1000
        self.active = {}   # slot -> prompt np.ndarray
        self.content = {}  # page -> tuple(block tokens) once "prefilled"

    # -- helpers ------------------------------------------------------- #
    def _write(self, page: int, block) -> None:
        """Simulate writing KV into `page` — legal only if the slot owns
        it exclusively and it is not shared through the hash index with
        anyone else (the COW discipline)."""
        assert self.al.ref(page) == 1, \
            f"write into shared page {page} (ref={self.al.ref(page)})"
        self.content[page] = tuple(int(t) for t in block)

    def _check_match(self, prompt, shared) -> None:
        for i, p in enumerate(shared):
            blk = tuple(int(t) for t in prompt[i * PAGE : (i + 1) * PAGE])
            assert self.content.get(p) == blk, \
                f"hash hit returned page {p} with wrong content"

    # -- ops ----------------------------------------------------------- #
    def admit(self, slot, lineage, pfx_blocks, tail_len, max_new) -> None:
        if slot in self.active:
            return
        base = self.lineages[lineage % self.N_LINEAGES]
        pfx = base[: (pfx_blocks % (len(base) // PAGE)) * PAGE]
        self.uniq += 1
        tail = np.full((tail_len % (2 * PAGE),), self.uniq, np.int32)
        prompt = np.concatenate([pfx, tail]).astype(np.int32)
        if len(prompt) == 0:
            return
        budget = len(prompt) + 1 + max_new % 8
        if not self.al.fits_slot(budget):
            return
        plan = self.al.plan(budget, prompt)
        self._check_match(prompt[: plan.cached_tokens + 1], plan.shared[
            : plan.cached_tokens // PAGE
        ])
        if not self.al.can_admit(budget, plan):
            return
        pages = self.al.alloc(slot, budget, plan)
        # simulate the suffix prefill: COW copy first, then fresh blocks
        if self.al.last_cow is not None:
            src, dst = self.al.last_cow
            assert self.al.ref(dst) == 1
            self.content[dst] = self.content.get(src)  # device page copy
            # the source stays intact for its other holders / the index
            assert self.al.is_registered(src) or self.al.ref(src) > 0
        n_shared = plan.cached_tokens // PAGE
        for i in range(n_shared, len(prompt) // PAGE):
            self._write(int(pages[i]), prompt[i * PAGE : (i + 1) * PAGE])
        self.al.register(slot, prompt)
        self.active[slot] = prompt

    def decode(self, slot) -> None:
        """One generated token: lazy growth, never into a shared page."""
        if slot not in self.active:
            return
        tokens = self.al._tokens[slot]
        need = pages_for(tokens + 1, PAGE)
        if need > self.al.pages_per_seq or \
                need - len(self.al.owned(slot)) > self.al.free_pages:
            return
        self.al.append(slot)
        # the decode write position must sit in an exclusively-owned page
        page = self.al.owned(slot)[tokens // PAGE]
        assert self.al.ref(page) >= 1
        if self.al.ref(page) > 1 or self.al.is_registered(page):
            # engine guarantee: decode never writes shared/registered
            # pages because registration covers only full PROMPT blocks
            # and decode writes at pos >= len(prompt)
            prompt = self.active[slot]
            assert tokens // PAGE < len(prompt) // PAGE, \
                "decode write position landed in a shared/registered page"

    def cow(self, slot, idx) -> None:
        """Explicit copy-on-write of an owned page (the fork path)."""
        if slot not in self.active or not self.al.owned(slot):
            return
        idx = idx % len(self.al.owned(slot))
        src = self.al.owned(slot)[idx]
        if self.al.ref(src) > 1 and not self.al._free and \
                not self.al._evictable:
            return  # no page to copy into
        src_content = self.content.get(src)
        src_ref = self.al.ref(src)
        pair = self.al.cow_write(slot, idx)
        if src_ref > 1:
            assert pair is not None and pair[0] == src
            dst = pair[1]
            assert self.al.ref(src) == src_ref - 1
            assert self.al.ref(dst) == 1 and self.al.owned(slot)[idx] == dst
            self.content[dst] = src_content
            # COW never mutates the shared source page
            assert self.content.get(src) == src_content
        else:
            assert pair is None
            assert not self.al.is_registered(src)  # unregistered in place

    def release(self, slot) -> None:
        if slot in self.active:
            self.al.release(slot)
            del self.active[slot]

    def flush(self) -> None:
        self.al.drop_cache()

    def apply(self, op) -> None:
        kind = op[0] % 8
        if kind <= 2:
            self.admit(op[1] % self.al.slots, op[2], op[3], op[4], op[1])
        elif kind <= 4:
            self.decode(op[1] % self.al.slots)
        elif kind == 5:
            self.cow(op[1] % self.al.slots, op[2])
        elif kind == 6:
            self.release(op[1] % self.al.slots)
        else:
            self.flush()
        self.al.check_invariants()

    def finish(self) -> None:
        for slot in list(self.active):
            self.release(slot)
        self.al.check_invariants()
        # every page is either free or a parked cached page; nothing leaks
        assert self.al.free_pages == self.al.num_pages - 1


def _run_ops(ops) -> None:
    churn = Churn()
    for op in ops:
        churn.apply(op)
    churn.finish()


_OP = (0, 8), (0, 64), (0, 12), (0, 64), (0, 64)


if HAS_HYPOTHESIS:
    op_strategy = st.tuples(*[st.integers(lo, hi) for lo, hi in _OP])

    @settings(max_examples=250, deadline=None, derandomize=True)
    @given(ops=st.lists(op_strategy, min_size=1, max_size=40))
    def test_prefix_allocator_churn_hypothesis(ops):
        _run_ops(ops)


def test_prefix_allocator_churn_seeded():
    """Seeded fallback: the same churn over 250 deterministic examples —
    keeps the invariants enforced where hypothesis is not installed."""
    rng = np.random.default_rng(0)
    for _ in range(250):
        n = int(rng.integers(1, 41))
        ops = [tuple(int(rng.integers(lo, hi + 1)) for lo, hi in _OP)
               for _ in range(n)]
        _run_ops(ops)


def test_cow_write_shared_page_semantics():
    """Directed COW: two slots share a page; a COW gives the writer a
    private copy and leaves the shared page untouched and still indexed."""
    al = PageAllocator(17, PAGE, 2, 16, prefix_cache=True)
    prompt = np.arange(8, dtype=np.int32)          # 2 full blocks
    al.alloc(0, 10, al.plan(10, prompt))
    al.register(0, prompt)
    plan = al.plan(10, prompt)
    assert plan.cached_tokens == 8 - 1 and plan.cow_last  # full-prompt hit
    plan2 = al.plan(12, np.concatenate([prompt, [9, 9, 9]]).astype(np.int32))
    assert plan2.cached_tokens == 8 and not plan2.cow_last
    al.alloc(1, 12, plan2)
    shared = al.owned(0)[0]
    assert al.owned(1)[0] == shared and al.ref(shared) == 2
    pair = al.cow_write(1, 0)
    assert pair is not None and pair[0] == shared
    assert al.ref(shared) == 1 and al.ref(pair[1]) == 1
    assert al.is_registered(shared) and not al.is_registered(pair[1])
    al.check_invariants()
    al.release(0), al.release(1)
    al.check_invariants()


def test_eviction_never_dangles_hash_index():
    """Evicting parked pages under pressure drops their index entries —
    the hash index never points at a freed page (checked structurally)."""
    al = PageAllocator(6, PAGE, 2, 32, prefix_cache=True)   # 5 usable pages
    pa = np.arange(8, dtype=np.int32)
    al.alloc(0, 8, al.plan(8, pa))
    al.register(0, pa)
    al.release(0)                       # both pages parked, still indexed
    assert len(al._evictable) == 2 and al.free_pages == 5
    pb = np.full((16,), 7, np.int32)    # needs 4+ pages -> forces eviction
    al.alloc(0, 17, al.plan(17, pb))
    assert al.stats["evictions"] >= 1
    al.check_invariants()
    assert al.match_prefix(pa) == [] or len(al.match_prefix(pa)) < 2
    al.release(0)
    al.check_invariants()


# --------------------------------------------------------------------- #
# parity: prefix caching / chunked prefill never change a token
# --------------------------------------------------------------------- #
def _build(kernel_impl="auto"):
    cfg = ModelConfig(
        name="t", family="dense", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=2, d_ff=128, vocab_size=64, dtype="float32",
        kernel_impl=kernel_impl,
    )
    model = build_model(cfg)
    return model, model.init(jax.random.PRNGKey(0))


def _serve(model, params, prompts, layout, max_new=4, **kw):
    eng = Engine(model, params, slots=2, max_len=64, cache_layout=layout,
                 page_size=8, **kw)
    for i, p in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=p, max_new=max_new))
    done = eng.run()
    assert len(done) == len(prompts)
    return eng, {r.uid: r.output for r in done}


def _shared_prefix_prompts(rng, n_pfx=16, tails=(5, 9, 0, 3)):
    pfx = rng.integers(0, 64, size=n_pfx).astype(np.int32)
    return [
        np.concatenate([pfx, rng.integers(0, 64, size=t).astype(np.int32)])
        for t in tails
    ]


@pytest.mark.parametrize("impl", ["xla", "pallas_interpret"])
def test_engine_prefix_cache_matches_cold_start(impl):
    """Greedy outputs with prefix caching ON (incl. a prompt exactly equal
    to the cached prefix — the COW path) match cold-start prefill in both
    the dense and the paged baseline engines."""
    model, params = _build(impl)
    rng = np.random.default_rng(11)
    prompts = _shared_prefix_prompts(rng)
    _, dense = _serve(model, params, prompts, "dense")
    _, paged = _serve(model, params, prompts, "paged")
    eng, pfx = _serve(model, params, prompts, "paged", prefix_cache=True)
    assert pfx == dense and paged == dense
    assert eng.alloc.stats["hit_tokens"] > 0, "prefix cache never hit"
    assert eng.alloc.stats["cow_copies"] >= 1, "exact-prefix COW not hit"
    eng.alloc.check_invariants()


def test_engine_chunked_prefill_matches_cold_start():
    """Bounded prefill chunks interleaved with decodes are invisible in
    the output stream, with and without prefix caching."""
    model, params = _build()
    rng = np.random.default_rng(12)
    prompts = _shared_prefix_prompts(rng, n_pfx=24, tails=(13, 1, 7, 0, 20))
    _, dense = _serve(model, params, prompts, "dense")
    for kw in (dict(prefill_chunk=8), dict(prefill_chunk=8, prefix_cache=True)):
        eng, out = _serve(model, params, prompts, "paged", **kw)
        assert out == dense, kw
        eng.alloc.check_invariants()
        assert eng.alloc.free_pages == eng.alloc.num_pages - 1


def test_engine_prefix_cache_under_eviction_pressure():
    """A pool too small to keep cached pages parked forces evictions;
    outputs still match the dense engine exactly."""
    model, params = _build()
    rng = np.random.default_rng(13)
    prompts = _shared_prefix_prompts(rng, tails=(2, 3))
    prompts += [rng.integers(0, 64, size=20).astype(np.int32)
                for _ in range(3)]
    _, dense = _serve(model, params, prompts, "dense")
    eng, out = _serve(model, params, prompts, "paged", num_pages=8,
                      prefix_cache=True, prefill_chunk=8)
    assert out == dense
    assert eng.alloc.stats["evictions"] > 0
    eng.alloc.check_invariants()


@pytest.mark.parametrize("impl", ["xla", "pallas_interpret"])
@pytest.mark.parametrize("chunk", [16, 64, 0])
def test_chunked_prefill_matches_one_shot_logits(impl, chunk):
    """Model-level: running prefill in chunks of 16 / 64 / max over the
    paged cache reproduces the one-shot prefill logits."""
    model, params = _build(impl)
    rng = np.random.default_rng(14)
    L, page, max_len = 37, 8, 64
    prompt = rng.integers(0, 64, size=L).astype(np.int32)
    lg_ref, _ = model.prefill(
        params, {"tokens": jnp.asarray(prompt[None], jnp.int32)}, max_len
    )
    al = PageAllocator(1 + 2 * (max_len // page), page, 2, max_len)
    cache = model.init_cache(2, max_len, layout="paged", page_size=page,
                             num_pages=al.num_pages)
    al.alloc(0, L + 4)
    layers = cache["layers"]
    start, lg = 0, None
    step = chunk or L
    while start < L:
        c = min(step, L - start)
        toks = np.zeros((1, step), np.int32)
        toks[0, :c] = prompt[start : start + c]
        lg, layers = model.prefill_chunk(
            params, layers, jnp.asarray(toks), jnp.asarray(al.table[0:1]),
            jnp.int32(start), jnp.int32(c),
        )
        start += c
    np.testing.assert_allclose(
        np.asarray(lg)[0, -1], np.asarray(lg_ref)[0, -1], atol=2e-4, rtol=2e-4
    )


def test_incremental_prefill_rejected_off_paged():
    model, params = _build()
    with pytest.raises(ValueError):
        Engine(model, params, slots=1, max_len=32, cache_layout="dense",
               prefix_cache=True)
    with pytest.raises(ValueError):
        Engine(model, params, slots=1, max_len=32, cache_layout="dense",
               prefill_chunk=8)


def test_block_hashes_are_chained():
    """Identical block content at different depths must hash differently
    (the index key covers the whole prefix, not just the block)."""
    a = np.asarray([1, 2, 3, 4, 1, 2, 3, 4], np.int32)
    h = block_hashes(a, 4)
    assert len(h) == 2 and h[0] != h[1]
    b = np.asarray([9, 9, 9, 9, 1, 2, 3, 4], np.int32)
    hb = block_hashes(b, 4)
    assert hb[1] != h[1]  # same block, different prefix
    assert block_hashes(a[:7], 4) == h[:1]  # partial tail never hashed
