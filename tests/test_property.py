"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dep: skip cleanly where absent
from hypothesis import given, settings, strategies as st

from repro.core.config import ModelConfig, TrainConfig
from repro.kernels import ops, ref
from repro.models.layers import rope
from repro.optim import adamw
from repro.optim.schedule import lr_at

# "ci" is registered in conftest.py (derandomized, no deadline) so the
# --hypothesis-profile=ci CLI flag resolves before module import; loading
# it here keeps plain local `pytest` runs on the same deterministic seed
settings.load_profile("ci")

dims = st.sampled_from([16, 32, 64])


@given(B=st.integers(1, 3), S=dims, seed=st.integers(0, 2**16))
def test_rope_preserves_norm(B, S, seed):
    """Rotary embedding is a rotation: per-pair norms are preserved."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (B, S, 2, 32))
    y = rope(x, jnp.arange(S), 10000.0)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1),
        rtol=1e-5, atol=1e-5,
    )


@given(S=dims, seed=st.integers(0, 2**16))
def test_causality(S, seed):
    """Changing a future token never changes past attention outputs."""
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (1, S, 2, 16))
    k = jax.random.normal(ks[1], (1, S, 2, 16))
    v = jax.random.normal(ks[2], (1, S, 2, 16))
    out1 = ops.attention(q, k, v, causal=True, impl="xla")
    t = S // 2
    k2 = k.at[:, t:].add(jax.random.normal(ks[3], (1, S - t, 2, 16)))
    v2 = v.at[:, t:].add(1.0)
    out2 = ops.attention(q, k2, v2, causal=True, impl="xla")
    np.testing.assert_allclose(
        np.asarray(out1[:, :t]), np.asarray(out2[:, :t]), atol=1e-5, rtol=1e-5
    )


@given(S=dims, window=st.sampled_from([4, 8, 16]), seed=st.integers(0, 2**16))
def test_sliding_window_locality(S, window, seed):
    """Tokens beyond the window cannot influence the output."""
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (1, S, 1, 8))
    k = jax.random.normal(ks[1], (1, S, 1, 8))
    v = jax.random.normal(ks[2], (1, S, 1, 8))
    out1 = ref.attention_ref(q, k, v, causal=True, window=window)
    # perturb everything older than (S-1) - window + 1
    cut = max(S - 1 - window + 1, 0)
    if cut == 0:
        return
    k2 = k.at[:, :cut].set(jax.random.normal(ks[3], (1, cut, 1, 8)))
    out2 = ref.attention_ref(q, k2, v, causal=True, window=window)
    np.testing.assert_allclose(
        np.asarray(out1[:, -1]), np.asarray(out2[:, -1]), atol=1e-5, rtol=1e-5
    )


@given(
    T=st.sampled_from([8, 32]), V=st.sampled_from([64, 300]),
    seed=st.integers(0, 2**16),
)
def test_cross_entropy_nonnegative_and_shift_invariant(T, V, seed):
    key = jax.random.PRNGKey(seed)
    D = 16
    h = jax.random.normal(key, (T, D))
    W = jax.random.normal(jax.random.fold_in(key, 1), (D, V)) * 0.1
    tgt = jax.random.randint(jax.random.fold_in(key, 2), (T,), 0, V)
    loss, lse = ops.cross_entropy(h, W, tgt, impl="xla")
    assert (np.asarray(loss) >= -1e-5).all()
    # adding a constant column shift b to all logits leaves loss unchanged:
    # implemented by shifting W with a rank-1 update along a constant direction
    # (softmax shift invariance holds per-row only for constant shifts, so we
    # verify via explicit logits here)
    logits = np.asarray(h @ W)
    loss2 = np.asarray(
        jax.nn.logsumexp(jnp.asarray(logits + 3.7), -1)
        - jnp.take_along_axis(jnp.asarray(logits + 3.7), tgt[:, None], 1)[:, 0]
    )
    np.testing.assert_allclose(np.asarray(loss), loss2, atol=2e-4, rtol=1e-4)


@given(seed=st.integers(0, 2**16), steps=st.integers(1, 5))
def test_adamw_descends_quadratic(seed, steps):
    """AdamW must reduce a convex quadratic within a few steps."""
    key = jax.random.PRNGKey(seed)
    x0 = {"w": jax.random.normal(key, (8,)) * 3}
    tc = TrainConfig(learning_rate=0.1, weight_decay=0.0)
    state = adamw.init_state(x0)

    def f(p):
        return jnp.sum(p["w"] ** 2)

    params = x0
    for _ in range(steps * 10):
        g = jax.grad(f)(params)
        params, state = adamw.apply_updates(params, g, state, jnp.float32(0.1), tc)
    assert float(f(params)) < float(f(x0))


@given(step=st.integers(0, 2000))
def test_wsd_schedule_bounds(step):
    tc = TrainConfig(learning_rate=1e-3, min_lr=1e-5, warmup_steps=100,
                     decay_steps=200, total_steps=1000, schedule="wsd")
    lr = float(lr_at(tc, step))
    assert 0.0 <= lr <= tc.learning_rate * (1 + 1e-6)  # fp32 rounding headroom


@given(
    B=st.integers(1, 2), S=st.sampled_from([16, 48]),
    gqa=st.sampled_from([(4, 1), (4, 2), (4, 4)]), seed=st.integers(0, 2**16),
)
def test_gqa_equals_repeated_mha(B, S, gqa, seed):
    """GQA == MHA with kv heads explicitly repeated."""
    H, Hkv = gqa
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, 16))
    k = jax.random.normal(ks[1], (B, S, Hkv, 16))
    v = jax.random.normal(ks[2], (B, S, Hkv, 16))
    out = ops.attention(q, k, v, impl="xla")
    krep = jnp.repeat(k, H // Hkv, axis=2)
    vrep = jnp.repeat(v, H // Hkv, axis=2)
    want = ops.attention(q, krep, vrep, impl="xla")
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-5, rtol=1e-5)


@given(seed=st.integers(0, 2**16))
def test_ssd_state_linearity_in_x(seed):
    """The SSD output is linear in x for fixed (dt, A, B, C) with D=0."""
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 6)
    B_, S, H, P, G, N = 1, 16, 2, 4, 1, 4
    x = jax.random.normal(ks[0], (B_, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B_, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (B_, S, G, N))
    Cm = jax.random.normal(ks[4], (B_, S, G, N))
    Dv = jnp.zeros((H,))
    y1, _ = ops.ssd(x, dt, A, Bm, Cm, Dv, chunk=8, impl="xla")
    y2, _ = ops.ssd(2.0 * x, dt, A, Bm, Cm, Dv, chunk=8, impl="xla")
    np.testing.assert_allclose(np.asarray(y2), 2 * np.asarray(y1), atol=1e-4, rtol=1e-4)
