"""Generation API v2: fused sampler parity + LLM facade behavior.

Kernel level (``ops.sample_tokens``): greedy degrades to exact argmax,
``xla`` / ``pallas_interpret`` / ``naive`` agree token-for-token (the
noise stream is a pure counter hash, not backend PRNG state), the
filters bound the support, and fixed-seed draws are reproducible in any
batch composition.

Facade level (``serving/api.py``): greedy decode through ``LLM`` is
token-identical to isolated argmax decoding (the pre-v2 engine
behavior) across dense / paged / prefix-cached layouts and across
kernel impls, and a fixed-seed sampled request reproduces its tokens
regardless of which requests share the batch.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.serving.api import LLM
from repro.serving.sampling import SamplingParams

IMPLS = ("xla", "pallas_interpret", "naive")


def _params(B, temp=1.0, top_k=0, top_p=1.0, seed0=0, step0=0):
    return (
        jnp.full((B,), temp, jnp.float32),
        jnp.full((B,), top_k, jnp.int32),
        jnp.full((B,), top_p, jnp.float32),
        jnp.arange(B, dtype=jnp.uint32) + jnp.uint32(seed0),
        jnp.full((B,), step0, jnp.uint32),
    )


def _logits(B=4, V=160, seed=0, scale=3.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(B, V)) * scale, jnp.float32)


# --------------------------------------------------------------- kernel
@pytest.mark.parametrize("impl", IMPLS)
def test_greedy_equals_argmax(impl):
    x = _logits()
    tok, logp = ops.sample_tokens(x, *_params(4, temp=0.0), impl=impl)
    np.testing.assert_array_equal(np.asarray(tok), np.asarray(jnp.argmax(x, -1)))
    want = np.asarray(jax.nn.log_softmax(x, -1))[np.arange(4), np.asarray(tok)]
    np.testing.assert_allclose(np.asarray(logp), want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("impl", IMPLS)
def test_greedy_ignores_filters_and_seed(impl):
    """temperature=0 is argmax no matter what the other knobs say."""
    x = _logits(seed=1)
    tok, _ = ops.sample_tokens(
        x, *_params(4, temp=0.0, top_k=3, top_p=0.5, seed0=99, step0=7),
        impl=impl,
    )
    np.testing.assert_array_equal(np.asarray(tok), np.asarray(jnp.argmax(x, -1)))


def test_impl_parity_sampled():
    """Heterogeneous per-row params: all three impls pick the same tokens
    (shared integer noise stream + matching kept sets)."""
    x = _logits(B=6, V=200, seed=2)
    temp = jnp.asarray([0.0, 1.0, 0.7, 1.5, 2.0, 0.3], jnp.float32)
    top_k = jnp.asarray([0, 5, 0, 3, 17, 0], jnp.int32)
    top_p = jnp.asarray([1.0, 1.0, 0.9, 0.8, 0.5, 0.99], jnp.float32)
    seed = jnp.asarray([1, 2, 3, 4, 5, 6], jnp.uint32)
    step = jnp.asarray([0, 1, 2, 3, 4, 5], jnp.uint32)
    res = {
        impl: ops.sample_tokens(x, temp, top_k, top_p, seed, step, impl=impl)
        for impl in IMPLS
    }
    for impl in IMPLS[1:]:
        np.testing.assert_array_equal(
            np.asarray(res["xla"][0]), np.asarray(res[impl][0]), err_msg=impl
        )
        np.testing.assert_allclose(
            np.asarray(res["xla"][1]), np.asarray(res[impl][1]),
            rtol=1e-5, atol=1e-5, err_msg=impl,
        )


@pytest.mark.parametrize("impl", IMPLS)
def test_top_k_one_is_argmax(impl):
    x = _logits(seed=3)
    tok, _ = ops.sample_tokens(x, *_params(4, temp=1.3, top_k=1), impl=impl)
    np.testing.assert_array_equal(np.asarray(tok), np.asarray(jnp.argmax(x, -1)))


@pytest.mark.parametrize("impl", ("xla", "pallas_interpret"))
def test_top_k_bounds_support(impl):
    """50 fixed seeds at high temperature: every draw lands in the top-k."""
    x = _logits(B=1, V=120, seed=4)
    top5 = set(np.argsort(-np.asarray(x[0]))[:5].tolist())
    seen = set()
    for s in range(50):
        tok, _ = ops.sample_tokens(
            x, *_params(1, temp=2.0, top_k=5, seed0=s), impl=impl
        )
        seen.add(int(tok[0]))
    assert seen <= top5
    assert len(seen) > 1, "high-temperature top-k should hit several tokens"


@pytest.mark.parametrize("impl", ("xla", "pallas_interpret"))
def test_top_p_bounds_support(impl):
    """Draws stay inside the minimal nucleus (crossing token included)."""
    x = _logits(B=1, V=120, seed=5)
    z = np.asarray(x[0], np.float64)
    p = np.exp(z - z.max()) / np.exp(z - z.max()).sum()
    order = np.argsort(-p)
    cum = np.cumsum(p[order])
    n = int(np.searchsorted(cum, 0.7) + 1)       # minimal set reaching 0.7
    nucleus = set(order[:n].tolist())
    for s in range(50):
        tok, _ = ops.sample_tokens(
            x, *_params(1, temp=1.0, top_p=0.7, seed0=s), impl=impl
        )
        assert int(tok[0]) in nucleus


@pytest.mark.parametrize("impl", IMPLS)
def test_masked_vocab_never_sampled(impl):
    """Megatron vocab padding (-1e30 columns, model.logits) is invisible
    to the filter, the mass, and the draw."""
    vocab, pad = 100, 28
    x = np.array(_logits(B=2, V=vocab + pad, seed=6))
    x[:, vocab:] = -1e30
    x = jnp.asarray(x)
    for s in range(25):
        tok, logp = ops.sample_tokens(
            x, *_params(2, temp=2.0, seed0=s), impl=impl
        )
        assert int(jnp.max(tok)) < vocab
        assert np.all(np.isfinite(np.asarray(logp)))


def test_logp_matches_renormalized_kept_set():
    """Reported logp is under the filtered, temperature-scaled,
    renormalized distribution."""
    x = _logits(B=1, V=80, seed=7)
    t, k = 0.8, 7
    tok, logp = ops.sample_tokens(x, *_params(1, temp=t, top_k=k), impl="xla")
    z = np.asarray(x[0], np.float64) / t
    kept = np.argsort(-z)[:k]
    lse = np.log(np.exp(z[kept] - z.max()).sum()) + z.max()
    want = z[int(tok[0])] - lse
    assert int(tok[0]) in kept
    np.testing.assert_allclose(float(logp[0]), want, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("impl", ("xla", "pallas_interpret"))
def test_reproducible_across_batch_composition(impl):
    """The noise stream is keyed by (seed, step, vocab id) only — the
    same row sampled alone, in a different slot, or beside different
    neighbors draws the same token."""
    x = _logits(B=5, V=150, seed=8)
    temp, top_k, top_p, seed, step = _params(5, temp=1.1, top_k=12, seed0=3,
                                             step0=2)
    tok_full, logp_full = ops.sample_tokens(
        x, temp, top_k, top_p, seed, step, impl=impl
    )
    for r in range(5):
        tok_one, logp_one = ops.sample_tokens(
            x[r:r + 1], temp[r:r + 1], top_k[r:r + 1], top_p[r:r + 1],
            seed[r:r + 1], step[r:r + 1], impl=impl,
        )
        assert int(tok_one[0]) == int(tok_full[r])
        np.testing.assert_allclose(float(logp_one[0]), float(logp_full[r]),
                                   rtol=1e-6)
    # reversed batch order: same per-row draws
    rev = slice(None, None, -1)
    tok_rev, _ = ops.sample_tokens(
        x[rev], temp[rev], top_k[rev], top_p[rev], seed[rev], step[rev],
        impl=impl,
    )
    np.testing.assert_array_equal(np.asarray(tok_rev)[::-1], np.asarray(tok_full))


def test_seed_and_step_decorrelate():
    """Different seeds (and different steps under one seed) explore the
    distribution instead of repeating one draw."""
    x = _logits(B=1, V=100, seed=9, scale=1.0)   # flat-ish: high entropy
    by_seed = {
        int(ops.sample_tokens(x, *_params(1, temp=1.5, seed0=s), impl="xla")[0][0])
        for s in range(20)
    }
    by_step = {
        int(ops.sample_tokens(x, *_params(1, temp=1.5, seed0=0, step0=t),
                              impl="xla")[0][0])
        for t in range(20)
    }
    assert len(by_seed) > 5 and len(by_step) > 5


def test_sampling_params_validation():
    with pytest.raises(ValueError, match="temperature"):
        SamplingParams(temperature=-0.1)
    with pytest.raises(ValueError, match="top_k"):
        SamplingParams(top_k=-1)
    with pytest.raises(ValueError, match="top_p"):
        SamplingParams(top_p=0.0)
    with pytest.raises(ValueError, match="max_new"):
        SamplingParams(max_new=0)
    with pytest.raises(ValueError, match="stop_sequences"):
        SamplingParams(stop_sequences=((),))
    sp = SamplingParams(stop_token_ids=[3, 4], stop_sequences=[[1, 2]])
    assert sp.stop_token_ids == (3, 4) and sp.stop_sequences == ((1, 2),)
    assert sp.greedy and not SamplingParams(temperature=0.5).greedy


# --------------------------------------------------------------- facade
# one smoke builder + one parity oracle for both serving suites
from test_serving_engine import build as _engine_build
from test_serving_engine import isolated_greedy as _isolated_greedy


def _build(kernel_impl="auto"):
    return _engine_build(kernel_impl=kernel_impl)


_LAYOUTS = (
    dict(cache_layout="dense"),
    dict(cache_layout="paged", page_size=8),
    dict(cache_layout="paged", page_size=8, prefix_cache=True, prefill_chunk=8),
)


@pytest.mark.parametrize("kw", _LAYOUTS,
                         ids=["dense", "paged", "paged+prefix+chunk"])
def test_llm_greedy_token_identical_to_seed_behavior(kw):
    """Acceptance: greedy decode through the v2 API reproduces isolated
    argmax decoding (the pre-redesign engine output) on every layout."""
    model, params = _build()
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, 64, size=L).astype(np.int32) for L in (9, 17, 7)]
    llm = LLM(model, params, slots=2, max_len=64, **kw)
    outs = llm.generate(prompts, SamplingParams(max_new=5))
    for c in outs:
        assert c.tokens == _isolated_greedy(model, params, prompts[c.index], 5)
        assert c.finish_reason == "length"


def test_llm_greedy_parity_across_kernel_impls():
    """xla and pallas_interpret engines emit identical greedy tokens."""
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, 64, size=L).astype(np.int32) for L in (6, 11)]
    outs = {}
    for impl in ("xla", "pallas_interpret"):
        model, params = _build(kernel_impl=impl)
        llm = LLM(model, params, slots=2, max_len=64)
        outs[impl] = [c.tokens for c in llm.generate(prompts,
                                                     SamplingParams(max_new=4))]
    assert outs["xla"] == outs["pallas_interpret"]


def test_llm_fixed_seed_reproducible_across_batch_mix():
    """The same sampled request (fixed seed) emits the same tokens when
    served alone, alongside greedy traffic, or alongside other sampled
    requests — per-slot PRNG state, not batch-level."""
    model, params = _build()
    rng = np.random.default_rng(2)
    target = rng.integers(0, 64, size=10).astype(np.int32)
    others = [rng.integers(0, 64, size=L).astype(np.int32) for L in (5, 13, 8)]
    sp = SamplingParams(temperature=1.0, top_k=20, seed=42, max_new=6)
    llm = LLM(model, params, slots=2, max_len=64)

    alone = llm.generate([target], [sp])[0].tokens
    with_greedy = llm.generate(
        [others[0], target, others[1]],
        [SamplingParams(max_new=6), sp, SamplingParams(max_new=6)],
    )[1].tokens
    with_sampled = llm.generate(
        [target] + others,
        [sp] + [SamplingParams(temperature=1.3, top_p=0.9, seed=i, max_new=6)
                for i in range(3)],
    )[0].tokens
    assert alone == with_greedy == with_sampled
    # the sampler is live: across several seeds at this temperature, at
    # least one draw must diverge from the greedy sequence (a sampler
    # that silently degraded to argmax would fail here)
    greedy = llm.generate([target], [SamplingParams(max_new=6)])[0].tokens
    sampled = [
        llm.generate([target], [dataclasses.replace(sp, seed=s)])[0].tokens
        for s in range(40, 46)
    ]
    assert any(t != greedy for t in sampled), "sampler degraded to argmax"


def test_llm_stream_matches_generate():
    model, params = _build()
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, 64, size=L).astype(np.int32) for L in (7, 12, 5)]
    sp = SamplingParams(temperature=0.9, top_k=16, seed=11, max_new=5,
                        logprobs=True)
    llm = LLM(model, params, slots=2, max_len=64)
    want = llm.generate(prompts, sp)
    got_toks = {i: [] for i in range(len(prompts))}
    got_lps = {i: [] for i in range(len(prompts))}
    finishes = {}
    for ch in llm.stream(prompts, sp):
        got_toks[ch.index].append(ch.token)
        got_lps[ch.index].append(ch.logprob)
        if ch.done:
            finishes[ch.index] = ch.finish_reason
    for c in want:
        assert got_toks[c.index] == c.tokens
        np.testing.assert_allclose(got_lps[c.index], c.logprobs, rtol=1e-6)
        assert finishes[c.index] == c.finish_reason


@pytest.mark.parametrize("kw", _LAYOUTS,
                         ids=["dense", "paged", "paged+prefix+chunk"])
def test_llm_stream_early_break_cancels_in_flight(kw):
    """Abandoning a stream mid-way must not orphan requests: their slots
    (and pages — including a mid-chunked-prefill request's partial
    pages) are released, and a subsequent generate() on the same LLM
    serves fresh prompts immediately and correctly."""
    model, params = _build()
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, 64, size=L).astype(np.int32) for L in (8, 26)]
    llm = LLM(model, params, slots=2, max_len=64, **kw)
    taken = 0
    for _ in llm.stream(prompts, SamplingParams(max_new=30)):
        taken += 1
        if taken == 3:
            break
    eng = llm.engine
    assert all(r is None for r in eng.slot_req), "cancelled slots not freed"
    assert not eng.queue
    if eng.alloc is not None:
        eng.alloc.check_invariants()
    # the engine serves the next batch normally
    outs = llm.generate(prompts, SamplingParams(max_new=4))
    for c in outs:
        assert c.tokens == _isolated_greedy(model, params, prompts[c.index], 4)


def test_llm_submit_failure_leaves_no_orphans():
    """A validation error on one prompt of a batch must withdraw the
    already-queued prompts — nothing may decode inside the next call."""
    model, params = _build()
    rng = np.random.default_rng(6)
    good = rng.integers(0, 64, size=6).astype(np.int32)
    too_long = rng.integers(0, 64, size=200).astype(np.int32)  # > max_len
    llm = LLM(model, params, slots=2, max_len=64)
    with pytest.raises(ValueError, match="overflows max_len"):
        llm.generate([good, too_long], SamplingParams(max_new=4))
    assert not llm.engine.queue
    # stream submits eagerly: the error fires at the call, not at the
    # first next(), and likewise leaves nothing queued
    with pytest.raises(ValueError, match="overflows max_len"):
        llm.stream([good, too_long], SamplingParams(max_new=4))
    assert not llm.engine.queue
    outs = llm.generate([good], SamplingParams(max_new=4))
    assert len(outs) == 1
    assert outs[0].tokens == _isolated_greedy(model, params, good, 4)


def test_llm_from_config_maps_sampling_knobs():
    from repro.core.config import ServeConfig

    model, params = _build()
    sc = ServeConfig(max_seq_len=64, batch_size=2, temperature=0.7,
                     top_k=9, top_p=0.85, seed=5)
    llm = LLM.from_config(model, params, sc)
    dp = llm.default_params
    assert (dp.temperature, dp.top_k, dp.top_p, dp.seed) == (0.7, 9, 0.85, 5)
    rng = np.random.default_rng(4)
    prompts = [rng.integers(0, 64, size=6).astype(np.int32)]
    # default params flow into requests submitted without explicit params
    a = llm.generate(prompts)[0].tokens
    b = llm.generate(prompts, dataclasses.replace(dp))[0].tokens
    assert a == b
