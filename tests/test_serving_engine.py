"""Continuous-batching engine: outputs equal isolated (batch-1) greedy
decoding for every request, regardless of admission interleaving."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.config import ModelConfig
from repro.models.model import build_model
from repro.serving.engine import Engine, Request


def build(family="dense", **over):
    kw = dict(
        name="t", family=family, num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=2, d_ff=128, vocab_size=64, dtype="float32",
    )
    if family == "ssm":
        kw.update(d_ff=0, num_kv_heads=4, ssm_state=16, ssm_headdim=32, ssm_chunk=8)
    kw.update(over)
    cfg = ModelConfig(**kw)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def isolated_greedy(model, params, prompt, n, max_len=64):
    logits, cache = model.prefill(
        params, {"tokens": jnp.asarray(prompt[None], jnp.int32)}, max_len
    )
    out = [int(jnp.argmax(logits[0, -1]))]
    for _ in range(n - 1):
        lg, cache = model.decode_step(
            params, cache, jnp.asarray([[out[-1]]], jnp.int32)
        )
        out.append(int(jnp.argmax(lg[0, -1])))
    return out


@pytest.mark.parametrize("layout", ["dense", "paged"])
def test_engine_matches_isolated_decoding(layout):
    model, params = build()
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, 64, size=L).astype(np.int32) for L in (5, 9, 7, 12, 6)]
    n_new = 6
    eng = Engine(model, params, slots=2, max_len=64, cache_layout=layout,
                 page_size=8)
    for i, p in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=p, max_new=n_new))
    done = eng.run()
    assert len(done) == len(prompts)
    for req in done:
        want = isolated_greedy(model, params, prompts[req.uid], n_new)
        assert req.output == want, (req.uid, req.output, want)


def test_engine_ssm_family():
    model, params = build("ssm")
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, 64, size=L).astype(np.int32) for L in (4, 8, 6)]
    eng = Engine(model, params, slots=2, max_len=64)
    for i, p in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=p, max_new=4))
    done = eng.run()
    assert len(done) == 3
    for req in done:
        want = isolated_greedy(model, params, prompts[req.uid], 4)
        assert req.output == want, (req.uid, req.output, want)


@pytest.mark.parametrize("layout", ["dense", "paged"])
def test_engine_rejects_empty_prompt_and_zero_budget(layout):
    """Regression (_bucket edge cases): an empty prompt used to be padded
    to an 8-token bucket and the last-logits slice clamped to a wrong row
    (under-allocation of valid tokens); max_new=0 used to emit one token
    anyway.  Both are now rejected at submit."""
    model, params = build()
    eng = Engine(model, params, slots=1, max_len=64, cache_layout=layout,
                 page_size=8)
    with pytest.raises(ValueError, match="empty prompt"):
        eng.submit(Request(uid=0, prompt=np.zeros((0,), np.int32), max_new=4))
    with pytest.raises(ValueError, match="max_new"):
        eng.submit(Request(uid=1, prompt=np.ones(4, np.int32), max_new=0))
    assert not eng.queue


@pytest.mark.parametrize("layout", ["dense", "paged"])
def test_engine_bucket_exact_max_len(layout):
    """Regression (_bucket edge cases): a prompt at the admission boundary
    (prompt + max_new == max_len, with max_len not a power of two) must
    bucket to a size that neither truncates the prompt nor overflows the
    cache, and produce the same tokens as unbucketed serving."""
    model, params = build()
    max_len = 48                                  # not a power of two
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, 64, size=max_len - 4).astype(np.int32)
    outs = {}
    for bucket in (True, False):
        eng = Engine(model, params, slots=1, max_len=max_len,
                     cache_layout=layout, page_size=8, bucket_prompts=bucket)
        if bucket:
            # the pow-2 bucket (64) must clamp to max_len, never below
            # the prompt length
            assert eng._bucket(len(prompt)) == max_len
            assert eng._bucket(max_len) == max_len
            assert eng._bucket(3) == 8
        eng.submit(Request(uid=0, prompt=prompt, max_new=4))
        done = eng.run()
        assert len(done) == 1 and len(done[0].output) == 4
        outs[bucket] = done[0].output
    assert outs[True] == outs[False]
    want = isolated_greedy(model, params, prompt, 4, max_len=max_len)
    assert outs[True] == want


def test_engine_eos_early_stop():
    model, params = build()
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, 64, size=6).astype(np.int32)
    # find the first greedy token, then use it as eos: request stops at len 1
    first = isolated_greedy(model, params, prompt, 1)[0]
    eng = Engine(model, params, slots=1, max_len=64)
    eng.submit(Request(uid=0, prompt=prompt, max_new=8, eos_id=first))
    done = eng.run()
    assert done[0].output == [first]
    assert done[0].finish_reason == "stop"


# ------------------------------------------------------- stop machinery
def test_stop_token_ids_via_params():
    """SamplingParams.stop_token_ids behaves like eos: the stop token is
    emitted, then the request finishes with reason "stop"."""
    from repro.serving.sampling import SamplingParams

    model, params = build()
    rng = np.random.default_rng(5)
    prompt = rng.integers(0, 64, size=7).astype(np.int32)
    ref = isolated_greedy(model, params, prompt, 6)
    stop_tok = ref[3]
    eng = Engine(model, params, slots=1, max_len=64)
    eng.submit(Request(uid=0, prompt=prompt,
                       params=SamplingParams(max_new=10,
                                             stop_token_ids=(stop_tok,))))
    done = eng.run()
    cut = ref.index(stop_tok) + 1
    assert done[0].output == ref[:cut]
    assert done[0].finish_reason == "stop"


@pytest.mark.parametrize("layout", ["dense", "paged"])
def test_stop_sequence_multi_token(layout):
    """A multi-token stop sequence fires only when the full suffix
    matches; matched tokens stay in the output."""
    from repro.serving.sampling import SamplingParams

    model, params = build()
    rng = np.random.default_rng(6)
    prompt = rng.integers(0, 64, size=9).astype(np.int32)
    ref = isolated_greedy(model, params, prompt, 8)
    seq = tuple(ref[2:4])
    # expected stop point: the FIRST prefix of ref whose suffix is the
    # full sequence (an untrained model may repeat tokens, so the pair
    # can complete earlier than index 3 — the stop rule, not a hardcoded
    # position, defines the truth)
    want = ref
    for n in range(len(seq), len(ref) + 1):
        if tuple(ref[n - len(seq):n]) == seq:
            want = ref[:n]
            break
    eng = Engine(model, params, slots=2, max_len=64, cache_layout=layout,
                 page_size=8)
    eng.submit(Request(uid=0, prompt=prompt,
                       params=SamplingParams(max_new=8,
                                             stop_sequences=(seq,))))
    # a second request must be unaffected by its neighbor stopping
    other = rng.integers(0, 64, size=5).astype(np.int32)
    eng.submit(Request(uid=1, prompt=other,
                       params=SamplingParams(max_new=6)))
    done = {r.uid: r for r in eng.run()}
    # stops exactly at the first FULL suffix match (a partial, single-
    # token overlap must not fire), matched tokens kept in the output
    assert done[0].output == want
    assert done[0].finish_reason == "stop"
    assert done[1].output == isolated_greedy(model, params, other, 6)


def test_stop_on_first_token_mid_chunked_prefill():
    """A request whose FIRST generated token (emitted as its chunked
    prefill completes, mid-stream between other requests' decode steps)
    is a stop token finishes without ever entering lockstep decode."""
    from repro.serving.sampling import SamplingParams

    model, params = build()
    rng = np.random.default_rng(7)
    prompt = rng.integers(0, 64, size=21).astype(np.int32)
    first = isolated_greedy(model, params, prompt, 1)[0]
    eng = Engine(model, params, slots=2, max_len=64, cache_layout="paged",
                 page_size=8, prefill_chunk=8)
    # keep a long-running decode in flight so the chunks interleave
    other = rng.integers(0, 64, size=4).astype(np.int32)
    eng.submit(Request(uid=1, prompt=other,
                       params=SamplingParams(max_new=12)))
    eng.submit(Request(uid=0, prompt=prompt,
                       params=SamplingParams(max_new=8,
                                             stop_token_ids=(first,))))
    done = {r.uid: r for r in eng.run()}
    assert done[0].output == [first]
    assert done[0].finish_reason == "stop"
    assert done[1].output == isolated_greedy(model, params, other, 12)


def test_params_without_max_new_inherits_request_budget():
    """Attaching sampling intent to a legacy request must not silently
    replace its explicit max_new (params.max_new=None inherits it)."""
    from repro.serving.sampling import SamplingParams

    model, params = build()
    rng = np.random.default_rng(10)
    prompt = rng.integers(0, 64, size=6).astype(np.int32)
    eng = Engine(model, params, slots=1, max_len=64)
    eng.submit(Request(uid=0, prompt=prompt, max_new=4,
                       params=SamplingParams(temperature=0.8, seed=1)))
    done = eng.run()
    assert len(done[0].output) == 4
    # an explicit params.max_new still wins over the legacy field
    eng.submit(Request(uid=1, prompt=prompt, max_new=4,
                       params=SamplingParams(max_new=7)))
    done = eng.run()
    assert len(done[-1].output) == 7


def test_eos_minus_one_never_stops():
    """eos_id=-1 (and no stop params) keeps the legacy never-stop
    semantics: the request always runs out its max_new budget."""
    model, params = build()
    rng = np.random.default_rng(8)
    prompt = rng.integers(0, 64, size=6).astype(np.int32)
    eng = Engine(model, params, slots=1, max_len=64)
    eng.submit(Request(uid=0, prompt=prompt, max_new=10, eos_id=-1))
    done = eng.run()
    assert len(done[0].output) == 10
    assert done[0].finish_reason == "length"


def test_cancel_queued_request_by_identity():
    """Engine.cancel must match by object identity: dataclass equality
    tuple-compares the numpy prompt field and raises on same-shape
    prompts (regression — uid reuse is common for raw-Engine callers)."""
    model, params = build()
    p = np.ones(6, np.int32)
    eng = Engine(model, params, slots=1, max_len=64)
    r1 = Request(uid=0, prompt=p.copy(), max_new=4)
    r2 = Request(uid=0, prompt=p.copy(), max_new=4)
    eng.submit(r1)
    eng.submit(r2)
    eng.cancel(r2)
    assert len(eng.queue) == 1 and eng.queue[0] is r1
    assert r2.finish_reason == "cancelled" and r2.output is None
    done = eng.run()
    assert any(r is r2 for r in done)
    r1_done = next(r for r in done if r is r1)
    assert len(r1_done.output) == 4 and r1_done.finish_reason == "length"


# ------------------------------------------- on-device selection regression
@pytest.mark.parametrize("layout", ["dense", "paged"])
def test_steady_state_step_single_bulk_transfer(layout, monkeypatch):
    """Acceptance: the jitted decode step selects tokens on device —
    a steady-state engine step performs exactly ONE bulk device->host
    transfer (the explicit device_get of the sampled tokens/logprobs)
    and NO implicit transfers (jax.transfer_guard("disallow") turns any
    stray int(jnp...)/np.asarray/jnp constant into an error)."""
    model, params = build()
    rng = np.random.default_rng(9)
    eng = Engine(model, params, slots=2, max_len=64, cache_layout=layout,
                 page_size=8)
    for i in range(2):   # fill every slot; queue empty => no admissions
        eng.submit(Request(uid=i, prompt=rng.integers(0, 64, size=6)
                           .astype(np.int32), max_new=40))
    eng.step()           # admissions + first decode (compiles)
    eng.step()           # warm steady state
    calls = []
    real_get = jax.device_get
    monkeypatch.setattr(jax, "device_get",
                        lambda x: calls.append(1) or real_get(x))
    with jax.transfer_guard("disallow"):
        n = eng.step()
    assert n == 2
    assert len(calls) == 1, f"expected 1 bulk transfer, saw {len(calls)}"
