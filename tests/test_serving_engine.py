"""Continuous-batching engine: outputs equal isolated (batch-1) greedy
decoding for every request, regardless of admission interleaving."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.config import ModelConfig
from repro.models.model import build_model
from repro.serving.engine import Engine, Request


def build(family="dense"):
    kw = dict(
        name="t", family=family, num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=2, d_ff=128, vocab_size=64, dtype="float32",
    )
    if family == "ssm":
        kw.update(d_ff=0, num_kv_heads=4, ssm_state=16, ssm_headdim=32, ssm_chunk=8)
    cfg = ModelConfig(**kw)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def isolated_greedy(model, params, prompt, n, max_len=64):
    logits, cache = model.prefill(
        params, {"tokens": jnp.asarray(prompt[None], jnp.int32)}, max_len
    )
    out = [int(jnp.argmax(logits[0, -1]))]
    for _ in range(n - 1):
        lg, cache = model.decode_step(
            params, cache, jnp.asarray([[out[-1]]], jnp.int32)
        )
        out.append(int(jnp.argmax(lg[0, -1])))
    return out


@pytest.mark.parametrize("layout", ["dense", "paged"])
def test_engine_matches_isolated_decoding(layout):
    model, params = build()
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, 64, size=L).astype(np.int32) for L in (5, 9, 7, 12, 6)]
    n_new = 6
    eng = Engine(model, params, slots=2, max_len=64, cache_layout=layout,
                 page_size=8)
    for i, p in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=p, max_new=n_new))
    done = eng.run()
    assert len(done) == len(prompts)
    for req in done:
        want = isolated_greedy(model, params, prompts[req.uid], n_new)
        assert req.output == want, (req.uid, req.output, want)


def test_engine_ssm_family():
    model, params = build("ssm")
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, 64, size=L).astype(np.int32) for L in (4, 8, 6)]
    eng = Engine(model, params, slots=2, max_len=64)
    for i, p in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=p, max_new=4))
    done = eng.run()
    assert len(done) == 3
    for req in done:
        want = isolated_greedy(model, params, prompts[req.uid], 4)
        assert req.output == want, (req.uid, req.output, want)


@pytest.mark.parametrize("layout", ["dense", "paged"])
def test_engine_rejects_empty_prompt_and_zero_budget(layout):
    """Regression (_bucket edge cases): an empty prompt used to be padded
    to an 8-token bucket and the last-logits slice clamped to a wrong row
    (under-allocation of valid tokens); max_new=0 used to emit one token
    anyway.  Both are now rejected at submit."""
    model, params = build()
    eng = Engine(model, params, slots=1, max_len=64, cache_layout=layout,
                 page_size=8)
    with pytest.raises(ValueError, match="empty prompt"):
        eng.submit(Request(uid=0, prompt=np.zeros((0,), np.int32), max_new=4))
    with pytest.raises(ValueError, match="max_new"):
        eng.submit(Request(uid=1, prompt=np.ones(4, np.int32), max_new=0))
    assert not eng.queue


@pytest.mark.parametrize("layout", ["dense", "paged"])
def test_engine_bucket_exact_max_len(layout):
    """Regression (_bucket edge cases): a prompt at the admission boundary
    (prompt + max_new == max_len, with max_len not a power of two) must
    bucket to a size that neither truncates the prompt nor overflows the
    cache, and produce the same tokens as unbucketed serving."""
    model, params = build()
    max_len = 48                                  # not a power of two
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, 64, size=max_len - 4).astype(np.int32)
    outs = {}
    for bucket in (True, False):
        eng = Engine(model, params, slots=1, max_len=max_len,
                     cache_layout=layout, page_size=8, bucket_prompts=bucket)
        if bucket:
            # the pow-2 bucket (64) must clamp to max_len, never below
            # the prompt length
            assert eng._bucket(len(prompt)) == max_len
            assert eng._bucket(max_len) == max_len
            assert eng._bucket(3) == 8
        eng.submit(Request(uid=0, prompt=prompt, max_new=4))
        done = eng.run()
        assert len(done) == 1 and len(done[0].output) == 4
        outs[bucket] = done[0].output
    assert outs[True] == outs[False]
    want = isolated_greedy(model, params, prompt, 4, max_len=max_len)
    assert outs[True] == want


def test_engine_eos_early_stop():
    model, params = build()
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, 64, size=6).astype(np.int32)
    # find the first greedy token, then use it as eos: request stops at len 1
    first = isolated_greedy(model, params, prompt, 1)[0]
    eng = Engine(model, params, slots=1, max_len=64)
    eng.submit(Request(uid=0, prompt=prompt, max_new=8, eos_id=first))
    done = eng.run()
    assert done[0].output == [first]
