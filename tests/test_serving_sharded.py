"""Sharded serving: tensor-parallel inference on the mesh, proven correct
by cross-mesh parity.

Every test runs in a subprocess with 8 virtual CPU devices (the XLA
device-count flag must be set before jax initializes; the main pytest
process stays at 1 device per the project rules).  Inside the subprocess a
single-device reference engine (mesh=None) and mesh engines on (1,8) and
(2,4) serve the same mixed greedy + seeded-sampled workload across all
three cache layouts (dense / paged / prefix+chunk); outputs must be
token-identical — the replicated logits row makes per-request sampling
seeds mesh-shape-independent.

The transfer-guard test re-pins the serving one-bulk-transfer-per-step
contract on the mesh: a steady-state decode step under
``jax.transfer_guard("disallow")`` performs exactly one ``jax.device_get``.
"""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_py(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, env=env,
        timeout=900,
    )
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


# 8 heads / 8 kv heads so every tested mesh's model axis divides the head
# dim — placement shardings require exact divisibility (sharding.fit_spec
# degrades uneven dims to replication, but the point here is to exercise
# the *sharded* pool).
_COMMON = textwrap.dedent("""
    import jax, numpy as np
    from repro.core.config import ModelConfig, ParallelConfig
    from repro.models.model import build_model
    from repro.serving.engine import Engine, Request
    from repro.serving.sampling import SamplingParams

    CFG = ModelConfig(name="smoke", family="dense", num_layers=2,
                      d_model=64, num_heads=8, num_kv_heads=8, d_ff=128,
                      vocab_size=64, dtype="float32")
    PARAMS = build_model(CFG).init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(7)
    PROMPTS = [rng.integers(1, CFG.vocab_size, size=n).astype(np.int32)
               for n in (5, 11, 17, 9)]

    def make_engine(mesh, **kw):
        model = build_model(CFG, ParallelConfig(), mesh)
        return Engine(model, PARAMS, slots=3, max_len=64, **kw)

    def serve(mesh, **kw):
        eng = make_engine(mesh, **kw)
        for i, p in enumerate(PROMPTS):
            sp = (None if i % 2 == 0 else
                  SamplingParams(temperature=0.8, top_k=12, seed=40 + i))
            eng.submit(Request(uid=i, prompt=p, max_new=8, params=sp))
        eng.run()
        assert len(eng.done) == len(PROMPTS)
        return {r.uid: tuple(r.output) for r in eng.done}
""")

_PARITY = _COMMON + textwrap.dedent("""
    LAYOUTS = {
        "dense": dict(cache_layout="dense"),
        "paged": dict(cache_layout="paged", page_size=8),
        "prefix+chunk": dict(cache_layout="paged", page_size=8,
                             prefix_cache=True, prefill_chunk=8),
    }
    mesh = jax.make_mesh(__MESH__, ("data", "model"))
    for name, kw in LAYOUTS.items():
        ref = serve(None, **kw)
        got = serve(mesh, **kw)
        assert got == ref, (name, ref, got)
        print("OK", name)
""")


@pytest.mark.parametrize("mesh_shape", [(1, 8), (2, 4)])
def test_mesh_parity_all_layouts(mesh_shape):
    out = run_py(_PARITY.replace("__MESH__", repr(mesh_shape)))
    assert out.count("OK") == 3, out


def test_mesh_decode_single_bulk_transfer():
    """Steady-state sharded decode keeps the one-device_get-per-step
    contract: no host->device uploads, exactly one bulk download."""
    code = _COMMON + textwrap.dedent("""
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        eng = make_engine(mesh, cache_layout="paged", page_size=8)
        for i, p in enumerate(PROMPTS[:3]):
            eng.submit(Request(uid=i, prompt=p, max_new=16))
        for _ in range(4):        # admit + settle into steady-state decode
            eng.step()
        real_get = jax.device_get
        calls = []
        jax.device_get = lambda x: (calls.append(1), real_get(x))[1]
        try:
            with jax.transfer_guard("disallow"):
                n = eng.step()
        finally:
            jax.device_get = real_get
        assert n > 0, "decode step emitted no tokens"
        assert len(calls) == 1, f"expected 1 bulk device_get, saw {len(calls)}"
        print("OK transfer", n, len(calls))
    """)
    assert "OK transfer" in run_py(code)


_CHURN = textwrap.dedent("""
    import sys
    sys.path.insert(0, __TESTS__)
    import jax, numpy as np
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec
    from test_prefix_cache import Churn, PAGE

    MESH = jax.make_mesh((1, 8), ("data", "model"))
    HKV, D = 8, 4

    class ShardedChurn(Churn):
        '''Churn's shadow content model, backed by a real device pool
        sharded over the KV-head (model) axis.  Every shadow write —
        prefill block, COW page copy — is mirrored into the sharded
        pool through the same ref==1 discipline, so any disagreement
        between the global host allocator and the per-shard device
        pools (a write into a shared page, a lost COW copy, a stale
        hash hit) shows up as a content mismatch.'''

        def __init__(self):
            super().__init__()
            self.sh = NamedSharding(MESH,
                                    PartitionSpec(None, None, "model", None))
            self.pool = jax.device_put(
                jnp.zeros((self.al.num_pages, PAGE, HKV, D), jnp.float32),
                self.sh)
            churn = self

            class Mirror(dict):
                def __setitem__(self, page, blk):
                    dict.__setitem__(self, page, blk)
                    churn._dev_write(page, blk)

            self.content = Mirror()

        def _dev_write(self, page, blk):
            if blk is None:
                return
            tok = jnp.asarray(np.asarray(blk, np.float32))
            tile = jnp.broadcast_to(tok[:, None, None], (PAGE, HKV, D))
            self.pool = jax.device_put(self.pool.at[page].set(tile), self.sh)

        def live_pages(self):
            pages = set()
            for slot in self.active:
                pages.update(int(p) for p in self.al.owned(slot))
            pages.update(int(p) for p in self.al._evictable)  # parked cached
            return pages

        def verify(self):
            assert len(self.pool.sharding.device_set) == 8, "pool unsharded"
            host = np.asarray(jax.device_get(self.pool))
            live = self.live_pages()
            for page in live:
                blk = self.content.get(page)
                if blk is None:
                    continue
                want = np.broadcast_to(
                    np.asarray(blk, np.float32)[:, None, None],
                    (PAGE, HKV, D))
                np.testing.assert_array_equal(
                    host[page], want,
                    err_msg=f"device pool disagrees on page {page}")
            # per-shard consistency: each device's head-slice of a live
            # page holds the same broadcast tokens — shards never drift
            for shard in self.pool.addressable_shards:
                data = np.asarray(shard.data)
                for page in sorted(live)[:2]:
                    blk = self.content.get(page)
                    if blk is None:
                        continue
                    want = np.broadcast_to(
                        np.asarray(blk, np.float32)[:, None, None],
                        data[page].shape)
                    np.testing.assert_array_equal(data[page], want)

        def apply(self, op):
            super().apply(op)
            self.verify()

    rng = np.random.default_rng(0)
    OPS = ((0, 8), (0, 64), (0, 12), (0, 64), (0, 64))
    for ex in range(40):
        churn = ShardedChurn()
        for _ in range(int(rng.integers(1, 31))):
            churn.apply(tuple(int(rng.integers(lo, hi + 1))
                              for lo, hi in OPS))
        churn.finish()
    print("OK churn")
""")


def test_sharded_kv_pool_churn_property():
    """Allocate/free/evict/COW churn on an 8-device mesh: the global host
    allocator and the per-shard device pools must never disagree (hash
    hits return matching pages; COW writes touch only exclusive pages)."""
    tests_dir = os.path.dirname(os.path.abspath(__file__))
    out = run_py(_CHURN.replace("__TESTS__", repr(tests_dir)))
    assert "OK churn" in out


def test_cache_shardings_shard_kv_over_model_axis():
    """The paged K/V pools actually shard over the head axis (the point of
    tensor-parallel serving): each device holds 1/model-axis of the pool,
    while block tables / pos stay replicated for host-side paging."""
    code = _COMMON + textwrap.dedent("""
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        eng = make_engine(mesh, cache_layout="paged", page_size=8)
        k_pool = eng.cache["layers"]["sub0"]["attn"]["k_pool"]
        shard_shape = k_pool.sharding.shard_shape(k_pool.shape)
        assert shard_shape[3] == k_pool.shape[3] // 4, (
            k_pool.shape, shard_shape)
        bt = eng.cache["block_table"]
        assert bt.sharding.is_fully_replicated
        assert eng.cache["pos"].sharding.is_fully_replicated
        print("OK shards", k_pool.shape, shard_shape)
    """)
    assert "OK shards" in run_py(code)
