"""End-to-end behaviour tests: training reduces loss, serving is consistent
with training-mode forward, checkpoints round-trip."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.config import ModelConfig, ParallelConfig, TrainConfig
from repro.data.dataset import build_synthetic_protein_memmap
from repro.data.pipeline import CLMBatches, MLMBatches
from repro.models.model import build_model
from repro.training.loop import run_training


def tiny_cfg(**kw):
    base = dict(
        name="tiny", family="dense", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=2, d_ff=128, vocab_size=64, dtype="float32",
    )
    base.update(kw)
    return ModelConfig(**base)


def test_training_reduces_loss(tmp_path):
    cfg = tiny_cfg()
    model = build_model(cfg)
    ds, tok = build_synthetic_protein_memmap(str(tmp_path / "prot"), n=200)
    tc = TrainConfig(
        global_batch=8, seq_len=32, total_steps=60, learning_rate=3e-3,
        warmup_steps=5, decay_steps=5, log_every=10,
    )
    _, history = run_training(model, tc, iter(CLMBatches(ds, 8, 32)), verbose=False)
    assert history[-1]["loss"] < history[0]["loss"] * 0.8, history
    assert np.isfinite(history[-1]["loss"])


def test_mlm_training_reduces_loss(tmp_path):
    cfg = tiny_cfg(objective="mlm", causal=False, vocab_size=33)
    model = build_model(cfg)
    ds, tok = build_synthetic_protein_memmap(str(tmp_path / "prot"), n=200)
    tc = TrainConfig(
        global_batch=8, seq_len=32, total_steps=60, learning_rate=3e-3,
        warmup_steps=5, decay_steps=5, log_every=10,
    )
    batches = iter(MLMBatches(ds, tok, None, 8, 32))
    _, history = run_training(model, tc, batches, verbose=False)
    assert history[-1]["loss"] < history[0]["loss"], history


def test_greedy_generation_matches_teacher_forcing():
    """Each greedy decode step must equal the training-mode forward argmax."""
    cfg = tiny_cfg()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 10), 0, 64)
    logits, cache = model.prefill(params, {"tokens": toks}, 32)
    cur = toks
    for _ in range(4):
        nxt = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        cur = jnp.concatenate([cur, nxt], axis=1)
        lg_tf, _ = model.prefill(params, {"tokens": cur}, 32)
        logits, cache = model.decode_step(params, cache, nxt)
        np.testing.assert_allclose(
            np.asarray(logits[:, -1]), np.asarray(lg_tf[:, -1]), atol=2e-4
        )


def test_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint import ckpt

    cfg = tiny_cfg()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    ckpt.save(str(tmp_path / "c"), params, step=7)
    restored = ckpt.restore(str(tmp_path / "c"), params)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_train_step_is_deterministic():
    from repro.training.train_step import init_train_state, make_train_step

    cfg = tiny_cfg()
    model = build_model(cfg)
    tc = TrainConfig(total_steps=1)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(2), (4, 16), 0, 64)}
    step = jax.jit(make_train_step(model, tc))
    s1 = init_train_state(model, jax.random.PRNGKey(0), tc)
    s2 = init_train_state(model, jax.random.PRNGKey(0), tc)
    o1, m1 = step(s1, batch)
    o2, m2 = step(s2, batch)
    assert float(m1["loss"]) == float(m2["loss"])
    for a, b in zip(jax.tree.leaves(o1.params), jax.tree.leaves(o2.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
