"""Distributed training engine acceptance suite.

Single-device (run in-process):
  * gradient accumulation: ``accum_steps=4`` equals one 4×-larger batch
    (CLM all-ones masks AND MLM uneven masks — token-weighted accumulation)
  * kill -> ``resume_from`` reproduces the uninterrupted run bit-exactly
    (full TrainState + data-iterator cursor round-trip)
  * steady-state transfer contract: ONE bulk ``jax.device_get`` per log
    interval and no implicit transfers (``jax.transfer_guard``)

8-virtual-device mesh (subprocess, ``xla_force_host_platform_device_count``):
  * sharded Trainer loss/grad-norm trajectory matches single-device
  * a checkpoint written on mesh (2,4) restores onto mesh (4,2) with
    identical leaf values and keeps training there
"""
import os
import subprocess
import sys
import textwrap
from dataclasses import replace

import jax
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.core.config import ModelConfig, TrainConfig
from repro.data.dataset import build_synthetic_protein_memmap
from repro.data.pipeline import CLMBatches, MLMBatches
from repro.models.model import build_model
from repro.training import train_step as TS
from repro.training.loop import Trainer

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def tiny_cfg(**kw):
    base = dict(
        name="tiny", family="dense", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=2, d_ff=128, vocab_size=64, dtype="float32",
    )
    base.update(kw)
    return ModelConfig(**base)


def tiny_tc(**kw):
    base = dict(
        global_batch=8, seq_len=32, total_steps=6, log_every=2,
        warmup_steps=2, decay_steps=2, learning_rate=1e-3,
    )
    base.update(kw)
    return TrainConfig(**base)


def clm_pipeline(tmp_path, name="prot"):
    ds, _ = build_synthetic_protein_memmap(str(tmp_path / name), n=200, seed=0)
    return CLMBatches(ds, 8, 32, seed=0)


# --------------------------------------------------- gradient accumulation
def _one_step(model, tc, batch, params_key=0):
    state = TS.init_train_state(model, jax.random.PRNGKey(params_key), tc)
    new_state, metrics = jax.jit(TS.make_train_step(model, tc))(state, batch)
    return new_state, metrics


def test_accum_equals_large_batch_clm():
    cfg = tiny_cfg()
    model = build_model(cfg)
    tc = tiny_tc()
    batch = {
        "tokens": np.random.default_rng(0)
        .integers(0, 64, size=(8, 32))
        .astype(np.int32)
    }
    s1, m1 = _one_step(model, tc, batch)
    s4, m4 = _one_step(model, replace(tc, accum_steps=4), batch)
    _assert_step_equivalent(s1, m1, s4, m4)


def _assert_step_equivalent(s1, m1, s4, m4):
    # a wrong accumulation scheme (unweighted mean, missing fp32
    # accumulators, sum instead of mean) diverges at O(1e-4)+; the slack
    # below only absorbs f32 reduction-order noise, which varies with CPU
    # thread availability under load
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 5e-5
    assert abs(float(m1["grad_norm"]) - float(m4["grad_norm"])) < 5e-4
    for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s4.params)):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), atol=1e-5, rtol=1e-4
        )


def test_accum_equals_large_batch_mlm_uneven_masks(tmp_path):
    """MLM microbatches mask different token counts — token-weighted
    accumulation must still reproduce the single large-batch step."""
    cfg = tiny_cfg(objective="mlm", causal=False, vocab_size=33)
    model = build_model(cfg)
    tc = tiny_tc()
    ds, tok = build_synthetic_protein_memmap(str(tmp_path / "prot"), n=200, seed=0)
    batch = next(iter(MLMBatches(ds, tok, None, 8, 32)))
    # uneven by construction: per-microbatch (2-row) masked-token counts
    counts = batch["loss_mask"].reshape(4, -1).sum(axis=1)
    assert len(set(counts.tolist())) > 1, counts
    s1, m1 = _one_step(model, tc, batch)
    s4, m4 = _one_step(model, replace(tc, accum_steps=4), batch)
    _assert_step_equivalent(s1, m1, s4, m4)


def test_accum_requires_divisible_batch():
    model = build_model(tiny_cfg())
    tc = tiny_tc(accum_steps=3)
    batch = {"tokens": np.zeros((8, 32), np.int32)}
    with pytest.raises(ValueError, match="not divisible"):
        jax.jit(TS.make_train_step(model, tc))(
            TS.init_train_state(model, jax.random.PRNGKey(0), tc), batch
        )


# ----------------------------------------------------------- resume exact
def test_save_resume_bit_exact(tmp_path):
    """Kill at step 3 of 6, resume from the checkpoint with the SAME
    config: params, optimizer moments and step counter must match the
    uninterrupted run bit-for-bit (state + data cursor round-trip)."""
    cfg = tiny_cfg()
    tc = tiny_tc(ckpt_every=3, ckpt_dir=str(tmp_path / "ck"))
    s_full, _ = Trainer(build_model(cfg), tc, verbose=False).run(
        clm_pipeline(tmp_path, "a")
    )
    s_res, hist = Trainer(build_model(cfg), tc, verbose=False).run(
        clm_pipeline(tmp_path, "b"),
        resume_from=str(tmp_path / "ck" / "step_3"),
    )
    assert [m["step"] for m in hist] == [4, 5]
    for a, b in zip(
        jax.tree.leaves((s_full.params, s_full.opt)),
        jax.tree.leaves((s_res.params, s_res.opt)),
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_resume_restores_counters_and_cursor(tmp_path):
    """tokens_seen continues across the resume and the restored pipeline
    draws the exact batch the interrupted run would have drawn next."""
    cfg = tiny_cfg()
    tc = tiny_tc(ckpt_every=3, ckpt_dir=str(tmp_path / "ck"))
    tr_a = Trainer(build_model(cfg), tc, verbose=False)
    _, hist_a = tr_a.run(clm_pipeline(tmp_path, "a"))

    pipe_b = clm_pipeline(tmp_path, "b")
    tr_b = Trainer(build_model(cfg), tc, verbose=False)
    tr_b.load(str(tmp_path / "ck" / "step_3"), pipe_b)
    assert tr_b.step_idx == 3
    # the cursor says 4 batches were drawn (3 consumed + none beyond: the
    # snapshot is per-consumed-batch, prefetch depth must not leak)
    ref = clm_pipeline(tmp_path, "c")
    ref_it = iter(ref)
    for _ in range(3):
        next(ref_it)
    want = next(ref_it)["tokens"]
    got = next(iter(pipe_b))["tokens"]
    np.testing.assert_array_equal(want, got)
    # uninterrupted tokens_seen at the end equals resumed run's total
    _, hist_b = tr_b.run(pipe_b)  # prepare() keeps the loaded state
    assert hist_b[-1]["tokens_seen"] == hist_a[-1]["tokens_seen"]


def test_resume_tokens_seen_at_misaligned_checkpoint(tmp_path):
    """A checkpoint between log flushes must still count the steps whose
    metrics are pending (ckpt_every=2 vs log_every=3: step_2 is saved
    while step 1's metrics sit unflushed)."""
    cfg = tiny_cfg()
    tc = tiny_tc(total_steps=6, log_every=3, ckpt_every=2,
                 ckpt_dir=str(tmp_path / "ck"))
    _, hist_a = Trainer(build_model(cfg), tc, verbose=False).run(
        clm_pipeline(tmp_path, "a")
    )
    _, hist_b = Trainer(build_model(cfg), tc, verbose=False).run(
        clm_pipeline(tmp_path, "b"),
        resume_from=str(tmp_path / "ck" / "step_2"),
    )
    per_step = 8 * 31
    assert hist_a[-1]["tokens_seen"] == 6 * per_step
    assert hist_b[-1]["tokens_seen"] == 6 * per_step


def test_seq2seq_pipeline_cursor(tmp_path):
    """The enc-dec launcher pipeline delegates the resume cursor to its
    underlying CLM packer (a raw generator would silently replay)."""
    from repro.launch.train import Seq2SeqBatches

    ds, _ = build_synthetic_protein_memmap(str(tmp_path / "p"), n=100, seed=0)
    pipe = Seq2SeqBatches(CLMBatches(ds, 4, 16, seed=0))
    it = iter(pipe)
    for _ in range(2):
        next(it)
    cursor = pipe.state_dict()
    want = next(iter(pipe))
    pipe2 = Seq2SeqBatches(CLMBatches(ds, 4, 16, seed=1))
    pipe2.load_state_dict(cursor)
    got = next(iter(pipe2))
    np.testing.assert_array_equal(want["tokens"], got["tokens"])
    np.testing.assert_array_equal(got["src_tokens"], got["tokens"])


# ------------------------------------------------- steady-state transfers
def test_one_bulk_transfer_per_log_interval(tmp_path, monkeypatch):
    """Acceptance: metrics stay on device between logs — a steady-state
    trainer step performs NO implicit transfers, and each log interval
    costs exactly ONE bulk device_get (serving-engine contract)."""
    cfg = tiny_cfg()
    tc = tiny_tc(total_steps=9, log_every=3)
    tr = Trainer(build_model(cfg), tc, verbose=False)
    tr.prepare(clm_pipeline(tmp_path))
    tr.step()  # s=0: compile + first log flush, outside the guard
    calls = []
    real_get = jax.device_get
    monkeypatch.setattr(
        jax, "device_get", lambda x: calls.append(1) or real_get(x)
    )
    with jax.transfer_guard("disallow"):
        while tr.step_idx < tc.total_steps:
            tr.step()
    # steps 1..8 under the guard flush at s=3, s=6, s=8
    assert len(calls) == 3, f"expected 3 bulk transfers, saw {len(calls)}"


def test_token_accounting_every_step(tmp_path):
    """tokens_seen counts EVERY step once (the old loop multiplied the
    logged step's count by log_every — wrong at step 0 and the final
    line) and tokens_per_sec is reported."""
    cfg = tiny_cfg()
    tc = tiny_tc(total_steps=5, log_every=2)
    _, hist = Trainer(build_model(cfg), tc, verbose=False).run(
        clm_pipeline(tmp_path)
    )
    # CLM: (seq_len - 1) targets per row, every step
    per_step = 8 * 31
    assert [m["tokens_seen"] for m in hist] == [
        per_step, 3 * per_step, 5 * per_step
    ]
    assert all(m["tokens_per_sec"] > 0 for m in hist)
    assert all("step_time" in m for m in hist)


# ------------------------------------------------------ 8-device subprocess
CODE = textwrap.dedent("""
    import tempfile
    from dataclasses import replace
    import jax, numpy as np
    from repro.core.config import ModelConfig, ParallelConfig, TrainConfig
    from repro.models.model import build_model
    from repro.data.dataset import build_synthetic_protein_memmap
    from repro.data.pipeline import CLMBatches
    from repro.training.loop import Trainer
    from repro.training import train_step as TS
    from repro.checkpoint import ckpt

    assert jax.device_count() == 8, jax.device_count()
    cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=64,
                      num_heads=8, num_kv_heads=2, d_ff=128, vocab_size=128,
                      dtype="float32")
    tmp = tempfile.mkdtemp()
    ds, _ = build_synthetic_protein_memmap(tmp + "/prot", n=200, seed=0)
    def pipe():
        return CLMBatches(ds, 8, 32, seed=0)
    tc = TrainConfig(global_batch=8, seq_len=32, total_steps=4, log_every=1,
                     warmup_steps=1, decay_steps=1, learning_rate=1e-3)

    # (a) sharded loss/grad-norm trajectory matches single-device
    _, h_ref = Trainer(build_model(cfg), tc, verbose=False).run(pipe())
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    m_sh = build_model(cfg, ParallelConfig(), mesh)
    tr_sh = Trainer(m_sh, tc, verbose=False)
    state_sh, h_sh = tr_sh.run(pipe())
    for a, b in zip(h_ref, h_sh):
        assert abs(a["loss"] - b["loss"]) < 1e-4, (a["loss"], b["loss"])
        assert abs(a["grad_norm"] - b["grad_norm"]) / max(b["grad_norm"], 1) < 1e-3
    print("trajectory ok")

    # (d) checkpoint saved on (2,4) restores onto (4,2): identical leaves
    ckdir = tmp + "/ck"
    tr_sh.save(ckdir)
    mesh2 = jax.make_mesh((4, 2), ("data", "model"))
    m2 = build_model(cfg, ParallelConfig(), mesh2)
    st2, step2, extra = ckpt.restore_train_state(
        ckdir, TS.abstract_train_state(m2), TS.state_shardings(m2))
    assert step2 == 4 and extra["step_idx"] == 4, (step2, extra)
    for a, b in zip(jax.tree.leaves((state_sh.params, state_sh.opt)),
                    jax.tree.leaves((st2.params, st2.opt))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    print("remesh restore ok")

    # ... and training continues from it on the new mesh shape
    tc2 = replace(tc, total_steps=6)
    _, h2 = Trainer(m2, tc2, verbose=False).run(pipe(), resume_from=ckdir)
    assert [m["step"] for m in h2] == [4, 5], h2
    print("ALL_OK")
""")


def test_sharded_trainer_8dev_subprocess():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    out = subprocess.run(
        [sys.executable, "-c", CODE], capture_output=True, text=True, env=env,
        timeout=900,
    )
    assert out.returncode == 0, out.stderr[-4000:]
    assert "ALL_OK" in out.stdout


# ------------------------------------------------------- non-finite guard
def test_nonfinite_step_withholds_update():
    """A step with NaN loss applies NO update: params and AdamW moments
    keep their old values and opt.step does not advance (so the lr
    schedule is unaffected); the metrics carry skipped=1."""
    model = build_model(tiny_cfg())
    tc = tiny_tc()
    rng = np.random.default_rng(0)
    batch = {"tokens": rng.integers(0, 64, size=(8, 32)).astype(np.int32)}
    step_fn = jax.jit(TS.make_train_step(model, tc))
    state = TS.init_train_state(model, jax.random.PRNGKey(0), tc)
    s1, m1 = step_fn(state, batch)
    assert float(m1["skipped"]) == 0.0
    assert int(s1.opt.step) == 1
    # poison the params: the forward loss goes non-finite, and without
    # the guard the "update" would overwrite everything with NaN
    import jax.numpy as jnp

    poisoned = jax.tree.map(
        lambda p: p.at[(0,) * p.ndim].set(jnp.nan)
        if jnp.issubdtype(p.dtype, jnp.floating) else p,
        s1.params,
    )
    from repro.training.train_step import TrainState

    s2, m2 = step_fn(TrainState(poisoned, s1.opt), batch)
    assert float(m2["skipped"]) == 1.0
    assert not np.isfinite(float(m2["loss"]))
    assert int(s2.opt.step) == 1  # did not advance
    for got, want in zip(jax.tree.leaves(s2.params), jax.tree.leaves(poisoned)):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    for got, want in zip(jax.tree.leaves(s2.opt.mu), jax.tree.leaves(s1.opt.mu)):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_trainer_aborts_after_consecutive_nonfinite(tmp_path):
    """K consecutive skipped steps abort the run with the offending step
    number instead of silently flatlining for the rest of the schedule."""
    from repro.training.loop import NonFiniteLossError

    model = build_model(tiny_cfg())
    tc = tiny_tc(total_steps=10, log_every=1, max_nonfinite_skips=3)
    pipe = clm_pipeline(tmp_path, name="nanprot")
    state = TS.init_train_state(model, jax.random.PRNGKey(0), tc)
    import jax.numpy as jnp

    state.params = jax.tree.map(
        lambda p: jnp.full_like(p, jnp.nan)
        if jnp.issubdtype(p.dtype, jnp.floating) else p,
        state.params,
    )
    tr = Trainer(model, tc, verbose=False)
    tr.prepare(pipe, state=state)
    with pytest.raises(NonFiniteLossError) as ei:
        while tr.step_idx < tc.total_steps:
            tr.step()
    assert ei.value.skips == 3
    assert ei.value.step == 2  # steps 0,1,2 skipped -> streak hits 3 at 2
    assert tr.skipped_total == 3
